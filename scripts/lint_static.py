#!/usr/bin/env python3
"""Static concurrency-correctness and determinism lints (PR 9).

Three rule classes over the Rust sources (stdlib-only, no deps):

  unsafe-comment    every `unsafe` block / fn / impl / trait in
                    rust/src and rust/tests must carry a `// SAFETY:`
                    justification on the same line or within the 6
                    lines above (the compiler half of this gate is
                    `#![deny(clippy::undocumented_unsafe_blocks)]` in
                    rust/src/lib.rs; this script also covers
                    integration tests, which are separate crates).

  atomic-ordering   every explicit `Ordering::{Relaxed,Acquire,
                    Release,AcqRel,SeqCst}` in non-test rust/src code
                    must have a pairing comment — a `//` comment
                    containing "pairs with" or "ordering:" on the same
                    line or within the 10 lines above — so each memory
                    ordering states what it synchronizes with (or that
                    it deliberately synchronizes nothing).

  nondeterminism    replay-deterministic modules (non-test rust/src)
                    must not reach for wall clocks or OS entropy
                    (`SystemTime::now`, `Instant::now`, `thread_rng`,
                    `from_entropy`, `getrandom`, `RandomState`,
                    `OsRng`, `rand::`), and must not iterate a
                    HashMap/HashSet (unordered!) unless the result is
                    sorted within the next 3 lines or the line carries
                    `// lint: ordered-ok`. Legitimate wall-clock users
                    (the real-time serving drivers, the bench harness,
                    the SimClock's own real half) are enumerated in
                    scripts/lint_allowlist.txt.

Findings print as `path:line: [rule] message`; any unallowed finding
exits 1. `--self-test` seeds one violation of each rule class (plus a
clean twin) in a temp tree and asserts the expected catches — CI runs
the self-test first, so a regression in the linter itself fails fast.
"""

import argparse
import re
import sys
import tempfile
from pathlib import Path

UNSAFE_RE = re.compile(r"\bunsafe\b\s*(\{|fn\b|impl\b|trait\b)")
ORDERING_RE = re.compile(r"\bOrdering::(Relaxed|Acquire|Release|AcqRel|SeqCst)\b")
PAIRING_RE = re.compile(r"pairs with|ordering:", re.IGNORECASE)
CFG_TEST_RE = re.compile(r"^\s*#\[cfg\((?:all\()?\s*test\b")
NONDET_PATTERNS = [
    ("SystemTime::now", "wall-clock read"),
    ("Instant::now", "wall-clock read"),
    (r"\bthread_rng\b", "OS-seeded RNG"),
    (r"\bfrom_entropy\b", "OS-seeded RNG"),
    (r"\bgetrandom\b", "OS entropy"),
    (r"\bRandomState\b", "randomized hasher"),
    (r"\bOsRng\b", "OS entropy"),
    (r"\brand::", "external RNG"),
]
HASH_DECL_RE = re.compile(
    r"\b(\w+)\s*:\s*&?(?:mut\s+)?(?:std::collections::)?Hash(?:Map|Set)\b"
    r"|\blet\s+(?:mut\s+)?(\w+)(?::[^=;]*)?=\s*(?:std::collections::)?Hash(?:Map|Set)\b"
)
SORTED_RE = re.compile(r"\.sort|sorted|BTree")
ORDERED_OK = "lint: ordered-ok"


def strip_strings(code):
    """Blank out string/char literal contents (crude but comment-safe)."""
    return re.sub(r'"(?:[^"\\]|\\.)*"', '""', code)


def split_comment(line):
    """Return (code, comment) halves of a source line."""
    stripped = strip_strings(line)
    if "//" in stripped:
        idx = stripped.index("//")
        return stripped[:idx], stripped[idx:]
    return stripped, ""


def pre_test_len(lines):
    """Lines before the first `#[cfg(test)]` / `#[cfg(all(test, ...))]`."""
    for i, line in enumerate(lines):
        if CFG_TEST_RE.match(line):
            return i
    return len(lines)


def comment_nearby(lines, i, span, pattern):
    """True if `pattern` appears in a comment on line i or `span` lines above."""
    for j in range(max(0, i - span), i + 1):
        _, comment = split_comment(lines[j])
        if pattern.search(comment) if hasattr(pattern, "search") else pattern in comment:
            return True
    return False


def lint_file(relpath, lines, findings):
    is_src = str(relpath).startswith("rust/src/")
    limit = pre_test_len(lines) if is_src else len(lines)

    hash_idents = set()
    if is_src:
        for line in lines[:limit]:
            code, _ = split_comment(line)
            for m in HASH_DECL_RE.finditer(code):
                hash_idents.add(m.group(1) or m.group(2))
    iter_res = [
        (
            ident,
            re.compile(
                r"\bfor\b[^;]*\bin\s+&?(?:mut\s+)?" + re.escape(ident) + r"\b"
                r"|\b" + re.escape(ident) + r"\s*\.\s*(?:iter|iter_mut|keys|values|values_mut|drain|into_iter)\s*\("
            ),
        )
        for ident in sorted(hash_idents)
    ]

    for i, line in enumerate(lines):
        code, _ = split_comment(line)

        # unsafe-comment: whole file, src and tests alike.
        if UNSAFE_RE.search(code) and not comment_nearby(lines, i, 6, "SAFETY"):
            findings.append(
                (relpath, i + 1, "unsafe-comment", line,
                 "unsafe without a `// SAFETY:` justification within 6 lines")
            )

        if not is_src or i >= limit:
            continue

        # atomic-ordering: every explicit ordering states its pairing.
        if ORDERING_RE.search(code) and not comment_nearby(lines, i, 10, PAIRING_RE):
            findings.append(
                (relpath, i + 1, "atomic-ordering", line,
                 "explicit Ordering without a pairing comment "
                 '("pairs with ..." / "ordering: ...") within 10 lines')
            )

        # nondeterminism: banned sources of run-to-run variation.
        for pat, why in NONDET_PATTERNS:
            if re.search(pat, code):
                findings.append(
                    (relpath, i + 1, "nondeterminism", line,
                     f"{why} in a replay-deterministic module")
                )

        for ident, rx in iter_res:
            if rx.search(code):
                window = "\n".join(lines[i : i + 4])
                if ORDERED_OK in window or SORTED_RE.search(window):
                    continue
                findings.append(
                    (relpath, i + 1, "nondeterminism", line,
                     f"iterating unordered `{ident}` (HashMap/HashSet) feeding "
                     "output: sort within 3 lines or mark `// lint: ordered-ok`")
                )


def load_allowlist(path):
    entries = []
    if path and path.exists():
        for raw in path.read_text().splitlines():
            stripped = raw.strip()
            if not stripped or stripped.startswith("#"):
                continue
            parts = stripped.split(maxsplit=2)
            if len(parts) == 3:
                entries.append(tuple(parts))
    return entries


def allowed(finding, entries):
    relpath, _, rule, line, _ = finding
    return any(
        rule == e_rule and str(relpath) == e_path and substr in line
        for e_rule, e_path, substr in entries
    )


def run(root, allowlist_path):
    findings = []
    for sub in ("rust/src", "rust/tests"):
        base = root / sub
        if not base.is_dir():
            continue
        for f in sorted(base.rglob("*.rs")):
            rel = f.relative_to(root)
            lint_file(rel, f.read_text().splitlines(), findings)
    entries = load_allowlist(allowlist_path)
    return [f for f in findings if not allowed(f, entries)]


def self_test():
    """Seed one violation per rule class plus clean twins; assert catches."""
    seeds = {
        # (file, contents, expected rules caught in that file)
        "rust/src/st_bad_unsafe.rs": (
            "pub fn deref(p: *const u32) -> u32 {\n    unsafe { *p }\n}\n",
            {"unsafe-comment"},
        ),
        "rust/src/st_good_unsafe.rs": (
            "pub fn deref(p: *const u32) -> u32 {\n"
            "    // SAFETY: caller guarantees `p` is valid and aligned.\n"
            "    unsafe { *p }\n}\n",
            set(),
        ),
        "rust/src/st_bad_atomic.rs": (
            "use std::sync::atomic::{AtomicU64, Ordering};\n"
            "pub fn bump(a: &AtomicU64) {\n"
            "    a.fetch_add(1, Ordering::Relaxed);\n}\n",
            {"atomic-ordering"},
        ),
        "rust/src/st_good_atomic.rs": (
            "use std::sync::atomic::{AtomicU64, Ordering};\n"
            "pub fn bump(a: &AtomicU64) {\n"
            "    // ordering: Relaxed pairs with the Relaxed reader.\n"
            "    a.fetch_add(1, Ordering::Relaxed);\n}\n",
            set(),
        ),
        "rust/src/st_bad_nondet.rs": (
            "pub fn stamp() -> std::time::Instant {\n"
            "    std::time::Instant::now()\n}\n",
            {"nondeterminism"},
        ),
        "rust/src/st_bad_iter.rs": (
            "use std::collections::HashMap;\n"
            "pub fn dump(m: &HashMap<u32, u32>) -> Vec<u32> {\n"
            "    let mut out = Vec::new();\n"
            "    for (k, _) in m.iter() {\n"
            "        out.push(*k);\n    }\n    out\n}\n",
            {"nondeterminism"},
        ),
        "rust/src/st_good_iter.rs": (
            "use std::collections::HashMap;\n"
            "pub fn dump(m: &HashMap<u32, u32>) -> Vec<u32> {\n"
            "    let mut out: Vec<u32> = m.keys().copied().collect();\n"
            "    out.sort_unstable();\n    out\n}\n",
            set(),
        ),
        "rust/src/st_test_gated.rs": (
            "pub fn fine() {}\n"
            "#[cfg(test)]\n"
            "mod tests {\n"
            "    use std::sync::atomic::{AtomicU64, Ordering};\n"
            "    #[test]\n"
            "    fn t() {\n"
            "        AtomicU64::new(0).fetch_add(1, Ordering::SeqCst);\n"
            "        let _ = std::time::Instant::now();\n    }\n}\n",
            set(),  # everything below #[cfg(test)] is out of scope
        ),
    }
    with tempfile.TemporaryDirectory() as tmp:
        root = Path(tmp)
        for rel, (contents, _) in seeds.items():
            path = root / rel
            path.parent.mkdir(parents=True, exist_ok=True)
            path.write_text(contents)

        remaining = run(root, None)
        by_file = {}
        for rel, _, rule, _, _ in remaining:
            by_file.setdefault(str(rel), set()).add(rule)
        ok = True
        for rel, (_, expected) in seeds.items():
            got = by_file.get(rel, set())
            if got != expected:
                print(f"self-test FAIL: {rel}: expected {sorted(expected)}, got {sorted(got)}")
                ok = False

        # Allowlist suppression: the same nondet seed, allowlisted away.
        allow = root / "allow.txt"
        allow.write_text(
            "# comment lines and blanks are ignored\n\n"
            "nondeterminism rust/src/st_bad_nondet.rs Instant::now\n"
        )
        suppressed = run(root, allow)
        still = [f for f in suppressed if str(f[0]) == "rust/src/st_bad_nondet.rs"]
        if still:
            print("self-test FAIL: allowlist did not suppress st_bad_nondet.rs")
            ok = False

        print("self-test ok" if ok else "self-test failed")
        return 0 if ok else 1


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", type=Path,
                    default=Path(__file__).resolve().parent.parent)
    ap.add_argument("--allowlist", type=Path, default=None,
                    help="default: <root>/scripts/lint_allowlist.txt")
    ap.add_argument("--self-test", action="store_true")
    args = ap.parse_args()
    if args.self_test:
        return self_test()

    allowlist = args.allowlist or args.root / "scripts" / "lint_allowlist.txt"
    findings = run(args.root, allowlist)
    for rel, lineno, rule, _, msg in findings:
        print(f"{rel}:{lineno}: [{rule}] {msg}")
    if findings:
        print(f"{len(findings)} finding(s)")
        return 1
    print("lint_static: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
