#!/usr/bin/env python3
"""Summarize and validate a robus JSONL batch trace (``--trace-out``).

Reads the trace written by the telemetry layer
(``rust/src/telemetry/trace.rs``: one JSON object per line, ``type``
discriminated into ``meta`` / ``span`` / ``event`` / ``snapshot`` /
``final``), prints a human-readable digest, and enforces the
conservation invariants the serving stack promises:

* **Workload conservation** — the ``final`` counter record must satisfy
  ``admitted == completed + queued`` (rejected queries were never
  admitted; requeued queries moved between queues without being
  re-counted). A finished run has ``queued == 0``, so admitted ==
  completed.
* **Span accounting** — the ``final`` record's ``spans`` count plus its
  ``dropped`` count bounds the span lines actually present (a bounded
  trace channel may drop records, but only while counting them).
* **Multiplier clamp bounds** — every ``multiplier_clamp`` event's value
  must lie within ``[1/max_boost - eps, max_boost + eps]`` of the run's
  ``meta.max_boost`` (the accountant clamps *to* the bound, never past
  it).
* **Snapshot monotonicity** — counters in successive ``snapshot``
  records never decrease.

Exit status: 0 when every invariant holds, 1 on any violation, 2 on
unusable input (missing file, no final record, malformed JSON).

Usage:
  python3 scripts/summarize_trace.py TRACE.jsonl
  python3 scripts/summarize_trace.py TRACE.jsonl --quiet   # checks only
"""

import argparse
import json
import sys
from collections import Counter, defaultdict

PHASES = ("drain_ms", "boost_ms", "solve_ms", "sample_ms", "transition_ms", "execute_ms")
EPS = 1e-9


def load(path):
    records = []
    try:
        with open(path, "r", encoding="utf-8") as f:
            for i, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    records.append(json.loads(line))
                except json.JSONDecodeError as e:
                    print(f"error: {path}:{i}: malformed JSON ({e})", file=sys.stderr)
                    sys.exit(2)
    except OSError as e:
        print(f"error: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    if not records:
        print(f"error: {path} is empty", file=sys.stderr)
        sys.exit(2)
    return records


def percentile(xs, p):
    """Linear-interpolation percentile, matching ``util::stats``."""
    if not xs:
        return 0.0
    ys = sorted(xs)
    if len(ys) == 1:
        return ys[0]
    rank = (p / 100.0) * (len(ys) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(ys) - 1)
    frac = rank - lo
    return ys[lo] * (1.0 - frac) + ys[hi] * frac


def summarize(records, quiet):
    meta = next((r for r in records if r.get("type") == "meta"), None)
    spans = [r for r in records if r.get("type") == "span"]
    events = [r for r in records if r.get("type") == "event"]
    snapshots = [r for r in records if r.get("type") == "snapshot"]
    finals = [r for r in records if r.get("type") == "final"]

    violations = []

    if not finals:
        print("error: trace has no final record (run did not shut down cleanly)",
              file=sys.stderr)
        sys.exit(2)
    final = finals[-1]

    if not quiet:
        if meta:
            print(f"run: driver={meta.get('driver')} tenants={meta.get('tenants')} "
                  f"shards={meta.get('shards')} max_boost={meta.get('max_boost')}")
        print(f"records: {len(spans)} spans, {len(events)} events, "
              f"{len(snapshots)} snapshots")

    # --- per-phase breakdown over spans ---
    if spans and not quiet:
        print("\nphase breakdown (host ms per batch step):")
        print(f"  {'phase':<14} {'total':>10} {'mean':>9} {'p50':>9} {'p99':>9}")
        for ph in PHASES:
            xs = [s.get(ph, 0.0) for s in spans]
            total = sum(xs)
            print(f"  {ph:<14} {total:>10.2f} {total / len(xs):>9.3f} "
                  f"{percentile(xs, 50):>9.3f} {percentile(xs, 99):>9.3f}")
        kinds = Counter(s.get("kind", "?") for s in spans)
        kind_txt = ", ".join(f"{k}: {n}" for k, n in sorted(kinds.items()))
        print(f"  solve kinds: {kind_txt}")
        n_q = [s.get("n", 0) for s in spans]
        print(f"  queries/span: total {sum(n_q)}, max {max(n_q)}, "
              f"p50 {percentile(n_q, 50):.0f}")
        shards = sorted({s.get("shard", -1) for s in spans})
        if shards != [-1]:
            per_shard = defaultdict(int)
            for s in spans:
                per_shard[s.get("shard", -1)] += s.get("n", 0)
            loads = ", ".join(f"s{k}: {v}" for k, v in sorted(per_shard.items()))
            print(f"  per-shard queries: {loads}")

    # --- events ---
    if events and not quiet:
        counts = Counter(e.get("kind", "?") for e in events)
        print("\nevents:")
        for k, n in sorted(counts.items()):
            print(f"  {k:<20} {n}")

    # --- invariant: workload conservation ---
    # Only serving drivers admit through probed queues; replay drivers
    # (`run`, `cluster`) route in bulk and legitimately report
    # admitted == 0 while spans still count completions.
    admitted = final.get("admitted", 0)
    completed = final.get("completed", 0)
    queued = final.get("queued", 0)
    if admitted > 0 and admitted != completed + queued:
        violations.append(
            f"conservation: admitted ({admitted}) != completed ({completed}) "
            f"+ queued ({queued})")

    # --- invariant: span accounting under bounded-channel drops ---
    dropped = final.get("dropped", 0)
    span_total = final.get("spans", 0)
    if len(spans) > span_total:
        violations.append(
            f"span accounting: {len(spans)} span lines exceed the final "
            f"record's count ({span_total})")
    if len(spans) + dropped < span_total:
        violations.append(
            f"span accounting: {len(spans)} span lines + {dropped} dropped "
            f"records cannot cover {span_total} recorded spans")

    # --- invariant: multiplier clamps stay within the boost bound ---
    clamps = [e for e in events if e.get("kind") == "multiplier_clamp"]
    max_boost = (meta or {}).get("max_boost")
    if clamps and max_boost:
        lo, hi = 1.0 / max_boost - EPS, max_boost + EPS
        for e in clamps:
            v = e.get("value", 0.0)
            if not (lo <= v <= hi):
                violations.append(
                    f"clamp bound: multiplier {v} outside [{1.0 / max_boost}, "
                    f"{max_boost}] (batch {e.get('batch')}, tenant {e.get('tenant')})")

    # --- invariant: snapshot counters are monotone ---
    for key in ("admitted", "rejected", "completed", "requeued"):
        prev = -1
        for s in snapshots:
            v = s.get(key, 0)
            if v < prev:
                violations.append(
                    f"snapshot monotonicity: {key} fell from {prev} to {v} "
                    f"at t={s.get('t')}")
                break
            prev = v

    if not quiet:
        print(f"\nfinal: admitted={admitted} completed={completed} "
              f"rejected={final.get('rejected', 0)} "
              f"requeued={final.get('requeued', 0)} queued={queued} "
              f"spans={span_total} trace_dropped={dropped}")

    if violations:
        print(f"\nFAIL: {len(violations)} invariant violation(s):", file=sys.stderr)
        for v in violations:
            print(f"  - {v}", file=sys.stderr)
        return 1
    print("\nOK: conservation, span accounting, clamp bounds, and snapshot "
          "monotonicity all hold")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("trace", help="JSONL trace file written by --trace-out")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the digest, print only the verdict")
    args = ap.parse_args()
    sys.exit(summarize(load(args.trace), args.quiet))


if __name__ == "__main__":
    main()
