#!/usr/bin/env python3
"""Bench-regression gate for the BENCH_*.json trajectory files.

Compares a fresh quick-bench run (``ROBUS_BENCH_QUICK=1 cargo bench``)
against the committed baselines in ``benchmarks/baseline/`` and fails
(exit 1) when a tracked metric regresses by more than the threshold
(default 15%). This is what turns the CI bench step from
"upload artifacts" into an actual gate.

Two metric classes:

* **ratio / fraction metrics** (fairness spread, 4-shard speedup,
  pipeline stall fraction, conservation) are hardware-independent and
  compared directly.
* **absolute host metrics** (batches/sec, solve p99, ns/iter) are
  normalized by the ``host_calibration_ns`` index every BENCH file
  embeds (ns for a fixed 2M-step mix64 chain, see
  ``rust/src/util/bench.rs::calibration_ns``): a 2× slower runner
  reports a ~2× larger calibration, which cancels out of the
  comparison, so the gate survives CI runner generation changes.

Bootstrap: a baseline whose ``_provenance`` is ``"seed"`` (committed
targets, not yet measured) enforces only the hardware-independent
metrics; normalized-absolute regressions are reported as warnings.
Run with ``--update`` after a trusted bench run to promote the fresh
output to a measured baseline (full enforcement).

Usage:
  python3 scripts/check_bench_regression.py               # gate
  python3 scripts/check_bench_regression.py --update      # refresh baselines
  python3 scripts/check_bench_regression.py --threshold 0.2
"""

import argparse
import json
import os
import sys

# A metric: (label, json-path, direction, kind, abs_floor)
#   json-path steps: a dict key, or a (array_key, match_key, match_val)
#     triple selecting the array element whose match_key == match_val.
#   direction: "higher" (regression = drop) or "lower" (regression = rise).
#   kind: "host"  — absolute host metric, normalized by calibration;
#         "ratio" — deterministic/simulated quantity (fairness spread,
#                   conservation): hardware-independent, enforced even
#                   against seed baselines;
#         "noisy" — timing-derived ratio (parallel speedup, stall
#                   fraction): core-count/scheduler dependent and NOT
#                   normalizable by calibration, so it is compared
#                   directly but only warns against seed baselines;
#         "bool"  — must be true.
#   abs_floor: absolute slack added on top of the relative threshold so
#     near-zero metrics (stall fractions, spreads near 1.0) don't flap.
SPEC = {
    "BENCH_solver.json": {
        "calibration": ["host_calibration_ns"],
        "metrics": [
            ("fastpf solve ns/iter",
             [("benchmarks", "name", "fastpf_gradient_solve_only"),
              "mean_ns_per_iter"],
             "lower", "host", 0.0),
            ("full coordinator batch ns/iter",
             [("benchmarks", "name", "coordinator_full_batch_fastpf_n4"),
              "mean_ns_per_iter"],
             "lower", "host", 0.0),
            # Warm-started solves must stay measurably below cold ones:
            # the ratio is host-independent but timing-derived (noisy).
            ("warm/cold solve p50 ratio",
             ["warm_start", "p50_warm_over_cold"],
             "lower", "noisy", 0.25),
            # Tiered retention (RAM+20×SSD vs RAM-only at equal total
            # bytes, fully simulated → deterministic): the generous
            # floor only trips when the tiered path collapses — e.g.
            # SSD residents stop counting as hits at all.
            ("tiered RAM+SSD/RAM-only throughput",
             ["tiered", "ram_ssd_over_ram_only"],
             "higher", "ratio", 0.25),
        ],
    },
    "BENCH_coordinator.json": {
        "calibration": ["microbench", "host_calibration_ns"],
        "metrics": [
            ("serial batches/sec",
             [("runs", "mode", "serial"), "batches_per_sec"],
             "higher", "host", 0.0),
            ("serial solve p99 ms",
             [("runs", "mode", "serial"), "solve_ms_p99"],
             "lower", "host", 2.0),
            ("pipelined batches/sec",
             [("runs", "mode", "pipelined"), "batches_per_sec"],
             "higher", "host", 0.0),
            ("pipeline stall fraction",
             [("runs", "mode", "pipelined"), "stall_fraction"],
             "lower", "noisy", 0.10),
        ],
    },
    "BENCH_cluster.json": {
        "calibration": ["microbench", "host_calibration_ns"],
        "metrics": [
            ("1-shard federation batches/sec",
             [("scaling", "shards", 1), "batches_per_sec"],
             "higher", "host", 0.0),
            ("4-shard speedup vs 1 shard",
             [("scaling", "shards", 4), "speedup_vs_1shard"],
             "higher", "noisy", 0.30),
            ("4-shard fairness spread",
             [("scaling", "shards", 4), "fairness_spread"],
             "lower", "ratio", 0.15),
            # The scale-wall gate: batches/sec at 64 shards must stay a
            # healthy multiple of the 1-shard rate now that the shard
            # runtime multiplexes shards over a fixed worker pool.
            # Parallel-efficiency ratios are scheduler/core-count
            # dependent, hence "noisy" (warns against seed baselines).
            ("64-shard scaling efficiency",
             [("scaling", "shards", 64), "speedup_vs_1shard"],
             "higher", "noisy", 0.30),
            ("64-shard fairness spread",
             [("scaling", "shards", 64), "fairness_spread"],
             "lower", "ratio", 0.15),
            ("federated serving q/host-sec",
             ["federated_serving", "completed_per_host_sec"],
             "higher", "host", 0.0),
            ("federated serving solve p99 ms",
             ["federated_serving", "solve_ms_p99"],
             "lower", "host", 2.0),
            ("federated serving conservation",
             ["federated_serving", "conserved"],
             "true", "bool", 0.0),
            # 4-shard tiered retention — same contract as the solver
            # bench's figure, but through the federation's per-shard
            # tier-budget split and the sharded demotion path.
            ("tiered 4-shard RAM+SSD/RAM-only throughput",
             ["tiered", "ram_ssd_over_ram_only"],
             "higher", "ratio", 0.25),
        ],
    },
}


def select(doc, path):
    cur = doc
    for step in path:
        if isinstance(step, tuple):
            key, mk, mv = step
            arr = cur[key]
            matches = [el for el in arr
                       if _loose_eq(el.get(mk), mv)]
            if not matches:
                raise KeyError(f"no element of '{key}' with {mk}={mv!r}")
            cur = matches[0]
        else:
            cur = cur[step]
    return cur


def _loose_eq(a, b):
    try:
        return float(a) == float(b)
    except (TypeError, ValueError):
        return a == b


def check_file(name, spec, base_dir, fresh_dir, threshold):
    """Returns (rows, n_regressions, n_warnings)."""
    base_path = os.path.join(base_dir, name)
    fresh_path = os.path.join(fresh_dir, name)
    if not os.path.exists(fresh_path):
        return ([(name, "<file>", "-", "-", "-", "MISSING FRESH")], 1, 0)
    if not os.path.exists(base_path):
        return ([(name, "<file>", "-", "-", "-", "MISSING BASELINE")], 1, 0)
    with open(base_path) as f:
        base = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    seed_baseline = base.get("_provenance", "measured") == "seed"

    try:
        cal_base = float(select(base, spec["calibration"]))
        cal_fresh = float(select(fresh, spec["calibration"]))
        host_factor = cal_fresh / cal_base if cal_base > 0 else 1.0
    except (KeyError, TypeError, ValueError):
        host_factor = 1.0

    rows, regressions, warnings = [], 0, 0
    for label, path, direction, kind, floor in spec["metrics"]:
        try:
            base_v = select(base, path)
            fresh_v = select(fresh, path)
        except (KeyError, TypeError) as e:
            rows.append((name, label, "-", "-", "-", f"PATH ERROR: {e}"))
            regressions += 1
            continue

        if kind == "bool":
            ok = bool(fresh_v)
            rows.append((name, label, str(base_v), str(fresh_v), "-",
                         "ok" if ok else "REGRESSION"))
            if not ok:
                regressions += 1
            continue

        base_v, fresh_v = float(base_v), float(fresh_v)
        # Expected fresh value on this host.
        if kind == "host":
            # time-like scales with the calibration; rate-like inversely.
            expected = base_v * host_factor if direction == "lower" \
                else base_v / host_factor
        else:
            expected = base_v
        if direction == "lower":
            bound = expected * (1.0 + threshold) + floor
            bad = fresh_v > bound
            delta = (fresh_v - expected) / expected if expected else 0.0
        else:
            bound = expected * (1.0 - threshold) - floor
            bad = fresh_v < bound
            delta = (expected - fresh_v) / expected if expected else 0.0

        if bad and kind in ("host", "noisy") and seed_baseline:
            status = "warn (seed baseline)"
            warnings += 1
        elif bad:
            status = "REGRESSION"
            regressions += 1
        else:
            status = "ok"
        rows.append((name, label, f"{expected:.3g}", f"{fresh_v:.3g}",
                     f"{delta:+.1%}", status))
    return rows, regressions, warnings


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="benchmarks/baseline",
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh", default=".",
                    help="directory holding the fresh bench output")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="relative regression threshold (default 0.15)")
    ap.add_argument("--update", action="store_true",
                    help="promote the fresh output to measured baselines")
    args = ap.parse_args()

    if args.update:
        os.makedirs(args.baseline, exist_ok=True)
        for name in SPEC:
            src = os.path.join(args.fresh, name)
            if not os.path.exists(src):
                print(f"skip {name}: no fresh output", file=sys.stderr)
                continue
            with open(src) as f:
                doc = json.load(f)
            doc["_provenance"] = "measured"
            dst = os.path.join(args.baseline, name)
            with open(dst, "w") as f:
                json.dump(doc, f, indent=2, sort_keys=False)
                f.write("\n")
            print(f"updated {dst}")
        return 0

    all_rows, total_reg, total_warn = [], 0, 0
    for name, spec in SPEC.items():
        rows, reg, warn = check_file(
            name, spec, args.baseline, args.fresh, args.threshold)
        all_rows += rows
        total_reg += reg
        total_warn += warn

    widths = [max(len(str(r[i])) for r in all_rows + [
        ("file", "metric", "expected", "fresh", "delta", "status")])
        for i in range(6)]
    header = ("file", "metric", "expected", "fresh", "delta", "status")
    for row in [header] + all_rows:
        print("  ".join(str(c).ljust(w) for c, w in zip(row, widths)))

    if total_warn:
        print(f"\n{total_warn} warning(s) against seed baselines — run a "
              f"trusted bench and `--update` to arm full enforcement.")
    if total_reg:
        print(f"\nFAIL: {total_reg} bench regression(s) beyond "
              f"{args.threshold:.0%} (if this change is an accepted "
              f"trade-off, refresh deliberately with --update)",
              file=sys.stderr)
        return 1
    print(f"\nOK: no bench regressions beyond {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
