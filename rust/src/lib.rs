//! # ROBUS — Fair Cache Allocation for Multi-tenant Data-parallel Workloads
//!
//! A from-scratch reproduction of Kunjir, Fain, Munagala & Babu,
//! *"ROBUS: Fair Cache Allocation for Multi-tenant Data-parallel
//! Workloads"* (SIGMOD 2017) as a Rust coordinator + JAX/Pallas solver
//! stack (three-layer rust_pallas architecture; see DESIGN.md).
//!
//! The crate provides:
//! - [`alloc`] — the paper's view-selection policies (STATIC, RSD, OPTP,
//!   MMF, FASTPF and the provably-good multiplicative-weights algorithms);
//! - [`coordinator`] — the batched five-step ROBUS loop of Figure 2;
//! - [`session`] — the unified builder API every driver (replay,
//!   pipelined, serve, federated) is constructed through;
//! - [`cluster`] — the sharded cache federation: N per-shard
//!   coordinators under size-aware placement, hot-view replication, and
//!   a global per-tenant fairness accountant;
//! - [`sim`] — a discrete-event Spark-like cluster simulator standing in
//!   for the paper's 10-node EC2 testbed;
//! - [`domain`] / [`workload`] — TPC-H + Sales catalogs, utility model,
//!   and the Poisson/Zipf workload generators of §5.1;
//! - [`solver`] — LP (simplex), knapsack (WELFARE oracle), and projected
//!   gradient substrates;
//! - [`runtime`] — PJRT loading/execution of the AOT-compiled JAX/Pallas
//!   solver artifacts (`artifacts/*.hlo.txt`);
//! - [`fairness`] — empirical SI / PE / core property checkers;
//! - [`experiments`] — configurations and runners regenerating every
//!   table and figure of the paper's evaluation.

// Concurrency-correctness gates (PR 9, enforced alongside
// `scripts/lint_static.py`): every unsafe operation inside an `unsafe fn`
// must sit in its own `unsafe {}` block, and every unsafe block must
// carry a `// SAFETY:` justification.
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(clippy::undocumented_unsafe_blocks)]

pub mod util;

pub mod telemetry;

pub mod solver;

pub mod domain;

pub mod workload;

pub mod alloc;

pub mod fairness;

pub mod cache;

pub mod sim;

pub mod coordinator;

pub mod cluster;

pub mod session;

pub mod runtime;

pub mod experiments;
