//! The workload generator of Figure 4: per tenant, a Poisson arrival
//! process paired with a data-access process (uniform TPC-H template
//! choice or Zipf Sales dataset choice, optionally routed through
//! hot/cold local windows).

use crate::domain::query::{Query, QueryId};
use crate::domain::tenant::TenantId;
use crate::util::rng::{Pcg64, Zipf};
use crate::workload::spec::{AccessSpec, TenantSpec, WindowSpec};
use crate::workload::universe::Universe;

/// Sales scan+aggregate compute cost per GiB scanned (core-seconds).
/// Calibrated to Spark 1.x SQL row-processing rates (~3 MB/s/core), which
/// dominate cached-query service times on the paper's testbed.
const SALES_COMPUTE_PER_GB: f64 = 150.0;

/// Per-tenant generator state.
pub struct TenantGenerator {
    tenant: TenantId,
    spec: TenantSpec,
    rng: Pcg64,
    /// Next arrival time (absolute simulated seconds).
    next_arrival: f64,
    /// Zipf over Sales datasets (None for TPC-H tenants).
    zipf: Option<Zipf>,
    /// Active hot/cold window: (end_time, candidate datasets).
    window: Option<(f64, Vec<usize>)>,
}

impl TenantGenerator {
    pub fn new(tenant: TenantId, spec: TenantSpec, universe: &Universe, seed: u64) -> Self {
        // Derive independent streams: arrivals+choices from (seed, tenant);
        // the Zipf permutation from the spec's skew_seed only, so g₁ means
        // the same skew for every tenant using it (as in Table 9's G₁).
        let mut rng = Pcg64::with_stream(seed ^ 0x9e37_79b9_7f4a_7c15, tenant.0 as u64 + 1);
        let zipf = match &spec.access {
            AccessSpec::SalesZipf { exponent, skew_seed } => {
                assert!(
                    !universe.sales_views.is_empty(),
                    "SalesZipf tenant in a universe without Sales data"
                );
                let mut perm_rng = Pcg64::with_stream(*skew_seed, 7);
                Some(Zipf::randomized(
                    universe.sales_views.len(),
                    *exponent,
                    &mut perm_rng,
                ))
            }
            AccessSpec::TpchUniform => {
                assert!(
                    !universe.tpch_templates.is_empty(),
                    "TpchUniform tenant in a universe without TPC-H data"
                );
                None
            }
        };
        let first_gap = rng.exponential(1.0 / spec.mean_interarrival);
        Self {
            tenant,
            spec,
            rng,
            next_arrival: first_gap,
            zipf,
            window: None,
        }
    }

    /// The Zipf access distribution (None for TPC-H tenants) — used by
    /// metrics to identify globally popular views (Figure 7).
    pub fn zipf(&self) -> Option<&Zipf> {
        self.zipf.as_ref()
    }

    /// Pick the Sales dataset for a query arriving at `now`, honouring
    /// the hot/cold window mechanism.
    fn pick_sales_dataset(&mut self, now: f64) -> usize {
        let zipf = self.zipf.as_ref().expect("sales tenant");
        match &self.spec.window {
            None => zipf.sample(&mut self.rng),
            Some(w) => {
                let refresh = match &self.window {
                    None => true,
                    Some((end, _)) => now >= *end,
                };
                if refresh {
                    self.window = Some(new_window(w, zipf, now, &mut self.rng));
                }
                let (_, candidates) = self.window.as_ref().unwrap();
                candidates[self.rng.index(candidates.len())]
            }
        }
    }

    /// Generate all queries arriving strictly before `t_end`, advancing
    /// internal state. Query ids are assigned by the caller's counter.
    pub fn generate_until(
        &mut self,
        t_end: f64,
        universe: &Universe,
        next_id: &mut u64,
    ) -> Vec<Query> {
        let mut out = Vec::new();
        while self.next_arrival < t_end {
            let arrival = self.next_arrival;
            let q = match self.spec.access.clone() {
                AccessSpec::TpchUniform => {
                    let t = &universe.tpch_templates
                        [self.rng.index(universe.tpch_templates.len())];
                    Query {
                        id: QueryId(*next_id),
                        tenant: self.tenant,
                        arrival,
                        template: format!("tpch-{}", t.name),
                        required_views: t.views.clone(),
                        bytes_read: t.bytes,
                        compute_cost: t.compute,
                    }
                }
                AccessSpec::SalesZipf { .. } => {
                    let d = self.pick_sales_dataset(arrival);
                    let view = universe.sales_views[d];
                    let v = universe.views.get(view);
                    let gb = v.scan_bytes as f64 / (1u64 << 30) as f64;
                    Query {
                        id: QueryId(*next_id),
                        tenant: self.tenant,
                        arrival,
                        template: format!("sales-scan-{d:02}"),
                        required_views: vec![view],
                        bytes_read: v.scan_bytes,
                        compute_cost: gb * SALES_COMPUTE_PER_GB,
                    }
                }
            };
            *next_id += 1;
            out.push(q);
            let gap = self.rng.exponential(1.0 / self.spec.mean_interarrival);
            self.next_arrival = arrival + gap;
        }
        out
    }
}

fn new_window(
    w: &WindowSpec,
    zipf: &Zipf,
    now: f64,
    rng: &mut Pcg64,
) -> (f64, Vec<usize>) {
    let len = rng.normal(w.mean_secs, w.std_secs).max(1.0);
    let mut candidates = Vec::with_capacity(w.candidates);
    // Draw (mostly distinct) candidates from the global Zipf.
    let mut guard = 0;
    while candidates.len() < w.candidates && guard < 200 {
        let d = zipf.sample(rng);
        if !candidates.contains(&d) {
            candidates.push(d);
        }
        guard += 1;
    }
    if candidates.is_empty() {
        candidates.push(zipf.sample(rng));
    }
    (now + len, candidates)
}

/// All tenants' generators plus the shared query-id counter.
pub struct WorkloadGenerator {
    pub generators: Vec<TenantGenerator>,
    next_id: u64,
}

impl WorkloadGenerator {
    pub fn new(specs: Vec<TenantSpec>, universe: &Universe, seed: u64) -> Self {
        let generators = specs
            .into_iter()
            .enumerate()
            .map(|(i, s)| TenantGenerator::new(TenantId(i), s, universe, seed))
            .collect();
        Self {
            generators,
            next_id: 0,
        }
    }

    /// Queries from all tenants arriving before `t_end`, sorted by
    /// arrival time.
    pub fn generate_until(&mut self, t_end: f64, universe: &Universe) -> Vec<Query> {
        let mut all = Vec::new();
        for g in self.generators.iter_mut() {
            all.extend(g.generate_until(t_end, universe, &mut self.next_id));
        }
        all.sort_by_key(|q| crate::util::ordf64::OrdF64(q.arrival));
        all
    }

    pub fn n_tenants(&self) -> usize {
        self.generators.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sales_spec(mean: f64) -> TenantSpec {
        TenantSpec::new(AccessSpec::g(1), mean)
    }

    #[test]
    fn arrival_counts_match_rate() {
        let u = Universe::sales_only();
        let mut gen = WorkloadGenerator::new(vec![sales_spec(20.0)], &u, 42);
        let qs = gen.generate_until(20.0 * 1000.0, &u);
        // Expect ~1000 arrivals; Poisson std is ~32.
        assert!((850..1150).contains(&qs.len()), "n={}", qs.len());
        // Arrivals sorted, in range.
        for w in qs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        assert!(qs.iter().all(|q| q.arrival < 20000.0));
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let u = Universe::sales_only();
        let mut g1 = WorkloadGenerator::new(vec![sales_spec(10.0)], &u, 7);
        let mut g2 = WorkloadGenerator::new(vec![sales_spec(10.0)], &u, 7);
        let a = g1.generate_until(500.0, &u);
        let b = g2.generate_until(500.0, &u);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.template, y.template);
            assert_eq!(x.arrival, y.arrival);
        }
        let mut g3 = WorkloadGenerator::new(vec![sales_spec(10.0)], &u, 8);
        let c = g3.generate_until(500.0, &u);
        assert!(
            a.len() != c.len()
                || a.iter().zip(&c).any(|(x, y)| x.arrival != y.arrival)
        );
    }

    #[test]
    fn zipf_access_is_skewed() {
        let u = Universe::sales_only();
        let mut gen = WorkloadGenerator::new(vec![sales_spec(1.0)], &u, 3);
        let qs = gen.generate_until(20_000.0, &u);
        let mut counts = vec![0u32; 30];
        for q in &qs {
            let d: usize = q.template.strip_prefix("sales-scan-").unwrap().parse().unwrap();
            counts[d] += 1;
        }
        let max = *counts.iter().max().unwrap() as f64;
        let total: u32 = counts.iter().sum();
        // Top dataset takes ~18% of accesses (Zipf s=0.8, n=30:
        // 1/sum k^-0.8 over 30 items ~ 0.178) -- far above uniform 3.3%.
        let frac = max / total as f64;
        assert!((0.13..0.30).contains(&frac), "frac={frac}");
    }

    #[test]
    fn same_skew_seed_same_favourite_across_tenants() {
        let u = Universe::sales_only();
        let specs = vec![sales_spec(1.0), sales_spec(1.0)];
        let mut gen = WorkloadGenerator::new(specs, &u, 5);
        let favs: Vec<usize> = gen
            .generators
            .iter()
            .map(|g| g.zipf().unwrap().items_by_rank()[0])
            .collect();
        assert_eq!(favs[0], favs[1]);
        // Different g → different favourite (with overwhelming probability).
        let specs2 = vec![
            TenantSpec::new(AccessSpec::g(1), 1.0),
            TenantSpec::new(AccessSpec::g(2), 1.0),
        ];
        let gen2 = WorkloadGenerator::new(specs2, &u, 5);
        let f0 = gen2.generators[0].zipf().unwrap().items_by_rank()[0];
        let f1 = gen2.generators[1].zipf().unwrap().items_by_rank()[0];
        assert_ne!(f0, f1);
    }

    #[test]
    fn tpch_tenant_uses_templates() {
        let u = Universe::mixed();
        let spec = TenantSpec::new(AccessSpec::h1(), 5.0);
        let mut gen = WorkloadGenerator::new(vec![spec], &u, 1);
        let qs = gen.generate_until(2000.0, &u);
        assert!(!qs.is_empty());
        let li = u.views.by_name("lineitem").unwrap().id;
        for q in &qs {
            assert!(q.template.starts_with("tpch-q"));
            assert!(q.required_views.contains(&li));
            assert!(q.bytes_read >= 3 * (1 << 30));
        }
        // Roughly uniform over 15 templates.
        let mut seen = std::collections::HashSet::new();
        for q in &qs {
            seen.insert(q.template.clone());
        }
        assert!(seen.len() >= 12, "templates seen: {}", seen.len());
    }

    #[test]
    fn hot_cold_window_concentrates_access() {
        let u = Universe::sales_only();
        let windowed = TenantSpec::new(AccessSpec::g(1), 1.0).with_window(WindowSpec {
            mean_secs: 300.0,
            std_secs: 10.0,
            candidates: 3,
        });
        let mut gen = WorkloadGenerator::new(vec![windowed], &u, 9);
        let qs = gen.generate_until(300.0, &u);
        // Within ~one window only ~3 distinct datasets appear.
        let mut seen = std::collections::HashSet::new();
        for q in &qs {
            seen.insert(q.template.clone());
        }
        assert!(seen.len() <= 4, "distinct datasets {}", seen.len());
        assert!(qs.len() > 100);
    }

    #[test]
    #[should_panic]
    fn sales_tenant_needs_sales_universe() {
        let u = Universe::tpch_only();
        let _ = WorkloadGenerator::new(vec![sales_spec(1.0)], &u, 0);
    }
}
