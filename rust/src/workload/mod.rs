//! Workload generation (§5.1, Figure 4): Poisson query arrivals, Zipf
//! data access with optional hot/cold local windows, the TPC-H h₁ query
//! mix, and trace record/replay.

pub mod generator;
pub mod queue;
pub mod spec;
pub mod trace;
pub mod universe;

pub use generator::{TenantGenerator, WorkloadGenerator};
pub use queue::{AdmissionPolicy, AdmissionQueue};
pub use spec::{AccessSpec, TenantSpec, WindowSpec};
pub use universe::Universe;
