//! Workload trace record/replay: serialize a generated query stream to
//! JSON so experiments can be re-run bit-identically (and so regression
//! tests can pin a workload).

use crate::domain::query::{Query, QueryId};
use crate::domain::tenant::TenantId;
use crate::domain::view::ViewId;
use crate::util::json::Json;

/// Serialize queries to a JSON array.
pub fn to_json(queries: &[Query]) -> Json {
    Json::Array(
        queries
            .iter()
            .map(|q| {
                Json::from_pairs(vec![
                    ("id", Json::Number(q.id.0 as f64)),
                    ("tenant", Json::Number(q.tenant.0 as f64)),
                    ("arrival", Json::Number(q.arrival)),
                    ("template", Json::String(q.template.clone())),
                    (
                        "views",
                        Json::Array(
                            q.required_views
                                .iter()
                                .map(|v| Json::Number(v.0 as f64))
                                .collect(),
                        ),
                    ),
                    ("bytes", Json::Number(q.bytes_read as f64)),
                    ("compute", Json::Number(q.compute_cost)),
                ])
            })
            .collect(),
    )
}

/// Deserialize queries from the JSON produced by [`to_json`].
pub fn from_json(json: &Json) -> Result<Vec<Query>, String> {
    let arr = json.as_array().ok_or("trace must be a JSON array")?;
    arr.iter()
        .map(|item| {
            let get_num = |key: &str| -> Result<f64, String> {
                item.get(key)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("missing/invalid field '{key}'"))
            };
            let views = item
                .get("views")
                .and_then(|v| v.as_array())
                .ok_or("missing views")?
                .iter()
                .map(|v| {
                    v.as_u64()
                        .map(|i| ViewId(i as usize))
                        .ok_or_else(|| "bad view id".to_string())
                })
                .collect::<Result<Vec<_>, _>>()?;
            Ok(Query {
                id: QueryId(get_num("id")? as u64),
                tenant: TenantId(get_num("tenant")? as usize),
                arrival: get_num("arrival")?,
                template: item
                    .get("template")
                    .and_then(|v| v.as_str())
                    .ok_or("missing template")?
                    .to_string(),
                required_views: views,
                bytes_read: get_num("bytes")? as u64,
                compute_cost: get_num("compute")?,
            })
        })
        .collect()
}

/// Write a trace file.
pub fn save(path: &str, queries: &[Query]) -> std::io::Result<()> {
    std::fs::write(path, to_json(queries).to_string_compact())
}

/// Read a trace file.
pub fn load(path: &str) -> Result<Vec<Query>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    let json = Json::parse(&text).map_err(|e| e.to_string())?;
    from_json(&json)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::generator::WorkloadGenerator;
    use crate::workload::spec::{AccessSpec, TenantSpec};
    use crate::workload::universe::Universe;

    #[test]
    fn roundtrip() {
        let u = Universe::mixed();
        let specs = vec![
            TenantSpec::new(AccessSpec::h1(), 10.0),
            TenantSpec::new(AccessSpec::g(1), 10.0),
        ];
        let mut gen = WorkloadGenerator::new(specs, &u, 42);
        let qs = gen.generate_until(300.0, &u);
        assert!(!qs.is_empty());
        let json = to_json(&qs);
        let back = from_json(&json).unwrap();
        assert_eq!(qs.len(), back.len());
        for (a, b) in qs.iter().zip(&back) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tenant, b.tenant);
            assert_eq!(a.template, b.template);
            assert_eq!(a.required_views, b.required_views);
            assert_eq!(a.bytes_read, b.bytes_read);
            assert!((a.arrival - b.arrival).abs() < 1e-9);
        }
    }

    #[test]
    fn file_roundtrip() {
        let u = Universe::sales_only();
        let mut gen =
            WorkloadGenerator::new(vec![TenantSpec::new(AccessSpec::g(2), 5.0)], &u, 1);
        let qs = gen.generate_until(100.0, &u);
        let path = "/tmp/robus_trace_test.json";
        save(path, &qs).unwrap();
        let back = load(path).unwrap();
        assert_eq!(qs.len(), back.len());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn malformed_trace_rejected() {
        assert!(from_json(&Json::Number(3.0)).is_err());
        let bad = Json::parse(r#"[{"id": 1}]"#).unwrap();
        assert!(from_json(&bad).is_err());
    }
}
