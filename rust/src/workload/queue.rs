//! Online admission: bounded per-tenant producer/consumer queues.
//!
//! In the paper's replay experiments the "tenant queues" of Figure 2 are
//! implicit — the generator materializes each batch window on demand. In
//! the online service (`robus serve`) they are real queues: generator
//! threads push arrivals concurrently while the coordinator cuts batches
//! by draining them. The queue is bounded; what happens at the bound is
//! the [`AdmissionPolicy`]: shed load (admission cap) or block the
//! producer (backpressure).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use crate::domain::query::Query;
use crate::telemetry::QueueProbe;

/// What to do with an arrival when a tenant's queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Reject the arrival and count it (per-tenant admission cap).
    Drop,
    /// Block the producer until the coordinator drains the queue
    /// (backpressure).
    Block,
}

impl AdmissionPolicy {
    pub fn parse(s: &str) -> Option<AdmissionPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "drop" => Some(AdmissionPolicy::Drop),
            "block" => Some(AdmissionPolicy::Block),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Drop => "drop",
            AdmissionPolicy::Block => "block",
        }
    }
}

#[derive(Debug, Default)]
struct QueueState {
    items: VecDeque<Query>,
    admitted: u64,
    rejected: u64,
    closed: bool,
    /// High-water mark of the queue length (pipeline-health metric).
    peak_depth: usize,
}

/// A bounded admission queue for one tenant: producers `offer`,
/// the coordinator `drain`s whole batches.
#[derive(Debug)]
pub struct AdmissionQueue {
    capacity: usize,
    state: Mutex<QueueState>,
    space: Condvar,
    /// Telemetry probe: admit/reject/requeue counters and drop/requeue
    /// trace events. Disconnected by default; probe calls are lock-free
    /// and happen after the queue lock is released.
    probe: QueueProbe,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> Self {
        Self::with_probe(capacity, QueueProbe::disconnected())
    }

    /// [`AdmissionQueue::new`] with a telemetry probe (see
    /// [`crate::telemetry::Telemetry::queue_probe`]).
    pub fn with_probe(capacity: usize, probe: QueueProbe) -> Self {
        Self {
            capacity: capacity.max(1),
            state: Mutex::new(QueueState::default()),
            space: Condvar::new(),
            probe,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Offer an arrival under `policy`. Returns true iff admitted.
    /// Closed queues reject everything (and wake blocked producers).
    pub fn offer(&self, query: Query, policy: AdmissionPolicy) -> bool {
        let tenant = query.tenant.0;
        let arrival = query.arrival;
        let mut st = self.state.lock().unwrap();
        if policy == AdmissionPolicy::Block {
            while st.items.len() >= self.capacity && !st.closed {
                st = self.space.wait(st).unwrap();
            }
        }
        if st.closed || st.items.len() >= self.capacity {
            st.rejected += 1;
            drop(st);
            self.probe.rejected(tenant, arrival);
            return false;
        }
        st.items.push_back(query);
        st.admitted += 1;
        st.peak_depth = st.peak_depth.max(st.items.len());
        drop(st);
        self.probe.admitted();
        true
    }

    /// Enqueue a query *without* admission accounting or a capacity
    /// check. This is the membership re-home path of the federated
    /// serving layer: a query drained from a retiring shard's queue was
    /// already admitted (and counted) once, so moving it to its new
    /// home must neither re-count it nor shed it — the target queue may
    /// transiently overshoot its capacity by the retiring shard's
    /// backlog rather than drop admitted work. Works on closed queues
    /// too (re-homes during the shutdown drain tail still conserve).
    pub fn requeue(&self, query: Query) {
        let tenant = query.tenant.0;
        let arrival = query.arrival;
        let mut st = self.state.lock().unwrap();
        st.items.push_back(query);
        st.peak_depth = st.peak_depth.max(st.items.len());
        drop(st);
        self.probe.requeued(tenant, arrival);
    }

    /// Remove everything currently queued (the batch cut). Frees space,
    /// so blocked producers wake.
    pub fn drain(&self) -> Vec<Query> {
        let mut out = Vec::new();
        self.drain_into(&mut out);
        out
    }

    /// [`AdmissionQueue::drain`] into a caller-owned buffer (appended,
    /// not cleared) — the serving loops cut every batch into a reused
    /// per-shard buffer instead of allocating a fresh `Vec` per cut.
    pub fn drain_into(&self, out: &mut Vec<Query>) {
        let mut st = self.state.lock().unwrap();
        let drained = st.items.len();
        out.extend(st.items.drain(..));
        drop(st);
        if drained > 0 {
            self.space.notify_all();
        }
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// `(admitted, rejected)` counters so far.
    pub fn counts(&self) -> (u64, u64) {
        let st = self.state.lock().unwrap();
        (st.admitted, st.rejected)
    }

    /// High-water mark of the queue length.
    pub fn peak_depth(&self) -> usize {
        self.state.lock().unwrap().peak_depth
    }

    /// Stop admitting; blocked producers wake and see rejection.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.space.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::query::QueryId;
    use crate::domain::tenant::TenantId;

    fn query(id: u64) -> Query {
        Query {
            id: QueryId(id),
            tenant: TenantId(0),
            arrival: id as f64,
            template: "t".into(),
            required_views: vec![],
            bytes_read: 1,
            compute_cost: 0.0,
        }
    }

    #[test]
    fn offers_and_drains_fifo() {
        let q = AdmissionQueue::new(8);
        for i in 0..3 {
            assert!(q.offer(query(i), AdmissionPolicy::Drop));
        }
        assert_eq!(q.len(), 3);
        let batch = q.drain();
        assert_eq!(batch.iter().map(|x| x.id.0).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert!(q.is_empty());
        assert_eq!(q.counts(), (3, 0));
        assert_eq!(q.peak_depth(), 3);
    }

    #[test]
    fn drop_policy_sheds_load_at_capacity() {
        let q = AdmissionQueue::new(2);
        assert!(q.offer(query(0), AdmissionPolicy::Drop));
        assert!(q.offer(query(1), AdmissionPolicy::Drop));
        assert!(!q.offer(query(2), AdmissionPolicy::Drop));
        assert_eq!(q.counts(), (2, 1));
        q.drain();
        assert!(q.offer(query(3), AdmissionPolicy::Drop));
    }

    #[test]
    fn block_policy_waits_for_drain() {
        let q = AdmissionQueue::new(1);
        assert!(q.offer(query(0), AdmissionPolicy::Block));
        std::thread::scope(|s| {
            s.spawn(|| {
                // Blocks until the main thread drains.
                assert!(q.offer(query(1), AdmissionPolicy::Block));
            });
            std::thread::sleep(std::time::Duration::from_millis(20));
            let first = q.drain();
            assert_eq!(first.len(), 1);
        });
        assert_eq!(q.counts(), (2, 0));
        assert_eq!(q.drain().len(), 1);
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        // A zero-capacity queue would deadlock block-mode producers and
        // shed everything in drop mode; the constructor clamps to 1.
        let q = AdmissionQueue::new(0);
        assert_eq!(q.capacity(), 1);
        assert!(q.offer(query(0), AdmissionPolicy::Drop));
        assert!(!q.offer(query(1), AdmissionPolicy::Drop));
        assert_eq!(q.counts(), (1, 1));
        assert_eq!(q.drain().len(), 1);
        // Block mode also works at the clamped bound (space after drain).
        assert!(q.offer(query(2), AdmissionPolicy::Block));
        assert_eq!(q.peak_depth(), 1);
    }

    #[test]
    fn drop_counts_survive_drains() {
        // Rejections are cumulative admission accounting, not queue
        // state: draining frees space but never resets the counters.
        let q = AdmissionQueue::new(2);
        assert!(q.offer(query(0), AdmissionPolicy::Drop));
        assert!(q.offer(query(1), AdmissionPolicy::Drop));
        assert!(!q.offer(query(2), AdmissionPolicy::Drop));
        assert!(!q.offer(query(3), AdmissionPolicy::Drop));
        assert_eq!(q.counts(), (2, 2));
        assert_eq!(q.drain().len(), 2);
        assert_eq!(q.counts(), (2, 2), "drain must not reset counters");
        assert!(q.offer(query(4), AdmissionPolicy::Drop));
        assert!(q.offer(query(5), AdmissionPolicy::Drop));
        assert!(!q.offer(query(6), AdmissionPolicy::Drop));
        assert_eq!(q.counts(), (4, 3));
        // Peak depth is the high-water mark across epochs, not current.
        assert_eq!(q.peak_depth(), 2);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn block_mode_under_full_queue_admits_all_producers() {
        // Two producers blocked on a full capacity-1 queue: repeated
        // drains must wake and admit both — backpressure never sheds.
        let q = AdmissionQueue::new(1);
        assert!(q.offer(query(0), AdmissionPolicy::Block));
        std::thread::scope(|s| {
            s.spawn(|| assert!(q.offer(query(1), AdmissionPolicy::Block)));
            s.spawn(|| assert!(q.offer(query(2), AdmissionPolicy::Block)));
            let mut drained = 0usize;
            for _ in 0..500 {
                drained += q.drain().len();
                if drained == 3 {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            if drained != 3 {
                // Unblock the producers before panicking so the scope
                // can join them instead of hanging the test run.
                q.close();
                panic!("blocked producers never got admitted (drained {drained})");
            }
        });
        assert_eq!(q.counts(), (3, 0));
        assert!(q.is_empty());
    }

    #[test]
    fn requeue_bypasses_capacity_and_admission_accounting() {
        // A re-homed query was already admitted on its original shard's
        // queue: moving it must not re-count it, must not shed it at the
        // bound, and must survive a closed target.
        let q = AdmissionQueue::new(1);
        assert!(q.offer(query(0), AdmissionPolicy::Drop));
        q.requeue(query(1));
        q.requeue(query(2));
        // Counters unchanged: one admission, zero rejections.
        assert_eq!(q.counts(), (1, 0));
        // Capacity overshoot is recorded in the high-water mark.
        assert_eq!(q.len(), 3);
        assert_eq!(q.peak_depth(), 3);
        // FIFO order is preserved across the transfer.
        assert_eq!(
            q.drain().iter().map(|x| x.id.0).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        // A closed queue still accepts re-homed (already-admitted) work
        // so the shutdown drain tail conserves it.
        q.close();
        assert!(!q.offer(query(3), AdmissionPolicy::Drop));
        q.requeue(query(4));
        assert_eq!(q.drain().iter().map(|x| x.id.0).collect::<Vec<_>>(), vec![4]);
        assert_eq!(q.counts(), (1, 1));
    }

    #[test]
    fn close_rejects_and_wakes_blocked_producers() {
        let q = AdmissionQueue::new(1);
        assert!(q.offer(query(0), AdmissionPolicy::Block));
        std::thread::scope(|s| {
            s.spawn(|| {
                // Woken by close, not by space: rejected.
                assert!(!q.offer(query(1), AdmissionPolicy::Block));
            });
            std::thread::sleep(std::time::Duration::from_millis(20));
            q.close();
        });
        assert!(q.is_closed());
        assert!(!q.offer(query(2), AdmissionPolicy::Drop));
        assert_eq!(q.counts(), (1, 2));
        // Already-queued work still drains after close.
        assert_eq!(q.drain().len(), 1);
    }
}
