//! Declarative workload specifications — the knobs Tables 8–14 vary.

/// How a tenant picks what data each query touches.
#[derive(Debug, Clone, PartialEq)]
pub enum AccessSpec {
    /// h₁ of §5.3.1: queries drawn uniformly at random over the 15 TPC-H
    /// templates.
    TpchUniform,
    /// g_k of §5.3.1: scan-and-aggregate queries over the 30 Sales
    /// datasets drawn from a Zipf distribution. Distinct `skew_seed`s
    /// produce distributions "skewed towards a different subset of
    /// datasets" (the rank→dataset permutation is seeded).
    SalesZipf { exponent: f64, skew_seed: u64 },
}

impl AccessSpec {
    /// The canonical g₁..g₄ distributions used across the evaluation.
    pub fn g(k: usize) -> AccessSpec {
        // Exponent 0.8: a long-tailed but not head-dominated skew, per
        // the (paper ref 31)/(paper ref 53) "small number of popular datasets plus a long
        // tail" characterization.
        AccessSpec::SalesZipf {
            exponent: 0.8,
            skew_seed: 1000 + k as u64,
        }
    }

    /// The canonical h₁ distribution.
    pub fn h1() -> AccessSpec {
        AccessSpec::TpchUniform
    }
}

/// Hot/cold local-window behaviour (§5.1, after (paper ref 31)/(paper ref 53)): every window
/// (length ~ Normal) a small candidate subset is drawn from the global
/// Zipf; within the window queries pick uniformly from the subset, so
/// recently accessed data is re-accessed while the global distribution
/// stays Zipf.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowSpec {
    pub mean_secs: f64,
    pub std_secs: f64,
    /// Size of the per-window candidate subset.
    pub candidates: usize,
}

impl Default for WindowSpec {
    fn default() -> Self {
        Self {
            mean_secs: 120.0,
            std_secs: 30.0,
            candidates: 4,
        }
    }
}

/// Full per-tenant workload description.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub access: AccessSpec,
    /// Mean inter-arrival time in seconds (Poisson process ⇒ exponential
    /// gaps with this mean). Table 11's "Poisson mean λ" is this value.
    pub mean_interarrival: f64,
    /// Optional hot/cold window; `None` samples the global distribution
    /// at all times (the paper's default for most experiments).
    pub window: Option<WindowSpec>,
}

impl TenantSpec {
    pub fn new(access: AccessSpec, mean_interarrival: f64) -> Self {
        Self {
            access,
            mean_interarrival,
            window: None,
        }
    }

    pub fn with_window(mut self, w: WindowSpec) -> Self {
        self.window = Some(w);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_distributions_distinct() {
        assert_ne!(AccessSpec::g(1), AccessSpec::g(2));
        assert_eq!(AccessSpec::g(1), AccessSpec::g(1));
        assert_eq!(AccessSpec::h1(), AccessSpec::TpchUniform);
    }

    #[test]
    fn builder() {
        let t = TenantSpec::new(AccessSpec::g(1), 20.0).with_window(WindowSpec::default());
        assert_eq!(t.mean_interarrival, 20.0);
        assert!(t.window.is_some());
    }
}
