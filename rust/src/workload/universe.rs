//! The data universe of a run: the union of the Sales and TPC-H catalogs
//! with one shared view index space, so mixed workloads (§5.3.1, Table 8)
//! can be described by a single candidate-view vector.

use crate::domain::dataset::DatasetCatalog;
use crate::domain::sales::SalesCatalog;
use crate::domain::tpch::{TpchCatalog, TpchTemplate, TEMPLATES};
use crate::domain::view::{ViewCatalog, ViewId};

/// A resolved TPC-H template: required views in the universe's index
/// space, total scan bytes, compute cost.
#[derive(Debug, Clone)]
pub struct ResolvedTemplate {
    pub name: &'static str,
    pub views: Vec<ViewId>,
    pub bytes: u64,
    pub compute: f64,
}

/// The combined catalogs.
#[derive(Debug, Clone)]
pub struct Universe {
    pub datasets: DatasetCatalog,
    pub views: ViewCatalog,
    /// Projection view for Sales dataset k (index into `views`); empty if
    /// the universe has no Sales data.
    pub sales_views: Vec<ViewId>,
    /// Resolved TPC-H templates; empty if the universe has no TPC-H data.
    pub tpch_templates: Vec<ResolvedTemplate>,
}

impl Universe {
    /// Sales catalog only (Tables 9/10 experiments).
    pub fn sales_only() -> Self {
        let sales = SalesCatalog::build();
        Self {
            sales_views: sales.view_of_dataset.clone(),
            datasets: sales.datasets,
            views: sales.views,
            tpch_templates: Vec::new(),
        }
    }

    /// TPC-H catalog only.
    pub fn tpch_only() -> Self {
        let tpch = TpchCatalog::build();
        let templates = resolve_templates(&tpch, 0);
        Self {
            datasets: tpch.datasets,
            views: tpch.views,
            sales_views: Vec::new(),
            tpch_templates: templates,
        }
    }

    /// Mixed universe: TPC-H tables first, then the 30 Sales datasets
    /// (Table 8 experiments).
    pub fn mixed() -> Self {
        let tpch = TpchCatalog::build();
        let sales = SalesCatalog::build();
        let mut datasets = DatasetCatalog::new();
        let mut views = ViewCatalog::new();

        // TPC-H first (view ids 0..8).
        for d in tpch.datasets.iter() {
            let nd = datasets.add(&d.name, d.disk_bytes);
            let v = tpch.views.for_dataset(d.id).unwrap();
            views.add(&v.name, nd, v.kind, v.cached_bytes, v.scan_bytes);
        }
        let templates = resolve_templates(&tpch, 0);

        // Sales second.
        let mut sales_views = Vec::new();
        for d in sales.datasets.iter() {
            let nd = datasets.add(&d.name, d.disk_bytes);
            let v = sales.views.for_dataset(d.id).unwrap();
            let nv = views.add(&v.name, nd, v.kind, v.cached_bytes, v.scan_bytes);
            sales_views.push(nv);
        }

        Self {
            datasets,
            views,
            sales_views,
            tpch_templates: templates,
        }
    }

    pub fn n_views(&self) -> usize {
        self.views.len()
    }
}

fn resolve_templates(tpch: &TpchCatalog, offset: usize) -> Vec<ResolvedTemplate> {
    TEMPLATES
        .iter()
        .map(|t: &TpchTemplate| {
            let (views, bytes, compute) = tpch.template_footprint(t);
            ResolvedTemplate {
                name: t.name,
                views: views.into_iter().map(|v| ViewId(v.0 + offset)).collect(),
                bytes,
                compute,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sales_only_shape() {
        let u = Universe::sales_only();
        assert_eq!(u.n_views(), 30);
        assert_eq!(u.sales_views.len(), 30);
        assert!(u.tpch_templates.is_empty());
    }

    #[test]
    fn mixed_shape_and_offsets() {
        let u = Universe::mixed();
        assert_eq!(u.n_views(), 38);
        assert_eq!(u.tpch_templates.len(), 15);
        // Sales views come after the 8 TPC-H views.
        assert!(u.sales_views.iter().all(|v| v.0 >= 8));
        // Template views stay in the TPC-H range.
        for t in &u.tpch_templates {
            assert!(t.views.iter().all(|v| v.0 < 8), "{:?}", t);
        }
        // lineitem view resolves and is ~3.7 GB.
        let li = u.views.by_name("lineitem").unwrap();
        assert!(li.cached_bytes > 3 * (1 << 30));
    }

    #[test]
    fn view_dataset_consistency() {
        let u = Universe::mixed();
        for v in u.views.iter() {
            assert_eq!(u.datasets.get(v.dataset).name.as_str(), {
                // Projection names are "<dataset>_proj".
                let n = v.name.strip_suffix("_proj").unwrap_or(&v.name);
                n
            });
        }
    }
}
