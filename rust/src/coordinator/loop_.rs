//! The five-step ROBUS loop (Figure 2):
//! 1. remove a time batch of queries from the tenant queues;
//! 2. run the view-selection algorithm over the batch (candidate views +
//!    utility model + cache budget → randomized allocation → sample);
//! 3. update the cache with the selected configuration;
//! 4. rewrite queries to use cached views (implicit here: the simulator
//!    reads a view from memory whenever it is cached);
//! 5. execute the batch on the (simulated) cluster.
//!
//! Batch b collects arrivals in [b·W, (b+1)·W); its execution starts at
//! max((b+1)·W, previous batch's completion) — a policy that cannot keep
//! up accumulates backlog and shows reduced throughput, exactly the
//! paper's throughput mechanics.
//!
//! The loop is split into two halves so the serial reference and the
//! pipelined runner (`coordinator::pipeline`) share every line of
//! batch logic: [`BatchPlanner`] owns steps 1–2 (workload drain + solve
//! + sample) and [`BatchExecutor`] owns steps 3–5 (cache transition +
//! simulated execution). The planner never reads the live cache — after
//! an update the cache holds exactly the emitted configuration, so a
//! local mirror mask reproduces the stateful boost bit-for-bit, which is
//! what lets the solve for batch b+1 overlap the execution of batch b.

use std::time::Instant;

use crate::alloc::{ConfigMask, Policy, WarmState};
use crate::cache::tier::{TierAssignment, TierSpec};
use crate::cache::{CacheDelta, CacheManager};
use crate::domain::query::{Query, QueryId};
use crate::domain::tenant::TenantSet;
use crate::domain::utility::{BatchUtilities, TierPlan};
use crate::sim::engine::{QueryOutcome, SimEngine};
use crate::telemetry::{LocalHistogram, SpanRecord, Telemetry};
use crate::util::event::{Clock, SimClock};
use crate::util::rng::Pcg64;
use crate::util::stats;
use crate::workload::generator::WorkloadGenerator;
use crate::workload::universe::Universe;

/// The tier dimension of one driver's solve loop, derived once from a
/// [`TierSpec`]: `None` in single-tier mode (SSD budget 0), which makes
/// every solve below route through exactly the legacy RAM-only code.
pub(crate) fn tier_plan_of(spec: &TierSpec) -> Option<TierPlan> {
    (!spec.is_single_tier()).then(|| TierPlan {
        ssd_budget: spec.budgets.ssd as f64,
        discount: spec.cost.ssd_discount(),
    })
}

/// The inputs of one batch solve that every driver shares (serial,
/// pipelined, the online service, and the sharded federation).
pub(crate) struct SolveContext<'a> {
    pub tenants: &'a TenantSet,
    pub universe: &'a Universe,
    /// RAM-tier byte budget (the legacy single budget).
    pub budget: u64,
    /// SSD-tier plane of the solve; `None` = single-tier (bit-identical
    /// to the pre-tier path).
    pub tier: Option<TierPlan>,
    pub stateful_gamma: Option<f64>,
    /// Per-tenant weight multipliers layered onto the base λ_i for this
    /// solve (the federation's global-fairness feedback). `None` routes
    /// straight to `policy.allocate` — bit-identical to an unweighted
    /// solve, which is what the single-node drivers pass.
    pub weight_mult: Option<&'a [f64]>,
}

/// One solved batch plus the accounting the federation's global
/// fairness accountant aggregates across shards.
pub(crate) struct SolveOutcome {
    /// The sampled `(view, tier)` configuration. Single-tier solves
    /// always emit an empty SSD plane.
    pub config: TierAssignment,
    /// Raw per-tenant utility attained by the sampled configuration
    /// (zeros for an empty batch).
    pub utilities: Vec<f64>,
    /// Per-tenant solo optimum U* of this batch problem (zeros for an
    /// empty batch — no demand means nothing attainable).
    pub u_star: Vec<f64>,
    /// Host seconds building the batch problem (stateful boost +
    /// utility matrix + weight multipliers) — the span's `boost` phase.
    pub boost_secs: f64,
    /// Host seconds in `policy.allocate[_warm]` proper — the span's
    /// `solve` phase.
    pub alloc_secs: f64,
    /// Host seconds sampling the configuration and scoring utilities —
    /// the span's `sample` phase.
    pub sample_secs: f64,
    /// `"cold"`, `"warm"` (carried state was reusable at entry), or
    /// `"none"` for an empty batch that solved nothing. Observational
    /// only: the warm/cold split is judged from the state's shape
    /// before the solve, not from the policy's internal reuse verdict.
    pub kind: &'static str,
}

impl SolveContext<'_> {
    /// Step 2 of the loop — the one batch-solve implementation: build
    /// the batch problem over `queries` (with the §5.4 stateful boost
    /// derived from `cached`, the cache contents at solve time), run
    /// the policy, sample a configuration. Empty batches keep the
    /// current contents.
    pub(crate) fn solve(
        &self,
        cached: &TierAssignment,
        queries: &[Query],
        policy: &dyn Policy,
        rng: &mut Pcg64,
    ) -> TierAssignment {
        self.solve_accounted(cached, queries, policy, rng).config
    }

    /// [`SolveContext::solve`] with optional warm-start state. `None`
    /// routes through `policy.allocate` — bit-identical to [`solve`],
    /// which is what replay-determinism drivers pass; `Some` hands the
    /// carried [`WarmState`] to `policy.allocate_warm`.
    pub(crate) fn solve_warm(
        &self,
        cached: &TierAssignment,
        queries: &[Query],
        policy: &dyn Policy,
        rng: &mut Pcg64,
        warm: Option<&mut WarmState>,
    ) -> TierAssignment {
        self.solve_accounted_warm(cached, queries, policy, rng, warm)
            .config
    }

    /// [`SolveContext::solve`] plus the attained/attainable per-tenant
    /// utilities of the sampled configuration. The extra accounting
    /// consumes no randomness, so `solve` and `solve_accounted` advance
    /// `rng` identically.
    pub(crate) fn solve_accounted(
        &self,
        cached: &TierAssignment,
        queries: &[Query],
        policy: &dyn Policy,
        rng: &mut Pcg64,
    ) -> SolveOutcome {
        self.solve_accounted_warm(cached, queries, policy, rng, None)
    }

    /// The one batch-solve implementation behind all four entry points.
    /// An empty batch keeps the current contents and touches neither the
    /// rng nor the warm state (the carried artifacts stay valid for the
    /// next non-empty batch).
    pub(crate) fn solve_accounted_warm(
        &self,
        cached: &TierAssignment,
        queries: &[Query],
        policy: &dyn Policy,
        rng: &mut Pcg64,
        warm: Option<&mut WarmState>,
    ) -> SolveOutcome {
        let n = self.tenants.len();
        if queries.is_empty() {
            return SolveOutcome {
                config: cached.clone(),
                utilities: vec![0.0; n],
                u_star: vec![0.0; n],
                boost_secs: 0.0,
                alloc_secs: 0.0,
                sample_secs: 0.0,
                kind: "none",
            };
        }
        // Phase timings are host-time observations only: `Instant` reads
        // never feed back into any simulated quantity, preserving the
        // determinism contract.
        let kind = match &warm {
            Some(w) if !w.is_cold() => "warm",
            _ => "cold",
        };
        let t0 = Instant::now();
        // §5.4 stateful boost comes from the RAM plane only: a demoted
        // view lost its RAM residency, so it loses its retention boost.
        let boost = self
            .stateful_gamma
            .map(|g| CacheManager::boost_vector(&cached.ram, g));
        let mut batch_problem = BatchUtilities::build(
            self.tenants,
            &self.universe.views,
            self.budget as f64,
            queries,
            boost.as_deref(),
        )
        .with_tier(self.tier);
        // We own the freshly built problem, so the federation's weight
        // multipliers apply in place — no clone on the hot path.
        if let Some(mult) = self.weight_mult {
            crate::alloc::apply_weight_multipliers(&mut batch_problem, mult);
        }
        let boost_secs = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let allocation = match warm {
            Some(w) => policy.allocate_warm(&batch_problem, rng, w),
            None => policy.allocate(&batch_problem, rng),
        };
        let alloc_secs = t1.elapsed().as_secs_f64();
        let t2 = Instant::now();
        let config = allocation.sample_pair(rng);
        let utilities = batch_problem.utilities_pair(&config);
        let u_star = batch_problem.u_star.clone();
        let sample_secs = t2.elapsed().as_secs_f64();
        SolveOutcome {
            config,
            utilities,
            u_star,
            boost_secs,
            alloc_secs,
            sample_secs,
            kind,
        }
    }
}

/// The configuration fields every driver shares (serial replay, the
/// pipelined runner, the online service, and both federations). Each
/// driver config embeds one of these; the CLI parses the corresponding
/// flags in exactly one place (`main::opt_common`).
#[derive(Debug, Clone)]
pub struct CommonConfig {
    /// Batch interval W in (simulated or real) seconds.
    pub batch_secs: f64,
    /// Stateful cache mode (§5.4): boost factor γ for cached views;
    /// `None` = stateless (the paper's default).
    pub stateful_gamma: Option<f64>,
    /// Seed for policy randomization (allocation sampling etc.).
    pub seed: u64,
    /// Carry solver state across batches (warm-started incremental
    /// solves). Off by default so `robus run` replay stays bit-identical
    /// to the historical path; `robus serve` turns it on.
    pub warm_start: bool,
    /// Tiered cache hierarchy (RAM + SSD budgets + cost model). `None`
    /// keeps the engine's single RAM budget — the pre-tier path, bit
    /// for bit. A spec whose SSD budget is 0 behaves identically.
    pub tiers: Option<TierSpec>,
}

impl Default for CommonConfig {
    fn default() -> Self {
        Self {
            batch_secs: 40.0,
            stateful_gamma: None,
            seed: 7,
            warm_start: false,
            tiers: None,
        }
    }
}

/// Coordinator configuration (the §5.3 experiment knobs).
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Knobs shared with every other driver.
    pub common: CommonConfig,
    /// Number of batches to run.
    pub n_batches: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            common: CommonConfig::default(),
            n_batches: 30,
        }
    }
}

/// Per-batch record for reporting and the Figure 7/11/12 series.
#[derive(Debug, Clone)]
pub struct BatchRecord {
    pub index: usize,
    /// Queries in the batch.
    pub n_queries: usize,
    /// The sampled configuration's RAM plane (the legacy view mask —
    /// everything that reads `config` keeps its pre-tier meaning).
    pub config: ConfigMask,
    /// The SSD plane of the sampled configuration (empty in single-tier
    /// mode).
    pub ssd: ConfigMask,
    /// Cache utilization after the update.
    pub cache_utilization: f64,
    /// Wall-clock (simulated) times: batch window end / execution span.
    pub window_end: f64,
    pub exec_start: f64,
    pub exec_end: f64,
    /// Wall-clock (host) seconds spent in the view-selection solve — the
    /// §5.4 "query wait times of the order of tens of milliseconds".
    pub solve_secs: f64,
    /// Pre-solved batches waiting when the executor picked this one up
    /// (0 in serial mode: nothing ever runs ahead).
    pub queue_depth: usize,
    /// Host seconds the executor stalled waiting for this batch's solve.
    /// Serial mode stalls for the whole solve; the pipelined runner only
    /// stalls when the solver falls behind execution.
    pub stall_secs: f64,
    /// The incremental cache transition this batch applied.
    pub delta: CacheDelta,
}

/// Streaming aggregates a [`BatchExecutor`] maintains for every batch,
/// raw retention or not. This is what lets a long real-clock `serve`
/// run drop per-batch/per-query records (`retain_raw = false`) while
/// the end-of-run report keeps its meaning: counts, sums, extrema, and
/// a mergeable log-scale histogram of solve latency stand in for the
/// raw vectors. Memory is O(tenants + histogram buckets), flat over
/// any soak length.
#[derive(Debug, Clone, Default)]
pub struct ExecSummary {
    /// Batches executed. After a federation merge this is the *global*
    /// batch count, not the per-shard sum — see `util_batches`.
    pub batches: u64,
    /// Shard-batches contributing to `util_sum` (equals `batches` on a
    /// single node; the per-shard sum after a merge).
    pub util_batches: u64,
    pub completed: u64,
    /// Queries served entirely off cached views.
    pub hits: u64,
    pub util_sum: f64,
    pub stall_secs_sum: f64,
    /// Largest single batch (queries).
    pub max_batch: usize,
    pub per_tenant_completed: Vec<u64>,
    pub bytes_loaded: u64,
    pub bytes_evicted: u64,
    /// Disk→SSD load bytes (tiered mode; 0 single-tier).
    pub bytes_ssd_loaded: u64,
    /// RAM→SSD demotion bytes (tiered mode; 0 single-tier).
    pub bytes_demoted: u64,
    /// SSD→RAM promotion bytes (tiered mode; 0 single-tier).
    pub bytes_promoted: u64,
    /// Per-batch solve latency (total solve, milliseconds).
    pub solve_ms: LocalHistogram,
}

impl ExecSummary {
    /// Fold `other` into `self` (federation result merge). `batches`
    /// deliberately does NOT accumulate — the merged global batch count
    /// is set by the caller; `util_batches` and everything else sums.
    pub fn absorb(&mut self, other: &ExecSummary) {
        self.util_batches += other.util_batches;
        self.completed += other.completed;
        self.hits += other.hits;
        self.util_sum += other.util_sum;
        self.stall_secs_sum += other.stall_secs_sum;
        self.max_batch = self.max_batch.max(other.max_batch);
        if self.per_tenant_completed.len() < other.per_tenant_completed.len() {
            self.per_tenant_completed
                .resize(other.per_tenant_completed.len(), 0);
        }
        for (a, b) in self
            .per_tenant_completed
            .iter_mut()
            .zip(&other.per_tenant_completed)
        {
            *a += b;
        }
        self.bytes_loaded += other.bytes_loaded;
        self.bytes_evicted += other.bytes_evicted;
        self.bytes_ssd_loaded += other.bytes_ssd_loaded;
        self.bytes_demoted += other.bytes_demoted;
        self.bytes_promoted += other.bytes_promoted;
        self.solve_ms.merge(&other.solve_ms);
    }
}

/// Complete result of a coordinator run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub policy: &'static str,
    /// Per-query outcomes. Empty when the run streamed its aggregates
    /// (`retain_raw = false`); report accessors below fall back to
    /// [`RunResult::summary`] in that case.
    pub outcomes: Vec<QueryOutcome>,
    /// Per-batch records; empty under streamed retention, like
    /// `outcomes`.
    pub batches: Vec<BatchRecord>,
    /// Simulated time at which all batches completed.
    pub end_time: f64,
    pub n_tenants: usize,
    pub weights: Vec<f64>,
    /// Host wall-clock seconds the whole run took (solve + bookkeeping;
    /// simulated execution is free). Basis of the batches/sec and
    /// stall-fraction service metrics.
    pub host_wall_secs: f64,
    /// Streaming aggregates, maintained whether or not raw records were
    /// retained.
    pub summary: ExecSummary,
}

impl RunResult {
    /// Whether raw per-query/per-batch records were retained. Accessors
    /// prefer the raw (exact) path when available and fall back to the
    /// streaming summary otherwise.
    fn raw(&self) -> bool {
        !self.batches.is_empty() || !self.outcomes.is_empty()
    }

    /// Queries completed over the whole run.
    pub fn completed(&self) -> usize {
        if self.raw() {
            self.outcomes.len()
        } else {
            self.summary.completed as usize
        }
    }

    /// Batches executed over the whole run.
    pub fn n_batches(&self) -> usize {
        if self.raw() {
            self.batches.len()
        } else {
            self.summary.batches as usize
        }
    }

    /// Queries completed per tenant (length `n_tenants`).
    pub fn per_tenant_completed(&self) -> Vec<u64> {
        let mut counts = vec![0u64; self.n_tenants];
        if self.raw() {
            for o in &self.outcomes {
                if o.tenant < counts.len() {
                    counts[o.tenant] += 1;
                }
            }
        } else {
            for (i, &c) in self.summary.per_tenant_completed.iter().enumerate() {
                if i < counts.len() {
                    counts[i] = c;
                }
            }
        }
        counts
    }

    /// Largest single batch (queries).
    pub fn max_batch(&self) -> usize {
        if self.raw() {
            self.batches.iter().map(|b| b.n_queries).max().unwrap_or(0)
        } else {
            self.summary.max_batch
        }
    }

    /// Queries per minute of simulated time (Equation 4).
    pub fn throughput_per_min(&self) -> f64 {
        if self.end_time <= 0.0 {
            return 0.0;
        }
        self.completed() as f64 / (self.end_time / 60.0)
    }

    /// Fraction of queries served entirely off cached views.
    pub fn hit_ratio(&self) -> f64 {
        if self.raw() {
            if self.outcomes.is_empty() {
                return 0.0;
            }
            self.outcomes.iter().filter(|o| o.from_cache).count() as f64
                / self.outcomes.len() as f64
        } else if self.summary.completed == 0 {
            0.0
        } else {
            self.summary.hits as f64 / self.summary.completed as f64
        }
    }

    /// Mean cache utilization across batches.
    pub fn avg_cache_utilization(&self) -> f64 {
        if self.raw() {
            if self.batches.is_empty() {
                return 0.0;
            }
            self.batches
                .iter()
                .map(|b| b.cache_utilization)
                .sum::<f64>()
                / self.batches.len() as f64
        } else if self.summary.util_batches == 0 {
            0.0
        } else {
            self.summary.util_sum / self.summary.util_batches as f64
        }
    }

    /// Fraction of batches in which each view was cached (Figure 7).
    pub fn view_cache_fraction(&self, n_views: usize) -> Vec<f64> {
        let mut frac = vec![0.0; n_views];
        for b in &self.batches {
            for v in b.config.ones() {
                frac[v] += 1.0;
            }
        }
        let n = self.batches.len().max(1) as f64;
        frac.iter_mut().for_each(|f| *f /= n);
        frac
    }

    /// Mean per-query execution time by tenant.
    pub fn mean_exec_by_tenant(&self) -> Vec<f64> {
        let mut sums = vec![0.0; self.n_tenants];
        let mut counts = vec![0usize; self.n_tenants];
        for o in &self.outcomes {
            sums[o.tenant] += o.execution_time();
            counts[o.tenant] += 1;
        }
        sums.iter()
            .zip(&counts)
            .map(|(s, &c)| if c > 0 { s / c as f64 } else { 0.0 })
            .collect()
    }

    /// Mean query wait time (arrival → first task launch).
    pub fn mean_wait(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.outcomes.iter().map(|o| o.wait_time()).sum::<f64>()
            / self.outcomes.len() as f64
    }

    /// Execution time per query keyed by id (for speedup joins).
    pub fn exec_times_by_id(&self) -> std::collections::BTreeMap<QueryId, (usize, f64)> {
        self.outcomes
            .iter()
            .map(|o| (o.id, (o.tenant, o.execution_time())))
            .collect()
    }

    /// Percentile of per-batch solve latency in milliseconds (host).
    pub fn solve_ms_percentile(&self, p: f64) -> f64 {
        self.solve_ms_percentiles(&[p])[0]
    }

    /// Several solve-latency percentiles over one pass: exact
    /// (single-sort `percentiles_of`) when raw batch records were
    /// retained, streaming-histogram quantiles otherwise.
    pub fn solve_ms_percentiles(&self, ps: &[f64]) -> Vec<f64> {
        if self.raw() {
            let ms: Vec<f64> = self.batches.iter().map(|b| b.solve_secs * 1e3).collect();
            stats::percentiles_of(&ms, ps)
        } else {
            ps.iter().map(|&p| self.summary.solve_ms.quantile(p)).collect()
        }
    }

    /// Fraction of the run's host wall-clock the executor spent stalled
    /// on solves: ≈1 in serial mode (the solve IS the critical path),
    /// near 0 when the pipeline keeps the solver ahead of execution.
    pub fn stall_fraction(&self) -> f64 {
        if self.host_wall_secs <= 0.0 {
            return 0.0;
        }
        let stalled: f64 = if self.raw() {
            self.batches.iter().map(|b| b.stall_secs).sum()
        } else {
            self.summary.stall_secs_sum
        };
        (stalled / self.host_wall_secs).min(1.0)
    }

    /// Batches retired per host wall-clock second.
    pub fn batches_per_sec(&self) -> f64 {
        if self.host_wall_secs <= 0.0 {
            return 0.0;
        }
        self.n_batches() as f64 / self.host_wall_secs
    }

    /// Total (bytes loaded, bytes evicted) across all batch transitions
    /// — the Figure 12 churn measure.
    pub fn cache_bytes_moved(&self) -> (u64, u64) {
        if self.raw() {
            self.batches.iter().fold((0, 0), |(l, e), b| {
                (l + b.delta.bytes_loaded, e + b.delta.bytes_evicted)
            })
        } else {
            (self.summary.bytes_loaded, self.summary.bytes_evicted)
        }
    }
}

/// One solved batch handed from the planner to the executor.
#[derive(Debug)]
pub struct PlannedBatch {
    pub index: usize,
    pub window_end: f64,
    pub queries: Vec<Query>,
    pub config: TierAssignment,
    pub solve_secs: f64,
    /// Span phase breakdown (host seconds; observational only — see
    /// [`SolveOutcome`]). `solve_secs` stays the total the reports use;
    /// the phases partition it: drain + boost + alloc + sample.
    pub drain_secs: f64,
    pub boost_secs: f64,
    pub alloc_secs: f64,
    pub sample_secs: f64,
    /// `"cold"` / `"warm"` / `"none"` (empty batch).
    pub solve_kind: &'static str,
}

/// Steps 1–2 of the loop: drain the workload window, build the batch
/// problem (with the stateful boost from the cache-contents mirror),
/// solve, sample. Deterministic given the generator and policy seeds, so
/// serial and pipelined runs produce identical plans.
pub struct BatchPlanner<'a> {
    universe: &'a Universe,
    tenants: &'a TenantSet,
    cfg: &'a CoordinatorConfig,
    policy: &'a dyn Policy,
    generator: &'a mut WorkloadGenerator,
    /// The planner's tier spec (RAM budget + optional SSD plane); in
    /// single-tier mode the RAM budget is exactly the engine's cache
    /// budget and the tier plan below is `None`.
    spec: TierSpec,
    /// Cached view sizes, for reproducing the executor's
    /// demotion-before-drop SSD fill on the mirror (tiered mode only).
    sizes: Vec<u64>,
    rng: Pcg64,
    /// Mirror of the cache contents: after `CacheManager::update_tiered`
    /// the cache holds exactly the previous emitted configuration, so
    /// the planner tracks it locally instead of reading the live cache.
    mirror: TierAssignment,
    /// Carried warm-start state (`Some` iff `cfg.warm_start`). Owned by
    /// the planner, so the serial and pipelined drivers warm-start
    /// identically — the pipeline moves the whole planner onto its
    /// solver thread.
    warm: Option<WarmState>,
    next: usize,
}

impl BatchPlanner<'_> {
    /// Plan the next batch, or `None` when all batches are planned.
    pub fn next_batch(&mut self) -> Option<PlannedBatch> {
        if self.next >= self.cfg.n_batches {
            return None;
        }
        let b = self.next;
        self.next += 1;
        let window_end = (b + 1) as f64 * self.cfg.common.batch_secs;
        // Step 1: drain the batch window.
        let t_drain = Instant::now();
        let queries = self.generator.generate_until(window_end, self.universe);
        let drain_secs = t_drain.elapsed().as_secs_f64();

        // Step 2: view selection.
        let t0 = Instant::now();
        let ctx = SolveContext {
            tenants: self.tenants,
            universe: self.universe,
            budget: self.spec.budgets.ram,
            tier: tier_plan_of(&self.spec),
            stateful_gamma: self.cfg.common.stateful_gamma,
            weight_mult: None,
        };
        let outcome = ctx.solve_accounted_warm(
            &self.mirror,
            &queries,
            self.policy,
            &mut self.rng,
            self.warm.as_mut(),
        );
        let solve_secs = t0.elapsed().as_secs_f64();
        // Mirror the cache contents the executor will hold after this
        // batch's transition. The planner never reads the live cache, so
        // in tiered mode it reproduces the demotion-before-drop SSD fill
        // with the same deterministic rule the manager applies.
        self.mirror = if self.spec.is_single_tier() {
            outcome.config.clone()
        } else {
            TierAssignment {
                ssd: CacheManager::resolve_ssd_plane(
                    &self.mirror.ram,
                    &outcome.config,
                    &self.sizes,
                    self.spec.budgets.ssd,
                ),
                ram: outcome.config.ram.clone(),
            }
        };
        Some(PlannedBatch {
            index: b,
            window_end,
            queries,
            config: outcome.config,
            solve_secs,
            drain_secs,
            boost_secs: outcome.boost_secs,
            alloc_secs: outcome.alloc_secs,
            sample_secs: outcome.sample_secs,
            solve_kind: outcome.kind,
        })
    }
}

/// Steps 3–5 of the loop: apply the incremental cache transition and
/// execute the batch on the simulated cluster.
pub struct BatchExecutor<'a> {
    engine: &'a SimEngine,
    scan_sizes: Vec<u64>,
    weights: Vec<f64>,
    cache: CacheManager,
    /// Discrete-event clock driving the simulated batch-window axis
    /// (the sim-side counterpart of the service loop's real-time clock).
    clock: SimClock,
    outcomes: Vec<QueryOutcome>,
    batches: Vec<BatchRecord>,
    prev_end: f64,
    /// Streaming aggregates, maintained for every batch regardless of
    /// `retain_raw`.
    summary: ExecSummary,
    /// When false, per-batch/per-query raw records are dropped after
    /// folding into `summary` — flat-memory mode for long real-clock
    /// serves. Defaults to true (replay determinism tests compare raw
    /// vectors).
    retain_raw: bool,
    /// Host seconds of the most recent batch's cache transition and
    /// simulated execution — the span's last two phases.
    last_transition_secs: f64,
    last_execute_secs: f64,
}

impl<'e> BatchExecutor<'e> {
    /// Build an executor over `engine`'s cluster slice with an explicit
    /// tier spec. Single-node drivers derive it from the config (see
    /// [`Coordinator::executor`]); the elastic federation hands each
    /// shard its current slice and re-splits it on membership changes
    /// via [`BatchExecutor::cache_mut`].
    pub(crate) fn build(
        engine: &'e SimEngine,
        universe: &Universe,
        tenants: &TenantSet,
        spec: TierSpec,
    ) -> BatchExecutor<'e> {
        let sizes: Vec<u64> = universe.views.iter().map(|v| v.cached_bytes).collect();
        let scan_sizes: Vec<u64> = universe.views.iter().map(|v| v.scan_bytes).collect();
        let weights = tenants.weights();
        let summary = ExecSummary {
            per_tenant_completed: vec![0; weights.len()],
            ..ExecSummary::default()
        };
        BatchExecutor {
            engine,
            scan_sizes,
            weights,
            cache: CacheManager::new_tiered(spec, sizes),
            clock: SimClock::new(),
            outcomes: Vec::new(),
            batches: Vec::new(),
            prev_end: 0.0,
            summary,
            retain_raw: true,
            last_transition_secs: 0.0,
            last_execute_secs: 0.0,
        }
    }
}

impl BatchExecutor<'_> {
    /// Execute one planned batch. `queue_depth`/`stall_secs` are the
    /// pipeline-health observations recorded on the [`BatchRecord`].
    pub fn execute(&mut self, planned: PlannedBatch, queue_depth: usize, stall_secs: f64) {
        self.execute_reclaim(planned, queue_depth, stall_secs);
    }

    /// [`BatchExecutor::execute`], but hand the batch's (cleared) query
    /// buffer back to the caller so steady-state loops can refill it
    /// instead of allocating a fresh `Vec` every batch — the zero-alloc
    /// contract of the shard runtime (DESIGN.md §2g).
    pub(crate) fn execute_reclaim(
        &mut self,
        planned: PlannedBatch,
        queue_depth: usize,
        stall_secs: f64,
    ) -> Vec<Query> {
        let PlannedBatch {
            index,
            window_end,
            mut queries,
            config,
            solve_secs,
            ..
        } = planned;
        // Step 3: incremental cache transition (tier-aware: demotion
        // before drop; single-tier assignments take the legacy path).
        let t_trans = Instant::now();
        let delta = self.cache.update_tiered(&config);
        self.last_transition_secs = t_trans.elapsed().as_secs_f64();

        // Steps 4+5: execute on the simulated cluster, starting once
        // the batch window has closed and the previous batch finished.
        let t_exec = Instant::now();
        let now = self.clock.wait_until(window_end);
        let exec_start = now.max(self.prev_end);
        let exec = self.engine.execute_batch(
            exec_start,
            &queries,
            &self.scan_sizes,
            &mut self.cache,
            &self.weights,
        );
        self.last_execute_secs = t_exec.elapsed().as_secs_f64();
        self.prev_end = exec.end_time;

        // Streaming aggregates first, raw retention second — the
        // summary is maintained either way so flat-memory serves report
        // the same fields.
        let utilization = self.cache.utilization();
        self.summary.batches += 1;
        self.summary.util_batches += 1;
        self.summary.util_sum += utilization;
        self.summary.stall_secs_sum += stall_secs;
        self.summary.max_batch = self.summary.max_batch.max(queries.len());
        self.summary.completed += exec.outcomes.len() as u64;
        self.summary.bytes_loaded += delta.bytes_loaded;
        self.summary.bytes_evicted += delta.bytes_evicted;
        self.summary.bytes_ssd_loaded += delta.bytes_ssd_loaded;
        self.summary.bytes_demoted += delta.bytes_demoted;
        self.summary.bytes_promoted += delta.bytes_promoted;
        self.summary.solve_ms.record(solve_secs * 1e3);
        for o in &exec.outcomes {
            if o.from_cache {
                self.summary.hits += 1;
            }
            if o.tenant < self.summary.per_tenant_completed.len() {
                self.summary.per_tenant_completed[o.tenant] += 1;
            }
        }

        if self.retain_raw {
            let TierAssignment { ram, ssd } = config;
            self.batches.push(BatchRecord {
                index,
                n_queries: queries.len(),
                config: ram,
                ssd,
                cache_utilization: utilization,
                window_end,
                exec_start,
                exec_end: exec.end_time,
                solve_secs,
                queue_depth,
                stall_secs,
                delta,
            });
            self.outcomes.extend(exec.outcomes);
        }
        queries.clear();
        queries
    }

    /// Final cache transition accounting.
    pub fn cache(&self) -> &CacheManager {
        &self.cache
    }

    /// Mutable cache access for the federation's elastic budget
    /// re-splits (`CacheManager::set_budget` on membership changes).
    pub(crate) fn cache_mut(&mut self) -> &mut CacheManager {
        &mut self.cache
    }

    /// Flat-memory mode: stop retaining raw per-batch/per-query records
    /// (the streaming [`ExecSummary`] keeps the report fields meaningful).
    pub(crate) fn set_retain_raw(&mut self, retain: bool) {
        self.retain_raw = retain;
    }

    /// Host seconds of the most recent batch's (cache transition,
    /// simulated execution) — the last two span phases.
    pub(crate) fn last_phase_secs(&self) -> (f64, f64) {
        (self.last_transition_secs, self.last_execute_secs)
    }

    /// Assemble the run result.
    pub fn into_result(
        self,
        policy: &'static str,
        cfg: &CoordinatorConfig,
        n_tenants: usize,
        host_wall_secs: f64,
    ) -> RunResult {
        RunResult {
            policy,
            outcomes: self.outcomes,
            batches: self.batches,
            end_time: self.prev_end.max(cfg.n_batches as f64 * cfg.common.batch_secs),
            n_tenants,
            weights: self.weights,
            host_wall_secs,
            summary: self.summary,
        }
    }
}

/// The coordinator: owns the workload universe handle, cache, engine,
/// policy configuration; builds planner/executor pairs for the serial
/// and pipelined drivers.
pub struct Coordinator<'a> {
    pub universe: &'a Universe,
    pub tenants: TenantSet,
    pub engine: SimEngine,
    pub config: CoordinatorConfig,
}

impl<'a> Coordinator<'a> {
    pub fn new(
        universe: &'a Universe,
        tenants: TenantSet,
        engine: SimEngine,
        config: CoordinatorConfig,
    ) -> Self {
        Self {
            universe,
            tenants,
            engine,
            config,
        }
    }

    /// The run's tier spec: the configured hierarchy, or the engine's
    /// single RAM budget when tiers are off.
    pub(crate) fn tier_spec(&self) -> TierSpec {
        self.config
            .common
            .tiers
            .unwrap_or_else(|| TierSpec::single(self.engine.config.cache_budget))
    }

    /// The solve half of the loop (shared by serial and pipelined runs).
    pub(crate) fn planner<'c>(
        &'c self,
        generator: &'c mut WorkloadGenerator,
        policy: &'c dyn Policy,
    ) -> BatchPlanner<'c> {
        let n_views = self.universe.views.len();
        BatchPlanner {
            universe: self.universe,
            tenants: &self.tenants,
            cfg: &self.config,
            policy,
            generator,
            spec: self.tier_spec(),
            sizes: self.universe.views.iter().map(|v| v.cached_bytes).collect(),
            rng: Pcg64::with_stream(self.config.common.seed, 0x0b5),
            mirror: TierAssignment::single(ConfigMask::empty(n_views)),
            warm: self.config.common.warm_start.then(WarmState::new),
            next: 0,
        }
    }

    /// The execute half of the loop (shared by serial and pipelined
    /// runs).
    pub(crate) fn executor(&self) -> BatchExecutor<'_> {
        BatchExecutor::build(&self.engine, self.universe, &self.tenants, self.tier_spec())
    }

    /// Run the full loop with `policy` over a fresh workload from
    /// `generator`, strictly serially (the reference semantics: each
    /// solve sits on the critical path). The generator seed fixes
    /// arrivals; `config.seed` fixes policy randomization — so two
    /// policies can be compared on identical workloads.
    #[deprecated(
        since = "0.2.0",
        note = "construct through `session::Session::replay(..).run(..)`"
    )]
    pub fn run(&self, generator: &mut WorkloadGenerator, policy: &dyn Policy) -> RunResult {
        self.run_impl(generator, policy, &Telemetry::off())
    }

    /// [`Coordinator::run`] with telemetry: one span per batch, a tick
    /// per batch window on the simulated clock. Telemetry is a pure
    /// observer — `run` and `run_with` are bit-identical in every
    /// simulated quantity.
    #[deprecated(
        since = "0.2.0",
        note = "construct through `session::Session::replay(..).telemetry(..).run(..)`"
    )]
    pub fn run_with(
        &self,
        generator: &mut WorkloadGenerator,
        policy: &dyn Policy,
        tel: &Telemetry,
    ) -> RunResult {
        self.run_impl(generator, policy, tel)
    }

    /// The serial driver behind [`Coordinator::run`]/[`run_with`] and
    /// the Session API.
    pub(crate) fn run_impl(
        &self,
        generator: &mut WorkloadGenerator,
        policy: &dyn Policy,
        tel: &Telemetry,
    ) -> RunResult {
        let t_run = Instant::now();
        let mut planner = self.planner(generator, policy);
        let mut executor = self.executor();
        while let Some(planned) = planner.next_batch() {
            // Serial mode: the executor waits out the whole solve.
            let stall = planned.solve_secs;
            let span = SpanRecord {
                t: planned.window_end,
                batch: planned.index,
                shard: -1,
                slot: -1,
                n_queries: planned.queries.len(),
                drain_ms: planned.drain_secs * 1e3,
                boost_ms: planned.boost_secs * 1e3,
                solve_ms: planned.alloc_secs * 1e3,
                sample_ms: planned.sample_secs * 1e3,
                transition_ms: 0.0,
                execute_ms: 0.0,
                solve_kind: planned.solve_kind,
            };
            executor.execute(planned, 0, stall);
            let (transition, exec) = executor.last_phase_secs();
            tel.span(&SpanRecord {
                transition_ms: transition * 1e3,
                execute_ms: exec * 1e3,
                ..span
            });
            tel.tick(span.t);
        }
        executor.into_result(
            policy.name(),
            &self.config,
            self.tenants.len(),
            t_run.elapsed().as_secs_f64(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::PolicyKind;
    use crate::sim::cluster::ClusterConfig;
    use crate::workload::spec::{AccessSpec, TenantSpec};

    fn small_run(kind: PolicyKind, n_batches: usize, seed: u64) -> RunResult {
        let universe = Universe::sales_only();
        let tenants = TenantSet::equal(2);
        let engine = SimEngine::new(ClusterConfig::default());
        let config = CoordinatorConfig {
            common: CommonConfig {
                seed,
                ..CommonConfig::default()
            },
            n_batches,
        };
        let coord = Coordinator::new(&universe, tenants, engine, config);
        // Windowed access (as in the §5.3 experiments) so the working
        // sets exceed the STATIC partitions and contention is real.
        let window = crate::workload::spec::WindowSpec {
            mean_secs: 120.0,
            std_secs: 30.0,
            candidates: 8,
        };
        let specs = vec![
            TenantSpec::new(AccessSpec::g(1), 10.0).with_window(window.clone()),
            TenantSpec::new(AccessSpec::g(2), 10.0).with_window(window),
        ];
        let mut gen = WorkloadGenerator::new(specs, &universe, seed);
        let policy = kind.build();
        coord.run_impl(&mut gen, policy.as_ref(), &Telemetry::off())
    }

    #[test]
    fn loop_runs_and_counts_queries() {
        let r = small_run(PolicyKind::FastPf, 5, 42);
        assert_eq!(r.batches.len(), 5);
        let total: usize = r.batches.iter().map(|b| b.n_queries).sum();
        assert_eq!(total, r.outcomes.len());
        assert!(total > 10, "expected ~40 queries, got {total}");
        assert!(r.throughput_per_min() > 0.0);
        assert!(r.end_time >= 200.0);
        assert!(r.host_wall_secs > 0.0);
        assert!(r.batches_per_sec() > 0.0);
    }

    #[test]
    fn shared_policies_beat_static_on_cache_use() {
        // At this small scale (2 tenants, 8 batches) hit ratios are
        // noisy; cache utilization is the robust separator — STATIC's
        // partitions strand budget whenever a tenant's preferred views
        // exceed its share. (The 30-batch 4-tenant experiments assert
        // the full Figure 6 ordering; see experiments::runner tests.)
        let s = small_run(PolicyKind::Static, 8, 42);
        let f = small_run(PolicyKind::FastPf, 8, 42);
        assert!(
            f.avg_cache_utilization() > s.avg_cache_utilization(),
            "FASTPF util {} vs STATIC {}",
            f.avg_cache_utilization(),
            s.avg_cache_utilization()
        );
        assert!(f.hit_ratio() > s.hit_ratio() - 0.1);
    }

    #[test]
    fn same_seed_same_workload_across_policies() {
        let a = small_run(PolicyKind::Static, 4, 9);
        let b = small_run(PolicyKind::Optp, 4, 9);
        // Identical arrivals: same query ids and counts.
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        let ids_a: Vec<_> = a.outcomes.iter().map(|o| o.id).collect();
        let ids_b: Vec<_> = b.outcomes.iter().map(|o| o.id).collect();
        assert_eq!(ids_a, ids_b);
    }

    #[test]
    fn stateful_mode_keeps_views_longer() {
        let universe = Universe::sales_only();
        let tenants = TenantSet::equal(2);
        let engine = SimEngine::new(ClusterConfig::default());
        let specs = || {
            vec![
                TenantSpec::new(AccessSpec::g(1), 8.0),
                TenantSpec::new(AccessSpec::g(1), 8.0),
            ]
        };
        let run = |gamma: Option<f64>| {
            let config = CoordinatorConfig {
                common: CommonConfig {
                    batch_secs: 20.0,
                    stateful_gamma: gamma,
                    seed: 5,
                    ..CommonConfig::default()
                },
                n_batches: 12,
            };
            let coord = Coordinator::new(&universe, tenants.clone(), engine.clone(), config);
            let mut gen = WorkloadGenerator::new(specs(), &universe, 5);
            let policy = PolicyKind::FastPf.build();
            coord.run_impl(&mut gen, policy.as_ref(), &Telemetry::off())
        };
        let stateless = run(None);
        let stateful = run(Some(2.0));
        // Count config changes across consecutive batches.
        let churn = |r: &RunResult| -> usize {
            r.batches
                .windows(2)
                .map(|w| w[0].config.diff_count(&w[1].config))
                .sum()
        };
        assert!(
            churn(&stateful) <= churn(&stateless),
            "stateful churn {} > stateless churn {}",
            churn(&stateful),
            churn(&stateless)
        );
        // The per-batch deltas record the same churn view-by-view.
        let delta_churn = |r: &RunResult| -> usize {
            r.batches.iter().skip(1).map(|b| b.delta.churn()).sum()
        };
        assert_eq!(churn(&stateless), delta_churn(&stateless));
        assert_eq!(churn(&stateful), delta_churn(&stateful));
    }

    #[test]
    fn view_cache_fraction_sums() {
        let r = small_run(PolicyKind::FastPf, 6, 3);
        let frac = r.view_cache_fraction(30);
        assert_eq!(frac.len(), 30);
        assert!(frac.iter().all(|&f| (0.0..=1.0).contains(&f)));
        assert!(frac.iter().sum::<f64>() > 0.0);
    }

    #[test]
    fn solve_time_recorded() {
        let r = small_run(PolicyKind::Mmf, 3, 11);
        assert!(r.batches.iter().any(|b| b.solve_secs > 0.0));
        // §5.4: solves should be tens of milliseconds, not seconds.
        for b in &r.batches {
            assert!(b.solve_secs < 5.0, "solve took {}s", b.solve_secs);
            // Serial mode: the executor stalls for the whole solve.
            assert_eq!(b.stall_secs, b.solve_secs);
            assert_eq!(b.queue_depth, 0);
        }
    }

    #[test]
    fn warm_start_run_matches_cold_quality() {
        let universe = Universe::sales_only();
        let engine = SimEngine::new(ClusterConfig::default());
        let window = crate::workload::spec::WindowSpec {
            mean_secs: 120.0,
            std_secs: 30.0,
            candidates: 8,
        };
        let specs = || {
            vec![
                TenantSpec::new(AccessSpec::g(1), 10.0).with_window(window.clone()),
                TenantSpec::new(AccessSpec::g(2), 10.0).with_window(window.clone()),
            ]
        };
        let run = |warm_start: bool| {
            let config = CoordinatorConfig {
                common: CommonConfig {
                    seed: 42,
                    warm_start,
                    ..CommonConfig::default()
                },
                n_batches: 8,
            };
            let coord =
                Coordinator::new(&universe, TenantSet::equal(2), engine.clone(), config);
            let mut gen = WorkloadGenerator::new(specs(), &universe, 42);
            let policy = PolicyKind::FastPf.build();
            coord.run_impl(&mut gen, policy.as_ref(), &Telemetry::off())
        };
        let cold = run(false);
        let warm = run(true);
        assert_eq!(cold.batches.len(), warm.batches.len());
        // Warm-started solves must land in the same quality neighbourhood
        // (equivalence is quality-within-ε, not bit-identity).
        assert!(
            (cold.hit_ratio() - warm.hit_ratio()).abs() < 0.15,
            "cold hit {} vs warm hit {}",
            cold.hit_ratio(),
            warm.hit_ratio()
        );
        assert!(
            (cold.avg_cache_utilization() - warm.avg_cache_utilization()).abs() < 0.15,
            "cold util {} vs warm util {}",
            cold.avg_cache_utilization(),
            warm.avg_cache_utilization()
        );
    }

    #[test]
    fn deltas_track_first_batch_loads() {
        let r = small_run(PolicyKind::FastPf, 4, 42);
        let first = &r.batches[0];
        // Everything cached in batch 0 was loaded by batch 0.
        assert_eq!(first.delta.loaded.len(), first.config.count_ones());
        assert!(first.delta.evicted.is_empty());
        let (loaded, evicted) = r.cache_bytes_moved();
        assert!(loaded >= first.delta.bytes_loaded);
        assert!(loaded >= evicted, "cannot evict more than was loaded");
    }
}
