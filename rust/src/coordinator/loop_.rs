//! The five-step ROBUS loop (Figure 2):
//! 1. remove a time batch of queries from the tenant queues;
//! 2. run the view-selection algorithm over the batch (candidate views +
//!    utility model + cache budget → randomized allocation → sample);
//! 3. update the cache with the selected configuration;
//! 4. rewrite queries to use cached views (implicit here: the simulator
//!    reads a view from memory whenever it is cached);
//! 5. execute the batch on the (simulated) cluster.
//!
//! Batch b collects arrivals in [b·W, (b+1)·W); its execution starts at
//! max((b+1)·W, previous batch's completion) — a policy that cannot keep
//! up accumulates backlog and shows reduced throughput, exactly the
//! paper's throughput mechanics.

use crate::alloc::{ConfigMask, Policy};
use crate::cache::CacheManager;
use crate::domain::query::QueryId;
use crate::domain::tenant::TenantSet;
use crate::domain::utility::BatchUtilities;
use crate::sim::engine::{QueryOutcome, SimEngine};
use crate::util::rng::Pcg64;
use crate::workload::generator::WorkloadGenerator;
use crate::workload::universe::Universe;

/// Coordinator configuration (the §5.3 experiment knobs).
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Batch interval W in (simulated) seconds.
    pub batch_secs: f64,
    /// Number of batches to run.
    pub n_batches: usize,
    /// Stateful cache mode (§5.4): boost factor γ for cached views;
    /// `None` = stateless (the paper's default).
    pub stateful_gamma: Option<f64>,
    /// Seed for policy randomization (allocation sampling etc.).
    pub seed: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            batch_secs: 40.0,
            n_batches: 30,
            stateful_gamma: None,
            seed: 7,
        }
    }
}

/// Per-batch record for reporting and the Figure 7/11/12 series.
#[derive(Debug, Clone)]
pub struct BatchRecord {
    pub index: usize,
    /// Queries in the batch.
    pub n_queries: usize,
    /// The sampled configuration (view mask).
    pub config: ConfigMask,
    /// Cache utilization after the update.
    pub cache_utilization: f64,
    /// Wall-clock (simulated) times: batch window end / execution span.
    pub window_end: f64,
    pub exec_start: f64,
    pub exec_end: f64,
    /// Wall-clock (host) seconds spent in the view-selection solve — the
    /// §5.4 "query wait times of the order of tens of milliseconds".
    pub solve_secs: f64,
}

/// Complete result of a coordinator run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub policy: &'static str,
    pub outcomes: Vec<QueryOutcome>,
    pub batches: Vec<BatchRecord>,
    /// Simulated time at which all batches completed.
    pub end_time: f64,
    pub n_tenants: usize,
    pub weights: Vec<f64>,
}

impl RunResult {
    /// Queries per minute of simulated time (Equation 4).
    pub fn throughput_per_min(&self) -> f64 {
        if self.end_time <= 0.0 {
            return 0.0;
        }
        self.outcomes.len() as f64 / (self.end_time / 60.0)
    }

    /// Fraction of queries served entirely off cached views.
    pub fn hit_ratio(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.outcomes.iter().filter(|o| o.from_cache).count() as f64
            / self.outcomes.len() as f64
    }

    /// Mean cache utilization across batches.
    pub fn avg_cache_utilization(&self) -> f64 {
        if self.batches.is_empty() {
            return 0.0;
        }
        self.batches
            .iter()
            .map(|b| b.cache_utilization)
            .sum::<f64>()
            / self.batches.len() as f64
    }

    /// Fraction of batches in which each view was cached (Figure 7).
    pub fn view_cache_fraction(&self, n_views: usize) -> Vec<f64> {
        let mut frac = vec![0.0; n_views];
        for b in &self.batches {
            for v in b.config.ones() {
                frac[v] += 1.0;
            }
        }
        let n = self.batches.len().max(1) as f64;
        frac.iter_mut().for_each(|f| *f /= n);
        frac
    }

    /// Mean per-query execution time by tenant.
    pub fn mean_exec_by_tenant(&self) -> Vec<f64> {
        let mut sums = vec![0.0; self.n_tenants];
        let mut counts = vec![0usize; self.n_tenants];
        for o in &self.outcomes {
            sums[o.tenant] += o.execution_time();
            counts[o.tenant] += 1;
        }
        sums.iter()
            .zip(&counts)
            .map(|(s, &c)| if c > 0 { s / c as f64 } else { 0.0 })
            .collect()
    }

    /// Mean query wait time (arrival → first task launch).
    pub fn mean_wait(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.outcomes.iter().map(|o| o.wait_time()).sum::<f64>()
            / self.outcomes.len() as f64
    }

    /// Execution time per query keyed by id (for speedup joins).
    pub fn exec_times_by_id(&self) -> std::collections::BTreeMap<QueryId, (usize, f64)> {
        self.outcomes
            .iter()
            .map(|o| (o.id, (o.tenant, o.execution_time())))
            .collect()
    }
}

/// The coordinator: owns the workload generator, cache, engine, policy.
pub struct Coordinator<'a> {
    pub universe: &'a Universe,
    pub tenants: TenantSet,
    pub engine: SimEngine,
    pub config: CoordinatorConfig,
}

impl<'a> Coordinator<'a> {
    pub fn new(
        universe: &'a Universe,
        tenants: TenantSet,
        engine: SimEngine,
        config: CoordinatorConfig,
    ) -> Self {
        Self {
            universe,
            tenants,
            engine,
            config,
        }
    }

    /// Run the full loop with `policy` over a fresh workload from
    /// `generator`. The generator seed fixes arrivals; `config.seed`
    /// fixes policy randomization — so two policies can be compared on
    /// identical workloads.
    pub fn run(&self, generator: &mut WorkloadGenerator, policy: &dyn Policy) -> RunResult {
        let mut rng = Pcg64::with_stream(self.config.seed, 0x0b5);
        let budget = self.engine.config.cache_budget;
        let sizes: Vec<u64> = self
            .universe
            .views
            .iter()
            .map(|v| v.cached_bytes)
            .collect();
        let scan_sizes: Vec<u64> = self
            .universe
            .views
            .iter()
            .map(|v| v.scan_bytes)
            .collect();
        let mut cache = CacheManager::new(budget, sizes);
        let weights = self.tenants.weights();

        let mut outcomes = Vec::new();
        let mut batches = Vec::new();
        let mut prev_end = 0.0f64;

        for b in 0..self.config.n_batches {
            let window_end = (b + 1) as f64 * self.config.batch_secs;
            // Step 1: drain the batch.
            let queries = generator.generate_until(window_end, self.universe);

            // Step 2: view selection.
            let t0 = std::time::Instant::now();
            let config_mask = if queries.is_empty() {
                cache.cached().clone()
            } else {
                let boost = self
                    .config
                    .stateful_gamma
                    .map(|g| cache.boost_vector(g));
                let batch_problem = BatchUtilities::build(
                    &self.tenants,
                    &self.universe.views,
                    budget as f64,
                    &queries,
                    boost.as_deref(),
                );
                let allocation = policy.allocate(&batch_problem, &mut rng);
                allocation.sample(&mut rng).clone()
            };
            let solve_secs = t0.elapsed().as_secs_f64();

            // Step 3: cache update.
            cache.update(&config_mask);

            // Steps 4+5: execute on the simulated cluster.
            let exec_start = window_end.max(prev_end);
            let exec = self.engine.execute_batch(
                exec_start,
                &queries,
                &scan_sizes,
                &mut cache,
                &weights,
            );
            prev_end = exec.end_time;

            batches.push(BatchRecord {
                index: b,
                n_queries: queries.len(),
                config: config_mask,
                cache_utilization: cache.utilization(),
                window_end,
                exec_start,
                exec_end: exec.end_time,
                solve_secs,
            });
            outcomes.extend(exec.outcomes);
        }

        RunResult {
            policy: policy.name(),
            outcomes,
            batches,
            end_time: prev_end.max(self.config.n_batches as f64 * self.config.batch_secs),
            n_tenants: self.tenants.len(),
            weights,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::PolicyKind;
    use crate::sim::cluster::ClusterConfig;
    use crate::workload::spec::{AccessSpec, TenantSpec};

    fn small_run(kind: PolicyKind, n_batches: usize, seed: u64) -> RunResult {
        let universe = Universe::sales_only();
        let tenants = TenantSet::equal(2);
        let engine = SimEngine::new(ClusterConfig::default());
        let config = CoordinatorConfig {
            batch_secs: 40.0,
            n_batches,
            stateful_gamma: None,
            seed,
        };
        let coord = Coordinator::new(&universe, tenants, engine, config);
        // Windowed access (as in the §5.3 experiments) so the working
        // sets exceed the STATIC partitions and contention is real.
        let window = crate::workload::spec::WindowSpec {
            mean_secs: 120.0,
            std_secs: 30.0,
            candidates: 8,
        };
        let specs = vec![
            TenantSpec::new(AccessSpec::g(1), 10.0).with_window(window.clone()),
            TenantSpec::new(AccessSpec::g(2), 10.0).with_window(window),
        ];
        let mut gen = WorkloadGenerator::new(specs, &universe, seed);
        let policy = kind.build();
        coord.run(&mut gen, policy.as_ref())
    }

    #[test]
    fn loop_runs_and_counts_queries() {
        let r = small_run(PolicyKind::FastPf, 5, 42);
        assert_eq!(r.batches.len(), 5);
        let total: usize = r.batches.iter().map(|b| b.n_queries).sum();
        assert_eq!(total, r.outcomes.len());
        assert!(total > 10, "expected ~40 queries, got {total}");
        assert!(r.throughput_per_min() > 0.0);
        assert!(r.end_time >= 200.0);
    }

    #[test]
    fn shared_policies_beat_static_on_cache_use() {
        // At this small scale (2 tenants, 8 batches) hit ratios are
        // noisy; cache utilization is the robust separator — STATIC's
        // partitions strand budget whenever a tenant's preferred views
        // exceed its share. (The 30-batch 4-tenant experiments assert
        // the full Figure 6 ordering; see experiments::runner tests.)
        let s = small_run(PolicyKind::Static, 8, 42);
        let f = small_run(PolicyKind::FastPf, 8, 42);
        assert!(
            f.avg_cache_utilization() > s.avg_cache_utilization(),
            "FASTPF util {} vs STATIC {}",
            f.avg_cache_utilization(),
            s.avg_cache_utilization()
        );
        assert!(f.hit_ratio() > s.hit_ratio() - 0.1);
    }

    #[test]
    fn same_seed_same_workload_across_policies() {
        let a = small_run(PolicyKind::Static, 4, 9);
        let b = small_run(PolicyKind::Optp, 4, 9);
        // Identical arrivals: same query ids and counts.
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        let ids_a: Vec<_> = a.outcomes.iter().map(|o| o.id).collect();
        let ids_b: Vec<_> = b.outcomes.iter().map(|o| o.id).collect();
        assert_eq!(ids_a, ids_b);
    }

    #[test]
    fn stateful_mode_keeps_views_longer() {
        let universe = Universe::sales_only();
        let tenants = TenantSet::equal(2);
        let engine = SimEngine::new(ClusterConfig::default());
        let specs = || {
            vec![
                TenantSpec::new(AccessSpec::g(1), 8.0),
                TenantSpec::new(AccessSpec::g(1), 8.0),
            ]
        };
        let run = |gamma: Option<f64>| {
            let config = CoordinatorConfig {
                batch_secs: 20.0,
                n_batches: 12,
                stateful_gamma: gamma,
                seed: 5,
            };
            let coord = Coordinator::new(&universe, tenants.clone(), engine.clone(), config);
            let mut gen = WorkloadGenerator::new(specs(), &universe, 5);
            let policy = PolicyKind::FastPf.build();
            coord.run(&mut gen, policy.as_ref())
        };
        let stateless = run(None);
        let stateful = run(Some(2.0));
        // Count config changes across consecutive batches.
        let churn = |r: &RunResult| -> usize {
            r.batches
                .windows(2)
                .map(|w| w[0].config.diff_count(&w[1].config))
                .sum()
        };
        assert!(
            churn(&stateful) <= churn(&stateless),
            "stateful churn {} > stateless churn {}",
            churn(&stateful),
            churn(&stateless)
        );
    }

    #[test]
    fn view_cache_fraction_sums() {
        let r = small_run(PolicyKind::FastPf, 6, 3);
        let frac = r.view_cache_fraction(30);
        assert_eq!(frac.len(), 30);
        assert!(frac.iter().all(|&f| (0.0..=1.0).contains(&f)));
        assert!(frac.iter().sum::<f64>() > 0.0);
    }

    #[test]
    fn solve_time_recorded() {
        let r = small_run(PolicyKind::Mmf, 3, 11);
        assert!(r.batches.iter().any(|b| b.solve_secs > 0.0));
        // §5.4: solves should be tens of milliseconds, not seconds.
        for b in &r.batches {
            assert!(b.solve_secs < 5.0, "solve took {}s", b.solve_secs);
        }
    }
}
