//! The ROBUS coordinator (Figure 2): the five-step batched loop plus the
//! performance metrics of §5.2.

pub mod loop_;
pub mod metrics;

pub use loop_::{Coordinator, CoordinatorConfig, RunResult};
pub use metrics::{fairness_index, per_tenant_speedups, MetricsSummary};
