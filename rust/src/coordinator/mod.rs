//! The ROBUS coordinator (Figure 2): the five-step batched loop (serial
//! reference + pipelined solve/execute), the real-time service driver
//! behind `robus serve`, and the performance metrics of §5.2.

pub mod loop_;
pub mod metrics;
pub mod pipeline;
pub mod service;

pub use loop_::{BatchRecord, Coordinator, CoordinatorConfig, RunResult};
pub use metrics::{fairness_index, per_tenant_speedups, MetricsSummary};
pub use pipeline::DEFAULT_PIPELINE_DEPTH;
pub use service::{AdmissionPolicy, ServeConfig, ServeReport};
