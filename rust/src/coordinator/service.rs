//! The real-time coordinator service behind `robus serve`: the same
//! five-step loop as `coordinator::loop_`, driven by a
//! [`RealTimeClock`] over **live traffic** instead of a trace replay.
//!
//! Per-tenant generator threads produce Poisson arrivals in real time
//! and push them into bounded [`AdmissionQueue`]s (shed or backpressure
//! at the bound, per [`AdmissionPolicy`]); the service loop cuts a batch
//! every `batch_secs` of wall-clock time, solves the allocation, applies
//! the incremental cache transition, and executes the batch on the
//! simulated cluster. Execution is simulated (free in host time), so the
//! host-side critical path is exactly what the paper's §5.4 claim is
//! about: admission plus the per-batch solve.
//!
//! The loop itself is written against the [`Clock`] trait: [`serve`]
//! paces it with the real-time driver and producer threads, while
//! [`serve_sim`] drives the *same* loop deterministically on a
//! [`SimClock`] with inline arrival generation — the reference the
//! federated serving layer's `--shards 1` equivalence is pinned
//! against (`cluster::serving`, `rust/tests/federated_serving.rs`).

use std::time::Instant;

use crate::alloc::{ConfigMask, Policy, WarmState};
use crate::cache::tier::{TierAssignment, TierSpec};
use crate::coordinator::loop_::{
    tier_plan_of, BatchExecutor, CommonConfig, Coordinator, CoordinatorConfig, PlannedBatch,
    RunResult, SolveContext,
};
use crate::domain::query::Query;
use crate::domain::tenant::{TenantId, TenantSet};
use crate::sim::engine::SimEngine;
use crate::telemetry::{SpanRecord, Telemetry};
use crate::util::event::{Clock, RealTimeClock, SimClock};
use crate::util::ordf64::OrdF64;
use crate::util::rng::{mix64, Pcg64};
use crate::util::stats;
use crate::workload::generator::TenantGenerator;
pub use crate::workload::queue::AdmissionPolicy;
use crate::workload::queue::AdmissionQueue;
use crate::workload::spec::{AccessSpec, TenantSpec, WindowSpec};
use crate::workload::universe::Universe;

/// Knobs of one `robus serve` run.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Knobs shared with every other driver (batch window, γ, seed,
    /// warm start, tier budgets). Serve defaults differ from replay:
    /// W = 0.25 s real-time windows, warm start ON (serving is the
    /// steady-state regime the warm path targets, and its equivalence
    /// contract is quality-within-ε, not bit-replay).
    pub common: CommonConfig,
    /// How long to accept traffic (wall-clock seconds).
    pub duration_secs: f64,
    /// Aggregate target arrival rate across all tenants (queries/sec).
    pub rate_per_sec: f64,
    pub n_tenants: usize,
    /// Per-tenant queue bound (the admission cap).
    pub queue_capacity: usize,
    pub admission: AdmissionPolicy,
    /// Print a live metrics line roughly once per second.
    pub verbose: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            common: CommonConfig {
                batch_secs: 0.25,
                seed: 42,
                warm_start: true,
                ..CommonConfig::default()
            },
            duration_secs: 5.0,
            rate_per_sec: 1000.0,
            n_tenants: 4,
            queue_capacity: 8192,
            admission: AdmissionPolicy::Drop,
            verbose: false,
        }
    }
}

impl ServeConfig {
    /// The workload spec of tenant `i`: g₁–g₄ Sales access round-robin
    /// with the §5.3 hot/cold window, paced so the tenants jointly hit
    /// `rate_per_sec`.
    pub fn tenant_spec(&self, tenant: usize) -> TenantSpec {
        let mean_interarrival = self.n_tenants as f64 / self.rate_per_sec;
        TenantSpec::new(AccessSpec::g(1 + tenant % 4), mean_interarrival).with_window(
            WindowSpec {
                mean_secs: 120.0,
                std_secs: 30.0,
                candidates: 8,
            },
        )
    }

    /// Generator seed of tenant `i`, derived *explicitly* from `--seed`
    /// (splitmix of seed and tenant index) so every piece of serve-mode
    /// randomness — arrivals, dataset choices, windows — is reproducible
    /// from the single CLI seed. Two runs with the same seed produce the
    /// same per-tenant arrival sequences; only the wall-clock batch
    /// boundaries differ.
    pub fn tenant_seed(&self, tenant: usize) -> u64 {
        mix64(self.common.seed ^ mix64(tenant as u64))
    }

    /// The per-tenant producer generator used by [`serve`] — exposed so
    /// tests (and replay tooling) can reproduce exactly what the online
    /// service generates for a given `--seed`.
    pub fn tenant_generator(&self, tenant: usize, universe: &Universe) -> TenantGenerator {
        TenantGenerator::new(
            TenantId(tenant),
            self.tenant_spec(tenant),
            universe,
            self.tenant_seed(tenant),
        )
    }
}

/// Summary of one serve run (host-side service metrics plus the
/// simulated cache-effectiveness metrics).
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Wall-clock seconds from start to the last batch retired.
    pub elapsed_secs: f64,
    pub batches: usize,
    pub admitted: u64,
    pub rejected: u64,
    pub completed: u64,
    /// Completed queries per wall-clock second of the active serving
    /// window (up to the last non-empty batch) — the headline
    /// service-throughput number.
    pub queries_per_sec: f64,
    /// Per-batch view-selection solve latency (host milliseconds).
    pub solve_ms_p50: f64,
    pub solve_ms_p99: f64,
    /// Mean wall-clock milliseconds an admitted query waited between
    /// arrival and its batch being cut (the admission wait).
    pub mean_admit_wait_ms: f64,
    /// Largest batch cut and highest per-tenant queue high-water mark.
    pub max_batch: usize,
    pub peak_queue_depth: usize,
    /// Simulated cache effectiveness over the served traffic.
    pub hit_ratio: f64,
    pub avg_cache_utilization: f64,
    pub per_tenant_completed: Vec<u64>,
    /// Jain's index over weight-normalized per-tenant completion counts.
    pub throughput_fairness: f64,
}

impl ServeReport {
    /// Human-readable multi-line summary for the CLI.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "served {} queries in {:.2}s ({:.0} q/s); {} rejected at admission\n",
            self.completed, self.elapsed_secs, self.queries_per_sec, self.rejected
        ));
        out.push_str(&format!(
            "batches: {} (max {} queries, peak queue depth {})\n",
            self.batches, self.max_batch, self.peak_queue_depth
        ));
        out.push_str(&format!(
            "solve latency: p50 {:.1} ms, p99 {:.1} ms; mean admission wait {:.0} ms\n",
            self.solve_ms_p50, self.solve_ms_p99, self.mean_admit_wait_ms
        ));
        out.push_str(&format!(
            "cache: hit ratio {:.2}, avg utilization {:.2}\n",
            self.hit_ratio, self.avg_cache_utilization
        ));
        out.push_str(&format!(
            "per-tenant completed: {:?} (throughput fairness {:.3})\n",
            self.per_tenant_completed, self.throughput_fairness
        ));
        out
    }
}

/// Accounting the service loop accumulates alongside the executor's
/// own run records.
#[derive(Debug, Default, Clone, Copy)]
pub(crate) struct ServeLoopStats {
    /// Σ over cut queries of (cut time − arrival).
    pub admit_wait_sum: f64,
    /// Clock time at which the last non-empty batch was cut — the
    /// active serving window the throughput figure is measured over
    /// (excludes the shutdown drain tail).
    pub served_until: f64,
}

/// The single-executor service loop shared by both drivers: cut →
/// solve → transition → execute every `batch_secs` on `clock`'s axis
/// until `pump` reports production closed and a cut comes up empty.
///
/// `pump(clock, now)` advances the arrival side up to `now` and returns
/// whether production has ended: the real-time driver's producers run
/// on their own threads, so its pump only checks for closed queues; the
/// deterministic sim driver generates and offers arrivals inline.
#[allow(clippy::too_many_arguments)]
fn service_loop<C: Clock>(
    clock: &mut C,
    queues: &[AdmissionQueue],
    executor: &mut BatchExecutor<'_>,
    solve_ctx: &SolveContext<'_>,
    policy: &dyn Policy,
    rng: &mut Pcg64,
    cfg: &ServeConfig,
    tel: &Telemetry,
    mut pump: impl FnMut(&mut C, f64) -> bool,
) -> ServeLoopStats {
    let mut stats = ServeLoopStats::default();
    let mut batch_idx = 0usize;
    let mut last_report = 0u64;
    let mut completed_live = 0u64;
    // Carried solver state (`--warm-start`, on by default for serve).
    let mut warm = cfg.common.warm_start.then(WarmState::new);
    // Mirror of the executor's tiered cache contents: after each
    // `update_tiered` the cache holds exactly the emitted assignment,
    // so the loop tracks both planes locally (the live cache only
    // exposes the RAM mask).
    let mut mirror =
        TierAssignment::single(ConfigMask::empty(solve_ctx.universe.views.len()));
    // Batch-cut buffer, recycled through the executor's buffer reclaim
    // so the steady-state loop allocates nothing per cut.
    let mut queries: Vec<Query> = Vec::new();
    loop {
        let window_end = (batch_idx + 1) as f64 * cfg.common.batch_secs;
        let now = clock.wait_until(window_end);
        let all_closed = pump(clock, now);

        // Step 1: cut the batch across all tenant queues.
        let t_drain = Instant::now();
        for q in queues {
            q.drain_into(&mut queries);
        }
        queries.sort_by_key(|q| OrdF64(q.arrival));
        for q in &queries {
            let wait = (now - q.arrival).max(0.0);
            stats.admit_wait_sum += wait;
            tel.admit_wait(wait * 1e3);
        }
        let n_cut = queries.len();
        let drain_secs = t_drain.elapsed().as_secs_f64();

        // Step 2: the shared solve (host critical path), boosted
        // from the mirror of the executor's live cache contents.
        let t0 = Instant::now();
        let solved =
            solve_ctx.solve_accounted_warm(&mirror, &queries, policy, rng, warm.as_mut());
        let solve_secs = t0.elapsed().as_secs_f64();

        // Steps 3–5: the loop's executor (incremental cache
        // transition + simulated execution; free in host time).
        // `queue_depth` records arrivals already waiting for the
        // *next* cut; in serve mode the solve is the stall.
        let backlog: usize = queues.iter().map(|q| q.len()).sum();
        tel.metrics().queue_depth.set(backlog as u64);
        queries = executor.execute_reclaim(
            PlannedBatch {
                index: batch_idx,
                window_end,
                queries,
                config: solved.config,
                solve_secs,
                drain_secs,
                boost_secs: solved.boost_secs,
                alloc_secs: solved.alloc_secs,
                sample_secs: solved.sample_secs,
                solve_kind: solved.kind,
            },
            backlog,
            solve_secs,
        );
        // Re-sync the mirror from the live cache (same thread, so this
        // is exact): the transition may have demoted dropped RAM views
        // into spare SSD capacity beyond the solver's own SSD plane.
        mirror = TierAssignment {
            ram: executor.cache().cached().clone(),
            ssd: executor.cache().ssd_contents().clone(),
        };
        let (transition_secs, execute_secs) = executor.last_phase_secs();
        tel.span(&SpanRecord {
            t: window_end,
            batch: batch_idx,
            shard: -1,
            slot: -1,
            n_queries: n_cut,
            drain_ms: drain_secs * 1e3,
            boost_ms: solved.boost_secs * 1e3,
            solve_ms: solved.alloc_secs * 1e3,
            sample_ms: solved.sample_secs * 1e3,
            transition_ms: transition_secs * 1e3,
            execute_ms: execute_secs * 1e3,
            solve_kind: solved.kind,
        });
        tel.tick(now);
        completed_live += n_cut as u64;
        batch_idx += 1;
        if n_cut > 0 {
            stats.served_until = now;
        }

        // Live metrics line, once per second — real-time driver only
        // (a jumping clock would print once per simulated batch).
        if cfg.verbose && clock.is_real_time() && now as u64 > last_report {
            last_report = now as u64;
            let (adm, rej) = queue_counts(queues);
            println!(
                "[t={now:6.2}s] admitted={adm} rejected={rej} completed={completed_live} \
                 last_batch={n_cut} solve={:.1}ms",
                solve_secs * 1e3
            );
        }

        // Done once producers have closed and nothing was left to
        // drain this round.
        if all_closed && n_cut == 0 {
            break;
        }
    }
    stats
}

/// Fold per-queue admission counters and the executor's run into the
/// service report. Shared by the single-node drivers here and the
/// federated serving layer (`cluster::serving`), so every serve mode
/// reports the same metric surface.
#[allow(clippy::too_many_arguments)]
pub(crate) fn assemble_report(
    run: &RunResult,
    admitted: u64,
    rejected: u64,
    peak_queue_depth: usize,
    stats: ServeLoopStats,
    elapsed_secs: f64,
    tenants: &TenantSet,
    n_tenants: usize,
) -> ServeReport {
    // Summary-backed accessors: exact under raw retention, streaming
    // aggregates under the flat-memory serve mode — either way the
    // report fields keep their meaning.
    let completed = run.completed() as u64;
    let mut per_tenant_completed = run.per_tenant_completed();
    per_tenant_completed.resize(n_tenants, 0);
    let normalized: Vec<f64> = per_tenant_completed
        .iter()
        .zip(&tenants.weights())
        .map(|(&c, w)| c as f64 / w.max(1e-12))
        .collect();
    let solve_ps = run.solve_ms_percentiles(&[50.0, 99.0]);

    ServeReport {
        elapsed_secs,
        batches: run.n_batches(),
        admitted,
        rejected,
        completed,
        queries_per_sec: if stats.served_until > 0.0 {
            completed as f64 / stats.served_until
        } else {
            0.0
        },
        solve_ms_p50: solve_ps[0],
        solve_ms_p99: solve_ps[1],
        mean_admit_wait_ms: if completed > 0 {
            1e3 * stats.admit_wait_sum / completed as f64
        } else {
            0.0
        },
        max_batch: run.max_batch(),
        peak_queue_depth,
        hit_ratio: run.hit_ratio(),
        avg_cache_utilization: run.avg_cache_utilization(),
        per_tenant_completed,
        throughput_fairness: stats::jain_index(&normalized),
    }
}

/// Total `(admitted, rejected)` across a set of admission queues — the
/// one counter fold every serve driver (single-node and federated)
/// reports from.
pub(crate) fn queue_counts<'a>(
    queues: impl IntoIterator<Item = &'a AdmissionQueue>,
) -> (u64, u64) {
    queues.into_iter().fold((0u64, 0u64), |(a, r), q| {
        let (qa, qr) = q.counts();
        (a + qa, r + qr)
    })
}

/// Run the online coordinator service: generator threads feed the
/// admission queues while the calling thread runs the batch loop on a
/// real-time clock. Returns when the duration has elapsed and all
/// admitted traffic has been served.
#[deprecated(
    since = "0.2.0",
    note = "construct through `session::Session::serve(..).run(..)`"
)]
pub fn serve(
    universe: &Universe,
    tenants: &TenantSet,
    engine: &SimEngine,
    policy: &dyn Policy,
    cfg: &ServeConfig,
) -> ServeReport {
    serve_impl(universe, tenants, engine, policy, cfg, &Telemetry::off())
}

/// [`serve`] with telemetry. The real-clock driver is where soak
/// memory matters, so it runs the executor in flat-memory mode
/// (streaming [`crate::coordinator::loop_::ExecSummary`] instead of
/// per-query raw records) — the report fields keep their meaning at
/// any duration.
#[deprecated(
    since = "0.2.0",
    note = "construct through `session::Session::serve(..).telemetry(..).run(..)`"
)]
pub fn serve_with(
    universe: &Universe,
    tenants: &TenantSet,
    engine: &SimEngine,
    policy: &dyn Policy,
    cfg: &ServeConfig,
    tel: &Telemetry,
) -> ServeReport {
    serve_impl(universe, tenants, engine, policy, cfg, tel)
}

/// The real-time serve driver behind [`serve`]/[`serve_with`] and the
/// Session API.
pub(crate) fn serve_impl(
    universe: &Universe,
    tenants: &TenantSet,
    engine: &SimEngine,
    policy: &dyn Policy,
    cfg: &ServeConfig,
    tel: &Telemetry,
) -> ServeReport {
    assert!(cfg.n_tenants > 0, "serve needs at least one tenant");
    assert!(cfg.common.batch_secs > 0.0 && cfg.duration_secs > 0.0);
    assert_eq!(tenants.len(), cfg.n_tenants, "tenant set size mismatch");
    tel.meta("serve", cfg.n_tenants, 1, 1.0);

    let queues: Vec<AdmissionQueue> = (0..cfg.n_tenants)
        .map(|_| AdmissionQueue::with_probe(cfg.queue_capacity, tel.queue_probe(-1)))
        .collect();
    let clock = RealTimeClock::new();
    let spec = cfg
        .common
        .tiers
        .unwrap_or_else(|| TierSpec::single(engine.config.cache_budget));

    // The execute half (steps 3–5) is the loop's own `BatchExecutor`;
    // the solve is the shared `SolveContext`. The online driver adds
    // only admission and real-time pacing around them.
    let coord_cfg = CoordinatorConfig {
        common: cfg.common.clone(),
        n_batches: 0, // the service loop is open-ended
    };
    let coordinator = Coordinator::new(universe, tenants.clone(), engine.clone(), coord_cfg);
    let mut executor = coordinator.executor();
    // Flat-memory soak mode: fold every batch into the streaming
    // summary instead of retaining raw per-query/per-batch vectors.
    executor.set_retain_raw(false);
    let solve_ctx = SolveContext {
        tenants,
        universe,
        budget: spec.budgets.ram,
        tier: tier_plan_of(&spec),
        stateful_gamma: cfg.common.stateful_gamma,
        weight_mult: None,
    };
    let mut rng = Pcg64::with_stream(cfg.common.seed, 0x0b5);
    let t_start = Instant::now();

    let stats = std::thread::scope(|scope| {
        // Producers: one real-time Poisson generator per tenant, each
        // seeded explicitly from `--seed` (see ServeConfig::tenant_seed).
        for (i, queue) in queues.iter().enumerate() {
            let mut tgen = cfg.tenant_generator(i, universe);
            let mut clk = clock.handle();
            let duration = cfg.duration_secs;
            let admission = cfg.admission;
            scope.spawn(move || {
                // Disjoint id ranges per producer.
                let mut next_id = (i as u64) << 32;
                let poll = 0.002f64;
                loop {
                    let now = clk.now();
                    if now >= duration {
                        break;
                    }
                    for q in tgen.generate_until(now, universe, &mut next_id) {
                        queue.offer(q, admission);
                    }
                    clk.wait_until(now + poll);
                }
                queue.close();
            });
        }

        // The service loop (this thread): the arrival side runs on the
        // producer threads, so the pump only checks for closed queues.
        let mut clk = clock.handle();
        service_loop(
            &mut clk,
            &queues,
            &mut executor,
            &solve_ctx,
            policy,
            &mut rng,
            cfg,
            tel,
            |_, _| queues.iter().all(|q| q.is_closed()),
        )
    });

    let elapsed_secs = t_start.elapsed().as_secs_f64();
    let run = executor.into_result(policy.name(), &coordinator.config, cfg.n_tenants, elapsed_secs);
    let (admitted, rejected) = queue_counts(&queues);
    let peak = queues.iter().map(|q| q.peak_depth()).max().unwrap_or(0);
    assemble_report(
        &run,
        admitted,
        rejected,
        peak,
        stats,
        elapsed_secs,
        tenants,
        cfg.n_tenants,
    )
}

/// Deterministic single-node serve: the *same* service loop as
/// [`serve`], driven by a [`SimClock`] with arrivals generated inline
/// instead of on producer threads. Every simulated quantity — admitted
/// sets, batch cuts, configurations, outcomes — is a pure function of
/// the config, which is what makes the federated serving layer's
/// `--shards 1` equivalence testable (see
/// `rust/tests/federated_serving.rs`). Only host-measured figures
/// (elapsed seconds, solve percentiles) vary run to run.
///
/// Returns the report plus the underlying [`RunResult`] so equivalence
/// tests can compare per-query outcomes exactly. Block admission would
/// deadlock a single-threaded driver (nothing drains while the pump
/// offers), so only [`AdmissionPolicy::Drop`] is supported.
#[deprecated(
    since = "0.2.0",
    note = "construct through `session::Session::serve(..).sim().run(..)`"
)]
pub fn serve_sim(
    universe: &Universe,
    tenants: &TenantSet,
    engine: &SimEngine,
    policy: &dyn Policy,
    cfg: &ServeConfig,
) -> (ServeReport, RunResult) {
    serve_sim_impl(universe, tenants, engine, policy, cfg, &Telemetry::off())
}

/// [`serve_sim`] with telemetry. Raw retention stays ON here — the sim
/// driver's whole point is returning exact per-query outcomes for
/// equivalence tests, and telemetry must not change a single one of
/// them (`rust/tests/telemetry_observer.rs`).
#[deprecated(
    since = "0.2.0",
    note = "construct through `session::Session::serve(..).telemetry(..).sim().run(..)`"
)]
pub fn serve_sim_with(
    universe: &Universe,
    tenants: &TenantSet,
    engine: &SimEngine,
    policy: &dyn Policy,
    cfg: &ServeConfig,
    tel: &Telemetry,
) -> (ServeReport, RunResult) {
    serve_sim_impl(universe, tenants, engine, policy, cfg, tel)
}

/// The deterministic sim-serve driver behind [`serve_sim`]/
/// [`serve_sim_with`] and the Session API.
pub(crate) fn serve_sim_impl(
    universe: &Universe,
    tenants: &TenantSet,
    engine: &SimEngine,
    policy: &dyn Policy,
    cfg: &ServeConfig,
    tel: &Telemetry,
) -> (ServeReport, RunResult) {
    assert!(cfg.n_tenants > 0, "serve needs at least one tenant");
    assert!(cfg.common.batch_secs > 0.0 && cfg.duration_secs > 0.0);
    assert_eq!(tenants.len(), cfg.n_tenants, "tenant set size mismatch");
    assert_eq!(
        cfg.admission,
        AdmissionPolicy::Drop,
        "the sim driver is single-threaded: block admission would deadlock"
    );
    tel.meta("serve-sim", cfg.n_tenants, 1, 1.0);

    let queues: Vec<AdmissionQueue> = (0..cfg.n_tenants)
        .map(|_| AdmissionQueue::with_probe(cfg.queue_capacity, tel.queue_probe(-1)))
        .collect();
    let spec = cfg
        .common
        .tiers
        .unwrap_or_else(|| TierSpec::single(engine.config.cache_budget));
    let coord_cfg = CoordinatorConfig {
        common: cfg.common.clone(),
        n_batches: 0,
    };
    let coordinator = Coordinator::new(universe, tenants.clone(), engine.clone(), coord_cfg);
    let mut executor = coordinator.executor();
    let solve_ctx = SolveContext {
        tenants,
        universe,
        budget: spec.budgets.ram,
        tier: tier_plan_of(&spec),
        stateful_gamma: cfg.common.stateful_gamma,
        weight_mult: None,
    };
    let mut rng = Pcg64::with_stream(cfg.common.seed, 0x0b5);
    let t_start = Instant::now();

    // Inline producers: same generators, same seeds, same disjoint id
    // ranges as the real-time driver's threads.
    let mut gens: Vec<TenantGenerator> = (0..cfg.n_tenants)
        .map(|i| cfg.tenant_generator(i, universe))
        .collect();
    let mut next_ids: Vec<u64> = (0..cfg.n_tenants).map(|i| (i as u64) << 32).collect();

    let mut clock = SimClock::new();
    let duration = cfg.duration_secs;
    let admission = cfg.admission;
    let stats = service_loop(
        &mut clock,
        &queues,
        &mut executor,
        &solve_ctx,
        policy,
        &mut rng,
        cfg,
        tel,
        |_, now| {
            let t_end = now.min(duration);
            for (i, g) in gens.iter_mut().enumerate() {
                for q in g.generate_until(t_end, universe, &mut next_ids[i]) {
                    queues[i].offer(q, admission);
                }
            }
            now >= duration
        },
    );

    let elapsed_secs = t_start.elapsed().as_secs_f64();
    let run = executor.into_result(policy.name(), &coordinator.config, cfg.n_tenants, elapsed_secs);
    let (admitted, rejected) = queue_counts(&queues);
    let peak = queues.iter().map(|q| q.peak_depth()).max().unwrap_or(0);
    let report = assemble_report(
        &run,
        admitted,
        rejected,
        peak,
        stats,
        elapsed_secs,
        tenants,
        cfg.n_tenants,
    );
    (report, run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::PolicyKind;
    use crate::sim::cluster::ClusterConfig;

    fn quick_cfg() -> ServeConfig {
        ServeConfig {
            common: CommonConfig {
                batch_secs: 0.05,
                seed: 9,
                warm_start: true,
                ..CommonConfig::default()
            },
            duration_secs: 0.3,
            rate_per_sec: 400.0,
            n_tenants: 2,
            queue_capacity: 4096,
            admission: AdmissionPolicy::Drop,
            verbose: false,
        }
    }

    fn run_serve(cfg: &ServeConfig) -> ServeReport {
        let universe = Universe::sales_only();
        let tenants = TenantSet::equal(cfg.n_tenants);
        let engine = SimEngine::new(ClusterConfig::default());
        let policy = PolicyKind::FastPf.build();
        serve_impl(
            &universe,
            &tenants,
            &engine,
            policy.as_ref(),
            cfg,
            &Telemetry::off(),
        )
    }

    #[test]
    fn serve_generators_reproducible_from_seed() {
        // The satellite guarantee behind `robus serve --seed`: every
        // producer's arrival stream is a pure function of the CLI seed.
        let universe = Universe::sales_only();
        let cfg = ServeConfig {
            n_tenants: 3,
            common: CommonConfig {
                seed: 123,
                ..ServeConfig::default().common
            },
            ..ServeConfig::default()
        };
        let stream = |cfg: &ServeConfig| -> Vec<(usize, String, f64)> {
            (0..cfg.n_tenants)
                .flat_map(|i| {
                    let mut g = cfg.tenant_generator(i, &universe);
                    let mut id = 0u64;
                    g.generate_until(60.0, &universe, &mut id)
                        .into_iter()
                        .map(move |q| (i, q.template, q.arrival))
                        .collect::<Vec<_>>()
                })
                .collect()
        };
        let a = stream(&cfg);
        assert!(!a.is_empty());
        assert_eq!(a, stream(&cfg), "same seed must replay identically");
        let other = ServeConfig {
            common: CommonConfig {
                seed: 124,
                ..cfg.common.clone()
            },
            ..cfg.clone()
        };
        assert_ne!(a, stream(&other), "different seed must differ");
        // Distinct tenants get distinct derived seeds (independent
        // streams, not clones of one another).
        assert_ne!(cfg.tenant_seed(0), cfg.tenant_seed(1));
        assert_ne!(cfg.tenant_seed(1), cfg.tenant_seed(2));
    }

    #[test]
    fn serves_live_traffic_end_to_end() {
        let cfg = quick_cfg();
        let r = run_serve(&cfg);
        // ~120 arrivals expected; be generous for slow CI hosts.
        assert!(r.completed > 10, "completed={}", r.completed);
        // Everything admitted is drained and served before shutdown.
        assert_eq!(r.completed, r.admitted);
        assert_eq!(r.per_tenant_completed.iter().sum::<u64>(), r.completed);
        assert!(r.batches >= 3);
        assert!(r.queries_per_sec > 0.0);
        assert!((0.0..=1.0 + 1e-9).contains(&r.throughput_fairness));
        assert!(r.solve_ms_p99 >= r.solve_ms_p50);
        assert!(r.elapsed_secs >= cfg.duration_secs);
        assert!(!r.render().is_empty());
    }

    #[test]
    fn backpressure_mode_never_rejects_before_close() {
        let mut cfg = quick_cfg();
        cfg.duration_secs = 0.15;
        cfg.admission = AdmissionPolicy::Block;
        cfg.queue_capacity = 4;
        let r = run_serve(&cfg);
        assert!(r.completed > 0);
        // Backpressure bounds the queue instead of shedding: the
        // high-water mark never exceeds the capacity (rejections can
        // still happen at shutdown, when close() wakes blocked
        // producers).
        assert!(
            r.peak_queue_depth <= cfg.queue_capacity,
            "peak depth {} > capacity {}",
            r.peak_queue_depth,
            cfg.queue_capacity
        );
    }

    #[test]
    fn sim_driver_is_deterministic_and_conserves() {
        // The SimClock driver underpins the federated serving
        // equivalence tests: every simulated quantity must be a pure
        // function of the config.
        let universe = Universe::sales_only();
        let cfg = ServeConfig {
            common: CommonConfig {
                batch_secs: 0.25,
                seed: 21,
                warm_start: true,
                ..CommonConfig::default()
            },
            duration_secs: 1.5,
            rate_per_sec: 300.0,
            n_tenants: 2,
            queue_capacity: 4096,
            admission: AdmissionPolicy::Drop,
            verbose: false,
        };
        let tenants = TenantSet::equal(cfg.n_tenants);
        let engine = SimEngine::new(ClusterConfig::default());
        let policy = PolicyKind::FastPf.build();
        let tel = Telemetry::off();
        let (r1, run1) =
            serve_sim_impl(&universe, &tenants, &engine, policy.as_ref(), &cfg, &tel);
        let (r2, run2) =
            serve_sim_impl(&universe, &tenants, &engine, policy.as_ref(), &cfg, &tel);
        assert!(r1.completed > 50, "completed={}", r1.completed);
        assert_eq!(r1.completed, r1.admitted, "sim serve must conserve");
        assert_eq!(r1.batches, r2.batches);
        assert_eq!(r1.admitted, r2.admitted);
        assert_eq!(r1.queries_per_sec, r2.queries_per_sec);
        assert_eq!(r1.per_tenant_completed, r2.per_tenant_completed);
        assert_eq!(run1.outcomes.len(), run2.outcomes.len());
        for (a, b) in run1.outcomes.iter().zip(&run2.outcomes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.start, b.start);
            assert_eq!(a.finish, b.finish);
            assert_eq!(a.from_cache, b.from_cache);
        }
        for (a, b) in run1.batches.iter().zip(&run2.batches) {
            assert_eq!(a.config, b.config);
            assert_eq!(a.n_queries, b.n_queries);
        }
    }

    #[test]
    fn tiny_capacity_drop_mode_sheds_load() {
        let mut cfg = quick_cfg();
        cfg.duration_secs = 0.2;
        cfg.rate_per_sec = 2000.0;
        cfg.queue_capacity = 1;
        let r = run_serve(&cfg);
        assert!(r.rejected > 0, "expected shed load with capacity 1");
        assert_eq!(r.completed, r.admitted);
    }
}
