//! The performance metrics of §5.2: throughput (Eq. 4), the speedup-based
//! fairness index relative to the STATIC baseline (Eq. 5), cache
//! utilization, hit ratio, plus the convergence series of Figure 11.

use crate::coordinator::loop_::RunResult;
use crate::util::stats;

/// Per-tenant mean speedups X_i of a policy run relative to a baseline
/// run over the *same* workload (queries joined by id): the speedup of a
/// query is baseline execution time / policy execution time; X_i is the
/// mean over tenant i's queries. Queries missing from either run are
/// skipped.
pub fn per_tenant_speedups(policy: &RunResult, baseline: &RunResult) -> Vec<f64> {
    let base = baseline.exec_times_by_id();
    let mut sums = vec![0.0; policy.n_tenants];
    let mut counts = vec![0usize; policy.n_tenants];
    for o in &policy.outcomes {
        if let Some(&(tenant, base_t)) = base.get(&o.id) {
            debug_assert_eq!(tenant, o.tenant);
            let exec = o.execution_time().max(1e-9);
            sums[o.tenant] += base_t / exec;
            counts[o.tenant] += 1;
        }
    }
    sums.iter()
        .zip(&counts)
        .map(|(s, &c)| if c > 0 { s / c as f64 } else { 0.0 })
        .collect()
}

/// Equation 5: Jain's index over weight-normalized mean speedups
/// X_i/λ_i. Tenants with no queries in either run are excluded.
pub fn fairness_index(policy: &RunResult, baseline: &RunResult) -> f64 {
    let x = per_tenant_speedups(policy, baseline);
    let normalized: Vec<f64> = x
        .iter()
        .zip(&policy.weights)
        .filter(|(xi, _)| **xi > 0.0)
        .map(|(xi, l)| xi / l)
        .collect();
    stats::jain_index(&normalized)
}

/// Fairness index computed over only the first `n_batches` batches'
/// queries — the Figure 11 convergence series.
pub fn fairness_index_prefix(
    policy: &RunResult,
    baseline: &RunResult,
    n_batches: usize,
) -> f64 {
    let cutoff = policy
        .batches
        .get(n_batches.saturating_sub(1))
        .map(|b| b.window_end)
        .unwrap_or(f64::INFINITY);
    let truncate = |r: &RunResult| -> RunResult {
        let mut t = r.clone();
        t.outcomes.retain(|o| o.arrival < cutoff);
        t
    };
    fairness_index(&truncate(policy), &truncate(baseline))
}

/// Mean wait time per tenant (arrival → first task launch).
pub fn mean_wait_by_tenant(run: &RunResult) -> Vec<f64> {
    let mut sums = vec![0.0; run.n_tenants];
    let mut counts = vec![0usize; run.n_tenants];
    for o in &run.outcomes {
        sums[o.tenant] += o.wait_time();
        counts[o.tenant] += 1;
    }
    sums.iter()
        .zip(&counts)
        .map(|(s, &c)| if c > 0 { s / c as f64 } else { 0.0 })
        .collect()
}

/// The §5.2 "wait time fairness index": Jain's index over per-tenant
/// inverse weighted wait times (smaller wait = better; we invert so the
/// index rewards equal service, mirroring Equation 5's structure).
pub fn wait_time_fairness(run: &RunResult) -> f64 {
    let waits = mean_wait_by_tenant(run);
    let inv: Vec<f64> = waits
        .iter()
        .zip(&run.weights)
        .filter(|(w, _)| **w > 0.0)
        .map(|(w, l)| 1.0 / (w * l).max(1e-9))
        .collect();
    stats::jain_index(&inv)
}

/// Mean flow time (arrival → completion) across all queries.
pub fn mean_flow_time(run: &RunResult) -> f64 {
    if run.outcomes.is_empty() {
        return 0.0;
    }
    run.outcomes.iter().map(|o| o.flow_time()).sum::<f64>()
        / run.outcomes.len() as f64
}

/// One row of the appendix tables (Tables 15-28).
#[derive(Debug, Clone)]
pub struct MetricsSummary {
    pub policy: &'static str,
    pub throughput_per_min: f64,
    pub avg_cache_utilization: f64,
    pub hit_ratio: f64,
    pub fairness_index: f64,
}

impl MetricsSummary {
    pub fn compute(policy: &RunResult, baseline: &RunResult) -> Self {
        Self {
            policy: policy.policy,
            throughput_per_min: policy.throughput_per_min(),
            avg_cache_utilization: policy.avg_cache_utilization(),
            hit_ratio: policy.hit_ratio(),
            fairness_index: fairness_index(policy, baseline),
        }
    }

    pub fn header() -> String {
        format!(
            "{:<22} {:>14} {:>16} {:>10} {:>15}",
            "Metric", "Throughput/min", "Avg cache util.", "Hit ratio", "Fairness index"
        )
    }

    pub fn row(&self) -> String {
        format!(
            "{:<22} {:>14.2} {:>16.2} {:>10.2} {:>15.2}",
            self.policy,
            self.throughput_per_min,
            self.avg_cache_utilization,
            self.hit_ratio,
            self.fairness_index
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::ConfigMask;
    use crate::coordinator::loop_::{BatchRecord, RunResult};
    use crate::domain::query::QueryId;
    use crate::sim::engine::QueryOutcome;

    fn outcome(id: u64, tenant: usize, exec: f64) -> QueryOutcome {
        QueryOutcome {
            id: QueryId(id),
            tenant,
            arrival: 0.0,
            start: 0.0,
            finish: exec,
            from_cache: false,
            bytes: 0,
        }
    }

    fn run_with(outcomes: Vec<QueryOutcome>, n_tenants: usize) -> RunResult {
        RunResult {
            policy: "TEST",
            outcomes,
            batches: vec![BatchRecord {
                index: 0,
                n_queries: 0,
                config: ConfigMask::empty(0),
                cache_utilization: 0.5,
                window_end: 40.0,
                exec_start: 40.0,
                exec_end: 50.0,
                solve_secs: 0.01,
                queue_depth: 0,
                stall_secs: 0.01,
                delta: crate::cache::CacheDelta::default(),
            }],
            end_time: 60.0,
            n_tenants,
            weights: vec![1.0; n_tenants],
            host_wall_secs: 0.02,
            summary: crate::coordinator::loop_::ExecSummary::default(),
        }
    }

    #[test]
    fn speedups_join_by_id() {
        let baseline = run_with(vec![outcome(1, 0, 10.0), outcome(2, 1, 10.0)], 2);
        let policy = run_with(vec![outcome(1, 0, 2.0), outcome(2, 1, 10.0)], 2);
        let x = per_tenant_speedups(&policy, &baseline);
        assert!((x[0] - 5.0).abs() < 1e-9);
        assert!((x[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fairness_index_equal_speedups_is_one() {
        let baseline = run_with(vec![outcome(1, 0, 10.0), outcome(2, 1, 8.0)], 2);
        let policy = run_with(vec![outcome(1, 0, 5.0), outcome(2, 1, 4.0)], 2);
        let j = fairness_index(&policy, &baseline);
        assert!((j - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fairness_index_skewed_speedups_below_one() {
        let baseline = run_with(vec![outcome(1, 0, 10.0), outcome(2, 1, 10.0)], 2);
        let policy = run_with(vec![outcome(1, 0, 1.0), outcome(2, 1, 10.0)], 2);
        let j = fairness_index(&policy, &baseline);
        // Speedups (10, 1): J = 121/(2·101) ≈ 0.599.
        assert!((j - 0.599).abs() < 0.001, "j={j}");
    }

    #[test]
    fn weights_normalize_speedups() {
        let baseline = run_with(vec![outcome(1, 0, 10.0), outcome(2, 1, 10.0)], 2);
        let mut policy = run_with(vec![outcome(1, 0, 5.0), outcome(2, 1, 2.5)], 2);
        // Tenant 1 has double weight and double speedup → perfectly fair.
        policy.weights = vec![1.0, 2.0];
        let j = fairness_index(&policy, &baseline);
        assert!((j - 1.0).abs() < 1e-9, "j={j}");
    }

    #[test]
    fn tenants_without_queries_excluded() {
        let baseline = run_with(vec![outcome(1, 0, 10.0)], 3);
        let policy = run_with(vec![outcome(1, 0, 5.0)], 3);
        let j = fairness_index(&policy, &baseline);
        assert!((j - 1.0).abs() < 1e-9);
    }

    #[test]
    fn wait_and_flow_metrics() {
        let mut o1 = outcome(1, 0, 5.0);
        o1.start = 2.0; // waited 2s, finished at 5s (exec 3s)
        let mut o2 = outcome(2, 1, 9.0);
        o2.start = 4.0;
        let run = run_with(vec![o1, o2], 2);
        let waits = mean_wait_by_tenant(&run);
        assert_eq!(waits, vec![2.0, 4.0]);
        // flow = finish − arrival = 5 and 9 → mean 7.
        assert!((mean_flow_time(&run) - 7.0).abs() < 1e-12);
        let j = wait_time_fairness(&run);
        assert!((0.0..=1.0).contains(&j));
        // Equal waits → perfectly fair.
        let mut e1 = outcome(3, 0, 5.0);
        e1.start = 3.0;
        let mut e2 = outcome(4, 1, 6.0);
        e2.start = 3.0;
        let eq = run_with(vec![e1, e2], 2);
        assert!((wait_time_fairness(&eq) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn summary_row_format() {
        let baseline = run_with(vec![outcome(1, 0, 10.0)], 1);
        let policy = run_with(vec![outcome(1, 0, 5.0)], 1);
        let s = MetricsSummary::compute(&policy, &baseline);
        assert!(s.row().contains("TEST"));
        assert!(MetricsSummary::header().contains("Throughput"));
    }
}
