//! Pipelined solve/execute: while batch *b* executes on the (simulated)
//! cluster, a solver thread prunes + solves batch *b+1*'s allocation.
//!
//! The hand-off is a bounded channel of [`PlannedBatch`]es; the solver
//! half runs as a job on a [`crate::util::pool`] worker (the generic
//! sibling of the shard runtime's pool). Determinism
//! holds because the planner half is self-contained: the workload
//! generator and the policy RNG advance in batch order on the solver
//! thread exactly as they do in the serial loop, and the stateful boost
//! comes from the planner's cache-contents mirror (after an update the
//! cache holds precisely the previous emitted configuration). The
//! pipelined runner is therefore **bit-identical** to
//! [`Coordinator::run`] on every simulated quantity — configurations,
//! outcomes, metrics — differing only in the host-time observability
//! fields (`solve_secs`, `stall_secs`, `queue_depth`,
//! `host_wall_secs`), the same discipline as the parallel experiment
//! runner of PR 1 (`experiments::runner`).

use std::time::Instant;

use crate::util::sync::atomic::{AtomicUsize, Ordering};
use crate::util::sync::mpsc;

use crate::alloc::Policy;
use crate::coordinator::loop_::{Coordinator, PlannedBatch, RunResult};
use crate::telemetry::{SpanRecord, Telemetry};
use crate::util::pool::with_worker_pool;
use crate::workload::generator::WorkloadGenerator;

/// Default number of pre-solved batches the solver may run ahead.
pub const DEFAULT_PIPELINE_DEPTH: usize = 2;

impl Coordinator<'_> {
    /// Run the loop with the solve for batch b+1 overlapping the
    /// execution of batch b. `depth` bounds how many solved batches may
    /// queue between the threads (backpressure on the solver); depth 0
    /// is clamped to 1.
    #[deprecated(
        since = "0.2.0",
        note = "construct through `session::Session::replay(..).pipelined(depth).run(..)`"
    )]
    pub fn run_pipelined(
        &self,
        generator: &mut WorkloadGenerator,
        policy: &dyn Policy,
        depth: usize,
    ) -> RunResult {
        self.run_pipelined_impl(generator, policy, depth, &Telemetry::off())
    }

    /// [`Coordinator::run_pipelined`] with telemetry: spans are emitted
    /// from the executor side (this thread), one per retired batch, so
    /// trace order matches execution order regardless of how far ahead
    /// the solver runs.
    #[deprecated(
        since = "0.2.0",
        note = "construct through `session::Session::replay(..).pipelined(depth).telemetry(..).run(..)`"
    )]
    pub fn run_pipelined_with(
        &self,
        generator: &mut WorkloadGenerator,
        policy: &dyn Policy,
        depth: usize,
        tel: &Telemetry,
    ) -> RunResult {
        self.run_pipelined_impl(generator, policy, depth, tel)
    }

    /// The pipelined driver behind [`Coordinator::run_pipelined`]/
    /// [`run_pipelined_with`] and the Session API.
    pub(crate) fn run_pipelined_impl(
        &self,
        generator: &mut WorkloadGenerator,
        policy: &dyn Policy,
        depth: usize,
        tel: &Telemetry,
    ) -> RunResult {
        let depth = depth.max(1);
        let t_run = Instant::now();
        let queued = AtomicUsize::new(0);
        let (tx, rx) = mpsc::sync_channel::<PlannedBatch>(depth);
        let mut executor = self.executor();
        // Built before entering the pool: pool jobs may only borrow
        // state that outlives the `with_worker_pool` call.
        let mut planner = self.planner(generator, policy);

        with_worker_pool(1, |pool| {
            let queued = &queued;
            pool.submit(move || {
                while let Some(planned) = planner.next_batch() {
                    // ordering: Relaxed pairs with the Relaxed
                    // fetch_sub in the executor loop — `queued` is an
                    // observability-only depth gauge; the sync_channel
                    // itself orders the hand-off of the batch data.
                    queued.fetch_add(1, Ordering::Relaxed);
                    // The receiver only hangs up when the pool is
                    // tearing down; nothing to do but stop planning.
                    if tx.send(planned).is_err() {
                        break;
                    }
                }
            });
            loop {
                let t0 = Instant::now();
                match rx.recv() {
                    Ok(planned) => {
                        let stall_secs = t0.elapsed().as_secs_f64();
                        // Solved batches still waiting after taking this
                        // one — how far ahead the solver is running.
                        // ordering: Relaxed pairs with the Relaxed
                        // fetch_add on the planner side; approximate
                        // depth is fine, the channel orders the data.
                        let queue_depth = queued.fetch_sub(1, Ordering::Relaxed).saturating_sub(1);
                        let span = SpanRecord {
                            t: planned.window_end,
                            batch: planned.index,
                            shard: -1,
                            slot: -1,
                            n_queries: planned.queries.len(),
                            drain_ms: planned.drain_secs * 1e3,
                            boost_ms: planned.boost_secs * 1e3,
                            solve_ms: planned.alloc_secs * 1e3,
                            sample_ms: planned.sample_secs * 1e3,
                            transition_ms: 0.0,
                            execute_ms: 0.0,
                            solve_kind: planned.solve_kind,
                        };
                        executor.execute(planned, queue_depth, stall_secs);
                        let (transition, exec) = executor.last_phase_secs();
                        tel.span(&SpanRecord {
                            transition_ms: transition * 1e3,
                            execute_ms: exec * 1e3,
                            ..span
                        });
                        tel.tick(span.t);
                    }
                    Err(_) => break, // planner finished and hung up
                }
            }
        });

        executor.into_result(
            policy.name(),
            &self.config,
            self.tenants.len(),
            t_run.elapsed().as_secs_f64(),
        )
    }
}

#[cfg(test)]
mod tests {
    use crate::alloc::PolicyKind;
    use crate::coordinator::loop_::{CommonConfig, Coordinator, CoordinatorConfig, RunResult};
    use crate::domain::tenant::TenantSet;
    use crate::telemetry::Telemetry;
    use crate::sim::cluster::ClusterConfig;
    use crate::sim::engine::SimEngine;
    use crate::workload::generator::WorkloadGenerator;
    use crate::workload::spec::{AccessSpec, TenantSpec, WindowSpec};
    use crate::workload::universe::Universe;

    fn run_both(kind: PolicyKind, gamma: Option<f64>, depth: usize) -> (RunResult, RunResult) {
        run_both_warm(kind, gamma, depth, false)
    }

    fn run_both_warm(
        kind: PolicyKind,
        gamma: Option<f64>,
        depth: usize,
        warm_start: bool,
    ) -> (RunResult, RunResult) {
        let universe = Universe::sales_only();
        let tenants = TenantSet::equal(3);
        let engine = SimEngine::new(ClusterConfig::default());
        let config = CoordinatorConfig {
            common: CommonConfig {
                batch_secs: 30.0,
                stateful_gamma: gamma,
                seed: 17,
                warm_start,
                tiers: None,
            },
            n_batches: 6,
        };
        let coord = Coordinator::new(&universe, tenants, engine, config);
        let specs = || -> Vec<TenantSpec> {
            (1..=3)
                .map(|g| {
                    TenantSpec::new(AccessSpec::g(g), 12.0)
                        .with_window(WindowSpec::default())
                })
                .collect()
        };
        let policy = kind.build();
        let tel = Telemetry::off();
        let mut gen_a = WorkloadGenerator::new(specs(), &universe, 17);
        let serial = coord.run_impl(&mut gen_a, policy.as_ref(), &tel);
        let mut gen_b = WorkloadGenerator::new(specs(), &universe, 17);
        let pipelined = coord.run_pipelined_impl(&mut gen_b, policy.as_ref(), depth, &tel);
        (serial, pipelined)
    }

    fn assert_bit_identical(serial: &RunResult, pipelined: &RunResult) {
        assert_eq!(serial.policy, pipelined.policy);
        assert_eq!(serial.end_time, pipelined.end_time);
        assert_eq!(serial.outcomes.len(), pipelined.outcomes.len());
        for (s, p) in serial.outcomes.iter().zip(&pipelined.outcomes) {
            assert_eq!(s.id, p.id);
            assert_eq!(s.start, p.start);
            assert_eq!(s.finish, p.finish);
            assert_eq!(s.from_cache, p.from_cache);
        }
        assert_eq!(serial.batches.len(), pipelined.batches.len());
        for (s, p) in serial.batches.iter().zip(&pipelined.batches) {
            assert_eq!(s.index, p.index);
            assert_eq!(s.config, p.config);
            assert_eq!(s.ssd, p.ssd);
            assert_eq!(s.cache_utilization, p.cache_utilization);
            assert_eq!(s.delta, p.delta);
            assert_eq!(s.exec_start, p.exec_start);
            assert_eq!(s.exec_end, p.exec_end);
        }
    }

    // The equivalence tests below each run a full 6-batch coordinator
    // twice — far too slow for the interpreter, so they are excluded
    // from the Miri subset (the channel/counter protocol itself is
    // covered by the model checker and the pool tests).
    #[test]
    #[cfg_attr(miri, ignore)]
    fn pipelined_matches_serial_stateless() {
        let (serial, pipelined) = run_both(PolicyKind::FastPf, None, 2);
        assert_bit_identical(&serial, &pipelined);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn pipelined_matches_serial_stateful() {
        // The stateful boost is the subtle case: the planner's mirror
        // must reproduce the live cache contents bit-for-bit.
        let (serial, pipelined) = run_both(PolicyKind::Mmf, Some(2.0), 3);
        assert_bit_identical(&serial, &pipelined);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn depth_zero_clamps_and_runs() {
        let (serial, pipelined) = run_both(PolicyKind::Static, None, 0);
        assert_bit_identical(&serial, &pipelined);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn pipelined_matches_serial_warm_started() {
        // The warm state rides inside the planner, which moves whole
        // onto the solver thread — warm serial and warm pipelined runs
        // stay bit-identical to each other.
        let (serial, pipelined) = run_both_warm(PolicyKind::FastPf, None, 2, true);
        assert_bit_identical(&serial, &pipelined);
    }
}
