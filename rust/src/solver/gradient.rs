//! Projected gradient ascent with line search for smooth concave
//! maximization over the non-negative orthant — the computational core of
//! the FASTPF heuristic (Algorithm 3): maximize
//! g(x) = Σ_i log V_i(x) − N‖x‖ subject to x ≥ 0 (Program 2).
//!
//! The implementation is generic over the objective so tests can exercise
//! it on closed-form problems; the PF-specific objective lives in
//! `alloc::fastpf`.

/// Objective interface: value and gradient at a point.
pub trait Objective {
    fn value(&self, x: &[f64]) -> f64;
    fn gradient(&self, x: &[f64], out: &mut [f64]);
}

/// Termination/config knobs.
#[derive(Debug, Clone)]
pub struct GradientConfig {
    pub max_iters: usize,
    /// Stop when the objective improves by less than this (relative).
    pub tol: f64,
    /// Initial step of the geometric line search.
    pub step0: f64,
    /// Number of geometric candidates per line search.
    pub ls_candidates: usize,
    /// Geometric decay between candidates.
    pub ls_decay: f64,
}

impl Default for GradientConfig {
    fn default() -> Self {
        Self {
            max_iters: 400,
            tol: 1e-10,
            step0: 1.0,
            ls_candidates: 20,
            ls_decay: 0.5,
        }
    }
}

/// Result of a solve.
#[derive(Debug, Clone)]
pub struct GradientResult {
    pub x: Vec<f64>,
    pub value: f64,
    pub iters: usize,
    pub converged: bool,
}

/// Maximize `obj` from `x0` by projected gradient ascent: at each step,
/// evaluate the objective at x + r·∇g projected onto x ≥ 0 for a
/// geometric ladder of step sizes r and keep the best (this mirrors
/// Algorithm 3's `r* = argmax_r g(x + r·∇g)` line with a practical
/// finite search; it is also exactly the vectorized-line-search structure
/// the L1 Pallas kernel implements).
pub fn maximize<O: Objective>(obj: &O, x0: &[f64], cfg: &GradientConfig) -> GradientResult {
    let n = x0.len();
    let mut x = x0.to_vec();
    project(&mut x);
    let mut value = obj.value(&x);
    let mut grad = vec![0.0; n];
    let mut cand = vec![0.0; n];
    let mut iters = 0;
    let mut converged = false;

    while iters < cfg.max_iters {
        iters += 1;
        obj.gradient(&x, &mut grad);

        // Line search over geometric steps.
        let mut best_step_value = value;
        let mut best_x: Option<Vec<f64>> = None;
        let mut r = cfg.step0;
        for _ in 0..cfg.ls_candidates {
            for i in 0..n {
                cand[i] = (x[i] + r * grad[i]).max(0.0);
            }
            let v = obj.value(&cand);
            if v > best_step_value {
                best_step_value = v;
                best_x = Some(cand.clone());
            }
            r *= cfg.ls_decay;
        }

        match best_x {
            Some(bx) => {
                let improvement = best_step_value - value;
                x = bx;
                value = best_step_value;
                if improvement < cfg.tol * (1.0 + value.abs()) {
                    converged = true;
                    break;
                }
            }
            None => {
                // No candidate improved: stationary (up to search
                // resolution).
                converged = true;
                break;
            }
        }
    }

    GradientResult {
        x,
        value,
        iters,
        converged,
    }
}

fn project(x: &mut [f64]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// g(x) = −Σ (x_i − c_i)² — maximum at the projection of c.
    struct Quadratic {
        c: Vec<f64>,
    }

    impl Objective for Quadratic {
        fn value(&self, x: &[f64]) -> f64 {
            -x.iter()
                .zip(&self.c)
                .map(|(xi, ci)| (xi - ci).powi(2))
                .sum::<f64>()
        }
        fn gradient(&self, x: &[f64], out: &mut [f64]) {
            for i in 0..x.len() {
                out[i] = -2.0 * (x[i] - self.c[i]);
            }
        }
    }

    #[test]
    fn quadratic_interior_maximum() {
        let obj = Quadratic { c: vec![1.0, 2.0, 0.5] };
        let r = maximize(&obj, &[0.0, 0.0, 0.0], &GradientConfig::default());
        for (xi, ci) in r.x.iter().zip(&obj.c) {
            assert!((xi - ci).abs() < 1e-4, "x={:?}", r.x);
        }
        assert!(r.converged);
    }

    #[test]
    fn quadratic_boundary_maximum() {
        // c has a negative component: projected maximum is at x_1 = 0.
        let obj = Quadratic { c: vec![2.0, -3.0] };
        let r = maximize(&obj, &[1.0, 1.0], &GradientConfig::default());
        assert!((r.x[0] - 2.0).abs() < 1e-4);
        assert!(r.x[1].abs() < 1e-9);
    }

    /// Simple PF-shaped objective: g(x) = Σ log(Vx)_i − N‖x‖ with
    /// V = I (each tenant wants its own config). Optimum: x_i = 1/N each
    /// (from stationarity: 1/x_i = N).
    struct PfIdentity {
        n: usize,
    }

    impl Objective for PfIdentity {
        fn value(&self, x: &[f64]) -> f64 {
            let norm: f64 = x.iter().sum();
            x.iter().map(|xi| xi.max(1e-12).ln()).sum::<f64>() - self.n as f64 * norm
        }
        fn gradient(&self, x: &[f64], out: &mut [f64]) {
            for i in 0..x.len() {
                out[i] = 1.0 / x[i].max(1e-12) - self.n as f64;
            }
        }
    }

    #[test]
    fn pf_identity_splits_evenly() {
        let n = 4;
        let obj = PfIdentity { n };
        let x0 = vec![1.0 / n as f64 * 0.3; n]; // deliberately off-optimum
        let r = maximize(
            &obj,
            &x0,
            &GradientConfig {
                max_iters: 2000,
                ..Default::default()
            },
        );
        for xi in &r.x {
            assert!((xi - 0.25).abs() < 1e-3, "x={:?}", r.x);
        }
        // Stationarity confirms d = N (Theorem 2's dual value).
        assert!((r.x.iter().sum::<f64>() - 1.0).abs() < 1e-2);
    }

    #[test]
    fn zero_iterations_returns_start() {
        let obj = Quadratic { c: vec![1.0] };
        let r = maximize(
            &obj,
            &[0.5],
            &GradientConfig {
                max_iters: 0,
                ..Default::default()
            },
        );
        assert_eq!(r.x, vec![0.5]);
        assert!(!r.converged);
    }

    #[test]
    fn start_is_projected() {
        let obj = Quadratic { c: vec![1.0] };
        let r = maximize(&obj, &[-5.0], &GradientConfig::default());
        assert!((r.x[0] - 1.0).abs() < 1e-4);
    }
}
