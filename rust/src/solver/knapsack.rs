//! The WELFARE oracle (Definition 5): given per-query values (already
//! weighted by the dual weights w and scaled by 1/U_i*), choose the
//! configuration S — a set of views whose total size fits the cache
//! budget — maximizing the total value of *fully satisfied* queries
//! (all-or-nothing utility model, §5.1/\[9\]).
//!
//! With multi-view queries this is a budgeted coverage-style problem
//! (NP-hard); sizes here are small (≤ ~64 candidate views per batch), so
//! we solve it exactly with branch-and-bound over views:
//!
//! - order views by "value density", where each query's value is spread
//!   over its required views proportionally to size;
//! - admissible upper bound: for any remaining budget, the fractional
//!   knapsack over those per-view value shares — for every feasible S,
//!   value(S) = Σ_q v_q·1[R(q) ⊆ S] ≤ Σ_{v∈S} d_v because each satisfied
//!   query contributes its full share on every one of its views;
//! - greedy incumbent first, so pruning is effective immediately.
//!
//! A pure greedy entry point is exposed for use as a fast heuristic.

/// One query class: a non-negative value obtained iff *all* views in
/// `views` are cached.
#[derive(Debug, Clone)]
pub struct ValuedQuery {
    pub value: f64,
    pub views: Vec<usize>,
}

/// A welfare-maximization instance over candidate views.
#[derive(Debug, Clone)]
pub struct WelfareProblem {
    /// Size of each candidate view (bytes, or any consistent unit).
    pub view_sizes: Vec<f64>,
    /// Cache budget in the same unit.
    pub budget: f64,
    /// Query classes with values and required view sets.
    pub queries: Vec<ValuedQuery>,
}

/// A solved configuration: which views to cache and the attained value.
#[derive(Debug, Clone, PartialEq)]
pub struct WelfareSolution {
    pub selected: Vec<bool>,
    pub value: f64,
}

impl WelfareProblem {
    /// Total value of fully satisfied queries under a selection.
    pub fn value_of(&self, selected: &[bool]) -> f64 {
        self.queries
            .iter()
            .filter(|q| q.views.iter().all(|&v| selected[v]))
            .map(|q| q.value)
            .sum()
    }

    /// Total size of a selection.
    pub fn size_of(&self, selected: &[bool]) -> f64 {
        self.view_sizes
            .iter()
            .zip(selected)
            .filter(|(_, &s)| s)
            .map(|(sz, _)| *sz)
            .sum()
    }

    fn feasible(&self, selected: &[bool]) -> bool {
        self.size_of(selected) <= self.budget + 1e-9
    }

    /// Per-view value density shares d_v (see module docs).
    fn density_shares(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.view_sizes.len()];
        for q in &self.queries {
            if q.value <= 0.0 {
                continue;
            }
            let total: f64 = q.views.iter().map(|&v| self.view_sizes[v]).sum();
            if total <= 0.0 {
                // Zero-size requirement: value is free; spread evenly to
                // keep the bound admissible (they cost nothing to include).
                continue;
            }
            for &v in &q.views {
                d[v] += q.value * self.view_sizes[v] / total;
            }
        }
        d
    }

    /// Greedy heuristic: repeatedly add the query class with the highest
    /// value per byte of *missing* views that still fits.
    pub fn solve_greedy(&self) -> WelfareSolution {
        let nv = self.view_sizes.len();
        let mut selected = vec![false; nv];
        // Include all zero-size views for free (and anything ≤ 0 size).
        for (v, &sz) in self.view_sizes.iter().enumerate() {
            if sz <= 0.0 {
                selected[v] = true;
            }
        }
        let mut used: f64 = self.size_of(&selected);
        let mut remaining: Vec<usize> = (0..self.queries.len())
            .filter(|&q| self.queries[q].value > 0.0)
            .collect();
        loop {
            let mut best: Option<(usize, f64, f64)> = None; // (query, miss_size, density)
            for &qi in &remaining {
                let q = &self.queries[qi];
                if q.views.iter().all(|&v| selected[v]) {
                    continue;
                }
                let miss: f64 = q
                    .views
                    .iter()
                    .filter(|&&v| !selected[v])
                    .map(|&v| self.view_sizes[v])
                    .sum();
                if used + miss > self.budget + 1e-9 {
                    continue;
                }
                let density = if miss > 0.0 { q.value / miss } else { f64::INFINITY };
                if best.map(|(_, _, d)| density > d).unwrap_or(true) {
                    best = Some((qi, miss, density));
                }
            }
            match best {
                None => break,
                Some((qi, miss, _)) => {
                    for &v in &self.queries[qi].views {
                        selected[v] = true;
                    }
                    used += miss;
                    remaining.retain(|&r| r != qi);
                }
            }
        }
        let value = self.value_of(&selected);
        WelfareSolution { selected, value }
    }

    /// Exact branch-and-bound solve with a default node budget that is
    /// effectively unlimited for the instance sizes ROBUS produces but
    /// guards against pathological blowup (falls back to the best
    /// incumbent found — still feasible, ≥ greedy).
    pub fn solve_exact(&self) -> WelfareSolution {
        self.solve_exact_budgeted(5_000_000)
    }

    /// Exact branch-and-bound with an explicit node budget.
    pub fn solve_exact_budgeted(&self, node_budget: u64) -> WelfareSolution {
        let nv = self.view_sizes.len();
        if nv == 0 {
            return WelfareSolution {
                selected: vec![],
                value: self.value_of(&[]),
            };
        }

        // Order views by density share per byte, descending; zero-size
        // views first (free). Views carrying no value share (they appear
        // in no positive-value query) can never help: excluding them from
        // the branching order is what keeps the tree small — without
        // this, subtrees differing only in worthless views blow up
        // exponentially (see EXPERIMENTS.md §Perf).
        let shares = self.density_shares();
        let mut order: Vec<usize> = (0..nv)
            .filter(|&v| shares[v] > 0.0 || self.view_sizes[v] <= 0.0)
            .collect();
        order.sort_by(|&a, &b| {
            let da = if self.view_sizes[a] > 0.0 {
                shares[a] / self.view_sizes[a]
            } else {
                f64::INFINITY
            };
            let db = if self.view_sizes[b] > 0.0 {
                shares[b] / self.view_sizes[b]
            } else {
                f64::INFINITY
            };
            db.partial_cmp(&da).unwrap()
        });

        let incumbent = self.solve_greedy();
        let mut best = incumbent;

        let mut selected = vec![false; nv];
        // Pre-select free views.
        for v in 0..nv {
            if self.view_sizes[v] <= 0.0 {
                selected[v] = true;
            }
        }

        // Fractional-knapsack upper bound over views order[pos..] given
        // remaining budget, added to the (admissible) share value of the
        // already-selected views.
        let bound_tail = |pos: usize, budget_left: f64| -> f64 {
            let mut b = 0.0;
            let mut left = budget_left;
            for &v in &order[pos..] {
                let sz = self.view_sizes[v];
                if sz <= 0.0 {
                    b += shares[v];
                    continue;
                }
                if left <= 0.0 {
                    break;
                }
                if sz <= left {
                    b += shares[v];
                    left -= sz;
                } else {
                    b += shares[v] * left / sz;
                    left = 0.0;
                }
            }
            b
        };

        // DFS with incremental satisfaction counting (perf pass, see
        // EXPERIMENTS.md §Perf): instead of re-scanning every query class
        // at each leaf (O(q·v)), per-query missing-view counters are
        // updated when a view enters/leaves the selection, and the
        // current value is maintained incrementally. The incumbent is
        // also updated at every node (any partial selection is feasible),
        // which tightens pruning substantially.
        let mut view_queries: Vec<Vec<usize>> = vec![Vec::new(); nv];
        for (qi, q) in self.queries.iter().enumerate() {
            for &v in &q.views {
                view_queries[v].push(qi);
            }
        }
        let mut missing: Vec<u32> = self
            .queries
            .iter()
            .map(|q| q.views.iter().filter(|&&v| !selected[v]).count() as u32)
            .collect();
        let mut cur_value: f64 = self
            .queries
            .iter()
            .zip(&missing)
            .filter(|(_, &m)| m == 0)
            .map(|(q, _)| q.value)
            .sum();

        struct Ctx<'a> {
            p: &'a WelfareProblem,
            order: &'a [usize],
            shares: &'a [f64],
            view_queries: &'a [Vec<usize>],
        }

        #[allow(clippy::too_many_arguments)]
        fn include(
            ctx: &Ctx,
            v: usize,
            selected: &mut [bool],
            missing: &mut [u32],
            cur_value: &mut f64,
        ) {
            selected[v] = true;
            for &qi in &ctx.view_queries[v] {
                missing[qi] -= 1;
                if missing[qi] == 0 {
                    *cur_value += ctx.p.queries[qi].value;
                }
            }
        }

        fn exclude(
            ctx: &Ctx,
            v: usize,
            selected: &mut [bool],
            missing: &mut [u32],
            cur_value: &mut f64,
        ) {
            selected[v] = false;
            for &qi in &ctx.view_queries[v] {
                if missing[qi] == 0 {
                    *cur_value -= ctx.p.queries[qi].value;
                }
                missing[qi] += 1;
            }
        }

        #[allow(clippy::too_many_arguments)]
        fn dfs(
            ctx: &Ctx,
            pos: usize,
            selected: &mut Vec<bool>,
            missing: &mut Vec<u32>,
            cur_value: &mut f64,
            used: f64,
            shares_in: f64,
            best: &mut WelfareSolution,
            bound_tail: &dyn Fn(usize, f64) -> f64,
            nodes_left: &mut u64,
        ) {
            if *nodes_left == 0 {
                return;
            }
            *nodes_left -= 1;
            // Any node's selection is feasible: update the incumbent now
            // so the bound prunes aggressively.
            if *cur_value > best.value + 1e-12 {
                *best = WelfareSolution {
                    selected: selected.clone(),
                    value: *cur_value,
                };
            }
            if pos == ctx.order.len() {
                return;
            }
            // Admissible bound: value(S_final) ≤ Σ_{v∈S_final} d_v
            //                  ≤ shares_in + fractional tail bound.
            // Relative tolerance: once the bound cannot beat the
            // incumbent by a meaningful margin, stop — otherwise ties
            // (common when the whole batch fits in cache) are explored
            // exponentially.
            let ub = shares_in + bound_tail(pos, ctx.p.budget - used);
            if ub <= best.value + 1e-7 * best.value.abs() + 1e-9 {
                return;
            }
            let v = ctx.order[pos];
            let sz = ctx.p.view_sizes[v];
            if selected[v] {
                // Pre-selected free view.
                dfs(
                    ctx,
                    pos + 1,
                    selected,
                    missing,
                    cur_value,
                    used,
                    shares_in + ctx.shares[v],
                    best,
                    bound_tail,
                    nodes_left,
                );
                return;
            }
            // Branch 1: include (if feasible).
            if used + sz <= ctx.p.budget + 1e-9 {
                include(ctx, v, selected, missing, cur_value);
                dfs(
                    ctx,
                    pos + 1,
                    selected,
                    missing,
                    cur_value,
                    used + sz,
                    shares_in + ctx.shares[v],
                    best,
                    bound_tail,
                    nodes_left,
                );
                exclude(ctx, v, selected, missing, cur_value);
            }
            // Branch 2: exclude.
            dfs(
                ctx,
                pos + 1,
                selected,
                missing,
                cur_value,
                used,
                shares_in,
                best,
                bound_tail,
                nodes_left,
            );
        }

        let initial_used = self.size_of(&selected);
        let mut nodes_left = node_budget;
        let ctx = Ctx {
            p: self,
            order: &order,
            shares: &shares,
            view_queries: &view_queries,
        };
        dfs(
            &ctx,
            0,
            &mut selected,
            &mut missing,
            &mut cur_value,
            initial_used,
            0.0,
            &mut best,
            &bound_tail,
            &mut nodes_left,
        );
        debug_assert!(self.feasible(&best.selected));
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, no_shrink};
    use crate::util::rng::Pcg64;

    fn brute_force(p: &WelfareProblem) -> f64 {
        let nv = p.view_sizes.len();
        assert!(nv <= 20);
        let mut best = 0.0f64;
        for mask in 0u32..(1 << nv) {
            let selected: Vec<bool> = (0..nv).map(|v| mask & (1 << v) != 0).collect();
            if p.size_of(&selected) <= p.budget + 1e-9 {
                best = best.max(p.value_of(&selected));
            }
        }
        best
    }

    #[test]
    fn single_view_queries_are_knapsack() {
        // Classic knapsack: sizes 2,3,4,5 values 3,4,5,6, budget 5 → 7.
        let p = WelfareProblem {
            view_sizes: vec![2.0, 3.0, 4.0, 5.0],
            budget: 5.0,
            queries: vec![
                ValuedQuery { value: 3.0, views: vec![0] },
                ValuedQuery { value: 4.0, views: vec![1] },
                ValuedQuery { value: 5.0, views: vec![2] },
                ValuedQuery { value: 6.0, views: vec![3] },
            ],
        };
        let s = p.solve_exact();
        assert!((s.value - 7.0).abs() < 1e-9);
        assert_eq!(s.selected, vec![true, true, false, false]);
    }

    #[test]
    fn multi_view_all_or_nothing() {
        // Query worth 10 needs views {0,1} (sizes 1+1); query worth 6
        // needs view {2} (size 2). Budget 2 → take the pair (value 10).
        let p = WelfareProblem {
            view_sizes: vec![1.0, 1.0, 2.0],
            budget: 2.0,
            queries: vec![
                ValuedQuery { value: 10.0, views: vec![0, 1] },
                ValuedQuery { value: 6.0, views: vec![2] },
            ],
        };
        let s = p.solve_exact();
        assert!((s.value - 10.0).abs() < 1e-9);
        assert_eq!(s.selected, vec![true, true, false]);
    }

    #[test]
    fn shared_views_counted_once() {
        // Two queries share view 0: caching {0,1,2} satisfies both.
        let p = WelfareProblem {
            view_sizes: vec![2.0, 1.0, 1.0, 4.0],
            budget: 4.0,
            queries: vec![
                ValuedQuery { value: 5.0, views: vec![0, 1] },
                ValuedQuery { value: 5.0, views: vec![0, 2] },
                ValuedQuery { value: 9.0, views: vec![3] },
            ],
        };
        let s = p.solve_exact();
        assert!((s.value - 10.0).abs() < 1e-9, "value={}", s.value);
    }

    #[test]
    fn spacebook_scenario3() {
        // §1 Scenario 3: views R,S,P each size M=1, cache 1. Weighted
        // query values: R→4, S→3.5, P→3 (weights folded into values).
        // Utility max caches R.
        let p = WelfareProblem {
            view_sizes: vec![1.0, 1.0, 1.0],
            budget: 1.0,
            queries: vec![
                ValuedQuery { value: 4.0, views: vec![0] },
                ValuedQuery { value: 3.5, views: vec![1] },
                ValuedQuery { value: 3.0, views: vec![2] },
            ],
        };
        let s = p.solve_exact();
        assert_eq!(s.selected, vec![true, false, false]);
        // Scenario 4: cache 2M → caches R and S (weighted utility 7.5).
        let p2 = WelfareProblem { budget: 2.0, ..p };
        let s2 = p2.solve_exact();
        assert_eq!(s2.selected, vec![true, true, false]);
        assert!((s2.value - 7.5).abs() < 1e-9);
    }

    #[test]
    fn empty_problem() {
        let p = WelfareProblem {
            view_sizes: vec![],
            budget: 1.0,
            queries: vec![],
        };
        assert_eq!(p.solve_exact().value, 0.0);
    }

    #[test]
    fn zero_budget_selects_nothing_costly() {
        let p = WelfareProblem {
            view_sizes: vec![1.0, 0.0],
            budget: 0.0,
            queries: vec![
                ValuedQuery { value: 5.0, views: vec![0] },
                ValuedQuery { value: 2.0, views: vec![1] },
            ],
        };
        let s = p.solve_exact();
        // Zero-size view is free → its query is satisfied.
        assert!((s.value - 2.0).abs() < 1e-9);
        assert!(!s.selected[0]);
    }

    #[test]
    fn greedy_is_feasible_and_dominated_by_exact() {
        let mut rng = Pcg64::new(77);
        for _ in 0..50 {
            let nv = 1 + rng.index(10);
            let p = random_problem(&mut rng, nv);
            let g = p.solve_greedy();
            let e = p.solve_exact();
            assert!(p.size_of(&g.selected) <= p.budget + 1e-9);
            assert!(g.value <= e.value + 1e-9);
            assert!((g.value - p.value_of(&g.selected)).abs() < 1e-9);
        }
    }

    fn random_problem(rng: &mut Pcg64, nv: usize) -> WelfareProblem {
        let view_sizes: Vec<f64> = (0..nv).map(|_| rng.range_f64(0.5, 4.0)).collect();
        let total: f64 = view_sizes.iter().sum();
        let budget = rng.range_f64(0.0, total);
        let nq = 1 + rng.index(12);
        let queries = (0..nq)
            .map(|_| {
                let k = 1 + rng.index(3.min(nv));
                let mut views: Vec<usize> = (0..nv).collect();
                rng.shuffle(&mut views);
                views.truncate(k);
                ValuedQuery {
                    value: rng.range_f64(0.0, 10.0),
                    views,
                }
            })
            .collect();
        WelfareProblem {
            view_sizes,
            budget,
            queries,
        }
    }

    #[test]
    fn exact_matches_brute_force_on_random_instances() {
        check(
            120,
            |rng| {
                let nv = 1 + rng.index(9);
                random_problem(rng, nv)
            },
            no_shrink,
            |p| {
                let e = p.solve_exact();
                let bf = brute_force(p);
                if (e.value - bf).abs() > 1e-6 {
                    return Err(format!("exact {} != brute {}", e.value, bf));
                }
                if p.size_of(&e.selected) > p.budget + 1e-9 {
                    return Err("exact solution over budget".into());
                }
                Ok(())
            },
        );
    }
}
