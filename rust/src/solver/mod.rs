//! Numeric substrates used by the allocation policies: a dense two-phase
//! simplex LP solver (replaces the paper's `lpsolve`), the exact WELFARE
//! knapsack oracle of Definition 5, and projected gradient ascent for the
//! proportional-fairness program.

pub mod gradient;
pub mod knapsack;
pub mod simplex;
