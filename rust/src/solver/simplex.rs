//! A dense two-phase primal simplex LP solver.
//!
//! The paper's MMF heuristic (§4.3, Program 3) solves
//! `max { λ | Σ_S V_i(S)·x_S ≥ λ ∀i, Σ_S x_S ≤ 1, x ≥ 0 }` with the
//! open-source `lpsolve` package; the lexicographic max-min allocation
//! then pins saturated tenants with equality constraints and re-solves.
//! The offline registry has no LP crate, so this module implements the
//! solver from scratch: standard-form conversion (slack / surplus /
//! artificial variables), phase-1 artificial minimization, phase-2
//! objective maximization, Bland's rule for anti-cycling.
//!
//! Problem sizes here are tiny (tens of variables/constraints), so a
//! dense tableau is the right tool.

/// Constraint sense.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

/// One linear constraint `coeffs · x (cmp) rhs`.
#[derive(Debug, Clone)]
pub struct Constraint {
    pub coeffs: Vec<f64>,
    pub cmp: Cmp,
    pub rhs: f64,
}

/// A linear program: maximize `objective · x` subject to `constraints`,
/// with all variables non-negative.
#[derive(Debug, Clone)]
pub struct Lp {
    pub objective: Vec<f64>,
    pub constraints: Vec<Constraint>,
}

/// Solver outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum LpResult {
    /// Optimal objective value and primal solution.
    Optimal { value: f64, x: Vec<f64> },
    Infeasible,
    Unbounded,
}

impl LpResult {
    pub fn optimal(&self) -> Option<(f64, &[f64])> {
        match self {
            LpResult::Optimal { value, x } => Some((*value, x)),
            _ => None,
        }
    }
}

const EPS: f64 = 1e-9;

impl Lp {
    pub fn new(objective: Vec<f64>) -> Self {
        Self {
            objective,
            constraints: Vec::new(),
        }
    }

    pub fn constrain(&mut self, coeffs: Vec<f64>, cmp: Cmp, rhs: f64) -> &mut Self {
        assert_eq!(
            coeffs.len(),
            self.objective.len(),
            "constraint arity must match objective arity"
        );
        self.constraints.push(Constraint { coeffs, cmp, rhs });
        self
    }

    /// Solve with two-phase simplex.
    pub fn solve(&self) -> LpResult {
        let n = self.objective.len();
        let m = self.constraints.len();
        if n == 0 {
            return LpResult::Optimal {
                value: 0.0,
                x: vec![],
            };
        }

        // Normalize rows to non-negative rhs (flip sense when negating).
        let mut rows: Vec<Constraint> = self.constraints.clone();
        for r in rows.iter_mut() {
            if r.rhs < 0.0 {
                for c in r.coeffs.iter_mut() {
                    *c = -*c;
                }
                r.rhs = -r.rhs;
                r.cmp = match r.cmp {
                    Cmp::Le => Cmp::Ge,
                    Cmp::Ge => Cmp::Le,
                    Cmp::Eq => Cmp::Eq,
                };
            }
        }

        // Column layout: [structural n][slack/surplus][artificial]
        let n_slack = rows
            .iter()
            .filter(|r| matches!(r.cmp, Cmp::Le | Cmp::Ge))
            .count();
        let n_art = rows
            .iter()
            .filter(|r| matches!(r.cmp, Cmp::Ge | Cmp::Eq))
            .count();
        let total = n + n_slack + n_art;

        // Tableau: m rows × (total + 1 rhs column).
        let mut t = vec![vec![0.0f64; total + 1]; m];
        let mut basis = vec![0usize; m];
        let mut slack_i = 0;
        let mut art_i = 0;
        for (i, r) in rows.iter().enumerate() {
            t[i][..n].copy_from_slice(&r.coeffs);
            t[i][total] = r.rhs;
            match r.cmp {
                Cmp::Le => {
                    t[i][n + slack_i] = 1.0;
                    basis[i] = n + slack_i;
                    slack_i += 1;
                }
                Cmp::Ge => {
                    t[i][n + slack_i] = -1.0; // surplus
                    t[i][n + n_slack + art_i] = 1.0;
                    basis[i] = n + n_slack + art_i;
                    slack_i += 1;
                    art_i += 1;
                }
                Cmp::Eq => {
                    t[i][n + n_slack + art_i] = 1.0;
                    basis[i] = n + n_slack + art_i;
                    art_i += 1;
                }
            }
        }

        // --- Phase 1: minimize sum of artificials (maximize −Σ art). ---
        if n_art > 0 {
            let mut obj = vec![0.0f64; total];
            for j in (n + n_slack)..total {
                obj[j] = -1.0;
            }
            let status = simplex_core(&mut t, &mut basis, &obj, total);
            if status == CoreStatus::Unbounded {
                // Phase 1 objective is bounded by 0; unbounded means a bug.
                unreachable!("phase-1 cannot be unbounded");
            }
            // Objective value = −Σ artificials at optimum.
            let phase1: f64 = basis
                .iter()
                .enumerate()
                .filter(|(_, &b)| b >= n + n_slack)
                .map(|(i, _)| t[i][total])
                .sum();
            if phase1 > 1e-7 {
                return LpResult::Infeasible;
            }
            // Drive remaining (degenerate, zero-valued) artificials out of
            // the basis where possible.
            for i in 0..m {
                if basis[i] >= n + n_slack {
                    if let Some(j) = (0..n + n_slack).find(|&j| t[i][j].abs() > EPS) {
                        pivot(&mut t, &mut basis, i, j, total);
                    }
                }
            }
        }

        // --- Phase 2: maximize the real objective. ---
        // Zero out the artificial columns so they never re-enter.
        for row in t.iter_mut() {
            for j in (n + n_slack)..total {
                row[j] = 0.0;
            }
        }
        let mut obj = vec![0.0f64; total];
        obj[..n].copy_from_slice(&self.objective);
        let status = simplex_core(&mut t, &mut basis, &obj, total);
        if status == CoreStatus::Unbounded {
            return LpResult::Unbounded;
        }

        let mut x = vec![0.0f64; n];
        for (i, &b) in basis.iter().enumerate() {
            if b < n {
                x[b] = t[i][total];
            }
        }
        let value: f64 = x
            .iter()
            .zip(self.objective.iter())
            .map(|(xi, ci)| xi * ci)
            .sum();
        LpResult::Optimal { value, x }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CoreStatus {
    Optimal,
    Unbounded,
}

/// Run primal simplex on tableau `t` with basis `basis`, maximizing `obj`.
/// Dantzig's rule with a Bland fallback after a stall budget (anti-cycle).
fn simplex_core(
    t: &mut [Vec<f64>],
    basis: &mut [usize],
    obj: &[f64],
    total: usize,
) -> CoreStatus {
    let m = t.len();
    let mut iters = 0usize;
    let max_iters = 50 * (total + m).max(100);
    loop {
        iters += 1;
        let bland = iters > max_iters / 2;
        // Reduced costs: c_j − c_B · B⁻¹ A_j (computed from the tableau).
        let mut entering: Option<usize> = None;
        let mut best = EPS;
        for j in 0..total {
            if basis.contains(&j) {
                continue;
            }
            let mut red = obj[j];
            for i in 0..m {
                red -= obj[basis[i]] * t[i][j];
            }
            if red > EPS {
                if bland {
                    entering = Some(j);
                    break;
                }
                if red > best {
                    best = red;
                    entering = Some(j);
                }
            }
        }
        let Some(e) = entering else {
            return CoreStatus::Optimal;
        };

        // Ratio test (Bland tie-break on row basis index).
        let mut leaving: Option<usize> = None;
        let mut best_ratio = f64::INFINITY;
        for i in 0..m {
            if t[i][e] > EPS {
                let ratio = t[i][total] / t[i][e];
                if ratio < best_ratio - EPS
                    || (ratio < best_ratio + EPS
                        && leaving.map(|l| basis[i] < basis[l]).unwrap_or(false))
                {
                    best_ratio = ratio;
                    leaving = Some(i);
                }
            }
        }
        let Some(l) = leaving else {
            return CoreStatus::Unbounded;
        };
        pivot(t, basis, l, e, total);
        if iters > max_iters {
            // Degenerate stall guard; with Bland's rule this should not
            // trigger, but return the current (feasible) point if it does.
            return CoreStatus::Optimal;
        }
    }
}

fn pivot(t: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize, total: usize) {
    let m = t.len();
    let p = t[row][col];
    debug_assert!(p.abs() > EPS);
    for j in 0..=total {
        t[row][j] /= p;
    }
    for i in 0..m {
        if i != row && t[i][col].abs() > EPS {
            let f = t[i][col];
            for j in 0..=total {
                t[i][j] -= f * t[row][j];
            }
        }
    }
    basis[row] = col;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn solve(obj: Vec<f64>, cons: Vec<(Vec<f64>, Cmp, f64)>) -> LpResult {
        let mut lp = Lp::new(obj);
        for (c, s, r) in cons {
            lp.constrain(c, s, r);
        }
        lp.solve()
    }

    #[test]
    fn textbook_max() {
        // max 3x + 5y, x ≤ 4, 2y ≤ 12, 3x + 2y ≤ 18 → x=2, y=6, z=36.
        let r = solve(
            vec![3.0, 5.0],
            vec![
                (vec![1.0, 0.0], Cmp::Le, 4.0),
                (vec![0.0, 2.0], Cmp::Le, 12.0),
                (vec![3.0, 2.0], Cmp::Le, 18.0),
            ],
        );
        let (v, x) = r.optimal().unwrap();
        assert!((v - 36.0).abs() < 1e-7);
        assert!((x[0] - 2.0).abs() < 1e-7);
        assert!((x[1] - 6.0).abs() < 1e-7);
    }

    #[test]
    fn ge_and_eq_constraints() {
        // max x + y, x + y = 10, x ≥ 3, y ≥ 2 → value 10.
        let r = solve(
            vec![1.0, 1.0],
            vec![
                (vec![1.0, 1.0], Cmp::Eq, 10.0),
                (vec![1.0, 0.0], Cmp::Ge, 3.0),
                (vec![0.0, 1.0], Cmp::Ge, 2.0),
            ],
        );
        let (v, x) = r.optimal().unwrap();
        assert!((v - 10.0).abs() < 1e-7);
        assert!(x[0] >= 3.0 - 1e-7 && x[1] >= 2.0 - 1e-7);
    }

    #[test]
    fn infeasible_detected() {
        let r = solve(
            vec![1.0],
            vec![
                (vec![1.0], Cmp::Ge, 5.0),
                (vec![1.0], Cmp::Le, 3.0),
            ],
        );
        assert_eq!(r, LpResult::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let r = solve(vec![1.0, 0.0], vec![(vec![0.0, 1.0], Cmp::Le, 1.0)]);
        assert_eq!(r, LpResult::Unbounded);
    }

    #[test]
    fn negative_rhs_normalization() {
        // max −x s.t. −x ≤ −2  (i.e. x ≥ 2) → x = 2, value −2.
        let r = solve(vec![-1.0], vec![(vec![-1.0], Cmp::Le, -2.0)]);
        let (v, x) = r.optimal().unwrap();
        assert!((x[0] - 2.0).abs() < 1e-7);
        assert!((v + 2.0).abs() < 1e-7);
    }

    #[test]
    fn degenerate_lp_terminates() {
        // Klee-Minty-flavoured degenerate instance; just require optimality.
        let r = solve(
            vec![100.0, 10.0, 1.0],
            vec![
                (vec![1.0, 0.0, 0.0], Cmp::Le, 1.0),
                (vec![20.0, 1.0, 0.0], Cmp::Le, 100.0),
                (vec![200.0, 20.0, 1.0], Cmp::Le, 10000.0),
            ],
        );
        let (v, _) = r.optimal().unwrap();
        assert!((v - 10000.0).abs() < 1e-4, "v={v}");
    }

    #[test]
    fn mmf_shaped_lp() {
        // The paper's Program 3 on Table 4's instance restricted to two
        // configurations {R}, {S}: V = [[1,0],[1,0],[0,1]] →
        // max λ s.t. x_R ≥ λ (twice), x_S ≥ λ, x_R + x_S ≤ 1 → λ = 1/2.
        let r = solve(
            vec![0.0, 0.0, 1.0], // vars: x_R, x_S, λ
            vec![
                (vec![1.0, 0.0, -1.0], Cmp::Ge, 0.0),
                (vec![1.0, 0.0, -1.0], Cmp::Ge, 0.0),
                (vec![0.0, 1.0, -1.0], Cmp::Ge, 0.0),
                (vec![1.0, 1.0, 0.0], Cmp::Le, 1.0),
            ],
        );
        let (v, x) = r.optimal().unwrap();
        assert!((v - 0.5).abs() < 1e-7, "λ={v} x={x:?}");
    }

    #[test]
    fn zero_variable_lp() {
        let r = Lp::new(vec![]).solve();
        assert_eq!(r.optimal().unwrap().0, 0.0);
    }

    /// Randomized cross-check against brute-force vertex enumeration on
    /// small dense ≤-form LPs (n=2..3, m=2..4).
    #[test]
    fn random_lps_match_vertex_enumeration() {
        use crate::util::proptest::{check, no_shrink};
        use crate::util::rng::Pcg64;

        #[derive(Debug)]
        struct Inst {
            obj: Vec<f64>,
            rows: Vec<(Vec<f64>, f64)>,
        }

        fn gen(rng: &mut Pcg64) -> Inst {
            let n = 2 + rng.index(2);
            let m = 2 + rng.index(3);
            let obj: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 4.0)).collect();
            // Positive row coefficients + positive rhs ⇒ bounded, feasible.
            let rows: Vec<(Vec<f64>, f64)> = (0..m)
                .map(|_| {
                    let coeffs: Vec<f64> =
                        (0..n).map(|_| rng.range_f64(0.2, 3.0)).collect();
                    (coeffs, rng.range_f64(1.0, 8.0))
                })
                .collect();
            Inst { obj, rows }
        }

        // Brute force: enumerate all intersections of n active constraints
        // (from rows + axes), keep feasible points, maximize objective.
        fn brute(inst: &Inst) -> f64 {
            let n = inst.obj.len();
            // Build full constraint list: rows (a·x ≤ b) and axes (x_i ≥ 0).
            let mut planes: Vec<(Vec<f64>, f64)> = inst.rows.clone();
            for i in 0..n {
                let mut a = vec![0.0; n];
                a[i] = -1.0;
                planes.push((a, 0.0));
            }
            let k = planes.len();
            let mut best = f64::NEG_INFINITY;
            // Choose n planes to be active; solve the n×n system by
            // Gaussian elimination.
            let mut combo = vec![0usize; n];
            fn rec(
                planes: &[(Vec<f64>, f64)],
                obj: &[f64],
                combo: &mut Vec<usize>,
                start: usize,
                depth: usize,
                best: &mut f64,
            ) {
                let n = obj.len();
                if depth == n {
                    // Solve active system.
                    let mut a = vec![vec![0.0; n + 1]; n];
                    for (r, &pi) in combo.iter().enumerate() {
                        a[r][..n].copy_from_slice(&planes[pi].0);
                        a[r][n] = planes[pi].1;
                    }
                    // Gaussian elimination with partial pivoting.
                    for col in 0..n {
                        let piv = (col..n)
                            .max_by(|&i, &j| {
                                a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap()
                            })
                            .unwrap();
                        if a[piv][col].abs() < 1e-10 {
                            return;
                        }
                        a.swap(col, piv);
                        for i in 0..n {
                            if i != col {
                                let f = a[i][col] / a[col][col];
                                for j in col..=n {
                                    a[i][j] -= f * a[col][j];
                                }
                            }
                        }
                    }
                    let x: Vec<f64> = (0..n).map(|i| a[i][n] / a[i][i]).collect();
                    // Feasibility w.r.t. every plane.
                    for (coeffs, rhs) in planes {
                        let lhs: f64 =
                            coeffs.iter().zip(&x).map(|(c, xi)| c * xi).sum();
                        if lhs > rhs + 1e-6 {
                            return;
                        }
                    }
                    let v: f64 = obj.iter().zip(&x).map(|(c, xi)| c * xi).sum();
                    if v > *best {
                        *best = v;
                    }
                    return;
                }
                for p in start..planes.len() {
                    combo[depth] = p;
                    rec(planes, obj, combo, p + 1, depth + 1, best);
                }
            }
            rec(&planes, &inst.obj, &mut combo, 0, 0, &mut best);
            assert_ne!(k, 0);
            best
        }

        check(
            60,
            gen,
            no_shrink,
            |inst| {
                let mut lp = Lp::new(inst.obj.clone());
                for (c, r) in &inst.rows {
                    lp.constrain(c.clone(), Cmp::Le, *r);
                }
                let (v, _) = lp.solve().optimal().ok_or("expected optimal")?;
                let bf = brute(inst);
                if (v - bf).abs() > 1e-5 * (1.0 + bf.abs()) {
                    return Err(format!("simplex {v} != brute {bf}"));
                }
                Ok(())
            },
        );
    }
}
