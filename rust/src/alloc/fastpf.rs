//! FASTPF (§4.3, Algorithm 3): proportional fairness over the pruned
//! configuration space via projected gradient ascent on the equivalent
//! unconstrained program (Program 2):
//!
//!   max g(x) = Σ_i λ_i log V_i(x) − Λ·‖x‖   s.t. x ≥ 0,  Λ = Σ_i λ_i
//!
//! (the dual variable of ‖x‖ ≤ 1 equals Λ at the PF optimum — Theorem 2's
//! d = N generalized to weights per §3.4). The optimum has ‖x‖ = 1; we
//! renormalize the numeric solution.
//!
//! The allocation satisfies the randomized core in expectation
//! (Theorem 2), hence also SI and PE.

use crate::alloc::config_space::ConfigSpace;
use crate::alloc::warm::{BatchSignature, FastPfWarm, WarmState};
use crate::alloc::{Allocation, ConfigMask, Policy};
use crate::cache::tier::TierAssignment;
use crate::domain::utility::BatchUtilities;
use crate::solver::gradient::{maximize, GradientConfig, Objective};
use crate::util::rng::Pcg64;

/// Floor on V_i(x) inside the log to keep gradients finite; tenants with
/// zero utility dominate the gradient direction as intended.
const V_FLOOR: f64 = 1e-9;

#[derive(Debug)]
pub struct FastPf {
    pub prune_vectors: usize,
    pub gradient: GradientConfig,
}

impl Default for FastPf {
    fn default() -> Self {
        Self {
            prune_vectors: 50,
            gradient: GradientConfig {
                max_iters: 500,
                ..Default::default()
            },
        }
    }
}

/// The PF objective over a fixed configuration space.
pub struct PfObjective<'a> {
    space: &'a ConfigSpace,
    /// Active tenants and their weights.
    tenants: Vec<(usize, f64)>,
    total_weight: f64,
}

impl<'a> PfObjective<'a> {
    pub fn new(space: &'a ConfigSpace, batch: &BatchUtilities) -> Self {
        let tenants: Vec<(usize, f64)> = batch
            .active_tenants()
            .into_iter()
            .map(|i| (i, batch.weights[i]))
            .collect();
        let total_weight = tenants.iter().map(|(_, w)| w).sum();
        Self {
            space,
            tenants,
            total_weight,
        }
    }
}

impl Objective for PfObjective<'_> {
    fn value(&self, x: &[f64]) -> f64 {
        let norm: f64 = x.iter().sum();
        let mut g = -self.total_weight * norm;
        for &(i, w) in &self.tenants {
            g += w * self.space.scaled_utility(i, x).max(V_FLOOR).ln();
        }
        g
    }

    fn gradient(&self, x: &[f64], out: &mut [f64]) {
        // ∂g/∂x_S = Σ_i λ_i V_i(S)/V_i(x) − Λ.
        for o in out.iter_mut() {
            *o = -self.total_weight;
        }
        for &(i, w) in &self.tenants {
            let vi = self.space.scaled_utility(i, x).max(V_FLOOR);
            let f = w / vi;
            for (o, row) in out.iter_mut().zip(self.space.rows()) {
                *o += f * row[i];
            }
        }
    }
}

impl FastPf {
    /// Solve PF over an explicit space; returns the (normalized)
    /// allocation vector. Exposed for reuse by tests, the pruning-error
    /// experiment, and cross-validation against the compiled L2 artifact.
    pub fn solve_over(
        space: &ConfigSpace,
        batch: &BatchUtilities,
        cfg: &GradientConfig,
    ) -> Vec<f64> {
        let m = space.len();
        let x0 = vec![1.0 / m.max(1) as f64; m];
        Self::solve_over_from(space, batch, cfg, &x0)
    }

    /// [`FastPf::solve_over`] from an explicit starting point — the
    /// warm path seeds the previous batch's converged distribution, so
    /// the gradient's relative-tolerance check exits after a handful of
    /// iterations in steady state instead of re-climbing from uniform.
    pub fn solve_over_from(
        space: &ConfigSpace,
        batch: &BatchUtilities,
        cfg: &GradientConfig,
        x0: &[f64],
    ) -> Vec<f64> {
        let m = space.len();
        if m == 0 || batch.active_tenants().is_empty() {
            return vec![0.0; m.max(1)];
        }
        let obj = PfObjective::new(space, batch);
        let mut result = maximize(&obj, x0, cfg);
        let norm: f64 = result.x.iter().sum();
        if norm > 0.0 {
            for xi in result.x.iter_mut() {
                *xi /= norm;
            }
        }
        result.x
    }

    /// Build the final allocation from a solved distribution over the
    /// space (deterministic empty fallback when the solve vanished).
    fn allocation_of(space: &ConfigSpace, x: &[f64], batch: &BatchUtilities) -> Allocation {
        if x.iter().sum::<f64>() <= 0.0 {
            return Allocation::deterministic(ConfigMask::empty(batch.n_views()));
        }
        Allocation::from_weighted_pairs(space.pairs().zip(x.iter().copied()).collect())
    }

    /// Store the just-solved batch as the next warm start.
    fn remember(
        warm: &mut WarmState,
        sig: BatchSignature,
        space: &ConfigSpace,
        rand_w: Vec<Vec<f64>>,
        rand_opt: Vec<TierAssignment>,
        x: &[f64],
    ) {
        warm.fastpf = Some(FastPfWarm {
            sig,
            pairs: space.pairs().collect(),
            rand_w,
            rand_opt,
            x_by_pair: space.pairs().zip(x.iter().copied()).collect(),
        });
    }
}

impl Policy for FastPf {
    fn name(&self) -> &'static str {
        "FASTPF"
    }

    fn allocate(&self, batch: &BatchUtilities, rng: &mut Pcg64) -> Allocation {
        let space = ConfigSpace::pruned(batch, self.prune_vectors, rng);
        let x = Self::solve_over(&space, batch, &self.gradient);
        Self::allocation_of(&space, &x, batch)
    }

    /// Warm-started FASTPF: re-score the carried configs against the
    /// fresh batch (cheap), re-run the exact WELFARE knapsack only for
    /// random weight vectors whose cached optimum is invalidated, and
    /// start the gradient from the previous converged distribution.
    fn allocate_warm(
        &self,
        batch: &BatchUtilities,
        rng: &mut Pcg64,
        warm: &mut WarmState,
    ) -> Allocation {
        let sig = BatchSignature::of(batch);
        let carried = warm
            .fastpf
            .take()
            .filter(|p| p.sig.same_shape(&sig) && p.rand_w.len() == self.prune_vectors);
        let Some(prev) = carried else {
            // Cold prune (shape changed, state invalidated, or first
            // batch), recording the trace for the next batch.
            let (space, trace) = ConfigSpace::pruned_traced(batch, self.prune_vectors, rng);
            let x = Self::solve_over(&space, batch, &self.gradient);
            let alloc = Self::allocation_of(&space, &x, batch);
            Self::remember(warm, sig, &space, trace.rand_w, trace.rand_opt, &x);
            return alloc;
        };

        // Re-score every carried config against the new batch: the
        // candidate set that challenges each cached optimum below.
        let prev_sig = prev.sig;
        let prev_space = ConfigSpace::from_pairs(batch, prev.pairs);

        // Fresh space with the same enumeration skeleton as `pruned`,
        // but only the cheap anchors solved exactly up front.
        let n = batch.n_tenants;
        let mut space = ConfigSpace::new(n);
        space.push(batch, ConfigMask::empty(batch.n_views()));
        let mut welfare = batch.welfare_template();
        for i in 0..n {
            if batch.u_star[i] <= 0.0 {
                continue;
            }
            let mut w = vec![0.0; n];
            w[i] = 1.0;
            let pair = welfare.solve_pair(&w);
            space.push_pair(batch, pair);
        }
        let pair = welfare.solve_pair(&vec![1.0; n]);
        space.push_pair(batch, pair);

        // The expensive half: one exact knapsack per random vector on
        // the cold path. Reuse the cached optimum S_k when (a) the
        // class structure over S_k's member views (either tier) is
        // unchanged and (b) S_k still wins weight vector w_k within the
        // re-scored previous space (every old candidate re-challenges
        // it under the new utilities); otherwise re-solve exactly.
        let mut rand_opt = Vec::with_capacity(prev.rand_w.len());
        for (w, prev_opt) in prev.rand_w.iter().zip(&prev.rand_opt) {
            let still_optimal = sig.views_unchanged(&prev_sig, &prev_opt.union())
                && prev_space
                    .id_of_pair(prev_opt)
                    .is_some_and(|id| prev_space.restricted_welfare(w) == id);
            let opt = if still_optimal {
                prev_opt.clone()
            } else {
                welfare.solve_pair(w)
            };
            space.push_pair(batch, opt.clone());
            rand_opt.push(opt);
        }

        // Gradient warm start from the previous converged distribution,
        // mapped through the interner onto the fresh id order.
        let m = space.len();
        let mut x0 = vec![0.0; m];
        for (pair, p) in &prev.x_by_pair {
            if let Some(id) = space.id_of_pair(pair) {
                x0[id.0] += *p;
            }
        }
        let seeded: f64 = x0.iter().sum();
        if seeded > 1e-12 {
            for xi in x0.iter_mut() {
                *xi /= seeded;
            }
        } else {
            x0 = vec![1.0 / m.max(1) as f64; m];
        }
        let x = Self::solve_over_from(&space, batch, &self.gradient, &x0);
        let alloc = Self::allocation_of(&space, &x, batch);
        Self::remember(warm, sig, &space, prev.rand_w, rand_opt, &x);
        alloc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::testing::{table2, table4, table5};

    fn pf_alloc(b: &BatchUtilities, seed: u64) -> Allocation {
        FastPf::default().allocate(b, &mut Pcg64::new(seed))
    }

    #[test]
    fn table2_equal_thirds() {
        let b = table2();
        let a = pf_alloc(&b, 1);
        let v = a.expected_scaled_utilities(&b);
        for vi in &v {
            assert!((vi - 1.0 / 3.0).abs() < 1e-3, "v={v:?}");
        }
    }

    #[test]
    fn table4_core_allocation() {
        // Paper (§3.3): the core allocation for Table 4 with N tenants is
        // x_R = (N−1)/N, x_S = 1/N — PF must find it (MMF picks ½/½).
        let n = 4;
        let b = table4(n);
        let a = pf_alloc(&b, 2);
        let v = a.expected_scaled_utilities(&b);
        // First N−1 tenants get (N−1)/N, the last gets 1/N.
        for vi in v.iter().take(n - 1) {
            assert!((vi - (n as f64 - 1.0) / n as f64).abs() < 5e-3, "v={v:?}");
        }
        assert!((v[n - 1] - 1.0 / n as f64).abs() < 5e-3, "v={v:?}");
    }

    #[test]
    fn table5_core_allocation() {
        // The paper notes x = ⟨½, ½⟩ lies in the core for Table 5; the
        // exact PF optimum is x_S = 0.50505 (stationarity of
        // log x_S + log(0.99·x_R + 0.01)), so V_A = 0.50505.
        let b = table5();
        let a = pf_alloc(&b, 3);
        let v = a.expected_scaled_utilities(&b);
        assert!((v[0] - 0.50505).abs() < 5e-3, "v={v:?}");
        assert!((v[1] - 0.49999).abs() < 5e-3, "v={v:?}");
    }

    #[test]
    fn pf_is_sharing_incentive() {
        for (b, n) in [(table2(), 3), (table4(5), 5), (table5(), 2)] {
            let a = pf_alloc(&b, 7);
            let v = a.expected_scaled_utilities(&b);
            for (i, vi) in v.iter().enumerate() {
                assert!(
                    *vi >= 1.0 / n as f64 - 5e-3,
                    "tenant {i} V={vi} < 1/{n}"
                );
            }
        }
    }

    #[test]
    fn warm_matches_cold_quality_over_steady_sequence() {
        use crate::alloc::testing::matrix_instance;
        use crate::alloc::warm::WarmState;
        let policy = FastPf::default();
        let mut warm = WarmState::new();
        // Utilities drift batch to batch; the class structure holds —
        // the §5.3 steady state. Warm must track cold within ε on the
        // PF objective (Σ log V_i) and on per-tenant fairness.
        for k in 0..6u64 {
            let a = 1 + (k % 3);
            let rows: Vec<Vec<u64>> =
                vec![vec![2 + a, 1, 0], vec![0, 1 + a, 0], vec![0, 1, 2 + a]];
            let refs: Vec<&[u64]> = rows.iter().map(|r| r.as_slice()).collect();
            let b = matrix_instance(&refs, 1.0);
            let cold = policy.allocate(&b, &mut Pcg64::new(100 + k));
            let warm_a = policy.allocate_warm(&b, &mut Pcg64::new(100 + k), &mut warm);
            let vc = cold.expected_scaled_utilities(&b);
            let vw = warm_a.expected_scaled_utilities(&b);
            let obj = |v: &[f64]| v.iter().map(|x| x.max(1e-9).ln()).sum::<f64>();
            assert!(
                (obj(&vc) - obj(&vw)).abs() < 0.05,
                "batch {k}: cold {vc:?} warm {vw:?}"
            );
            for (c, w) in vc.iter().zip(&vw) {
                assert!((c - w).abs() < 0.05, "batch {k}: cold {vc:?} warm {vw:?}");
            }
        }
    }

    #[test]
    fn warm_reuses_random_vectors_and_invalidates_on_shape_change() {
        use crate::alloc::testing::matrix_instance;
        use crate::alloc::warm::WarmState;
        let policy = FastPf::default();
        let mut warm = WarmState::new();
        let b1 = matrix_instance(&[&[2, 1, 0], &[0, 1, 0], &[0, 1, 2]], 1.0);
        policy.allocate_warm(&b1, &mut Pcg64::new(1), &mut warm);
        let w_first = warm.fastpf.as_ref().unwrap().rand_w.clone();
        assert_eq!(w_first.len(), policy.prune_vectors);
        // Same shape next batch: the drawn vectors are carried verbatim
        // (no RNG consumption on the warm path).
        let b2 = matrix_instance(&[&[4, 2, 0], &[0, 2, 0], &[0, 2, 4]], 1.0);
        policy.allocate_warm(&b2, &mut Pcg64::new(2), &mut warm);
        assert_eq!(warm.fastpf.as_ref().unwrap().rand_w, w_first);
        // Budget change = shape change: full cold re-prune, fresh draws.
        let b3 = matrix_instance(&[&[4, 2, 0], &[0, 2, 0], &[0, 2, 4]], 2.0);
        policy.allocate_warm(&b3, &mut Pcg64::new(3), &mut warm);
        assert_ne!(warm.fastpf.as_ref().unwrap().rand_w, w_first);
        // Explicit invalidation also voids the carried state.
        warm.invalidate();
        assert!(warm.fastpf.is_none());
    }

    #[test]
    fn warm_first_call_matches_cold_exactly() {
        use crate::alloc::warm::WarmState;
        // With no carried state, allocate_warm runs the same pruning
        // and gradient as allocate, consuming the same RNG stream.
        let b = table2();
        let policy = FastPf::default();
        let cold = policy.allocate(&b, &mut Pcg64::new(11));
        let mut warm = WarmState::new();
        let first = policy.allocate_warm(&b, &mut Pcg64::new(11), &mut warm);
        assert_eq!(cold.configs, first.configs);
        assert_eq!(cold.probs, first.probs);
    }

    #[test]
    fn allocation_is_normalized() {
        let b = table4(4);
        let a = pf_alloc(&b, 8);
        assert!((a.total_probability() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn grouped_instance_proportional_split() {
        // Lemma 1's grouped instance: k=3 unit views, groups of sizes
        // 3,2,1 → PF rates x_i = N_i/N = 1/2, 1/3, 1/6.
        use crate::alloc::testing::matrix_instance;
        let rows: Vec<Vec<u64>> = vec![
            vec![1, 0, 0],
            vec![1, 0, 0],
            vec![1, 0, 0],
            vec![0, 1, 0],
            vec![0, 1, 0],
            vec![0, 0, 1],
        ];
        let refs: Vec<&[u64]> = rows.iter().map(|r| r.as_slice()).collect();
        let b = matrix_instance(&refs, 1.0);
        let a = pf_alloc(&b, 9);
        let v = a.expected_scaled_utilities(&b);
        let expect = [0.5, 0.5, 0.5, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 6.0];
        for (vi, e) in v.iter().zip(expect) {
            assert!((vi - e).abs() < 6e-3, "v={v:?}");
        }
    }
}
