//! FASTPF (§4.3, Algorithm 3): proportional fairness over the pruned
//! configuration space via projected gradient ascent on the equivalent
//! unconstrained program (Program 2):
//!
//!   max g(x) = Σ_i λ_i log V_i(x) − Λ·‖x‖   s.t. x ≥ 0,  Λ = Σ_i λ_i
//!
//! (the dual variable of ‖x‖ ≤ 1 equals Λ at the PF optimum — Theorem 2's
//! d = N generalized to weights per §3.4). The optimum has ‖x‖ = 1; we
//! renormalize the numeric solution.
//!
//! The allocation satisfies the randomized core in expectation
//! (Theorem 2), hence also SI and PE.

use crate::alloc::config_space::ConfigSpace;
use crate::alloc::{Allocation, ConfigMask, Policy};
use crate::domain::utility::BatchUtilities;
use crate::solver::gradient::{maximize, GradientConfig, Objective};
use crate::util::rng::Pcg64;

/// Floor on V_i(x) inside the log to keep gradients finite; tenants with
/// zero utility dominate the gradient direction as intended.
const V_FLOOR: f64 = 1e-9;

#[derive(Debug)]
pub struct FastPf {
    pub prune_vectors: usize,
    pub gradient: GradientConfig,
}

impl Default for FastPf {
    fn default() -> Self {
        Self {
            prune_vectors: 50,
            gradient: GradientConfig {
                max_iters: 500,
                ..Default::default()
            },
        }
    }
}

/// The PF objective over a fixed configuration space.
pub struct PfObjective<'a> {
    space: &'a ConfigSpace,
    /// Active tenants and their weights.
    tenants: Vec<(usize, f64)>,
    total_weight: f64,
}

impl<'a> PfObjective<'a> {
    pub fn new(space: &'a ConfigSpace, batch: &BatchUtilities) -> Self {
        let tenants: Vec<(usize, f64)> = batch
            .active_tenants()
            .into_iter()
            .map(|i| (i, batch.weights[i]))
            .collect();
        let total_weight = tenants.iter().map(|(_, w)| w).sum();
        Self {
            space,
            tenants,
            total_weight,
        }
    }
}

impl Objective for PfObjective<'_> {
    fn value(&self, x: &[f64]) -> f64 {
        let norm: f64 = x.iter().sum();
        let mut g = -self.total_weight * norm;
        for &(i, w) in &self.tenants {
            g += w * self.space.scaled_utility(i, x).max(V_FLOOR).ln();
        }
        g
    }

    fn gradient(&self, x: &[f64], out: &mut [f64]) {
        // ∂g/∂x_S = Σ_i λ_i V_i(S)/V_i(x) − Λ.
        for o in out.iter_mut() {
            *o = -self.total_weight;
        }
        for &(i, w) in &self.tenants {
            let vi = self.space.scaled_utility(i, x).max(V_FLOOR);
            let f = w / vi;
            for (o, row) in out.iter_mut().zip(self.space.rows()) {
                *o += f * row[i];
            }
        }
    }
}

impl FastPf {
    /// Solve PF over an explicit space; returns the (normalized)
    /// allocation vector. Exposed for reuse by tests, the pruning-error
    /// experiment, and cross-validation against the compiled L2 artifact.
    pub fn solve_over(
        space: &ConfigSpace,
        batch: &BatchUtilities,
        cfg: &GradientConfig,
    ) -> Vec<f64> {
        let m = space.len();
        if m == 0 || batch.active_tenants().is_empty() {
            return vec![0.0; m.max(1)];
        }
        let obj = PfObjective::new(space, batch);
        let x0 = vec![1.0 / m as f64; m];
        let mut result = maximize(&obj, &x0, cfg);
        let norm: f64 = result.x.iter().sum();
        if norm > 0.0 {
            for xi in result.x.iter_mut() {
                *xi /= norm;
            }
        }
        result.x
    }
}

impl Policy for FastPf {
    fn name(&self) -> &'static str {
        "FASTPF"
    }

    fn allocate(&self, batch: &BatchUtilities, rng: &mut Pcg64) -> Allocation {
        let space = ConfigSpace::pruned(batch, self.prune_vectors, rng);
        let x = Self::solve_over(&space, batch, &self.gradient);
        if x.iter().sum::<f64>() <= 0.0 {
            return Allocation::deterministic(ConfigMask::empty(batch.n_views()));
        }
        Allocation::from_weighted(
            space
                .masks()
                .iter()
                .cloned()
                .zip(x.iter().copied())
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::testing::{table2, table4, table5};

    fn pf_alloc(b: &BatchUtilities, seed: u64) -> Allocation {
        FastPf::default().allocate(b, &mut Pcg64::new(seed))
    }

    #[test]
    fn table2_equal_thirds() {
        let b = table2();
        let a = pf_alloc(&b, 1);
        let v = a.expected_scaled_utilities(&b);
        for vi in &v {
            assert!((vi - 1.0 / 3.0).abs() < 1e-3, "v={v:?}");
        }
    }

    #[test]
    fn table4_core_allocation() {
        // Paper (§3.3): the core allocation for Table 4 with N tenants is
        // x_R = (N−1)/N, x_S = 1/N — PF must find it (MMF picks ½/½).
        let n = 4;
        let b = table4(n);
        let a = pf_alloc(&b, 2);
        let v = a.expected_scaled_utilities(&b);
        // First N−1 tenants get (N−1)/N, the last gets 1/N.
        for vi in v.iter().take(n - 1) {
            assert!((vi - (n as f64 - 1.0) / n as f64).abs() < 5e-3, "v={v:?}");
        }
        assert!((v[n - 1] - 1.0 / n as f64).abs() < 5e-3, "v={v:?}");
    }

    #[test]
    fn table5_core_allocation() {
        // The paper notes x = ⟨½, ½⟩ lies in the core for Table 5; the
        // exact PF optimum is x_S = 0.50505 (stationarity of
        // log x_S + log(0.99·x_R + 0.01)), so V_A = 0.50505.
        let b = table5();
        let a = pf_alloc(&b, 3);
        let v = a.expected_scaled_utilities(&b);
        assert!((v[0] - 0.50505).abs() < 5e-3, "v={v:?}");
        assert!((v[1] - 0.49999).abs() < 5e-3, "v={v:?}");
    }

    #[test]
    fn pf_is_sharing_incentive() {
        for (b, n) in [(table2(), 3), (table4(5), 5), (table5(), 2)] {
            let a = pf_alloc(&b, 7);
            let v = a.expected_scaled_utilities(&b);
            for (i, vi) in v.iter().enumerate() {
                assert!(
                    *vi >= 1.0 / n as f64 - 5e-3,
                    "tenant {i} V={vi} < 1/{n}"
                );
            }
        }
    }

    #[test]
    fn allocation_is_normalized() {
        let b = table4(4);
        let a = pf_alloc(&b, 8);
        assert!((a.total_probability() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn grouped_instance_proportional_split() {
        // Lemma 1's grouped instance: k=3 unit views, groups of sizes
        // 3,2,1 → PF rates x_i = N_i/N = 1/2, 1/3, 1/6.
        use crate::alloc::testing::matrix_instance;
        let rows: Vec<Vec<u64>> = vec![
            vec![1, 0, 0],
            vec![1, 0, 0],
            vec![1, 0, 0],
            vec![0, 1, 0],
            vec![0, 1, 0],
            vec![0, 0, 1],
        ];
        let refs: Vec<&[u64]> = rows.iter().map(|r| r.as_slice()).collect();
        let b = matrix_instance(&refs, 1.0);
        let a = pf_alloc(&b, 9);
        let v = a.expected_scaled_utilities(&b);
        let expect = [0.5, 0.5, 0.5, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 6.0];
        for (vi, e) in v.iter().zip(expect) {
            assert!((vi - e).abs() < 6e-3, "v={v:?}");
        }
    }
}
