//! MMF (§4.3): lexicographic max-min fairness over the pruned
//! configuration space, solved with the restricted linear program
//! (Program 3) and iterative saturation exactly as in paper ref 28:
//! maximize the minimum scaled utility; freeze tenants that cannot do
//! better; repeat until all tenants are saturated.
//!
//! Weighted tenants are handled by max-minning V_i(x)/w̃_i where w̃ is the
//! weight normalized to mean 1, reducing to the unweighted definition
//! for equal weights.

use crate::alloc::config_space::ConfigSpace;
use crate::alloc::{Allocation, ConfigMask, Policy};
use crate::domain::utility::BatchUtilities;
use crate::solver::simplex::{Cmp, Lp, LpResult};
use crate::util::rng::Pcg64;

#[derive(Debug)]
pub struct MaxMinFair {
    /// Number of random weight vectors for configuration pruning (§4.3;
    /// the paper's sweep shows 50 gives 0.6% error).
    pub prune_vectors: usize,
}

impl Default for MaxMinFair {
    fn default() -> Self {
        Self { prune_vectors: 50 }
    }
}

impl MaxMinFair {
    /// Lexicographic max-min over an explicit config space. Exposed so
    /// tests and the accelerated runtime path can reuse it.
    pub fn solve_over(
        space: &ConfigSpace,
        batch: &BatchUtilities,
    ) -> (Vec<f64>, Vec<f64>) {
        let active = batch.active_tenants();
        let m = space.len();
        if active.is_empty() || m == 0 {
            return (vec![0.0; m.max(1)], vec![0.0; batch.n_tenants]);
        }
        // Normalized weights w̃ (mean 1 over active tenants).
        let wsum: f64 = active.iter().map(|&i| batch.weights[i]).sum();
        let wnorm: Vec<f64> = (0..batch.n_tenants)
            .map(|i| batch.weights[i] * active.len() as f64 / wsum)
            .collect();

        // Saturated tenants and their frozen rates (of V_i/w̃_i).
        let mut saturated: Vec<Option<f64>> = vec![None; batch.n_tenants];
        let mut final_x = vec![0.0; m];

        // Effective rate of tenant i in the LP: Σ_S x_S V_i(S) / w̃_i.
        let rate_row = |i: usize| -> Vec<f64> {
            let mut row: Vec<f64> = space.rows().map(|r| r[i] / wnorm[i]).collect();
            row.push(0.0); // λ column, filled by caller
            row
        };

        for _round in 0..active.len() {
            let unsat: Vec<usize> = active
                .iter()
                .copied()
                .filter(|&i| saturated[i].is_none())
                .collect();
            if unsat.is_empty() {
                break;
            }
            // Vars: x_0..x_{m-1}, λ. Maximize λ.
            let mut obj = vec![0.0; m + 1];
            obj[m] = 1.0;
            let mut lp = Lp::new(obj);
            for &i in &unsat {
                let mut row = rate_row(i);
                row[m] = -1.0;
                lp.constrain(row, Cmp::Ge, 0.0);
            }
            for &i in &active {
                if let Some(r) = saturated[i] {
                    let row = rate_row(i);
                    lp.constrain(row, Cmp::Ge, r - 1e-9);
                }
            }
            let mut norm = vec![1.0; m];
            norm.push(0.0);
            lp.constrain(norm, Cmp::Le, 1.0);

            let LpResult::Optimal { value: lambda, x } = lp.solve() else {
                // Numerically infeasible round: keep the last solution.
                break;
            };
            final_x = x[..m].to_vec();

            // Saturation test per unsaturated tenant: can its rate exceed
            // λ while everyone else stays ≥ their bound?
            let mut any_unsaturated_left = false;
            for &i in &unsat {
                let mut obj_i = rate_row(i);
                obj_i[m] = 0.0;
                let mut lp2 = Lp::new(obj_i);
                for &j in &unsat {
                    if j != i {
                        let mut row = rate_row(j);
                        row[m] = 0.0;
                        lp2.constrain(row, Cmp::Ge, lambda - 1e-9);
                    }
                }
                for &j in &active {
                    if let Some(r) = saturated[j] {
                        let mut row = rate_row(j);
                        row[m] = 0.0;
                        lp2.constrain(row, Cmp::Ge, r - 1e-9);
                    }
                }
                let mut norm = vec![1.0; m];
                norm.push(0.0);
                lp2.constrain(norm, Cmp::Le, 1.0);
                match lp2.solve() {
                    LpResult::Optimal { value, .. } if value > lambda + 1e-7 => {
                        any_unsaturated_left = true;
                    }
                    _ => {
                        saturated[i] = Some(lambda);
                    }
                }
            }
            if !any_unsaturated_left {
                // Everyone still unsaturated is now pinned at λ.
                for &i in &unsat {
                    saturated[i].get_or_insert(lambda);
                }
            }
        }

        let rates: Vec<f64> = (0..batch.n_tenants)
            .map(|i| space.scaled_utility(i, &final_x))
            .collect();
        (final_x, rates)
    }
}

impl Policy for MaxMinFair {
    fn name(&self) -> &'static str {
        "MMF"
    }

    fn allocate(&self, batch: &BatchUtilities, rng: &mut Pcg64) -> Allocation {
        let space = ConfigSpace::pruned(batch, self.prune_vectors, rng);
        let (x, _) = Self::solve_over(&space, batch);
        if x.iter().sum::<f64>() <= 0.0 {
            return Allocation::deterministic(ConfigMask::empty(batch.n_views()));
        }
        Allocation::from_weighted_pairs(space.pairs().zip(x.iter().copied()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::testing::{matrix_instance, table2, table4, table5};

    fn mmf_alloc(b: &BatchUtilities, seed: u64) -> Allocation {
        MaxMinFair::default().allocate(b, &mut Pcg64::new(seed))
    }

    #[test]
    fn table2_equal_thirds() {
        let b = table2();
        let a = mmf_alloc(&b, 1);
        let v = a.expected_scaled_utilities(&b);
        for vi in &v {
            assert!((vi - 1.0 / 3.0).abs() < 1e-6, "v={v:?}");
        }
    }

    #[test]
    fn table4_half_half() {
        // Paper: MMF value is 1/2 via x_R = x_S = 1/2 (N = 4).
        let b = table4(4);
        let a = mmf_alloc(&b, 2);
        let v = a.expected_scaled_utilities(&b);
        for vi in &v {
            assert!((vi - 0.5).abs() < 1e-6, "v={v:?}");
        }
    }

    #[test]
    fn table5_half_half() {
        // The paper notes ⟨x_R = ½, x_S = ½⟩ lies in the core; the exact
        // max-min optimum equalizes V_A = x_S and V_B = 0.99·x_R + 0.01 at
        // x_S = 1/1.99 ⇒ both rates = 0.50251.
        let b = table5();
        let a = mmf_alloc(&b, 3);
        let v = a.expected_scaled_utilities(&b);
        assert!((v[0] - 0.50251).abs() < 1e-4, "v={v:?}");
        assert!((v[1] - 0.50251).abs() < 1e-4, "v={v:?}");
    }

    #[test]
    fn mmf_is_si_and_lexicographic() {
        // Lexicographic behaviour: tenant 0 can reach 1.0 without hurting
        // the min. Utilities: t0 wants v0 (only); t1 and t2 both want v1.
        // Budget 2 of 3 unit views → cache v0 and v1: everyone at 1.0.
        let b = matrix_instance(&[&[4, 0, 0], &[0, 3, 0], &[0, 3, 0]], 2.0);
        let a = mmf_alloc(&b, 4);
        let v = a.expected_scaled_utilities(&b);
        for vi in &v {
            assert!((vi - 1.0).abs() < 1e-6, "v={v:?}");
        }
    }

    #[test]
    fn weighted_mmf_favours_heavy_tenant() {
        use crate::domain::dataset::DatasetCatalog;
        use crate::domain::query::{Query, QueryId};
        use crate::domain::tenant::{TenantId, TenantSet};
        use crate::domain::view::{ViewCatalog, ViewId, ViewKind};

        let mut ds = DatasetCatalog::new();
        let mut vc = ViewCatalog::new();
        for v in 0..2 {
            let d = ds.add(&format!("d{v}"), 100);
            vc.add(&format!("v{v}"), d, ViewKind::BaseTable, 100, 100);
        }
        let mut ts = TenantSet::new();
        let a = ts.add("light", 1.0);
        let bq = ts.add("heavy", 3.0);
        let queries = vec![
            Query {
                id: QueryId(1),
                tenant: a,
                arrival: 0.0,
                template: "x".into(),
                required_views: vec![ViewId(0)],
                bytes_read: 10,
                compute_cost: 0.0,
            },
            Query {
                id: QueryId(2),
                tenant: bq,
                arrival: 0.0,
                template: "y".into(),
                required_views: vec![ViewId(1)],
                bytes_read: 10,
                compute_cost: 0.0,
            },
        ];
        let b = crate::domain::utility::BatchUtilities::build(&ts, &vc, 100.0, &queries, None);
        let alloc = mmf_alloc(&b, 5);
        let v = alloc.expected_scaled_utilities(&b);
        // Weight-proportional split: heavy tenant ≈ 3× the light one.
        assert!((v[1] / v[0] - 3.0).abs() < 0.05, "v={v:?}");
    }

    #[test]
    fn empty_batch_is_graceful() {
        let b = matrix_instance(&[&[0], &[0]], 1.0);
        let a = mmf_alloc(&b, 6);
        assert!((a.total_probability() - 1.0).abs() < 1e-9);
    }
}
