//! LRU (§1 Scenario 2): the traditional cache policy the paper argues
//! against. Batched approximation faithful to an access-driven LRU: the
//! policy keeps per-view recency state (most recent batch in which any
//! query demanded the view) and caches the most-recently-used views
//! that fit the budget, ties broken by demand frequency.
//!
//! LRU is neither Sharing Incentive nor core: a hot view monopolizes the
//! cache regardless of who benefits (SpaceBook's VP never sees `P`
//! cached while the analysts hammer `R`). Included as a baseline for
//! the fairness audit and ablations.

use std::sync::Mutex;

use crate::alloc::{Allocation, ConfigMask, Policy};
use crate::domain::utility::BatchUtilities;
use crate::util::rng::Pcg64;

#[derive(Debug, Default)]
struct LruState {
    /// Batch counter.
    tick: u64,
    /// Per-view last-demanded tick (0 = never).
    last_used: Vec<u64>,
}

/// Batched LRU view selection. Recency state lives behind a `Mutex`
/// (rather than a `RefCell`) so the policy is `Sync` and can run inside
/// the parallel experiment grid; each run owns its policy instance, so
/// the lock is never contended.
#[derive(Debug, Default)]
pub struct LeastRecentlyUsed {
    state: Mutex<LruState>,
}

impl Policy for LeastRecentlyUsed {
    fn name(&self) -> &'static str {
        "LRU"
    }

    fn allocate(&self, batch: &BatchUtilities, _rng: &mut Pcg64) -> Allocation {
        let nv = batch.n_views();
        let mut state = self.state.lock().unwrap();
        if state.last_used.len() != nv {
            // Fresh run (or a different universe): reset.
            state.last_used = vec![0; nv];
            state.tick = 0;
        }
        state.tick += 1;
        let tick = state.tick;

        // Demand counts this batch.
        let mut demand = vec![0u64; nv];
        for c in &batch.classes {
            for &v in &c.views {
                demand[v] += c.count as u64;
            }
        }
        for (v, &d) in demand.iter().enumerate() {
            if d > 0 {
                state.last_used[v] = tick;
            }
        }

        // Most-recently-used first, then most-demanded, then smallest.
        let mut order: Vec<usize> = (0..nv).filter(|&v| state.last_used[v] > 0).collect();
        order.sort_by(|&a, &b| {
            state.last_used[b]
                .cmp(&state.last_used[a])
                .then(demand[b].cmp(&demand[a]))
                .then(
                    batch.view_sizes[a]
                        .partial_cmp(&batch.view_sizes[b])
                        .unwrap(),
                )
        });

        let mut selected = ConfigMask::empty(nv);
        let mut used = 0.0;
        for v in order {
            let sz = batch.view_sizes[v];
            if used + sz <= batch.budget + 1e-9 {
                selected.insert(v);
                used += sz;
            }
        }
        Allocation::deterministic(selected)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::instances::matrix_instance;

    #[test]
    fn caches_hot_view_and_starves_cold_tenant() {
        // Scenario 2: analysts hammer R every batch; VP's P is demanded
        // too but R (same recency) wins by demand count. Unit sizes,
        // budget 1.
        let b = matrix_instance(&[&[2, 0], &[2, 0], &[0, 1]], 1.0);
        let lru = LeastRecentlyUsed::default();
        let a = lru.allocate(&b, &mut Pcg64::new(0));
        assert_eq!(a.configs[0], ConfigMask::from_bools(&[true, false]));
        let v = a.expected_scaled_utilities(&b);
        assert_eq!(v[2], 0.0, "VP starved, as in Scenario 2");
    }

    #[test]
    fn recency_beats_frequency_across_batches() {
        let lru = LeastRecentlyUsed::default();
        // Batch 1: only view 0 demanded.
        let b1 = matrix_instance(&[&[5, 0]], 1.0);
        let a1 = lru.allocate(&b1, &mut Pcg64::new(0));
        assert_eq!(a1.configs[0], ConfigMask::from_bools(&[true, false]));
        // Batch 2: only view 1 demanded → it evicts view 0.
        let b2 = matrix_instance(&[&[0, 1]], 1.0);
        let a2 = lru.allocate(&b2, &mut Pcg64::new(0));
        assert_eq!(a2.configs[0], ConfigMask::from_bools(&[false, true]));
    }

    #[test]
    fn respects_budget() {
        let b = matrix_instance(&[&[1, 1, 1]], 2.0);
        let lru = LeastRecentlyUsed::default();
        let a = lru.allocate(&b, &mut Pcg64::new(0));
        assert!(b.size_of(&a.configs[0]) <= b.budget + 1e-9);
        assert_eq!(a.configs[0].count_ones(), 2);
    }

    #[test]
    fn lru_violates_sharing_incentive() {
        use crate::fairness::properties::sharing_incentive_violations;
        // Table-5-like: tenant A only benefits from S; LRU caches R
        // (higher demand) → A gets nothing.
        let b = matrix_instance(&[&[0, 1], &[100, 1]], 1.0);
        let lru = LeastRecentlyUsed::default();
        // R demanded by one query of B with count 1, S by two queries...
        // demand: R:1, S:2 → LRU picks S here; craft instead a case
        // where B floods R with many query instances.
        let _ = b;
        let rows: Vec<Vec<u64>> = vec![vec![0, 1], vec![100, 0], vec![100, 0]];
        let refs: Vec<&[u64]> = rows.iter().map(|r| r.as_slice()).collect();
        let b2 = matrix_instance(&refs, 1.0);
        let a = lru.allocate(&b2, &mut Pcg64::new(0));
        let viol = sharing_incentive_violations(&a, &b2, 1e-6);
        assert!(!viol.is_empty(), "LRU should violate SI");
    }
}
