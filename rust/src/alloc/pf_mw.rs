//! PF-MW (§4.1, Theorem 4): the provably-good additive-ε approximation of
//! proportional fairness via PFFEAS(Q) feasibility checks inside a binary
//! search over Q ∈ [−N·log N, 0].
//!
//! PFFEAS(Q) (Definition 6) decides feasibility of
//!   (F)  Σ_S x_S·V_i(S) − γ_i ≥ 0  ∀i
//! over (P1) ‖x‖ ≤ 1, x ≥ 0 and (P2) Σ_i log γ_i ≥ Q, γ_i ∈ [1/N, 1]
//! with the AHK procedure. The oracle decouples (virtual-welfare style):
//!   · the x part is WELFARE(y) — put all mass on the best configuration;
//!   · the γ part minimizes Σ y_i·γ_i over (P2) by the Lagrangian
//!     parametric search γ_i(L) = clamp(L/y_i, 1/N, 1) with L chosen so
//!     Σ log γ_i(L) = Q.
//!
//! One [`WelfareTemplate`] is shared across every AHK iteration of every
//! feasibility check — the oracle rewrites only the dual-weight values.

use crate::alloc::mw::{ahk_from, AhkOutcome, AhkParams, OracleResponse};
use crate::alloc::warm::{BatchSignature, PfMwWarm, WarmState};
use crate::alloc::{Allocation, ConfigMask, Policy};
use crate::cache::tier::TierAssignment;
use crate::domain::utility::{BatchUtilities, WelfareTemplate};
use crate::util::rng::Pcg64;

/// Warm feasibility checks may stop once the WELFARE optimum has been
/// identical for this many consecutive AHK iterations.
const PF_STABLE_EXIT: usize = 8;

#[derive(Debug)]
pub struct PfMw {
    /// Additive approximation target ε.
    pub epsilon: f64,
    /// Cap on AHK iterations per feasibility check (theory: 4N⁴logN/ε²).
    pub max_iters: usize,
    /// Binary-search iterations over Q.
    pub search_steps: usize,
}

impl Default for PfMw {
    fn default() -> Self {
        Self {
            epsilon: 0.1,
            max_iters: 600,
            search_steps: 12,
        }
    }
}

/// Minimize Σ y_i γ_i subject to Σ log γ_i ≥ Q, γ_i ∈ [1/N, 1]:
/// parametric search over the Lagrange multiplier L (γ is non-decreasing
/// in L, so bisect L until the log-sum constraint is tight).
fn min_gamma(y: &[f64], q: f64, n: usize) -> Vec<f64> {
    let lo_g = 1.0 / n as f64;
    let gamma_at = |l: f64| -> Vec<f64> {
        y.iter()
            .map(|&yi| {
                if yi <= 1e-15 {
                    // Zero dual weight: γ free; push to upper bound to help
                    // feasibility of Σ log γ ≥ Q at no cost.
                    1.0
                } else {
                    (l / yi).clamp(lo_g, 1.0)
                }
            })
            .collect()
    };
    let logsum = |g: &[f64]| -> f64 { g.iter().map(|x| x.ln()).sum() };

    // If even γ = 1 everywhere misses Q (q > 0) the constraint is
    // trivially tight at γ = 1; if γ = 1/N satisfies it, take the minimum.
    if logsum(&gamma_at(0.0)) >= q {
        return gamma_at(0.0);
    }
    let mut lo = 0.0f64;
    let mut hi = y.iter().cloned().fold(0.0, f64::max).max(1e-9); // γ all 1 at L ≥ max y
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if logsum(&gamma_at(mid)) >= q {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    gamma_at(hi)
}

impl PfMw {
    /// One PFFEAS(Q) check over active tenants. Returns the configuration
    /// sequence of the feasible run (to be averaged) or None.
    fn pf_feas(
        &self,
        batch: &BatchUtilities,
        welfare: &mut WelfareTemplate,
        active: &[usize],
        q: f64,
    ) -> Option<Vec<TierAssignment>> {
        self.pf_feas_from(batch, welfare, active, q, None, None).0
    }

    /// [`pf_feas`](Self::pf_feas) with warm-start hooks: `y0` seeds the
    /// AHK duals and `stable_exit` enables the early feasibility exit.
    /// Always returns the final duals alongside the outcome so a failed
    /// probe still hands its dual progress to the next check. With both
    /// hooks `None` the outcome is bit-identical to `pf_feas`.
    fn pf_feas_from(
        &self,
        batch: &BatchUtilities,
        welfare: &mut WelfareTemplate,
        active: &[usize],
        q: f64,
        y0: Option<&[f64]>,
        stable_exit: Option<usize>,
    ) -> (Option<Vec<TierAssignment>>, Vec<f64>) {
        let n = active.len();
        let params = AhkParams {
            rho: 1.0,
            delta: (self.epsilon / (n * n) as f64).max(1e-3),
            max_iters: self.max_iters,
        };
        let run = ahk_from(
            n,
            &params,
            |_y| 0.0, // b = 0
            |y: &[f64]| {
                // x part: WELFARE(y) over the full configuration space.
                let mut full_w = vec![0.0; batch.n_tenants];
                for (j, &i) in active.iter().enumerate() {
                    full_w[i] = y[j];
                }
                let pair = welfare.solve_pair(&full_w);
                let v = batch.scaled_utilities_pair(&pair);
                // γ part: minimize Σ y_i γ_i over (P2).
                let gamma = min_gamma(y, q, n);
                let value: f64 = active
                    .iter()
                    .enumerate()
                    .map(|(j, &i)| y[j] * (v[i] - gamma[j]))
                    .sum();
                let slacks: Vec<f64> = active
                    .iter()
                    .enumerate()
                    .map(|(j, &i)| v[i] - gamma[j])
                    .collect();
                OracleResponse {
                    point: pair,
                    value,
                    slacks,
                }
            },
            y0,
            stable_exit,
        );
        let result = match run.outcome {
            AhkOutcome::Feasible { points } => Some(points),
            AhkOutcome::Infeasible => None,
        };
        (result, run.duals)
    }

    /// Binary search for the largest feasible Q; returns the allocation
    /// from the last feasible run. Configurations are `(RAM, SSD)`
    /// pairs; SSD planes are empty in single-tier mode.
    pub fn solve(&self, batch: &BatchUtilities) -> Vec<(TierAssignment, f64)> {
        let active = batch.active_tenants();
        let n = active.len();
        if n == 0 {
            return vec![(
                TierAssignment::single(ConfigMask::empty(batch.n_views())),
                1.0,
            )];
        }
        let mut welfare = batch.welfare_template();
        let mut lo = -(n as f64) * (n as f64).ln() - 1e-9; // Q of all-SI floor
        let mut hi = 0.0;
        // Q = lo is always feasible (the SI allocation exists: RSD's).
        let mut best = self.pf_feas(batch, &mut welfare, &active, lo);
        if best.is_none() {
            // Extremely degenerate batch; fall back to empty config.
            return vec![(
                TierAssignment::single(ConfigMask::empty(batch.n_views())),
                1.0,
            )];
        }
        for _ in 0..self.search_steps {
            let mid = 0.5 * (lo + hi);
            match self.pf_feas(batch, &mut welfare, &active, mid) {
                Some(points) => {
                    best = Some(points);
                    lo = mid;
                }
                None => {
                    hi = mid;
                }
            }
        }
        let points = best.unwrap();
        let w = 1.0 / points.len() as f64;
        points.into_iter().map(|p| (p, w)).collect()
    }

    /// [`solve`](Self::solve) with carried state. When `warm` holds a
    /// same-shape, same-active-set record, the previous converged Q* is
    /// probed first (skipping the always-feasible floor probe on
    /// success), every AHK run is seeded with the latest duals, and the
    /// stable-optimum early exit is enabled. With nothing carried the
    /// pair sequence is bit-identical to `solve` (and the run's Q*/duals
    /// are recorded for the next batch either way).
    pub fn solve_warm(
        &self,
        batch: &BatchUtilities,
        warm: &mut WarmState,
    ) -> Vec<(TierAssignment, f64)> {
        let active = batch.active_tenants();
        let n = active.len();
        if n == 0 {
            return vec![(
                TierAssignment::single(ConfigMask::empty(batch.n_views())),
                1.0,
            )];
        }
        let sig = BatchSignature::of(batch);
        let prev = warm
            .pf
            .take()
            .filter(|p| p.sig.same_shape(&sig) && p.active == active);
        let seeded = prev.is_some();
        let stable = seeded.then_some(PF_STABLE_EXIT);
        let mut welfare = batch.welfare_template();
        let floor = -(n as f64) * (n as f64).ln() - 1e-9; // Q of all-SI floor
        let mut lo = floor;
        let mut hi = 0.0;
        let mut best: Option<Vec<TierAssignment>> = None;
        let mut duals: Option<Vec<f64>> = prev.as_ref().map(|p| p.duals.clone());
        if let Some(p) = &prev {
            // Probe the previous converged Q* first: in steady state it
            // is still feasible and brackets the search from below.
            if (floor..=0.0).contains(&p.q_lo) {
                let seed = duals.take().filter(|_| seeded);
                let (r, d) = self.pf_feas_from(
                    batch, &mut welfare, &active, p.q_lo, seed.as_deref(), stable,
                );
                match r {
                    Some(points) => {
                        lo = p.q_lo;
                        best = Some(points);
                    }
                    None => hi = p.q_lo.min(hi),
                }
                duals = Some(d);
            }
        }
        if best.is_none() {
            // Q = lo is always feasible (the SI allocation exists: RSD's).
            let seed = duals.take().filter(|_| seeded);
            let (r, d) =
                self.pf_feas_from(batch, &mut welfare, &active, floor, seed.as_deref(), stable);
            duals = Some(d);
            match r {
                Some(points) => best = Some(points),
                None => {
                    // Extremely degenerate batch; fall back to empty config.
                    return vec![(
                        TierAssignment::single(ConfigMask::empty(batch.n_views())),
                        1.0,
                    )];
                }
            }
            lo = floor;
        }
        for _ in 0..self.search_steps {
            let mid = 0.5 * (lo + hi);
            let seed = duals.take().filter(|_| seeded);
            let (r, d) =
                self.pf_feas_from(batch, &mut welfare, &active, mid, seed.as_deref(), stable);
            match r {
                Some(points) => {
                    best = Some(points);
                    lo = mid;
                }
                None => hi = mid,
            }
            duals = Some(d);
        }
        warm.pf = Some(PfMwWarm {
            sig,
            active,
            q_lo: lo,
            duals: duals.unwrap(),
        });
        let points = best.unwrap();
        let w = 1.0 / points.len() as f64;
        points.into_iter().map(|p| (p, w)).collect()
    }
}

impl Policy for PfMw {
    fn name(&self) -> &'static str {
        "PF-MW"
    }

    fn allocate(&self, batch: &BatchUtilities, _rng: &mut Pcg64) -> Allocation {
        Allocation::from_weighted_pairs(self.solve(batch))
    }

    fn allocate_warm(
        &self,
        batch: &BatchUtilities,
        _rng: &mut Pcg64,
        warm: &mut WarmState,
    ) -> Allocation {
        Allocation::from_weighted_pairs(self.solve_warm(batch, warm))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::testing::{table2, table4, table5};

    #[test]
    fn min_gamma_respects_bounds_and_constraint() {
        let y = [0.5, 0.3, 0.2];
        let n = 3;
        let q = -1.5;
        let g = min_gamma(&y, q, n);
        for &gi in &g {
            assert!((1.0 / 3.0 - 1e-9..=1.0 + 1e-9).contains(&gi), "g={g:?}");
        }
        let logsum: f64 = g.iter().map(|x| x.ln()).sum();
        assert!(logsum >= q - 1e-6, "logsum={logsum} q={q}");
    }

    #[test]
    fn min_gamma_zero_q_all_ones() {
        let g = min_gamma(&[0.5, 0.5], 0.0, 2);
        assert!(g.iter().all(|&x| (x - 1.0).abs() < 1e-9));
    }

    #[test]
    fn table2_near_equal_split() {
        let b = table2();
        let a = PfMw::default().allocate(&b, &mut Pcg64::new(0));
        let v = a.expected_scaled_utilities(&b);
        // PF optimum: 1/3 each. The capped-iteration MW run should be in
        // the right neighbourhood.
        for vi in &v {
            assert!((0.2..0.5).contains(vi), "v={v:?}");
        }
    }

    #[test]
    fn table4_biases_toward_shared_view() {
        // PF: x_R = 3/4 for N = 4 — the MW approximation should put more
        // mass on R than on S (unlike MMF's ½/½).
        let b = table4(4);
        let a = PfMw::default().allocate(&b, &mut Pcg64::new(0));
        let v = a.expected_scaled_utilities(&b);
        // Majority tenants should clear 0.6 (ideal 0.75).
        assert!(v[0] > 0.6, "v={v:?}");
        // The minority tenant keeps a positive share (ideal 0.25).
        assert!(v[3] > 0.1, "v={v:?}");
    }

    #[test]
    fn table5_si_floor_respected() {
        let b = table5();
        let a = PfMw::default().allocate(&b, &mut Pcg64::new(0));
        let v = a.expected_scaled_utilities(&b);
        for vi in &v {
            assert!(*vi >= 0.5 - 0.12, "v={v:?}");
        }
    }

    #[test]
    fn warm_first_call_matches_cold_exactly() {
        let b = table2();
        let policy = PfMw::default();
        let mut warm = WarmState::new();
        let cold = policy.solve(&b);
        let first = policy.solve_warm(&b, &mut warm);
        assert_eq!(cold, first);
        let rec = warm.pf.as_ref().expect("state recorded");
        assert_eq!(rec.active, b.active_tenants());
        assert!(rec.q_lo.is_finite());
    }

    #[test]
    fn warm_resolve_keeps_quality() {
        let b = table4(4);
        let policy = PfMw::default();
        let mut warm = WarmState::new();
        policy.solve_warm(&b, &mut warm);
        // The seeded re-solve on the same workload keeps PF structure:
        // majority tenants biased up, minority tenant retained.
        let pairs = policy.solve_warm(&b, &mut warm);
        let v = Allocation::from_weighted_pairs(pairs).expected_scaled_utilities(&b);
        assert!(v[0] > 0.6, "v={v:?}");
        assert!(v[3] > 0.1, "v={v:?}");
        let floor = -4.0 * 4.0f64.ln() - 1e-6;
        assert!(warm.pf.as_ref().unwrap().q_lo >= floor);
    }

    #[test]
    fn warm_seed_rejected_on_active_set_change() {
        use crate::alloc::testing::matrix_instance;
        let policy = PfMw::default();
        let mut warm = WarmState::new();
        policy.solve_warm(&matrix_instance(&[&[1, 0], &[0, 1]], 1.0), &mut warm);
        // Tenant 1 goes inactive: same shape but a different active set,
        // so the carried record is dropped and the run is cold-identical.
        let b2 = matrix_instance(&[&[1, 0], &[0, 0]], 1.0);
        assert_eq!(policy.solve_warm(&b2, &mut warm), policy.solve(&b2));
        assert_eq!(warm.pf.as_ref().unwrap().active, vec![0]);
    }
}
