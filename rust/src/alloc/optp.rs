//! OPTP (§5.3): pure utility maximization — treat the whole batch as one
//! tenant and cache the configuration with the highest total raw utility
//! (I/O savings). Pareto-efficient but not Sharing Incentive: tenants
//! who contribute little to total utility can be starved (§3.2,
//! Figure 9's empirical demonstration).

use crate::alloc::{Allocation, ConfigMask, Policy};
use crate::domain::utility::BatchUtilities;
use crate::util::rng::Pcg64;

#[derive(Debug, Default)]
pub struct UtilityMax;

impl Policy for UtilityMax {
    fn name(&self) -> &'static str {
        "OPTP"
    }

    fn allocate(&self, batch: &BatchUtilities, _rng: &mut Pcg64) -> Allocation {
        let sol = batch.total_utility_problem().solve_exact();
        Allocation::deterministic(ConfigMask::from_bools(&sol.selected))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::testing::{matrix_instance, table3, table5};

    #[test]
    fn picks_highest_total_utility() {
        // Table 3 raw utilities: R→2, S→3, P→2; OPTP caches S.
        let b = table3();
        let a = UtilityMax.allocate(&b, &mut Pcg64::new(0));
        assert_eq!(a.configs[0], ConfigMask::from_bools(&[false, true, false]));
    }

    #[test]
    fn starves_minority_tenant() {
        // Table 5: R is worth 100 to B; S worth 1+1. OPTP caches R,
        // giving tenant A nothing → not SI.
        let b = table5();
        let a = UtilityMax.allocate(&b, &mut Pcg64::new(0));
        assert_eq!(a.configs[0], ConfigMask::from_bools(&[true, false]));
        let v = a.expected_scaled_utilities(&b);
        assert_eq!(v[0], 0.0);
    }

    #[test]
    fn uses_budget_for_multiple_views() {
        let b = matrix_instance(&[&[5, 3, 1], &[0, 2, 4]], 2.0);
        let a = UtilityMax.allocate(&b, &mut Pcg64::new(0));
        // Best pair: views {0,1} = 5+3+2 = 10 vs {0,2} = 5+1+4 = 10 vs
        // {1,2} = 3+2+1+4 = 10 — all tie at 10; any 2-view answer is
        // optimal.
        assert_eq!(a.configs[0].count_ones(), 2);
    }
}
