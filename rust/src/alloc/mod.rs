//! View-selection (cache-allocation) policies — the paper's §3/§4.
//!
//! A policy maps a per-batch [`BatchUtilities`] problem to a randomized
//! [`Allocation`]: a probability distribution over cache configurations
//! (Definition 2). The coordinator samples one configuration per batch;
//! fairness holds in expectation per batch and deterministically over
//! the workload horizon (§3.1).
//!
//! Configurations are [`ConfigMask`] bitsets throughout (see
//! `util::mask`); policies are `Send + Sync` so the experiment runner
//! can fan the policy × seed grid across threads.

pub mod config_space;
pub mod fastpf;
pub mod lru;
pub mod mmf;
pub mod mmf_mw;
pub mod mw;
pub mod optp;
pub mod pf_mw;
pub mod rsd;
pub mod static_part;
pub mod warm;

pub use config_space::{ConfigId, ConfigSpace};
pub use crate::util::mask::ConfigMask;
pub use warm::{BatchSignature, WarmState};

use crate::cache::tier::TierAssignment;
use crate::domain::utility::BatchUtilities;
use crate::util::rng::Pcg64;

/// A randomized allocation: configurations with probabilities summing
/// to 1 (Definition 2). Configurations are `(RAM, SSD)` plane pairs;
/// in single-tier mode every SSD plane is empty and `configs` alone is
/// the full configuration, exactly as before tiers existed.
#[derive(Debug, Clone)]
pub struct Allocation {
    /// RAM planes (the whole configuration in single-tier mode).
    pub configs: Vec<ConfigMask>,
    /// SSD planes, parallel to `configs` (empty masks in single-tier
    /// mode).
    pub ssd: Vec<ConfigMask>,
    pub probs: Vec<f64>,
}

impl Allocation {
    /// A deterministic allocation (one configuration with probability 1).
    pub fn deterministic(config: ConfigMask) -> Self {
        Self::deterministic_pair(TierAssignment::single(config))
    }

    /// A deterministic allocation over a `(RAM, SSD)` pair.
    pub fn deterministic_pair(pair: TierAssignment) -> Self {
        Self {
            configs: vec![pair.ram],
            ssd: vec![pair.ssd],
            probs: vec![1.0],
        }
    }

    /// Build from (config, weight) pairs, normalizing and dropping
    /// negligible-probability entries. Duplicate configurations are
    /// merged. Panics if total weight is not positive.
    pub fn from_weighted(pairs: Vec<(ConfigMask, f64)>) -> Self {
        Self::from_weighted_pairs(
            pairs
                .into_iter()
                .map(|(c, w)| (TierAssignment::single(c), w))
                .collect(),
        )
    }

    /// [`Allocation::from_weighted`] over `(RAM, SSD)` pairs. The merge
    /// map is keyed by the pair; with all-empty SSD planes the derived
    /// pair ordering collapses to the RAM-mask ordering, so single-tier
    /// output is bit-identical to the pre-tier builder.
    pub fn from_weighted_pairs(pairs: Vec<(TierAssignment, f64)>) -> Self {
        use std::collections::BTreeMap;
        let mut merged: BTreeMap<TierAssignment, f64> = BTreeMap::new();
        for (c, w) in pairs {
            // LP/gradient solvers can emit O(1e-9) negative residuals;
            // clamp those, reject anything materially negative.
            assert!(w >= -1e-6, "negative probability {w}");
            if w > 0.0 {
                *merged.entry(c).or_insert(0.0) += w;
            }
        }
        let total: f64 = merged.values().sum();
        assert!(total > 0.0, "allocation has zero total probability");
        let (kept, probs): (Vec<_>, Vec<_>) = merged
            .into_iter()
            .filter(|(_, w)| *w / total > 1e-9)
            .unzip();
        let renorm: f64 = probs.iter().sum();
        let (configs, ssd) = kept.into_iter().map(|p| (p.ram, p.ssd)).unzip();
        Self {
            configs,
            ssd,
            probs: probs.into_iter().map(|p| p / renorm).collect(),
        }
    }

    /// ‖x‖ (should be 1; exposed for invariant tests).
    pub fn total_probability(&self) -> f64 {
        self.probs.iter().sum()
    }

    /// Sample one configuration's RAM plane.
    pub fn sample(&self, rng: &mut Pcg64) -> &ConfigMask {
        &self.configs[rng.weighted_index(&self.probs)]
    }

    /// Sample one full `(RAM, SSD)` configuration. Consumes exactly the
    /// same single RNG draw as [`Allocation::sample`], so single-tier
    /// replay streams are unchanged.
    pub fn sample_pair(&self, rng: &mut Pcg64) -> TierAssignment {
        let i = rng.weighted_index(&self.probs);
        TierAssignment {
            ram: self.configs[i].clone(),
            ssd: self.ssd[i].clone(),
        }
    }

    /// Expected scaled utilities V_i(x) = Σ_S x_S V_i(S), tier-aware
    /// (SSD-resident classes count at the tier discount; with empty SSD
    /// planes the evaluation is the unchanged single-tier one).
    pub fn expected_scaled_utilities(&self, batch: &BatchUtilities) -> Vec<f64> {
        let mut v = vec![0.0; batch.n_tenants];
        for ((c, s), p) in self.configs.iter().zip(&self.ssd).zip(&self.probs) {
            let pair = TierAssignment {
                ram: c.clone(),
                ssd: s.clone(),
            };
            for (i, u) in batch.scaled_utilities_pair(&pair).iter().enumerate() {
                v[i] += p * u;
            }
        }
        v
    }

    /// Expected raw utilities U_i(x).
    pub fn expected_utilities(&self, batch: &BatchUtilities) -> Vec<f64> {
        let mut u = vec![0.0; batch.n_tenants];
        for (c, p) in self.configs.iter().zip(&self.probs) {
            for (i, s) in batch.utilities(c).iter().enumerate() {
                u[i] += p * s;
            }
        }
        u
    }

    /// Expected RAM-tier cache bytes used.
    pub fn expected_cache_bytes(&self, batch: &BatchUtilities) -> f64 {
        self.configs
            .iter()
            .zip(&self.probs)
            .map(|(c, p)| p * batch.size_of(c))
            .sum()
    }

    /// Expected SSD-tier cache bytes used (0 in single-tier mode).
    pub fn expected_ssd_bytes(&self, batch: &BatchUtilities) -> f64 {
        self.ssd
            .iter()
            .zip(&self.probs)
            .map(|(c, p)| p * batch.size_of(c))
            .sum()
    }
}

/// A view-selection policy. `Send + Sync` so allocations for independent
/// runs can be computed on worker threads (experiments::runner).
pub trait Policy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Compute the per-batch allocation. `rng` drives any internal
    /// randomization (random weight vectors, permutations).
    fn allocate(&self, batch: &BatchUtilities, rng: &mut Pcg64) -> Allocation;

    /// Warm-started variant: like [`Policy::allocate`], but the policy
    /// may reuse (and must refresh) state carried in `warm` from the
    /// owner's previous batch — see [`warm::WarmState`]. The default
    /// ignores the state, so policies without an incremental path stay
    /// bit-identical to their cold solve; FASTPF and the MW policies
    /// override it. Only called by drivers running with `--warm-start`;
    /// allocations must match the cold solve's welfare/fairness within
    /// ε, not bit-for-bit.
    fn allocate_warm(
        &self,
        batch: &BatchUtilities,
        rng: &mut Pcg64,
        warm: &mut WarmState,
    ) -> Allocation {
        let _ = warm;
        self.allocate(batch, rng)
    }
}

/// Scale a batch problem's tenant weights λ_i in place by per-tenant
/// multipliers — the federation's global-fairness feedback entering a
/// shard's solve. Weights are the only weight-dependent state in
/// [`BatchUtilities`] (classes, the bitmask index, and U* are
/// weight-independent), so owners of a freshly built problem apply
/// multipliers without cloning anything.
pub fn apply_weight_multipliers(batch: &mut BatchUtilities, mult: &[f64]) {
    assert_eq!(mult.len(), batch.n_tenants, "multiplier length mismatch");
    for (w, &m) in batch.weights.iter_mut().zip(mult) {
        assert!(m > 0.0, "weight multiplier must be positive, got {m}");
        *w *= m;
    }
}

/// Weighted solve entry (the federation's global-fairness feedback
/// path): run `policy` on `batch` with per-tenant weight multipliers
/// layered onto the base λ_i. `None` routes straight to
/// `policy.allocate` and is bit-identical to an unweighted solve. This
/// borrowed-problem form clones the batch; the hot path
/// (`SolveContext::solve_accounted`, which owns its problem) uses
/// [`apply_weight_multipliers`] directly instead.
pub fn allocate_weighted(
    policy: &dyn Policy,
    batch: &BatchUtilities,
    weight_mult: Option<&[f64]>,
    rng: &mut Pcg64,
) -> Allocation {
    match weight_mult {
        None => policy.allocate(batch, rng),
        Some(mult) => {
            let mut reweighted = batch.clone();
            apply_weight_multipliers(&mut reweighted, mult);
            policy.allocate(&reweighted, rng)
        }
    }
}

/// The policies compared in §5.3 plus the provably-good MW variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    Static,
    Lru,
    Rsd,
    Optp,
    Mmf,
    FastPf,
    MmfMw,
    PfMw,
}

impl PolicyKind {
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Static => "STATIC",
            PolicyKind::Lru => "LRU",
            PolicyKind::Rsd => "RSD",
            PolicyKind::Optp => "OPTP",
            PolicyKind::Mmf => "MMF",
            PolicyKind::FastPf => "FASTPF",
            PolicyKind::MmfMw => "MMF-MW",
            PolicyKind::PfMw => "PF-MW",
        }
    }

    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s.to_ascii_uppercase().as_str() {
            "STATIC" => Some(PolicyKind::Static),
            "LRU" => Some(PolicyKind::Lru),
            "RSD" => Some(PolicyKind::Rsd),
            "OPTP" => Some(PolicyKind::Optp),
            "MMF" => Some(PolicyKind::Mmf),
            "FASTPF" => Some(PolicyKind::FastPf),
            "MMF-MW" | "MMFMW" => Some(PolicyKind::MmfMw),
            "PF-MW" | "PFMW" => Some(PolicyKind::PfMw),
            _ => None,
        }
    }

    /// Instantiate with default parameters.
    pub fn build(&self) -> Box<dyn Policy> {
        match self {
            PolicyKind::Static => Box::new(static_part::StaticPartition),
            PolicyKind::Lru => Box::new(lru::LeastRecentlyUsed::default()),
            PolicyKind::Rsd => Box::new(rsd::RandomSerialDictatorship::default()),
            PolicyKind::Optp => Box::new(optp::UtilityMax),
            PolicyKind::Mmf => Box::new(mmf::MaxMinFair::default()),
            PolicyKind::FastPf => Box::new(fastpf::FastPf::default()),
            PolicyKind::MmfMw => Box::new(mmf_mw::SimpleMmfMw::default()),
            PolicyKind::PfMw => Box::new(pf_mw::PfMw::default()),
        }
    }
}

pub mod instances {
    //! Instance builders for the paper's canonical examples (Tables 2–5)
    //! — shared by tests, benches, the fairness audit example, and the
    //! Lemma 1/2 analyses.

    use crate::domain::dataset::DatasetCatalog;
    use crate::domain::query::{Query, QueryId};
    use crate::domain::tenant::{TenantId, TenantSet};
    use crate::domain::utility::BatchUtilities;
    use crate::domain::view::{ViewCatalog, ViewId, ViewKind};

    /// Build a unit-size-views instance from a utility matrix
    /// `util[tenant][view]` with cache budget `budget` (in view units).
    pub fn matrix_instance(util: &[&[u64]], budget: f64) -> BatchUtilities {
        let n_tenants = util.len();
        let n_views = util[0].len();
        let mut ds = DatasetCatalog::new();
        let mut vc = ViewCatalog::new();
        for v in 0..n_views {
            let d = ds.add(&format!("d{v}"), 100);
            vc.add(&format!("v{v}"), d, ViewKind::BaseTable, 100, 100);
        }
        let ts = TenantSet::equal(n_tenants);
        let mut queries = Vec::new();
        let mut qid = 0u64;
        for (t, row) in util.iter().enumerate() {
            for (v, &u) in row.iter().enumerate() {
                if u > 0 {
                    qid += 1;
                    queries.push(Query {
                        id: QueryId(qid),
                        tenant: TenantId(t),
                        arrival: 0.0,
                        template: format!("t{t}v{v}"),
                        required_views: vec![ViewId(v)],
                        bytes_read: u,
                        compute_cost: 0.0,
                    });
                }
            }
        }
        BatchUtilities::build(&ts, &vc, budget * 100.0, &queries, None)
    }

    /// Table 2: three tenants each wanting a different unit view.
    pub fn table2() -> BatchUtilities {
        matrix_instance(&[&[1, 0, 0], &[0, 1, 0], &[0, 0, 1]], 1.0)
    }

    /// Table 3: shared secondary preference.
    pub fn table3() -> BatchUtilities {
        matrix_instance(&[&[2, 1, 0], &[0, 1, 0], &[0, 1, 2]], 1.0)
    }

    /// Table 4: N−1 tenants want R, one wants S (here N = 4).
    pub fn table4(n: usize) -> BatchUtilities {
        let rows: Vec<Vec<u64>> = (0..n)
            .map(|i| if i < n - 1 { vec![1, 0] } else { vec![0, 1] })
            .collect();
        let refs: Vec<&[u64]> = rows.iter().map(|r| r.as_slice()).collect();
        matrix_instance(&refs, 1.0)
    }

    /// Table 5: the envy counterexample.
    pub fn table5() -> BatchUtilities {
        matrix_instance(&[&[0, 1], &[100, 1]], 1.0)
    }
}

#[cfg(test)]
pub(crate) use instances as testing;

#[cfg(test)]
mod tests {
    use super::*;

    fn mask(bits: &[bool]) -> ConfigMask {
        ConfigMask::from_bools(bits)
    }

    #[test]
    fn allocation_normalization_and_merge() {
        let a = Allocation::from_weighted(vec![
            (mask(&[true, false]), 1.0),
            (mask(&[false, true]), 2.0),
            (mask(&[true, false]), 1.0),
        ]);
        assert_eq!(a.configs.len(), 2);
        assert!((a.total_probability() - 1.0).abs() < 1e-12);
        let p_r = a
            .configs
            .iter()
            .zip(&a.probs)
            .find(|(c, _)| c.get(0))
            .unwrap()
            .1;
        assert!((p_r - 0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn zero_weight_allocation_panics() {
        Allocation::from_weighted(vec![(mask(&[true]), 0.0)]);
    }

    #[test]
    fn pair_builder_merges_on_both_planes() {
        let ram = mask(&[true, false]);
        let a = Allocation::from_weighted_pairs(vec![
            (TierAssignment::single(ram.clone()), 1.0),
            (
                TierAssignment {
                    ram: ram.clone(),
                    ssd: mask(&[false, true]),
                },
                2.0,
            ),
            (TierAssignment::single(ram.clone()), 1.0),
        ]);
        // Same RAM plane with different SSD planes stays distinct.
        assert_eq!(a.configs.len(), 2);
        assert_eq!(a.ssd.len(), 2);
        assert!((a.total_probability() - 1.0).abs() < 1e-12);
        // Single-tier builder output carries empty SSD planes and
        // matches the pair builder restricted to empty planes.
        let single = Allocation::from_weighted(vec![
            (mask(&[true, false]), 1.0),
            (mask(&[false, true]), 3.0),
        ]);
        assert!(single.ssd.iter().all(|s| s.none_set()));
        assert_eq!(single.configs.len(), single.ssd.len());
    }

    #[test]
    fn sample_pair_consumes_one_draw_like_sample() {
        let a = Allocation::from_weighted_pairs(vec![
            (TierAssignment::single(mask(&[true, false])), 3.0),
            (
                TierAssignment {
                    ram: mask(&[false, true]),
                    ssd: mask(&[true, false]),
                },
                1.0,
            ),
        ]);
        let mut r1 = Pcg64::new(11);
        let mut r2 = Pcg64::new(11);
        for _ in 0..200 {
            let ram_only = a.sample(&mut r1).clone();
            let pair = a.sample_pair(&mut r2);
            assert_eq!(ram_only, pair.ram);
        }
        // Identical residual RNG state: the pair sample used exactly one
        // draw per call too.
        assert_eq!(r1.next_f64(), r2.next_f64());
    }

    #[test]
    fn expected_utilities_table2() {
        let b = testing::table2();
        let a = Allocation::from_weighted(vec![
            (mask(&[true, false, false]), 1.0),
            (mask(&[false, true, false]), 1.0),
            (mask(&[false, false, true]), 1.0),
        ]);
        let v = a.expected_scaled_utilities(&b);
        for vi in v {
            assert!((vi - 1.0 / 3.0).abs() < 1e-9);
        }
    }

    #[test]
    fn sampling_respects_distribution() {
        let a = Allocation::from_weighted(vec![
            (mask(&[true, false]), 3.0),
            (mask(&[false, true]), 1.0),
        ]);
        let mut rng = Pcg64::new(5);
        let mut count_r = 0;
        for _ in 0..20_000 {
            if a.sample(&mut rng).get(0) {
                count_r += 1;
            }
        }
        let frac = count_r as f64 / 20_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn allocate_weighted_none_is_bit_identical() {
        let b = testing::table3();
        for kind in [PolicyKind::Mmf, PolicyKind::FastPf, PolicyKind::Static] {
            let policy = kind.build();
            let mut r1 = Pcg64::new(3);
            let mut r2 = Pcg64::new(3);
            let direct = policy.allocate(&b, &mut r1);
            let via = allocate_weighted(policy.as_ref(), &b, None, &mut r2);
            assert_eq!(direct.configs, via.configs, "{}", kind.name());
            assert_eq!(direct.probs, via.probs, "{}", kind.name());
        }
    }

    #[test]
    fn allocate_weighted_multipliers_steer_the_solve() {
        // Table 5 shape: tenant 0 only values view 1, tenant 1 strongly
        // prefers view 0. Boosting tenant 0's weight hard must raise its
        // expected scaled utility relative to the unweighted solve.
        let b = testing::table5();
        let policy = PolicyKind::Mmf.build();
        let base = allocate_weighted(policy.as_ref(), &b, None, &mut Pcg64::new(1));
        let boosted = allocate_weighted(
            policy.as_ref(),
            &b,
            Some(&[50.0, 1.0]),
            &mut Pcg64::new(1),
        );
        let v_base = base.expected_scaled_utilities(&b);
        let v_boost = boosted.expected_scaled_utilities(&b);
        // Weighted MMF is weight-proportional (see mmf.rs): a 50×
        // multiplier must strictly raise tenant 0's share above the
        // ~0.5025 equal-weight optimum — a no-op reweighting fails here.
        assert!(
            v_boost[0] > v_base[0] + 0.05,
            "multipliers had no effect: boosted {} vs base {}",
            v_boost[0],
            v_base[0]
        );
        // The reweighting never mutates the caller's batch problem.
        assert_eq!(b.weights, vec![1.0, 1.0]);
    }

    #[test]
    fn policy_kind_parse_roundtrip() {
        for k in [
            PolicyKind::Static,
            PolicyKind::Lru,
            PolicyKind::Rsd,
            PolicyKind::Optp,
            PolicyKind::Mmf,
            PolicyKind::FastPf,
            PolicyKind::MmfMw,
            PolicyKind::PfMw,
        ] {
            assert_eq!(PolicyKind::parse(k.name()), Some(k));
        }
        assert_eq!(PolicyKind::parse("nope"), None);
    }
}
