//! Warm-started incremental batch solves: persistent per-coordinator
//! (and per-shard) state carried from batch *b* to batch *b+1*.
//!
//! Batch b+1's workload heavily overlaps batch b's, yet the cold solve
//! path re-runs the full §4.3 pruning enumeration (M exact WELFARE
//! knapsacks) and restarts every multiplicative-weights loop from
//! uniform weights. [`WarmState`] caches the three reusable artifacts:
//!
//! * **FASTPF** — the previous pruned [`ConfigSpace`] (stable masks from
//!   the interning arena), the M random weight vectors with their
//!   cached WELFARE optima, and the converged gradient distribution.
//!   The next batch re-*scores* every cached config against the fresh
//!   problem (cheap word-wise subset tests) but re-*solves* the exact
//!   knapsack only for weight vectors whose cached optimum is
//!   invalidated; the gradient ascent starts from the previous
//!   distribution and early-exits on its built-in tolerance.
//! * **MMF-MW / PF-MW** — the converged dual weights of the MW loops,
//!   plus (for PF-MW) the converged binary-search point Q*, so
//!   steady-state batches re-enter near the fixed point and exit after
//!   a fraction of the 400–600 iteration cap.
//!
//! Validity is governed by [`BatchSignature`]: any change in tenant
//! count, view count, or cache budget (membership events and budget
//! re-splits always change one of these) voids everything; per-view
//! *structural* signatures (which tenant/view-set classes touch a view)
//! decide per-cached-optimum reuse under ordinary workload drift.
//! Owners additionally call [`WarmState::invalidate`] on membership,
//! re-home, and budget re-split events so elasticity never trusts stale
//! state even transiently. Equivalence is defined by quality, not bits:
//! warm allocations must match cold welfare/fairness within ε
//! (`rust/tests/warm_equivalence.rs`); drivers replaying history run
//! with warm-start off and stay bit-identical to the legacy path.

use crate::cache::tier::TierAssignment;
use crate::domain::utility::BatchUtilities;
use crate::util::mask::ConfigMask;
use crate::util::rng::mix64;

/// Structural identity of a batch problem, used to decide how much of
/// the previous batch's solve survives.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchSignature {
    pub n_tenants: usize,
    pub n_views: usize,
    /// Exact bit pattern of the cache budget: a federation budget
    /// re-split (total/N′ on membership change) always lands here, so a
    /// shape mismatch forces a full cold re-prune even if the owner
    /// forgot to invalidate explicitly.
    pub budget_bits: u64,
    /// Hash of the tier plan (SSD budget and discount bit patterns); 0
    /// in single-tier mode. A tier-budget re-split or cost-model change
    /// is a shape change: cached pair optima priced under the old
    /// discount are wrong in a way re-scoring cannot detect.
    pub tier_bits: u64,
    /// Per-view hash chained over the *structure* of the query classes
    /// touching the view — (tenant, required view set) only, not the
    /// per-batch utility/count, which drift every batch under Poisson
    /// arrivals. A view's signature changes when a tenant starts or
    /// stops issuing a class over it (workload mix shift), which is
    /// when a cached WELFARE optimum containing the view goes stale in
    /// a way re-scoring alone cannot detect.
    pub view_sigs: Vec<u64>,
}

impl BatchSignature {
    pub fn of(batch: &BatchUtilities) -> Self {
        let mut view_sigs = vec![0x9e37_79b9_7f4a_7c15u64; batch.n_views()];
        for c in &batch.classes {
            let mut h = mix64(0xa076_1d64_78bd_642fu64 ^ c.tenant as u64);
            for &v in &c.views {
                h = mix64(h ^ v as u64);
            }
            for &v in &c.views {
                view_sigs[v] = mix64(view_sigs[v] ^ h);
            }
        }
        let tier_bits = match batch.tier {
            None => 0,
            Some(t) => mix64(t.ssd_budget.to_bits() ^ mix64(t.discount.to_bits())),
        };
        Self {
            n_tenants: batch.n_tenants,
            n_views: batch.n_views(),
            budget_bits: batch.budget.to_bits(),
            tier_bits,
            view_sigs,
        }
    }

    /// Same problem shape: tenant count, view count, and budgets (both
    /// tiers). Any mismatch voids all carried state (cold re-prune).
    pub fn same_shape(&self, other: &Self) -> bool {
        self.n_tenants == other.n_tenants
            && self.n_views == other.n_views
            && self.budget_bits == other.budget_bits
            && self.tier_bits == other.tier_bits
    }

    /// True when every member view of `mask` has an unchanged class
    /// structure relative to `other` (drawn when the cached optimum was
    /// produced).
    pub fn views_unchanged(&self, other: &Self, mask: &ConfigMask) -> bool {
        mask.ones().all(|v| self.view_sigs[v] == other.view_sigs[v])
    }
}

/// FASTPF's carried state (see module docs).
#[derive(Debug, Clone)]
pub(crate) struct FastPfWarm {
    pub sig: BatchSignature,
    /// Every `(RAM, SSD)` pair of the previous batch's pruned space, in
    /// id order (SSD planes all empty in single-tier mode).
    pub pairs: Vec<TierAssignment>,
    /// The M random unit weight vectors drawn at the last cold prune
    /// (reused verbatim while the shape holds — they are still M random
    /// unit vectors; §4.3 only needs them to spray the Pareto frontier).
    pub rand_w: Vec<Vec<f64>>,
    /// Cached exact-WELFARE optimum per random vector.
    pub rand_opt: Vec<TierAssignment>,
    /// The previous converged allocation (pair → probability), the
    /// gradient warm start.
    pub x_by_pair: Vec<(TierAssignment, f64)>,
}

/// SIMPLEMMF's carried state: converged dual weights over the active
/// tenant set.
#[derive(Debug, Clone)]
pub(crate) struct MmfWarm {
    pub sig: BatchSignature,
    pub active: Vec<usize>,
    pub weights: Vec<f64>,
}

/// PF-MW's carried state: the converged binary-search point Q* and the
/// final AHK duals of the last feasible check.
#[derive(Debug, Clone)]
pub(crate) struct PfMwWarm {
    pub sig: BatchSignature,
    pub active: Vec<usize>,
    pub q_lo: f64,
    pub duals: Vec<f64>,
}

/// Persistent warm-start state, one per solve owner (coordinator
/// planner, serving loop, federated shard). Policies read and refresh
/// the slot they own through [`crate::alloc::Policy::allocate_warm`];
/// an empty state makes every warm entry behave exactly like a cold
/// solve that also records its trace.
#[derive(Debug, Clone, Default)]
pub struct WarmState {
    pub(crate) fastpf: Option<FastPfWarm>,
    pub(crate) mmf: Option<MmfWarm>,
    pub(crate) pf: Option<PfMwWarm>,
}

impl WarmState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop everything: the next solve of every policy runs fully cold.
    /// Called on membership events, view re-homes, and budget re-splits
    /// (belt and braces on top of the [`BatchSignature`] shape check).
    pub fn invalidate(&mut self) {
        *self = Self::new();
    }

    /// True when no state is carried (fresh or just invalidated).
    pub fn is_cold(&self) -> bool {
        self.fastpf.is_none() && self.mmf.is_none() && self.pf.is_none()
    }
}

/// Canonical reason strings attached to `warm_invalidation` trace
/// events, so the telemetry vocabulary stays closed (one constant per
/// caller class of [`WarmState::invalidate`]) and `summarize_trace.py`
/// can aggregate without free-text parsing.
pub mod reason {
    /// Views moved owners (placement re-home).
    pub const REHOME: &str = "rehome";
    /// The shard's cache-budget slice changed (total/N′ re-split).
    pub const BUDGET_RESPLIT: &str = "budget_resplit";
    /// A membership event voided the carried state wholesale.
    pub const MEMBERSHIP: &str = "membership";
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::testing::{matrix_instance, table3};

    #[test]
    fn signature_same_shape_and_drift() {
        let a = BatchSignature::of(&table3());
        let b = BatchSignature::of(&table3());
        assert!(a.same_shape(&b));
        assert_eq!(a, b);
        // Different utility *values* keep the structural view sigs: the
        // same (tenant, view-set) classes touch the same views.
        let scaled = matrix_instance(&[&[4, 2, 0], &[0, 2, 0], &[0, 2, 4]], 1.0);
        let c = BatchSignature::of(&scaled);
        assert!(a.same_shape(&c));
        assert_eq!(a.view_sigs, c.view_sigs);
        // A tenant dropping a class changes exactly that view's sig.
        let shifted = matrix_instance(&[&[2, 0, 0], &[0, 1, 0], &[0, 1, 2]], 1.0);
        let d = BatchSignature::of(&shifted);
        assert!(a.same_shape(&d));
        assert_ne!(a.view_sigs[1], d.view_sigs[1]);
        assert_eq!(a.view_sigs[2], d.view_sigs[2]);
    }

    #[test]
    fn signature_budget_mismatch_voids_shape() {
        let a = BatchSignature::of(&matrix_instance(&[&[1, 0], &[0, 1]], 1.0));
        let b = BatchSignature::of(&matrix_instance(&[&[1, 0], &[0, 1]], 2.0));
        assert!(!a.same_shape(&b));
    }

    #[test]
    fn signature_tier_plan_is_shape() {
        use crate::domain::utility::TierPlan;
        let single = BatchSignature::of(&matrix_instance(&[&[1, 0], &[0, 1]], 1.0));
        assert_eq!(single.tier_bits, 0);
        let plan = TierPlan {
            ssd_budget: 2000.0,
            discount: 0.8,
        };
        let tiered = BatchSignature::of(
            &matrix_instance(&[&[1, 0], &[0, 1]], 1.0).with_tier(Some(plan)),
        );
        assert!(!single.same_shape(&tiered));
        // An SSD-budget re-split (total/N′) is a shape change too.
        let resplit = BatchSignature::of(&matrix_instance(&[&[1, 0], &[0, 1]], 1.0).with_tier(
            Some(TierPlan {
                ssd_budget: 1000.0,
                discount: 0.8,
            }),
        ));
        assert!(!tiered.same_shape(&resplit));
        // Same plan → same shape; view sigs are tier-independent.
        let again = BatchSignature::of(
            &matrix_instance(&[&[1, 0], &[0, 1]], 1.0).with_tier(Some(plan)),
        );
        assert!(tiered.same_shape(&again));
        assert_eq!(single.view_sigs, tiered.view_sigs);
    }

    #[test]
    fn views_unchanged_masks_member_views_only() {
        let base = BatchSignature::of(&table3());
        let shifted =
            BatchSignature::of(&matrix_instance(&[&[2, 0, 0], &[0, 1, 0], &[0, 1, 2]], 1.0));
        // View 1's classes changed, views 0/2 did not.
        let v0 = ConfigMask::from_bools(&[true, false, false]);
        let v1 = ConfigMask::from_bools(&[false, true, false]);
        assert!(shifted.views_unchanged(&base, &v0));
        assert!(!shifted.views_unchanged(&base, &v1));
        // The empty mask is trivially unchanged.
        assert!(shifted.views_unchanged(&base, &ConfigMask::empty(3)));
    }

    #[test]
    fn invalidate_clears_all_slots() {
        let mut w = WarmState::new();
        assert!(w.is_cold());
        w.mmf = Some(MmfWarm {
            sig: BatchSignature::of(&table3()),
            active: vec![0, 1, 2],
            weights: vec![0.4, 0.3, 0.3],
        });
        assert!(!w.is_cold());
        w.invalidate();
        assert!(w.is_cold());
    }
}
