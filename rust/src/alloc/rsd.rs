//! Random Serial Dictatorship (§3.2): order tenants by a random
//! permutation; each in turn greedily caches the best views for itself
//! in the residual cache space. RSD is Sharing Incentive (each tenant is
//! first with probability 1/N) but not Pareto-efficient — it ignores
//! shared secondary preferences (Table 3).
//!
//! For small N the exact allocation (expectation over all N!
//! permutations) is computed; beyond that, a sampled set of permutations
//! approximates it. The coordinator only needs to *sample* a
//! configuration, but the exact distribution matters for fairness
//! analysis and for the Table 6 property checks.

use crate::alloc::{Allocation, ConfigMask, Policy};
use crate::domain::utility::BatchUtilities;
use crate::solver::knapsack::{ValuedQuery, WelfareProblem};
use crate::util::rng::Pcg64;

#[derive(Debug)]
pub struct RandomSerialDictatorship {
    /// Enumerate all permutations exactly up to this many tenants.
    pub exact_up_to: usize,
    /// Number of sampled permutations beyond that.
    pub samples: usize,
}

impl Default for RandomSerialDictatorship {
    fn default() -> Self {
        Self {
            exact_up_to: 6,
            samples: 64,
        }
    }
}

impl RandomSerialDictatorship {
    /// Run one serial-dictatorship pass for a fixed tenant order.
    fn config_for_order(batch: &BatchUtilities, order: &[usize]) -> ConfigMask {
        let mut selected = ConfigMask::empty(batch.n_views());
        let mut used = 0.0;
        for &tenant in order {
            if batch.u_star[tenant] <= 0.0 {
                continue;
            }
            // The tenant optimizes its own utility over the residual
            // budget, keeping already-selected views for free.
            let (lo, hi) = batch.index.tenant_ranges[tenant];
            let queries: Vec<ValuedQuery> = batch.classes[lo as usize..hi as usize]
                .iter()
                .map(|c| ValuedQuery {
                    value: c.utility,
                    views: c.views.clone(),
                })
                .collect();
            // Views already cached cost nothing for this dictator.
            let sizes: Vec<f64> = batch
                .view_sizes
                .iter()
                .enumerate()
                .map(|(v, &sz)| if selected.get(v) { 0.0 } else { sz })
                .collect();
            let sol = WelfareProblem {
                view_sizes: sizes,
                budget: batch.budget - used,
                queries,
            }
            .solve_exact();
            for (v, &s) in sol.selected.iter().enumerate() {
                if s && !selected.get(v) {
                    selected.insert(v);
                    used += batch.view_sizes[v];
                }
            }
        }
        selected
    }
}

impl Policy for RandomSerialDictatorship {
    fn name(&self) -> &'static str {
        "RSD"
    }

    fn allocate(&self, batch: &BatchUtilities, rng: &mut Pcg64) -> Allocation {
        let n = batch.n_tenants;
        let mut pairs: Vec<(ConfigMask, f64)> = Vec::new();
        if n <= self.exact_up_to {
            // Enumerate all permutations (weights follow tenant weights:
            // a weighted RSD draws orders with probability proportional
            // to sequential weighted sampling; with equal weights this is
            // uniform. We implement the equal-probability classic RSD and
            // note tenant weights via repetition-free weighted orders.)
            let mut order: Vec<usize> = (0..n).collect();
            permutations(&mut order, 0, &mut |perm| {
                let w: f64 = perm_weight(batch, perm);
                pairs.push((Self::config_for_order(batch, perm), w));
            });
        } else {
            for _ in 0..self.samples {
                let order = weighted_permutation(batch, rng);
                pairs.push((Self::config_for_order(batch, &order), 1.0));
            }
        }
        Allocation::from_weighted(pairs)
    }
}

/// Probability of a permutation under sequential weighted sampling
/// without replacement (reduces to 1/N! for equal weights).
fn perm_weight(batch: &BatchUtilities, perm: &[usize]) -> f64 {
    let mut remaining: f64 = batch.weights.iter().sum();
    let mut p = 1.0;
    for &t in perm {
        p *= batch.weights[t] / remaining;
        remaining -= batch.weights[t];
    }
    p
}

/// Sample a weighted random permutation (successively draw tenants with
/// probability proportional to weight).
fn weighted_permutation(batch: &BatchUtilities, rng: &mut Pcg64) -> Vec<usize> {
    let mut pool: Vec<usize> = (0..batch.n_tenants).collect();
    let mut order = Vec::with_capacity(pool.len());
    while !pool.is_empty() {
        let weights: Vec<f64> = pool.iter().map(|&t| batch.weights[t]).collect();
        let k = rng.weighted_index(&weights);
        order.push(pool.remove(k));
    }
    order
}

fn permutations<F: FnMut(&[usize])>(items: &mut Vec<usize>, k: usize, f: &mut F) {
    if k == items.len() {
        f(items);
        return;
    }
    for i in k..items.len() {
        items.swap(k, i);
        permutations(items, k + 1, f);
        items.swap(k, i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::testing::{table2, table3};

    #[test]
    fn table2_gives_each_view_third() {
        let b = table2();
        let a = RandomSerialDictatorship::default().allocate(&b, &mut Pcg64::new(0));
        assert_eq!(a.configs.len(), 3);
        for p in &a.probs {
            assert!((p - 1.0 / 3.0).abs() < 1e-9);
        }
        let v = a.expected_scaled_utilities(&b);
        for vi in v {
            assert!((vi - 1.0 / 3.0).abs() < 1e-9);
        }
    }

    #[test]
    fn table3_matches_paper_utilities() {
        // Paper: A and C get expected (unscaled) utility 1, B gets 1/3.
        let b = table3();
        let a = RandomSerialDictatorship::default().allocate(&b, &mut Pcg64::new(0));
        let u = a.expected_utilities(&b);
        assert!((u[0] - 1.0).abs() < 1e-9, "u={u:?}");
        assert!((u[1] - 1.0 / 3.0).abs() < 1e-9, "u={u:?}");
        assert!((u[2] - 1.0).abs() < 1e-9, "u={u:?}");
    }

    #[test]
    fn rsd_is_sharing_incentive_on_tables() {
        for b in [table2(), table3()] {
            let a = RandomSerialDictatorship::default().allocate(&b, &mut Pcg64::new(0));
            let v = a.expected_scaled_utilities(&b);
            for (i, vi) in v.iter().enumerate() {
                assert!(
                    *vi >= 1.0 / b.n_tenants as f64 - 1e-9,
                    "tenant {i}: V={vi}"
                );
            }
        }
    }

    #[test]
    fn sampled_mode_close_to_exact() {
        let b = table3();
        let exact = RandomSerialDictatorship::default().allocate(&b, &mut Pcg64::new(0));
        let sampled = RandomSerialDictatorship {
            exact_up_to: 0,
            samples: 4000,
        }
        .allocate(&b, &mut Pcg64::new(1));
        let ve = exact.expected_scaled_utilities(&b);
        let vs = sampled.expected_scaled_utilities(&b);
        for (a, b) in ve.iter().zip(&vs) {
            assert!((a - b).abs() < 0.05, "{ve:?} vs {vs:?}");
        }
    }

    #[test]
    fn dictators_share_already_cached_views() {
        // Both tenants want the same big view; after the first dictator
        // caches it, the second gets it for free and can add its second
        // choice.
        use crate::alloc::testing::matrix_instance;
        let b = matrix_instance(&[&[9, 1, 0], &[9, 0, 1]], 2.0);
        let a = RandomSerialDictatorship::default().allocate(&b, &mut Pcg64::new(0));
        // Every permutation caches view 0 plus the first dictator's
        // secondary view.
        for c in &a.configs {
            assert!(c.get(0));
            assert_eq!(c.count_ones(), 2);
        }
    }
}
