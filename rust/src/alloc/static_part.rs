//! STATIC (§5.3): the cache is partitioned across tenants in proportion
//! to their weights; each tenant independently caches its best views
//! within its own partition. Deterministic, trivially "fair" in cache
//! bytes, but Pareto-dominated whenever preferred views exceed the
//! partition size (§1 Scenario 1, §3.2).

use crate::alloc::{Allocation, ConfigMask, Policy};
use crate::domain::utility::BatchUtilities;
use crate::util::rng::Pcg64;

#[derive(Debug, Default)]
pub struct StaticPartition;

impl Policy for StaticPartition {
    fn name(&self) -> &'static str {
        "STATIC"
    }

    fn allocate(&self, batch: &BatchUtilities, _rng: &mut Pcg64) -> Allocation {
        let total_weight: f64 = batch.weights.iter().sum();
        let mut selected = ConfigMask::empty(batch.n_views());
        for tenant in 0..batch.n_tenants {
            let share = batch.budget * batch.weights[tenant] / total_weight;
            // The tenant's solo knapsack within its partition.
            let mut problem = batch.welfare_problem(&unit(batch.n_tenants, tenant));
            problem.budget = share;
            let sol = problem.solve_exact();
            // Views selected by multiple tenants occupy one copy; STATIC
            // still charges each partition, so the union is feasible in
            // the real (shared) cache.
            for (v, &s) in sol.selected.iter().enumerate() {
                if s {
                    selected.insert(v);
                }
            }
        }
        debug_assert!(batch.size_of(&selected) <= batch.budget * (1.0 + 1e-9) + 1.0);
        Allocation::deterministic(selected)
    }
}

fn unit(n: usize, i: usize) -> Vec<f64> {
    let mut w = vec![0.0; n];
    w[i] = 1.0;
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::testing::{matrix_instance, table2};

    #[test]
    fn nothing_fits_in_partitions() {
        // Table 2 with cache = 1 view and 3 tenants: each partition is
        // 1/3 view — nothing fits (§1 Scenario 1).
        let b = table2();
        let a = StaticPartition.allocate(&b, &mut Pcg64::new(0));
        assert_eq!(a.configs.len(), 1);
        assert!(a.configs[0].none_set());
        let v = a.expected_scaled_utilities(&b);
        assert!(v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn partitions_cache_small_views() {
        // Two tenants, budget 2 units → each gets 1 unit and caches its
        // preferred view.
        let b = matrix_instance(&[&[5, 0], &[0, 3]], 2.0);
        let a = StaticPartition.allocate(&b, &mut Pcg64::new(0));
        assert_eq!(a.configs[0], ConfigMask::from_bools(&[true, true]));
        let v = a.expected_scaled_utilities(&b);
        assert_eq!(v, vec![1.0, 1.0]);
    }

    #[test]
    fn shared_views_not_double_cached() {
        // Both tenants want the same unit view; partitions of 1 each.
        let b = matrix_instance(&[&[7], &[9]], 2.0);
        let a = StaticPartition.allocate(&b, &mut Pcg64::new(0));
        assert_eq!(a.configs[0], ConfigMask::from_bools(&[true]));
        assert!(b.size_of(&a.configs[0]) <= b.budget);
    }
}
