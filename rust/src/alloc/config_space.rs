//! Configuration pruning (§4.3): generate M = O(N²) random unit weight
//! vectors, solve WELFARE(w) exactly for each, and keep the distinct
//! Pareto-optimal configurations found. The convex programs for PF and
//! MMF are then solved restricted to this small configuration set.
//!
//! The space is an *interning arena*: each distinct [`ConfigMask`] is
//! stored once and identified by a dense [`ConfigId`]; duplicate pushes
//! are deduplicated with a hash lookup (replacing the old O(n²) linear
//! scan), and the per-config scaled utilities live in one flat
//! row-major matrix (`v[s·N + i] = V_i(S_s)`), so the restricted-LP and
//! gradient solvers stream over contiguous memory.
//!
//! The paper measures the approximation error of this pruning at 10.4% /
//! 1.4% / 0.6% for 5 / 25 / 50 random vectors (five tenants); the
//! `pruning-error` experiment regenerates that sweep.

use std::collections::HashMap;

use crate::cache::tier::TierAssignment;
use crate::domain::utility::BatchUtilities;
use crate::util::mask::ConfigMask;
use crate::util::rng::Pcg64;

/// Dense identifier of an interned configuration within one
/// [`ConfigSpace`] (its row index in the `v` matrix).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ConfigId(pub usize);

/// The random-vector half of one §4.3 pruning run (see
/// [`ConfigSpace::pruned_traced`]): each drawn unit weight vector and
/// the exact-WELFARE optimum it produced. A warm-started solve replays
/// these instead of re-running the M exact knapsacks.
#[derive(Debug, Clone)]
pub struct PruneTrace {
    pub rand_w: Vec<Vec<f64>>,
    /// The `(RAM, SSD)` optimum per random vector; the SSD plane is
    /// empty in single-tier mode.
    pub rand_opt: Vec<TierAssignment>,
}

/// A pruned configuration space with precomputed scaled utilities.
///
/// Configurations are `(RAM, SSD)` plane pairs ([`TierAssignment`]); in
/// single-tier mode every SSD plane is empty and the space behaves
/// exactly like the pre-tier mask arena (interning, ids, and v rows all
/// bit-identical).
#[derive(Debug, Clone)]
pub struct ConfigSpace {
    /// Interned RAM planes, in insertion order (index = ConfigId).
    configs: Vec<ConfigMask>,
    /// SSD planes, parallel to `configs` (all-empty in single-tier mode).
    ssd: Vec<ConfigMask>,
    /// Flat row-major scaled-utility matrix: `v[s * n_tenants + i]` =
    /// `V_i(configs[s])`.
    v: Vec<f64>,
    n_tenants: usize,
    /// Interning table: (RAM, SSD) pair → id (deduplication in O(1)
    /// expected).
    interner: HashMap<TierAssignment, ConfigId>,
}

impl ConfigSpace {
    /// An empty space for a problem with `n_tenants` tenants.
    pub fn new(n_tenants: usize) -> Self {
        ConfigSpace {
            configs: Vec::new(),
            ssd: Vec::new(),
            v: Vec::new(),
            n_tenants,
            interner: HashMap::new(),
        }
    }

    /// Build from explicit single-tier configurations.
    pub fn from_configs(batch: &BatchUtilities, configs: Vec<ConfigMask>) -> Self {
        let mut space = Self::new(batch.n_tenants);
        for c in configs {
            space.push(batch, c);
        }
        space
    }

    /// Build from explicit `(RAM, SSD)` pairs.
    pub fn from_pairs(batch: &BatchUtilities, pairs: Vec<TierAssignment>) -> Self {
        let mut space = Self::new(batch.n_tenants);
        for p in pairs {
            space.push_pair(batch, p);
        }
        space
    }

    /// The §4.3 pruning: `m` random weight vectors (plus the per-tenant
    /// unit vectors so every tenant's solo optimum is always present,
    /// which guarantees SI is representable, and the uniform vector).
    pub fn pruned(batch: &BatchUtilities, m: usize, rng: &mut Pcg64) -> Self {
        Self::pruned_traced(batch, m, rng).0
    }

    /// [`ConfigSpace::pruned`] plus the trace a warm-started solve needs
    /// to skip re-enumeration next batch: the random weight vectors
    /// drawn and the exact-WELFARE optimum each produced. Identical
    /// enumeration order and RNG consumption to `pruned`.
    pub fn pruned_traced(
        batch: &BatchUtilities,
        m: usize,
        rng: &mut Pcg64,
    ) -> (Self, PruneTrace) {
        let n = batch.n_tenants;
        let mut space = Self::new(n);

        // Always include the empty configuration so the LP can express
        // "cache nothing" mass.
        space.push(batch, ConfigMask::empty(batch.n_views()));

        // One reusable WELFARE skeleton for the whole sweep.
        let mut welfare = batch.welfare_template();

        // Per-tenant solo optima (unit weight vectors). `solve_pair` is
        // the plain exact solve plus (in two-tier mode only) the SSD
        // phase; single-tier float operations and RNG draws are
        // untouched.
        for i in 0..n {
            if batch.u_star[i] <= 0.0 {
                continue;
            }
            let mut w = vec![0.0; n];
            w[i] = 1.0;
            let pair = welfare.solve_pair(&w);
            space.push_pair(batch, pair);
        }

        // Uniform weights (the overall welfare optimum).
        let pair = welfare.solve_pair(&vec![1.0; n]);
        space.push_pair(batch, pair);

        // m random unit vectors.
        let mut trace = PruneTrace {
            rand_w: Vec::with_capacity(m),
            rand_opt: Vec::with_capacity(m),
        };
        for _ in 0..m {
            let w = rng.unit_weight_vector(n);
            let pair = welfare.solve_pair(&w);
            space.push_pair(batch, pair.clone());
            trace.rand_w.push(w);
            trace.rand_opt.push(pair);
        }
        (space, trace)
    }

    /// Intern a single-tier configuration (empty SSD plane); returns its
    /// (possibly pre-existing) id.
    pub fn push(&mut self, batch: &BatchUtilities, config: ConfigMask) -> ConfigId {
        self.push_pair(batch, TierAssignment::single(config))
    }

    /// Intern a `(RAM, SSD)` pair; returns its (possibly pre-existing)
    /// id. With an empty SSD plane the scoring delegates to the
    /// single-tier evaluation, so single-tier v rows are bit-identical
    /// to the pre-tier arena.
    pub fn push_pair(&mut self, batch: &BatchUtilities, pair: TierAssignment) -> ConfigId {
        if let Some(&id) = self.interner.get(&pair) {
            return id;
        }
        let id = ConfigId(self.configs.len());
        self.v.extend(batch.scaled_utilities_pair(&pair));
        self.interner.insert(pair.clone(), id);
        self.configs.push(pair.ram);
        self.ssd.push(pair.ssd);
        id
    }

    pub fn len(&self) -> usize {
        self.configs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.configs.is_empty()
    }

    /// The interned RAM planes in id order (the full configuration in
    /// single-tier mode).
    pub fn masks(&self) -> &[ConfigMask] {
        &self.configs
    }

    /// The interned SSD planes in id order (all empty in single-tier
    /// mode).
    pub fn ssd_masks(&self) -> &[ConfigMask] {
        &self.ssd
    }

    /// One configuration's RAM plane by id.
    pub fn config(&self, id: ConfigId) -> &ConfigMask {
        &self.configs[id.0]
    }

    /// One full `(RAM, SSD)` pair by id.
    pub fn pair(&self, id: ConfigId) -> TierAssignment {
        TierAssignment {
            ram: self.configs[id.0].clone(),
            ssd: self.ssd[id.0].clone(),
        }
    }

    /// Iterate the interned `(RAM, SSD)` pairs in id order.
    pub fn pairs(&self) -> impl Iterator<Item = TierAssignment> + '_ {
        self.configs
            .iter()
            .zip(&self.ssd)
            .map(|(r, s)| TierAssignment {
                ram: r.clone(),
                ssd: s.clone(),
            })
    }

    /// Look up the id of an already-interned single-tier configuration
    /// (i.e. the pair with an empty SSD plane).
    pub fn id_of(&self, config: &ConfigMask) -> Option<ConfigId> {
        self.id_of_pair(&TierAssignment::single(config.clone()))
    }

    /// Look up the id of an already-interned `(RAM, SSD)` pair.
    pub fn id_of_pair(&self, pair: &TierAssignment) -> Option<ConfigId> {
        self.interner.get(pair).copied()
    }

    /// Scaled-utility row of configuration `s`: `V_i(S_s)` for all i.
    pub fn v_row(&self, s: usize) -> &[f64] {
        &self.v[s * self.n_tenants..(s + 1) * self.n_tenants]
    }

    /// Iterate the scaled-utility rows in id order.
    pub fn rows(&self) -> impl Iterator<Item = &[f64]> {
        self.v.chunks_exact(self.n_tenants.max(1))
    }

    /// V_i(x) for an allocation vector over this space.
    pub fn scaled_utility(&self, tenant: usize, x: &[f64]) -> f64 {
        x.iter()
            .zip(self.rows())
            .map(|(xs, row)| xs * row[tenant])
            .sum()
    }

    /// The welfare-optimal configuration for weight vector w, restricted
    /// to this space (used by the restricted MW solvers and by the L2
    /// JAX `mmf_mw` artifact which operates on the same data).
    pub fn restricted_welfare(&self, w: &[f64]) -> ConfigId {
        let mut best = 0;
        let mut best_score = f64::NEG_INFINITY;
        for (s, row) in self.rows().enumerate() {
            let score: f64 = w.iter().zip(row).map(|(wi, vi)| wi * vi).sum();
            if score > best_score {
                best_score = score;
                best = s;
            }
        }
        ConfigId(best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::testing::{table2, table3};

    fn mask(bits: &[bool]) -> ConfigMask {
        ConfigMask::from_bools(bits)
    }

    #[test]
    fn pruned_space_contains_solo_optima() {
        let b = table2();
        let mut rng = Pcg64::new(1);
        let space = ConfigSpace::pruned(&b, 10, &mut rng);
        // Each tenant's preferred unit view must appear as a config
        // giving it scaled utility 1.
        for i in 0..3 {
            assert!(
                space.rows().any(|row| (row[i] - 1.0).abs() < 1e-9),
                "tenant {i} has no optimal config in space"
            );
        }
        // Empty config present.
        assert!(space.masks().iter().any(|c| c.none_set()));
    }

    #[test]
    fn interning_dedups_in_constant_lookups() {
        let b = table2();
        let mut space = ConfigSpace::from_configs(&b, vec![]);
        let a = space.push(&b, mask(&[true, false, false]));
        let bidx = space.push(&b, mask(&[true, false, false]));
        assert_eq!(a, bidx);
        assert_eq!(space.len(), 1);
        let c = space.push(&b, mask(&[false, true, false]));
        assert_eq!(c, ConfigId(1));
        assert_eq!(space.config(c), &mask(&[false, true, false]));
        // v matrix stays one row per distinct config.
        assert_eq!(space.rows().count(), 2);
    }

    #[test]
    fn restricted_welfare_picks_best() {
        let b = table3();
        let space = ConfigSpace::from_configs(
            &b,
            vec![
                mask(&[true, false, false]),
                mask(&[false, true, false]),
                mask(&[false, false, true]),
            ],
        );
        // Uniform weights: S gives every tenant 1/2 → total 1.5 scaled;
        // R gives tenant A 1.0 only; P gives tenant C 1.0 only.
        let best = space.restricted_welfare(&[1.0, 1.0, 1.0]);
        assert_eq!(space.config(best), &mask(&[false, true, false]));
    }

    #[test]
    fn scaled_utility_matches_batch() {
        let b = table3();
        let space = ConfigSpace::from_configs(&b, vec![mask(&[false, true, false])]);
        let x = vec![1.0];
        // Table 3: caching S gives A 1/2, B 1, C 1/2 (scaled by U* = 2,1,2).
        assert!((space.scaled_utility(0, &x) - 0.5).abs() < 1e-9);
        assert!((space.scaled_utility(1, &x) - 1.0).abs() < 1e-9);
        assert!((space.scaled_utility(2, &x) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn pruned_traced_matches_pruned_and_records_optima() {
        let b = table2();
        let space_a = ConfigSpace::pruned(&b, 12, &mut Pcg64::new(7));
        let (space_b, trace) = ConfigSpace::pruned_traced(&b, 12, &mut Pcg64::new(7));
        // Identical enumeration and RNG consumption.
        assert_eq!(space_a.masks(), space_b.masks());
        assert_eq!(trace.rand_w.len(), 12);
        assert_eq!(trace.rand_opt.len(), 12);
        // Every recorded optimum is interned, and re-solving the exact
        // oracle for the recorded vector reproduces it. Single-tier:
        // every recorded pair has an empty SSD plane.
        let mut welfare = b.welfare_template();
        for (w, opt) in trace.rand_w.iter().zip(&trace.rand_opt) {
            assert!(space_b.id_of_pair(opt).is_some());
            assert!(opt.ssd.none_set());
            let sol = welfare.solve(w);
            assert_eq!(mask(&sol.selected), opt.ram);
        }
    }

    #[test]
    fn tiered_pruning_interns_pairs_and_scores_with_discount() {
        use crate::cache::tier::TierAssignment;
        use crate::domain::utility::TierPlan;
        let b = table2();
        let plan = TierPlan {
            ssd_budget: b.budget,
            discount: 0.5,
        };
        let bt = b.clone().with_tier(Some(plan));
        let (space, trace) = ConfigSpace::pruned_traced(&bt, 10, &mut Pcg64::new(7));
        // The RAM planes match the single-tier sweep exactly (phase 1 is
        // the unchanged exact solve over the same RNG stream)…
        let single = ConfigSpace::pruned(&b, 10, &mut Pcg64::new(7));
        let ram_planes: Vec<_> = space.pairs().map(|p| p.ram).collect();
        for m in single.masks() {
            assert!(ram_planes.contains(m), "missing RAM plane {m:?}");
        }
        // …and at least one pair fills its SSD plane (budget equals RAM,
        // so a second-best view always fits).
        assert!(space.pairs().any(|p| !p.ssd.none_set()));
        assert!(trace.rand_opt.iter().all(|p| space.id_of_pair(p).is_some()));
        // v rows are the discounted pair evaluation.
        for (s, p) in space.pairs().enumerate() {
            assert_eq!(space.v_row(s), bt.scaled_utilities_pair(&p).as_slice());
        }
        // Pairs differing only in the SSD plane intern as distinct ids.
        let mut arena = ConfigSpace::new(b.n_tenants);
        let ram = mask(&[true, false, false]);
        let a = arena.push_pair(&bt, TierAssignment::single(ram.clone()));
        let bb = arena.push_pair(
            &bt,
            TierAssignment {
                ram: ram.clone(),
                ssd: mask(&[false, true, false]),
            },
        );
        assert_ne!(a, bb);
        assert_eq!(arena.len(), 2);
        assert_eq!(arena.id_of(&ram), Some(a));
    }

    /// Cross-batch reuse: ids assigned by `from_configs` stay stable
    /// under incremental `push`, and duplicates pushed during a re-score
    /// sweep dedup onto the original rows.
    #[test]
    fn interner_stable_across_from_configs_and_push() {
        let b = table2();
        let carried = vec![
            mask(&[true, false, false]),
            mask(&[false, true, false]),
            mask(&[false, false, true]),
        ];
        let mut space = ConfigSpace::from_configs(&b, carried.clone());
        for (i, c) in carried.iter().enumerate() {
            assert_eq!(space.id_of(c), Some(ConfigId(i)));
        }
        // Incremental push of a new mask appends; re-pushing carried
        // masks (the warm re-score path) returns the original ids and
        // adds no rows.
        let fresh = space.push(&b, mask(&[true, true, false]));
        assert_eq!(fresh, ConfigId(3));
        for (i, c) in carried.iter().enumerate() {
            assert_eq!(space.push(&b, c.clone()), ConfigId(i));
        }
        assert_eq!(space.len(), 4);
        assert_eq!(space.rows().count(), 4);
        assert_eq!(space.id_of(&mask(&[false, false, false])), None);
    }

    /// Stale-v invalidation: the v matrix is bound to the batch it was
    /// scored against. When a view's utility changes, a rebuilt space
    /// over the same masks must re-score — carrying the old rows would
    /// return the stale scaled utilities.
    #[test]
    fn rescoring_refreshes_stale_v_rows() {
        use crate::alloc::testing::matrix_instance;
        let before = matrix_instance(&[&[2, 1, 0], &[0, 1, 0], &[0, 1, 2]], 1.0);
        let after = matrix_instance(&[&[2, 4, 0], &[0, 1, 0], &[0, 1, 2]], 1.0);
        let masks = vec![mask(&[true, false, false]), mask(&[false, true, false])];
        let old = ConfigSpace::from_configs(&before, masks.clone());
        let new = ConfigSpace::from_configs(&after, masks.clone());
        // Same interned ids either way…
        for (i, c) in masks.iter().enumerate() {
            assert_eq!(old.id_of(c), Some(ConfigId(i)));
            assert_eq!(new.id_of(c), Some(ConfigId(i)));
        }
        // …but tenant 0's scaled utilities moved: U* rose from 2 to 4,
        // so {R} scores 2/4 and {S} scores 4/4 under the new batch.
        assert!((old.v_row(0)[0] - 1.0).abs() < 1e-12);
        assert!((new.v_row(0)[0] - 0.5).abs() < 1e-12);
        assert!((old.v_row(1)[0] - 0.5).abs() < 1e-12);
        assert!((new.v_row(1)[0] - 1.0).abs() < 1e-12);
        // The refreshed rows match the fresh batch exactly.
        for (s, c) in masks.iter().enumerate() {
            assert_eq!(new.v_row(s), after.scaled_utilities(c).as_slice());
        }
        // And the restricted argmax flips with the re-score.
        assert_eq!(old.restricted_welfare(&[1.0, 0.0, 0.0]), ConfigId(0));
        assert_eq!(new.restricted_welfare(&[1.0, 0.0, 0.0]), ConfigId(1));
    }

    #[test]
    fn v_rows_match_scaled_utilities() {
        let b = table3();
        let configs = vec![
            mask(&[true, false, false]),
            mask(&[true, true, false]),
        ];
        let space = ConfigSpace::from_configs(&b, configs.clone());
        for (s, c) in configs.iter().enumerate() {
            assert_eq!(space.v_row(s), b.scaled_utilities(c).as_slice());
        }
    }
}
