//! Configuration pruning (§4.3): generate M = O(N²) random unit weight
//! vectors, solve WELFARE(w) exactly for each, and keep the distinct
//! Pareto-optimal configurations found. The convex programs for PF and
//! MMF are then solved restricted to this small configuration set.
//!
//! The paper measures the approximation error of this pruning at 10.4% /
//! 1.4% / 0.6% for 5 / 25 / 50 random vectors (five tenants); the
//! `pruning-error` experiment regenerates that sweep.

use crate::domain::utility::BatchUtilities;
use crate::util::rng::Pcg64;

/// A pruned configuration space with precomputed scaled utilities.
#[derive(Debug, Clone)]
pub struct ConfigSpace {
    /// Candidate configurations (view masks), deduplicated.
    pub configs: Vec<Vec<bool>>,
    /// `v[s][i]` = `V_i(configs[s])` — scaled utility of tenant i.
    pub v: Vec<Vec<f64>>,
}

impl ConfigSpace {
    /// Build from explicit configurations.
    pub fn from_configs(batch: &BatchUtilities, configs: Vec<Vec<bool>>) -> Self {
        let mut space = ConfigSpace {
            configs: Vec::new(),
            v: Vec::new(),
        };
        for c in configs {
            space.push(batch, c);
        }
        space
    }

    /// The §4.3 pruning: `m` random weight vectors (plus the per-tenant
    /// unit vectors so every tenant's solo optimum is always present,
    /// which guarantees SI is representable, and the uniform vector).
    pub fn pruned(batch: &BatchUtilities, m: usize, rng: &mut Pcg64) -> Self {
        let n = batch.n_tenants;
        let mut space = ConfigSpace {
            configs: Vec::new(),
            v: Vec::new(),
        };

        // Always include the empty configuration so the LP can express
        // "cache nothing" mass.
        space.push(batch, vec![false; batch.n_views()]);

        // Per-tenant solo optima (unit weight vectors).
        for i in 0..n {
            if batch.u_star[i] <= 0.0 {
                continue;
            }
            let mut w = vec![0.0; n];
            w[i] = 1.0;
            let sol = batch.welfare_problem(&w).solve_exact();
            space.push(batch, sol.selected);
        }

        // Uniform weights (the overall welfare optimum).
        let sol = batch
            .welfare_problem(&vec![1.0; n])
            .solve_exact();
        space.push(batch, sol.selected);

        // m random unit vectors.
        for _ in 0..m {
            let w = rng.unit_weight_vector(n);
            let sol = batch.welfare_problem(&w).solve_exact();
            space.push(batch, sol.selected);
        }
        space
    }

    /// Add a configuration if new; returns its index.
    pub fn push(&mut self, batch: &BatchUtilities, config: Vec<bool>) -> usize {
        if let Some(pos) = self.configs.iter().position(|c| *c == config) {
            return pos;
        }
        self.v.push(batch.scaled_utilities(&config));
        self.configs.push(config);
        self.configs.len() - 1
    }

    pub fn len(&self) -> usize {
        self.configs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.configs.is_empty()
    }

    /// V_i(x) for an allocation vector over this space.
    pub fn scaled_utility(&self, tenant: usize, x: &[f64]) -> f64 {
        x.iter()
            .zip(&self.v)
            .map(|(xs, vs)| xs * vs[tenant])
            .sum()
    }

    /// The welfare-optimal configuration index for weight vector w,
    /// restricted to this space (used by the restricted MW solvers and
    /// by the L2 JAX `mmf_mw` artifact which operates on the same data).
    pub fn restricted_welfare(&self, w: &[f64]) -> usize {
        let mut best = 0;
        let mut best_score = f64::NEG_INFINITY;
        for (s, vs) in self.v.iter().enumerate() {
            let score: f64 = w.iter().zip(vs).map(|(wi, vi)| wi * vi).sum();
            if score > best_score {
                best_score = score;
                best = s;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::testing::{table2, table3};

    #[test]
    fn pruned_space_contains_solo_optima() {
        let b = table2();
        let mut rng = Pcg64::new(1);
        let space = ConfigSpace::pruned(&b, 10, &mut rng);
        // Each tenant's preferred unit view must appear as a config
        // giving it scaled utility 1.
        for i in 0..3 {
            assert!(
                space.v.iter().any(|vs| (vs[i] - 1.0).abs() < 1e-9),
                "tenant {i} has no optimal config in space"
            );
        }
        // Empty config present.
        assert!(space.configs.iter().any(|c| c.iter().all(|&x| !x)));
    }

    #[test]
    fn dedup_works() {
        let b = table2();
        let mut space = ConfigSpace::from_configs(&b, vec![]);
        let a = space.push(&b, vec![true, false, false]);
        let bidx = space.push(&b, vec![true, false, false]);
        assert_eq!(a, bidx);
        assert_eq!(space.len(), 1);
    }

    #[test]
    fn restricted_welfare_picks_best() {
        let b = table3();
        let space = ConfigSpace::from_configs(
            &b,
            vec![
                vec![true, false, false],
                vec![false, true, false],
                vec![false, false, true],
            ],
        );
        // Uniform weights: S gives every tenant 1/2 → total 1.5 scaled;
        // R gives tenant A 1.0 only; P gives tenant C 1.0 only.
        let best = space.restricted_welfare(&[1.0, 1.0, 1.0]);
        assert_eq!(space.configs[best], vec![false, true, false]);
    }

    #[test]
    fn scaled_utility_matches_batch() {
        let b = table3();
        let space = ConfigSpace::from_configs(&b, vec![vec![false, true, false]]);
        let x = vec![1.0];
        // Table 3: caching S gives A 1/2, B 1, C 1/2 (scaled by U* = 2,1,2).
        assert!((space.scaled_utility(0, &x) - 0.5).abs() < 1e-9);
        assert!((space.scaled_utility(1, &x) - 1.0).abs() < 1e-9);
        assert!((space.scaled_utility(2, &x) - 0.5).abs() < 1e-9);
    }
}
