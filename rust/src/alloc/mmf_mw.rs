//! SIMPLEMMF (Algorithm 2, Theorem 5): approximate
//! max_x min_i V_i(x) with the multiplicative-weights method over the
//! *full* (exponential) configuration space, using the exact WELFARE
//! knapsack oracle per iteration:
//!
//!   w₁ = 1/N;  for k = 1..T:  S ← WELFARE(w_k);
//!   w_{i,k+1} ← w_{ik}·exp(−ε·V_i(S)); normalize; x_S += 1/T.
//!
//! T = 4N²log N/ε² guarantees min_i V_i(x) ≥ λ*(1−ε); experiments cap T.
//! This is both a usable policy (the max-min step of lexicographic MMF)
//! and the provably-good reference that the §4.3 pruning heuristic is
//! validated against (the 5/25/50-vector error sweep).
//!
//! The oracle instance is built once as a [`WelfareTemplate`] and only
//! its values are rewritten per iteration — the skeleton (view sets,
//! sizes, budget) never changes across the T solves.

use crate::alloc::warm::{BatchSignature, MmfWarm, WarmState};
use crate::alloc::{Allocation, ConfigMask, Policy};
use crate::cache::tier::TierAssignment;
use crate::domain::utility::BatchUtilities;
use crate::util::rng::Pcg64;

/// Warm runs may stop once the WELFARE optimum has been identical for
/// this many consecutive iterations (the dual weights have entered the
/// region where one configuration dominates)...
const MMF_STABLE_EXIT: usize = 8;
/// ...but never before this many iterations, so the averaged iterate
/// always mixes at least a few configurations.
const MMF_MIN_ITERS: usize = 16;

#[derive(Debug)]
pub struct SimpleMmfMw {
    pub epsilon: f64,
    /// Cap on T (the theoretical count is 4N²logN/ε²).
    pub max_iters: usize,
}

impl Default for SimpleMmfMw {
    fn default() -> Self {
        Self {
            epsilon: 0.2,
            max_iters: 400,
        }
    }
}

impl SimpleMmfMw {
    /// Theoretical iteration count for N active tenants, capped.
    pub fn iterations(&self, n: usize) -> usize {
        let t = (4.0 * (n * n) as f64 * (n.max(2) as f64).ln()
            / (self.epsilon * self.epsilon))
            .ceil() as usize;
        t.clamp(1, self.max_iters)
    }

    /// Run Algorithm 2; returns (configs, probabilities) before
    /// normalization into an [`Allocation`]. Configurations are
    /// `(RAM, SSD)` pairs; SSD planes are empty in single-tier mode.
    pub fn solve(&self, batch: &BatchUtilities) -> Vec<(TierAssignment, f64)> {
        let mut no_warm = None;
        self.solve_inner(batch, &mut no_warm)
    }

    /// [`solve`](Self::solve) with carried dual weights. When `warm`
    /// holds converged weights for a same-shape batch with the same
    /// active-tenant set, the loop starts from them instead of uniform
    /// and may early-exit once the per-iteration WELFARE optimum is
    /// stable (the remaining probability mass goes to the stable
    /// configuration — exactly what the truncated iterations would have
    /// pushed). The converged weights are always stored back.
    pub fn solve_warm(
        &self,
        batch: &BatchUtilities,
        warm: &mut WarmState,
    ) -> Vec<(TierAssignment, f64)> {
        let mut slot = Some(warm);
        self.solve_inner(batch, &mut slot)
    }

    fn solve_inner(
        &self,
        batch: &BatchUtilities,
        warm: &mut Option<&mut WarmState>,
    ) -> Vec<(TierAssignment, f64)> {
        let active = batch.active_tenants();
        let n = active.len();
        if n == 0 {
            return vec![(
                TierAssignment::single(ConfigMask::empty(batch.n_views())),
                1.0,
            )];
        }
        let sig = warm.as_ref().map(|_| BatchSignature::of(batch));
        let seeded = match (warm.as_mut(), sig.as_ref()) {
            (Some(w), Some(sig)) => w
                .mmf
                .take()
                .filter(|p| p.sig.same_shape(sig) && p.active == active)
                .map(|p| p.weights),
            _ => None,
        };
        let was_seeded = seeded.is_some();
        let t_iters = self.iterations(n);
        let mut welfare = batch.welfare_template();
        // Dual weights live on active tenants only.
        let mut w = seeded.unwrap_or_else(|| vec![1.0 / n as f64; n]);
        let mut full_w = vec![0.0; batch.n_tenants];
        let mut pairs: Vec<(TierAssignment, f64)> = Vec::new();
        let mut stable = 0usize;
        for k in 0..t_iters {
            // WELFARE(w): lift the active-tenant weights into a full
            // weight vector.
            for (j, &i) in active.iter().enumerate() {
                full_w[i] = w[j];
            }
            let pair = welfare.solve_pair(&full_w);
            let v = batch.scaled_utilities_pair(&pair);
            // Multiplicative update: tenants satisfied by S are
            // down-weighted (Algorithm 2 line 7).
            for (j, &i) in active.iter().enumerate() {
                w[j] *= (-self.epsilon * v[i]).exp();
            }
            let norm: f64 = w.iter().sum();
            for wj in w.iter_mut() {
                *wj /= norm;
            }
            match pairs.last() {
                Some((last, _)) if *last == pair => stable += 1,
                _ => stable = 0,
            }
            pairs.push((pair.clone(), 1.0 / t_iters as f64));
            // Seeded runs re-enter near the fixed point; once the
            // optimum stops moving, hand the rest of the mass to it.
            if was_seeded && stable >= MMF_STABLE_EXIT && k + 1 >= MMF_MIN_ITERS {
                let remaining = (t_iters - (k + 1)) as f64 / t_iters as f64;
                if remaining > 0.0 {
                    pairs.push((pair, remaining));
                }
                break;
            }
        }
        if let (Some(slot), Some(sig)) = (warm.as_mut(), sig) {
            slot.mmf = Some(MmfWarm {
                sig,
                active,
                weights: w,
            });
        }
        pairs
    }
}

impl Policy for SimpleMmfMw {
    fn name(&self) -> &'static str {
        "MMF-MW"
    }

    fn allocate(&self, batch: &BatchUtilities, _rng: &mut Pcg64) -> Allocation {
        Allocation::from_weighted_pairs(self.solve(batch))
    }

    fn allocate_warm(
        &self,
        batch: &BatchUtilities,
        _rng: &mut Pcg64,
        warm: &mut WarmState,
    ) -> Allocation {
        Allocation::from_weighted_pairs(self.solve_warm(batch, warm))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::testing::{table2, table4, table5};

    #[test]
    fn table2_approaches_third() {
        let b = table2();
        let a = SimpleMmfMw::default().allocate(&b, &mut Pcg64::new(0));
        let v = a.expected_scaled_utilities(&b);
        let min = v.iter().cloned().fold(f64::INFINITY, f64::min);
        // λ* = 1/3; guarantee (1−ε) with ε=0.2 plus cap slack.
        assert!(min >= (1.0 / 3.0) * 0.75, "v={v:?}");
    }

    #[test]
    fn table4_approaches_half() {
        let b = table4(4);
        let a = SimpleMmfMw::default().allocate(&b, &mut Pcg64::new(0));
        let v = a.expected_scaled_utilities(&b);
        let min = v.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min >= 0.5 * 0.75, "v={v:?}");
    }

    #[test]
    fn table5_approaches_half() {
        let b = table5();
        let a = SimpleMmfMw::default().allocate(&b, &mut Pcg64::new(0));
        let v = a.expected_scaled_utilities(&b);
        assert!(v[0] >= 0.5 * 0.8 && v[1] >= 0.5 * 0.8, "v={v:?}");
    }

    #[test]
    fn tighter_epsilon_improves_minimum() {
        let b = table4(3);
        let loose = SimpleMmfMw {
            epsilon: 0.5,
            max_iters: 40,
        };
        let tight = SimpleMmfMw {
            epsilon: 0.1,
            max_iters: 4000,
        };
        let vl = loose
            .allocate(&b, &mut Pcg64::new(0))
            .expected_scaled_utilities(&b);
        let vt = tight
            .allocate(&b, &mut Pcg64::new(0))
            .expected_scaled_utilities(&b);
        let min_l = vl.iter().cloned().fold(f64::INFINITY, f64::min);
        let min_t = vt.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min_t >= min_l - 1e-9, "loose={min_l} tight={min_t}");
        assert!(min_t >= 0.5 * 0.95, "tight={min_t}");
    }

    #[test]
    fn empty_batch_graceful() {
        use crate::alloc::testing::matrix_instance;
        let b = matrix_instance(&[&[0], &[0]], 1.0);
        let a = SimpleMmfMw::default().allocate(&b, &mut Pcg64::new(0));
        assert!((a.total_probability() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn warm_first_call_matches_cold_and_mass_conserved() {
        let b = table4(4);
        let policy = SimpleMmfMw::default();
        let mut warm = WarmState::new();
        // An empty WarmState seeds nothing: identical pairs to cold.
        let cold = policy.solve(&b);
        let first = policy.solve_warm(&b, &mut warm);
        assert_eq!(cold, first);
        assert!(warm.mmf.is_some());
        // A seeded re-solve may truncate but must conserve unit mass and
        // keep the min-fairness guarantee.
        let again = policy.solve_warm(&b, &mut warm);
        let mass: f64 = again.iter().map(|(_, p)| p).sum();
        assert!((mass - 1.0).abs() < 1e-9, "mass={mass}");
        let v = Allocation::from_weighted_pairs(again).expected_scaled_utilities(&b);
        let min = v.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min >= 0.5 * 0.75, "v={v:?}");
    }

    #[test]
    fn warm_seed_rejected_on_shape_change() {
        use crate::alloc::testing::matrix_instance;
        let policy = SimpleMmfMw::default();
        let mut warm = WarmState::new();
        policy.solve_warm(&matrix_instance(&[&[1, 0], &[0, 1]], 1.0), &mut warm);
        // Budget change → shape mismatch → runs cold from uniform and
        // stores fresh weights for the new shape.
        let b2 = matrix_instance(&[&[1, 0], &[0, 1]], 2.0);
        let warm_pairs = policy.solve_warm(&b2, &mut warm);
        assert_eq!(warm_pairs, policy.solve(&b2));
        assert!(warm.mmf.as_ref().unwrap().sig.budget_bits == 2.0f64.to_bits());
    }
}
