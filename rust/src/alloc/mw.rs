//! The Arora–Hazan–Kale multiplicative-weights procedure (Algorithm 1):
//! decide feasibility of `Ax ≥ b, x ∈ P` to additive precision δ using an
//! ORACLE that maximizes `yᵀAx` over `P` for dual weights `y`.
//!
//! The matrix A is implicit: the caller provides the oracle, which
//! returns both a point's identifier and its per-constraint slacks
//! `a_i·x − b_i`. Theorem 3's guarantee: if the system is feasible, the
//! averaged iterate satisfies every constraint up to additive δ.

/// Outcome of an AHK run.
#[derive(Debug, Clone)]
pub enum AhkOutcome<X> {
    /// The averaged iterates (uniform weight over `points`).
    Feasible { points: Vec<X> },
    /// A dual certificate was found: `yᵀAx < yᵀb` for all x ∈ P.
    Infeasible,
}

/// Parameters for the AHK loop. `rho` is the width
/// ρ = max_i max_{x∈P} |a_i·x − b_i|; `delta` the additive precision.
#[derive(Debug, Clone)]
pub struct AhkParams {
    pub rho: f64,
    pub delta: f64,
    /// Hard cap on iterations (the theory needs 4ρ²ln(r)/δ², which can be
    /// large; experiments cap it and accept the weaker guarantee).
    pub max_iters: usize,
}

impl AhkParams {
    /// The theoretical iteration count K = 4ρ² ln(r) / δ², capped.
    pub fn iterations(&self, r: usize) -> usize {
        let k = (4.0 * self.rho * self.rho * (r.max(2) as f64).ln()
            / (self.delta * self.delta))
            .ceil() as usize;
        k.clamp(1, self.max_iters)
    }
}

/// One oracle response: an abstract point, its oracle value `yᵀAx`, and
/// the slack vector `a_i·x − b_i` for every constraint.
pub struct OracleResponse<X> {
    pub point: X,
    pub value: f64,
    pub slacks: Vec<f64>,
}

/// Run AHK over `r` constraints. `y_dot_b` computes `yᵀb` for the current
/// duals; `oracle` returns the best point for the duals.
pub fn ahk<X, F>(r: usize, params: &AhkParams, y_dot_b: impl Fn(&[f64]) -> f64, oracle: F) -> AhkOutcome<X>
where
    X: PartialEq,
    F: FnMut(&[f64]) -> OracleResponse<X>,
{
    ahk_from(r, params, y_dot_b, oracle, None, None).outcome
}

/// One AHK run's outcome plus the final dual weights — the warm-start
/// hand-off for the next batch's feasibility checks.
pub struct AhkRun<X> {
    pub outcome: AhkOutcome<X>,
    pub duals: Vec<f64>,
}

/// [`ahk`] with warm-start hooks: `y0` seeds the dual weights (any
/// invalid seed — wrong length, negative entries, zero mass — falls
/// back to uniform), and `stable_exit = Some(k)` declares feasibility
/// early once the oracle returns the *same* point for `k` consecutive
/// iterations — the duals have settled into a region where one
/// configuration dominates, so further iterations only replicate it in
/// the average. Early exit weakens the Theorem 3 additive-δ guarantee
/// to a heuristic and is only used on warm solve paths, where
/// equivalence is quality-within-ε (the infeasibility certificate
/// `yᵀAx < yᵀb` is still checked every iteration, so seeded runs never
/// misreport an infeasible system as feasible through the seed alone).
/// With `y0 = None` and `stable_exit = None`, iteration count, updates,
/// and outcome are bit-identical to [`ahk`].
pub fn ahk_from<X, F>(
    r: usize,
    params: &AhkParams,
    y_dot_b: impl Fn(&[f64]) -> f64,
    mut oracle: F,
    y0: Option<&[f64]>,
    stable_exit: Option<usize>,
) -> AhkRun<X>
where
    X: PartialEq,
    F: FnMut(&[f64]) -> OracleResponse<X>,
{
    let iters = params.iterations(r);
    let mut y = match y0 {
        Some(seed)
            if seed.len() == r
                && seed.iter().all(|v| v.is_finite() && *v >= 0.0)
                && seed.iter().sum::<f64>() > 0.0 =>
        {
            let norm: f64 = seed.iter().sum();
            seed.iter().map(|v| v / norm).collect()
        }
        _ => vec![1.0 / r as f64; r],
    };
    let mut points: Vec<X> = Vec::with_capacity(iters);
    let mut stable = 0usize;
    for _t in 0..iters {
        let resp = oracle(&y);
        debug_assert_eq!(resp.slacks.len(), r);
        if resp.value < y_dot_b(&y) - 1e-12 {
            return AhkRun {
                outcome: AhkOutcome::Infeasible,
                duals: y,
            };
        }
        // Multiplicative update (Algorithm 1 lines 7-12): constraints
        // with positive slack get down-weighted, violated constraints
        // up-weighted.
        for i in 0..r {
            let m = (resp.slacks[i] / params.rho).clamp(-1.0, 1.0);
            if m >= 0.0 {
                y[i] *= (1.0 - params.delta).powf(m);
            } else {
                y[i] *= (1.0 + params.delta).powf(-m);
            }
        }
        let norm: f64 = y.iter().sum();
        if norm > 0.0 {
            for yi in y.iter_mut() {
                *yi /= norm;
            }
        }
        match points.last() {
            Some(last) if *last == resp.point => stable += 1,
            _ => stable = 0,
        }
        points.push(resp.point);
        if stable_exit.is_some_and(|k| stable >= k) {
            break;
        }
    }
    AhkRun {
        outcome: AhkOutcome::Feasible { points },
        duals: y,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Feasibility of x ∈ [0,1]², x₁ ≥ 0.3, x₂ ≥ 0.4 — trivially feasible;
    /// the oracle maximizes y·x over the box (corner x = (1,1)).
    #[test]
    fn feasible_box_system() {
        let params = AhkParams {
            rho: 1.0,
            delta: 0.05,
            max_iters: 5000,
        };
        let b = [0.3, 0.4];
        let outcome = ahk(
            2,
            &params,
            |y| y[0] * b[0] + y[1] * b[1],
            |_y| OracleResponse {
                point: (1.0f64, 1.0f64),
                value: 1.0,
                slacks: vec![1.0 - b[0], 1.0 - b[1]],
            },
        );
        match outcome {
            AhkOutcome::Feasible { points } => assert!(!points.is_empty()),
            _ => panic!("expected feasible"),
        }
    }

    /// Infeasible: x ∈ [0,1], need x ≥ 0.6 and 1−x ≥ 0.6. For ANY duals,
    /// max_x yᵀAx = max_x (y₁x + y₂(1−x)) = max(y₁, y₂) < 0.6 = yᵀb
    /// whenever min(y₁,y₂) large... actually max(y₁,y₂) ≥ 1/2 ≥ ... use
    /// tighter: need x ≥ 0.9 and 1−x ≥ 0.9: yᵀb = 0.9, oracle max =
    /// max(y₁, y₂) ≤ 1 but with y₁=y₂=0.5 oracle = 0.5 < 0.9 → infeasible
    /// detected at the first iteration.
    #[test]
    fn infeasible_interval_system() {
        let params = AhkParams {
            rho: 1.0,
            delta: 0.1,
            max_iters: 100,
        };
        let outcome = ahk(
            2,
            &params,
            |y| 0.9 * (y[0] + y[1]),
            |y| {
                // maximize y₁x + y₂(1−x) over [0,1]: pick x = 1 if y₁≥y₂.
                let x = if y[0] >= y[1] { 1.0 } else { 0.0 };
                OracleResponse {
                    point: x,
                    value: y[0] * x + y[1] * (1.0 - x),
                    slacks: vec![x - 0.9, (1.0 - x) - 0.9],
                }
            },
        );
        assert!(matches!(outcome, AhkOutcome::Infeasible));
    }

    /// Averaged iterates approximately satisfy a genuinely mixing system:
    /// x ∈ {(1,0),(0,1)} (vertices), constraints x₁ ≥ 0.45, x₂ ≥ 0.45.
    /// Only the *average* (½,½) satisfies them — classic MW behaviour.
    #[test]
    fn averaging_mixes_vertices() {
        let params = AhkParams {
            rho: 1.0,
            delta: 0.02,
            max_iters: 20_000,
        };
        let outcome = ahk(
            2,
            &params,
            |y| 0.45 * (y[0] + y[1]),
            |y: &[f64]| {
                let pick0 = y[0] >= y[1];
                let (x1, x2) = if pick0 { (1.0, 0.0) } else { (0.0, 1.0) };
                OracleResponse {
                    point: (x1, x2),
                    value: y[0] * x1 + y[1] * x2,
                    slacks: vec![x1 - 0.45, x2 - 0.45],
                }
            },
        );
        let AhkOutcome::Feasible { points } = outcome else {
            panic!("expected feasible");
        };
        let n = points.len() as f64;
        let avg1: f64 = points.iter().map(|p| p.0).sum::<f64>() / n;
        let avg2: f64 = points.iter().map(|p| p.1).sum::<f64>() / n;
        assert!(avg1 >= 0.45 - 0.05, "avg1={avg1}");
        assert!(avg2 >= 0.45 - 0.05, "avg2={avg2}");
    }

    #[test]
    fn iteration_formula() {
        let p = AhkParams {
            rho: 1.0,
            delta: 0.1,
            max_iters: 1_000_000,
        };
        // 4·ln(4)/0.01 ≈ 555.
        let k = p.iterations(4);
        assert!((500..600).contains(&k), "k={k}");
        let capped = AhkParams {
            max_iters: 10,
            ..p
        };
        assert_eq!(capped.iterations(4), 10);
    }
}
