//! Elastic federation membership: a [`MembershipPlan`] is a schedule of
//! shard add/remove/kill events keyed by batch index, driving the
//! federation's live resharding machinery (see `cluster::federation`).
//!
//! The three actions map to the three production transitions:
//!
//! - **Add** — a cold shard joins; under hash placement ~1/N of the
//!   views re-home onto it via the consistent-hash ring (pack placement
//!   re-packs by the observed demand instead), and the joiner sits out
//!   the global accountant for a warm-up window so its empty cache does
//!   not read as tenant starvation.
//! - **Remove** — a planned decommission: the shard *drains* (its cached
//!   contents are migrated out — previewed with `CacheManager::
//!   drain_delta` and charged to `rebalance_churn_bytes`) and its homed
//!   views move to the survivors before the batch routes.
//! - **Kill** — fault injection: the shard drops with **no** drain, its
//!   cached bytes are lost, homed views re-route to survivors and every
//!   survivor's budget re-splits to `total/N'`. The per-batch
//!   `ClusterRecord`s capture the fairness-spread and throughput
//!   transients the accountant then absorbs.
//!
//! Plans parse from a compact CLI string (`robus cluster --membership
//! "add@40,kill@80"`): comma-separated `action[:shard]@batch` tokens
//! where `batch` is an index or `mid` (the run midpoint) and the
//! optional `:shard` picks an explicit victim for remove/kill (default:
//! the highest-id live shard). [`MembershipPlan::resolve`] fixes the
//! batch indices and simulates the schedule against the initial shard
//! count, rejecting plans that would drop the federation below one live
//! shard or target a shard that is not alive at event time.

use std::collections::BTreeSet;

/// What a membership event does to the live shard set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MembershipAction {
    /// A cold shard joins (drain-free; warm-up accounting applies).
    Add,
    /// A planned decommission: drain, then re-home to survivors.
    Remove,
    /// Fault injection: drop without drain; cached bytes are lost.
    Kill,
}

impl MembershipAction {
    pub fn name(&self) -> &'static str {
        match self {
            MembershipAction::Add => "add",
            MembershipAction::Remove => "remove",
            MembershipAction::Kill => "kill",
        }
    }

    fn parse(s: &str) -> Option<MembershipAction> {
        match s.to_ascii_lowercase().as_str() {
            "add" => Some(MembershipAction::Add),
            "remove" => Some(MembershipAction::Remove),
            "kill" => Some(MembershipAction::Kill),
            _ => None,
        }
    }
}

/// When an event fires: an explicit batch index or the run midpoint
/// (`mid` — resolved to `n_batches / 2` once the batch count is known,
/// so the same plan string works at `--quick` scale).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchPoint {
    At(usize),
    Mid,
}

/// One scheduled membership change.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MembershipEvent {
    pub at: BatchPoint,
    pub action: MembershipAction,
    /// Explicit target shard for remove/kill (`kill:2@80`); `None`
    /// targets the highest-id live shard. Rejected at parse time for
    /// adds (the joiner always gets the next fresh id).
    pub shard: Option<usize>,
}

/// A schedule of membership events. Empty plans (the default) keep the
/// federation static — bit-identical to the pre-elastic behavior.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MembershipPlan {
    pub events: Vec<MembershipEvent>,
}

/// One plan entry with its batch index fixed and its target shard
/// resolved against the simulated live set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResolvedEvent {
    pub batch: usize,
    pub action: MembershipAction,
    /// The concrete shard: the fresh id for adds, the victim otherwise.
    pub shard: usize,
}

impl MembershipPlan {
    /// The static (no-events) plan.
    pub fn empty() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse a comma-separated schedule: `action[:shard]@batch` with
    /// `action` ∈ {add, remove, kill} and `batch` a batch index or
    /// `mid`. Examples: `"add@40,kill@80"`, `"kill:0@mid"`.
    pub fn parse(s: &str) -> Result<MembershipPlan, String> {
        let mut events = Vec::new();
        for token in s.split(',') {
            let token = token.trim();
            if token.is_empty() {
                continue;
            }
            let (head, at) = token
                .split_once('@')
                .ok_or_else(|| format!("membership event '{token}' is missing '@batch'"))?;
            let (action_str, shard) = match head.split_once(':') {
                None => (head, None),
                Some((a, id)) => {
                    let id = id.trim().parse::<usize>().map_err(|_| {
                        format!("membership event '{token}': bad shard id '{id}'")
                    })?;
                    (a, Some(id))
                }
            };
            let action = MembershipAction::parse(action_str.trim()).ok_or_else(|| {
                format!(
                    "membership event '{token}': unknown action '{}' (use add|remove|kill)",
                    action_str.trim()
                )
            })?;
            // A joiner always receives the next fresh id; accepting an
            // explicit ':shard' here would let a later remove/kill
            // silently target the wrong shard.
            if action == MembershipAction::Add && shard.is_some() {
                return Err(format!(
                    "membership event '{token}': 'add' cannot name a shard — \
                     joiners get the next fresh id"
                ));
            }
            let at = match at.trim().to_ascii_lowercase().as_str() {
                "mid" => BatchPoint::Mid,
                b => BatchPoint::At(b.parse::<usize>().map_err(|_| {
                    format!("membership event '{token}': bad batch '{b}' (index or 'mid')")
                })?),
            };
            events.push(MembershipEvent { at, action, shard });
        }
        Ok(MembershipPlan { events })
    }

    /// Fix batch points against `n_batches`, order events by batch
    /// (stable — same-batch events keep their plan order), and simulate
    /// the schedule from `n_shards` initial shards, assigning fresh ids
    /// to adds and default victims to remove/kill. Errors on events
    /// past the run, targets that are not alive, and schedules that
    /// would drop the federation below one live shard.
    pub fn resolve(
        &self,
        n_shards: usize,
        n_batches: usize,
    ) -> Result<Vec<ResolvedEvent>, String> {
        let mut ordered: Vec<(usize, MembershipEvent)> = self
            .events
            .iter()
            .map(|e| {
                let batch = match e.at {
                    BatchPoint::At(b) => b,
                    BatchPoint::Mid => n_batches / 2,
                };
                (batch, *e)
            })
            .collect();
        ordered.sort_by_key(|(b, _)| *b);

        let mut live: BTreeSet<usize> = (0..n_shards).collect();
        let mut next_id = n_shards;
        let mut resolved = Vec::with_capacity(ordered.len());
        for (batch, ev) in ordered {
            if batch >= n_batches {
                return Err(format!(
                    "membership event {}@{batch} is past the run ({n_batches} batches)",
                    ev.action.name()
                ));
            }
            let shard = match ev.action {
                MembershipAction::Add => {
                    let id = next_id;
                    next_id += 1;
                    live.insert(id);
                    id
                }
                MembershipAction::Remove | MembershipAction::Kill => {
                    let target = match ev.shard {
                        Some(id) => id,
                        None => *live.iter().next_back().expect("live set never empty"),
                    };
                    if !live.contains(&target) {
                        return Err(format!(
                            "membership event {}@{batch}: shard {target} is not alive",
                            ev.action.name()
                        ));
                    }
                    if live.len() == 1 {
                        return Err(format!(
                            "membership event {}@{batch} would remove the last live shard",
                            ev.action.name()
                        ));
                    }
                    live.remove(&target);
                    target
                }
            };
            resolved.push(ResolvedEvent {
                batch,
                action: ev.action,
                shard,
            });
        }
        Ok(resolved)
    }
}

/// Reactive membership (`robus serve --membership auto[:lo,hi]`): the
/// closed-loop counterpart of the scheduled [`MembershipPlan`]. Instead
/// of firing at pre-written batch indices, the federated serving layer
/// watches sustained per-shard admission load over a sliding window and
/// *derives* the events — auto-add a shard when the hottest shard's
/// load stays above `hi_qps`, auto-drain the idlest when its load stays
/// below `lo_qps` — reusing the same drain→re-home→warm-up machinery
/// the scheduled plan drives (see `cluster::serving`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoMembership {
    /// Drain trigger: a shard whose admitted load stays below this
    /// (queries/sec) for a full window is idle.
    pub lo_qps: f64,
    /// Add trigger: when the hottest shard's admitted load stays above
    /// this (queries/sec) for a full window, the federation grows.
    pub hi_qps: f64,
    /// Sliding-window length in batches a signal must be sustained for.
    pub window: usize,
    /// Batches after any membership event before the next may fire
    /// (lets the re-home and warm-up settle instead of thrashing).
    pub cooldown: usize,
}

impl AutoMembership {
    /// Default sustained-signal window (batches).
    pub const DEFAULT_WINDOW: usize = 4;

    /// Parse the serve-mode membership argument: `auto` (bounds derived
    /// from the configured arrival rate at resolve time) or
    /// `auto:lo,hi` with explicit queries/sec bounds. Scheduled plans
    /// (`add@40,...`) are rejected here — they belong to `robus
    /// cluster`, whose batch indices mean trace-replay batches, not
    /// wall-clock windows.
    pub fn parse(s: &str) -> Result<AutoMembershipSpec, String> {
        let s = s.trim().to_ascii_lowercase();
        let s = s.as_str();
        if s == "auto" {
            return Ok(AutoMembershipSpec {
                lo_qps: None,
                hi_qps: None,
            });
        }
        if let Some(bounds) = s.strip_prefix("auto:") {
            let (lo, hi) = bounds.split_once(',').ok_or_else(|| {
                format!("'auto:{bounds}' needs two bounds: auto:lo,hi (queries/sec)")
            })?;
            let parse = |v: &str, which: &str| -> Result<f64, String> {
                v.trim()
                    .parse::<f64>()
                    .map_err(|_| format!("bad {which} bound '{}' (queries/sec)", v.trim()))
            };
            return Ok(AutoMembershipSpec {
                lo_qps: Some(parse(lo, "lo")?),
                hi_qps: Some(parse(hi, "hi")?),
            });
        }
        Err(format!(
            "serve supports reactive membership only: 'auto' or 'auto:lo,hi' \
             (got '{s}'; batch-scheduled plans like 'add@40' belong to robus cluster)"
        ))
    }
}

/// A parsed-but-unresolved `--membership auto[:lo,hi]`: bounds may
/// still be deferred to the configured arrival rate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoMembershipSpec {
    pub lo_qps: Option<f64>,
    pub hi_qps: Option<f64>,
}

impl AutoMembershipSpec {
    /// Fill defaulted bounds from the serve config and validate. The
    /// defaults bracket the initial fair share `rate / n_shards`: add
    /// above 2× (sustained overload even if traffic were spread
    /// evenly), drain below ¼× (a shard earning well under its share).
    /// Validation — both bounds positive, `lo < hi` — applies to
    /// explicit bounds too, so `auto:200,100` and `auto:0,0` are
    /// errors, not silent no-ops.
    pub fn resolve(
        &self,
        rate_per_sec: f64,
        n_shards: usize,
    ) -> Result<AutoMembership, String> {
        let fair = rate_per_sec / n_shards.max(1) as f64;
        let hi = self.hi_qps.unwrap_or(2.0 * fair);
        let lo = self.lo_qps.unwrap_or(0.25 * fair);
        if lo <= 0.0 || hi <= 0.0 || lo.is_nan() || hi.is_nan() {
            return Err(format!(
                "auto bounds must be positive queries/sec (got lo={lo}, hi={hi})"
            ));
        }
        if lo >= hi {
            return Err(format!(
                "auto bounds must satisfy lo < hi (got lo={lo}, hi={hi})"
            ));
        }
        Ok(AutoMembership {
            lo_qps: lo,
            hi_qps: hi,
            window: Self::default_window(),
            cooldown: Self::default_window(),
        })
    }

    fn default_window() -> usize {
        AutoMembership::DEFAULT_WINDOW
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_and_defaults() {
        let plan = MembershipPlan::parse("add@40, kill@80").unwrap();
        assert_eq!(plan.events.len(), 2);
        assert_eq!(plan.events[0].action, MembershipAction::Add);
        assert_eq!(plan.events[0].at, BatchPoint::At(40));
        assert_eq!(plan.events[1].action, MembershipAction::Kill);
        assert_eq!(plan.events[1].shard, None);
        assert!(MembershipPlan::empty().is_empty());
        assert!(MembershipPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn parse_explicit_shard_and_mid() {
        let plan = MembershipPlan::parse("kill:2@mid,remove:0@7").unwrap();
        assert_eq!(plan.events[0].shard, Some(2));
        assert_eq!(plan.events[0].at, BatchPoint::Mid);
        assert_eq!(plan.events[1].action, MembershipAction::Remove);
        assert_eq!(plan.events[1].shard, Some(0));
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(MembershipPlan::parse("add40").is_err());
        assert!(MembershipPlan::parse("grow@40").is_err());
        assert!(MembershipPlan::parse("add@soon").is_err());
        assert!(MembershipPlan::parse("kill:x@4").is_err());
        // An explicit shard on 'add' is a user error (the joiner's id
        // is assigned, not chosen) — surface it instead of ignoring it.
        assert!(MembershipPlan::parse("add:5@3").is_err());
    }

    #[test]
    fn resolve_assigns_fresh_ids_and_default_victims() {
        let plan = MembershipPlan::parse("add@2,kill@5,remove@8").unwrap();
        let r = plan.resolve(3, 10).unwrap();
        // Add gets the first fresh id (3); the default kill victim is
        // the highest live id (the fresh shard); the remove then takes
        // the highest original (2).
        assert_eq!(
            r,
            vec![
                ResolvedEvent { batch: 2, action: MembershipAction::Add, shard: 3 },
                ResolvedEvent { batch: 5, action: MembershipAction::Kill, shard: 3 },
                ResolvedEvent { batch: 8, action: MembershipAction::Remove, shard: 2 },
            ]
        );
    }

    #[test]
    fn resolve_mid_and_ordering() {
        let plan = MembershipPlan::parse("kill@mid,add@1").unwrap();
        let r = plan.resolve(4, 20).unwrap();
        assert_eq!(r[0].batch, 1);
        assert_eq!(r[0].action, MembershipAction::Add);
        assert_eq!(r[1].batch, 10);
        assert_eq!(r[1].action, MembershipAction::Kill);
    }

    #[test]
    fn resolve_rejects_impossible_schedules() {
        // Below one live shard.
        let p = MembershipPlan::parse("kill@1,kill@2").unwrap();
        assert!(p.resolve(2, 10).is_err());
        // Dead target.
        let p = MembershipPlan::parse("kill:1@1,remove:1@2").unwrap();
        assert!(p.resolve(3, 10).is_err());
        // Unknown target.
        let p = MembershipPlan::parse("kill:9@1").unwrap();
        assert!(p.resolve(3, 10).is_err());
        // Past the run.
        let p = MembershipPlan::parse("add@10").unwrap();
        assert!(p.resolve(3, 10).is_err());
        // A kill then an add keeping ≥1 alive is fine.
        let p = MembershipPlan::parse("kill@1,add@2").unwrap();
        assert!(p.resolve(2, 10).is_ok());
    }

    #[test]
    fn auto_parse_forms() {
        let spec = AutoMembership::parse("auto").unwrap();
        assert_eq!(spec.lo_qps, None);
        assert_eq!(spec.hi_qps, None);
        let spec = AutoMembership::parse("auto:50,400").unwrap();
        assert_eq!(spec.lo_qps, Some(50.0));
        assert_eq!(spec.hi_qps, Some(400.0));
        // Whitespace and case are tolerated.
        let spec = AutoMembership::parse(" AUTO:12.5, 80 ").unwrap();
        assert_eq!(spec.lo_qps, Some(12.5));
        assert_eq!(spec.hi_qps, Some(80.0));
        // Scheduled plans are cluster-mode syntax, not serve-mode.
        assert!(AutoMembership::parse("add@40").is_err());
        assert!(AutoMembership::parse("auto:100").is_err());
        assert!(AutoMembership::parse("auto:a,b").is_err());
    }

    #[test]
    fn auto_resolve_defaults_bracket_fair_share() {
        let auto = AutoMembership::parse("auto")
            .unwrap()
            .resolve(1000.0, 4)
            .unwrap();
        // Fair share 250 q/s: add above 2×, drain below ¼×.
        assert!((auto.hi_qps - 500.0).abs() < 1e-9);
        assert!((auto.lo_qps - 62.5).abs() < 1e-9);
        assert_eq!(auto.window, AutoMembership::DEFAULT_WINDOW);
        assert!(auto.cooldown >= 1);
    }

    /// Satellite (ISSUE 5): `--membership auto` bounds are validated —
    /// lo < hi and both positive — instead of silently misbehaving.
    #[test]
    fn auto_resolve_rejects_bad_bounds() {
        let bad = |s: &str| AutoMembership::parse(s).unwrap().resolve(1000.0, 2);
        assert!(bad("auto:200,100").is_err(), "lo >= hi must be rejected");
        assert!(bad("auto:100,100").is_err());
        assert!(bad("auto:0,100").is_err(), "lo must be positive");
        assert!(bad("auto:-5,100").is_err());
        assert!(bad("auto:10,-1").is_err());
        // Explicit good bounds pass through untouched.
        let auto = bad("auto:10,900").unwrap();
        assert_eq!(auto.lo_qps, 10.0);
        assert_eq!(auto.hi_qps, 900.0);
    }
}
