//! The sharded federation coordinator: N single-node ROBUS
//! planner/executor pairs (one per cache shard) under a global fairness
//! accountant, with **elastic membership** — shards can join, drain out,
//! or die mid-run on a [`MembershipPlan`] schedule.
//!
//! Per batch window the federation:
//! 1. applies the membership events scheduled for this batch:
//!    - **add** — a cold shard joins; the placement re-homes ~1/N of the
//!      views onto it (consistent-hash ring diff), every live budget
//!      re-splits to `total/N'`, and the joiner sits out the global
//!      accountant for a warm-up window so its empty cache does not read
//!      as tenant starvation;
//!    - **remove** — a planned decommission: the leaver's cached
//!      contents drain (previewed with `CacheManager::drain_delta`,
//!      charged to `rebalance_churn_bytes`) and its homed views re-home
//!      to the survivors before routing;
//!    - **kill** — fault injection: the victim drops with no drain (its
//!      cached bytes are lost), homed views re-route to survivors and
//!      budgets re-split — the per-batch records capture the fairness
//!      and throughput transient the accountant then absorbs;
//! 2. drains the *same* workload window a single-node coordinator would
//!    (identical arrivals — the scale-out changes routing, not demand);
//! 3. applies hot-view replication, **replica decay** (a replica whose
//!    demand share stayed below `--replicate-hot` for `--replica-decay`
//!    consecutive batches is evicted from non-home holders), and
//!    periodic demand-driven rebalance decisions from the previous
//!    batch's observations;
//! 4. routes each query to a live shard holding all its required views
//!    (replicated views spread deterministically across holders;
//!    spanning queries fall back to the home shard of their largest
//!    view);
//! 5. solves + executes every live shard concurrently on the
//!    federation's persistent worker pool ([`crate::cluster::runtime`]:
//!    `--workers` threads created once per run, shard steps multiplexed
//!    as messages — no per-batch thread spawns) — each shard runs the
//!    unmodified PR-2 `SolveContext`/`BatchExecutor` machinery over its
//!    routed queries with the current budget slice, under per-tenant
//!    weight multipliers from the accountant;
//! 6. aggregates attained/attainable per-tenant utilities across shards
//!    into the [`GlobalAccountant`] (warming joiners excluded), whose
//!    weighted-PF feedback boosts tenants starved anywhere in the
//!    federation on *every* shard next batch — fairness stays global per
//!    tenant through membership churn (Delta Fair Sharing's isolation
//!    under churn, LERC's coordinated cache decisions).
//!
//! With an empty plan every elastic path is inert, and with `--shards 1`
//! every step degenerates to the serial coordinator (no reweighting, no
//! replication, the identity placement): the run is bit-identical to
//! `Coordinator::run` — asserted across the §5.3 grid in
//! `rust/tests/cluster_equivalence.rs`; the elastic contract lives in
//! `rust/tests/elastic_membership.rs`.

use std::sync::Arc;
use std::time::Instant;

use crate::alloc::Policy;
use crate::cache::tier::TierSpec;
use crate::cluster::membership::{MembershipAction, MembershipPlan};
use crate::cluster::metrics::{ClusterRecord, ClusterResult, MembershipChange};
use crate::cluster::placement::{Placement, PlacementStrategy};
use crate::cluster::runtime::{resolve_workers, with_shard_pool, ShardPool, StepCtx};
use crate::cluster::shard::{Shard, ShardBatchOutcome};
use crate::coordinator::loop_::{tier_plan_of, CoordinatorConfig};
use crate::alloc::warm::reason;
use crate::domain::query::Query;
use crate::domain::tenant::TenantSet;
use crate::sim::engine::SimEngine;
use crate::telemetry::{EventKind, Telemetry};
use crate::util::rng::mix64;
use crate::workload::generator::WorkloadGenerator;
use crate::workload::universe::Universe;

/// Federation knobs (`robus cluster ...`).
#[derive(Debug, Clone)]
pub struct FederationConfig {
    pub n_shards: usize,
    pub placement: PlacementStrategy,
    /// Hot-view replication threshold: a view whose share of the
    /// previous batch's demanded bytes exceeds this fraction is
    /// replicated to every shard (replica bytes charged to each holder).
    /// `None` disables replication.
    pub replicate_hot: Option<f64>,
    /// Re-home views by cumulative demand (pack placer) every `k`
    /// batches; churn is previewed with `CacheManager::delta_to`.
    /// `None` disables rebalancing.
    pub rebalance_every: Option<usize>,
    /// Clamp on the global accountant's per-tenant weight multipliers
    /// (boosts live in `[1/max_boost, max_boost]`).
    pub max_boost: f64,
    /// Elastic membership schedule (`--membership "add@40,kill@80"`).
    /// Empty keeps the shard set fixed for the whole run.
    pub membership: MembershipPlan,
    /// Replica decay: evict a hot-view replica from its non-home
    /// holders once its demand share has stayed below `replicate_hot`
    /// for this many consecutive batches, charging the projected
    /// eviction to `rebalance_churn_bytes`. `None` keeps replication
    /// one-way (the PR-3 behavior).
    pub replica_decay: Option<usize>,
    /// Batches a freshly added shard is excluded from the global
    /// accountant while its cold cache warms up.
    pub warmup_batches: usize,
    /// Per-shard warm-started incremental solves. Off by default so
    /// `robus cluster` replays stay bit-identical to the historical
    /// path; the federated serving layer follows `serve`'s default (on).
    pub warm_start: bool,
    /// Worker-pool width for the shard runtime: `None` sizes to the
    /// host's available parallelism, `Some(0)` steps shards inline
    /// (sequential, no pool threads), `Some(n)` pins `n` workers.
    /// Simulated results are bit-identical across all settings — this
    /// only changes host-side scheduling.
    pub workers: Option<usize>,
}

impl Default for FederationConfig {
    fn default() -> Self {
        Self {
            n_shards: 1,
            placement: PlacementStrategy::Hash,
            replicate_hot: None,
            rebalance_every: None,
            max_boost: 4.0,
            membership: MembershipPlan::empty(),
            replica_decay: None,
            warmup_batches: 2,
            warm_start: false,
            workers: None,
        }
    }
}

impl FederationConfig {
    pub fn with_shards(n_shards: usize) -> Self {
        Self {
            n_shards,
            ..Self::default()
        }
    }
}

/// The global fairness accountant: folds every shard's per-batch
/// attained utility into one cumulative per-tenant ledger and emits the
/// weighted-PF weight multipliers for the next batch. A tenant whose
/// federation-wide attainment trails the mean gets boosted on every
/// shard — including shards where it is doing fine — so starvation on
/// one shard is compensated globally. The ledger is membership-
/// agnostic: observations are per-tenant sums over whatever shard set
/// was live (and warm) that batch, so adds, removes, and kills change
/// *what* is summed, never the ledger's shape.
#[derive(Debug, Clone)]
pub struct GlobalAccountant {
    /// Cumulative attained global scaled utility per tenant
    /// (Σ over batches of ΣU_i across shards / ΣU*_i across shards).
    cum: Vec<f64>,
    /// Batches in which the tenant was active anywhere.
    active: Vec<usize>,
    max_boost: f64,
}

impl GlobalAccountant {
    pub fn new(n_tenants: usize, max_boost: f64) -> Self {
        assert!(max_boost >= 1.0, "max_boost must be ≥ 1");
        Self {
            cum: vec![0.0; n_tenants],
            active: vec![0; n_tenants],
            max_boost,
        }
    }

    /// Fold one batch: `utilities` and `u_star` are the per-tenant sums
    /// across all observed (live, warmed-up) shards.
    pub fn observe(&mut self, utilities: &[f64], u_star: &[f64]) {
        for i in 0..self.cum.len() {
            if u_star[i] > 0.0 {
                self.cum[i] += utilities[i] / u_star[i];
                self.active[i] += 1;
            }
        }
    }

    /// Per-tenant weight multipliers for the next batch. Tenants at the
    /// mean attainment get exactly 1.0; starved tenants get boosted up
    /// to `max_boost`, over-served tenants damped down to `1/max_boost`.
    /// Inactive tenants stay at 1.0.
    pub fn multipliers(&self, weights: &[f64]) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.cum.len());
        self.multipliers_into(weights, &mut out);
        out
    }

    /// [`GlobalAccountant::multipliers`] into a caller-owned buffer
    /// (cleared first) — the federation loops call this every batch
    /// with a reused buffer, so the steady state allocates nothing.
    /// Two passes over the ledger; the normalized-attainment sum runs
    /// in tenant order, exactly as the collecting version did, so the
    /// floating-point results are bit-identical.
    pub fn multipliers_into(&self, weights: &[f64], out: &mut Vec<f64>) {
        out.clear();
        let norm = |c: f64, a: usize, w: f64| c / a as f64 / w.max(1e-12);
        let mut sum = 0.0;
        let mut n_act = 0usize;
        for ((&c, &a), &w) in self.cum.iter().zip(&self.active).zip(weights) {
            if a > 0 {
                sum += norm(c, a, w);
                n_act += 1;
            }
        }
        if n_act == 0 {
            out.resize(self.cum.len(), 1.0);
            return;
        }
        let mean = sum / n_act as f64;
        let eps = mean * 1e-3 + 1e-12;
        for ((&c, &a), &w) in self.cum.iter().zip(&self.active).zip(weights) {
            out.push(if a > 0 {
                ((mean + eps) / (norm(c, a, w) + eps))
                    .clamp(1.0 / self.max_boost, self.max_boost)
            } else {
                1.0
            });
        }
    }
}

/// The federation coordinator. Owns the same inputs as a single-node
/// [`crate::coordinator::loop_::Coordinator`] plus the
/// [`FederationConfig`]; `engine` describes one shard's cluster slice
/// with the *total* cache budget (each live shard gets `total / N'`,
/// re-split on every membership change).
pub struct ShardedCoordinator<'a> {
    pub universe: &'a Universe,
    pub tenants: TenantSet,
    pub engine: SimEngine,
    pub config: CoordinatorConfig,
    pub fed: FederationConfig,
}

impl<'a> ShardedCoordinator<'a> {
    pub fn new(
        universe: &'a Universe,
        tenants: TenantSet,
        engine: SimEngine,
        config: CoordinatorConfig,
        fed: FederationConfig,
    ) -> Self {
        assert!(fed.n_shards >= 1, "federation needs at least one shard");
        Self {
            universe,
            tenants,
            engine,
            config,
            fed,
        }
    }

    /// The federation's *total* tier specification: the configured
    /// `common.tiers` when tiered, else single-tier over the engine's
    /// whole cache budget (the legacy path).
    pub(crate) fn total_spec(&self) -> TierSpec {
        self.config
            .common
            .tiers
            .unwrap_or_else(|| TierSpec::single(self.engine.config.cache_budget))
    }

    /// Each shard's *initial* RAM slice of the total cache budget
    /// (elastic membership re-splits to `total / N'` as the live count
    /// changes; in tiered mode the SSD slice splits the same way).
    pub fn shard_budget(&self) -> u64 {
        self.total_spec().split(self.fed.n_shards).budgets.ram
    }

    /// Run the federated loop with `policy` over a fresh workload from
    /// `generator`. Same determinism contract as the single-node
    /// drivers: the generator seed fixes arrivals, `config.seed` fixes
    /// every shard's policy randomization, the membership schedule is
    /// deterministic by construction, and the worker-pool width
    /// (`fed.workers`) changes host-side scheduling only — shard steps
    /// are shard-local, so the simulated results are bit-identical at
    /// any width. Panics on an invalid membership plan — front doors
    /// validate with [`MembershipPlan::resolve`] first.
    #[deprecated(
        since = "0.2.0",
        note = "construct through `session::Session::federated(..).run(..)`"
    )]
    pub fn run(&self, generator: &mut WorkloadGenerator, policy: &dyn Policy) -> ClusterResult {
        self.run_impl(generator, policy, &Telemetry::off())
    }

    /// [`ShardedCoordinator::run`] with telemetry: per-shard batch
    /// spans (emitted by [`Shard::step`] on whichever pool worker runs
    /// it), scheduled membership / clamp / warm-invalidation events,
    /// and periodic counter snapshots on the simulated clock.
    #[deprecated(
        since = "0.2.0",
        note = "construct through `session::Session::federated(..).telemetry(..).run(..)`"
    )]
    pub fn run_with(
        &self,
        generator: &mut WorkloadGenerator,
        policy: &dyn Policy,
        tel: &Telemetry,
    ) -> ClusterResult {
        self.run_impl(generator, policy, tel)
    }

    /// The federated driver behind [`ShardedCoordinator::run`]/
    /// [`run_with`] and the Session API.
    pub(crate) fn run_impl(
        &self,
        generator: &mut WorkloadGenerator,
        policy: &dyn Policy,
        tel: &Telemetry,
    ) -> ClusterResult {
        let t_run = Instant::now();
        tel.meta(
            "cluster-replay",
            self.tenants.len(),
            self.fed.n_shards,
            self.fed.max_boost,
        );
        // One engine clone serves every shard executor (execution
        // behavior does not depend on the budget field); budgets are
        // handed to executors explicitly and re-split on membership
        // changes. Built before the pool so the shards' engine borrow
        // outlives the workers.
        let mut exec_engine = self.engine.clone();
        exec_engine.config.cache_budget = self.shard_budget();
        let exec_engine = exec_engine;
        let ctx = StepCtx {
            tenants: &self.tenants,
            universe: self.universe,
            policy,
            stateful_gamma: self.config.common.stateful_gamma,
            tel,
        };
        // The run's worker pool: the only thread creation of the whole
        // run. Per-batch fan-out/fan-in from here on is channel sends.
        with_shard_pool(resolve_workers(self.fed.workers), ctx, |pool| {
            self.run_on_pool(generator, policy, &exec_engine, t_run, tel, pool)
        })
    }

    /// The federated batch loop, driven on an already-running worker
    /// pool (see [`ShardedCoordinator::run`], which owns the pool's
    /// lifetime around this).
    fn run_on_pool<'e>(
        &self,
        generator: &mut WorkloadGenerator,
        policy: &dyn Policy,
        exec_engine: &'e SimEngine,
        t_run: Instant,
        tel: &Telemetry,
        pool: &mut ShardPool<'_, Shard<'e>>,
    ) -> ClusterResult {
        let n_shards = self.fed.n_shards;
        let n_views = self.universe.views.len();
        let n_tenants = self.tenants.len();
        let n_batches = self.config.n_batches;
        let cached_sizes: Vec<u64> = self
            .universe
            .views
            .iter()
            .map(|v| v.cached_bytes)
            .collect();
        let scan_sizes: Vec<u64> = self
            .universe
            .views
            .iter()
            .map(|v| v.scan_bytes)
            .collect();
        let weights = self.tenants.weights();
        let total_spec = self.total_spec();

        let schedule = self
            .fed
            .membership
            .resolve(n_shards, n_batches)
            .expect("invalid membership plan");
        let mut sched_i = 0usize;

        let mut placement = Placement::build(self.fed.placement, n_shards, &cached_sizes);

        let mut live_spec = total_spec.split(n_shards);

        let mut shards: Vec<Shard<'e>> = (0..n_shards)
            .map(|s| {
                Shard::new(
                    s,
                    exec_engine,
                    self.universe,
                    &self.tenants,
                    placement.shard_mask(s),
                    self.config.common.seed,
                    live_spec,
                    0,
                    self.fed.warm_start,
                )
            })
            .collect();
        // Shards retired by remove/kill, held until the end so their
        // RunResults share the final host wall-clock.
        let mut dead: Vec<Shard<'_>> = Vec::new();

        let mut accountant = GlobalAccountant::new(n_tenants, self.fed.max_boost);
        let mut records: Vec<ClusterRecord> = Vec::with_capacity(n_batches);
        let mut replication_bytes = 0u64;
        let mut rebalance_churn_bytes = 0u64;
        // Previous batch's demanded bytes per view (replication + decay
        // signal) and the whole-run cumulative demand (rebalance signal).
        let mut prev_demand = vec![0u64; n_views];
        let mut cum_demand = vec![0u64; n_views];
        // Consecutive batches each view's demand share stayed below the
        // replication threshold (the decay clock).
        let mut decay_streaks = vec![0usize; n_views];
        // Per-batch scratch, hoisted so the steady-state loop allocates
        // nothing per batch (DESIGN.md §2g): routing tables, demand
        // tallies, outcome fan-in, the accountant's observation sums,
        // and the shared multiplier buffer (refcounted out to workers,
        // reused in place once they hand their clones back).
        let mut id_to_idx: Vec<usize> = Vec::new();
        let mut batch_demand = vec![0u64; n_views];
        let mut targets: Vec<usize> = Vec::new();
        let mut outcomes: Vec<ShardBatchOutcome> = Vec::new();
        let mut obs_u = vec![0.0; n_tenants];
        let mut obs_star = vec![0.0; n_tenants];
        let mut mult_buf: Arc<Vec<f64>> = Arc::new(vec![1.0; n_tenants]);

        for b in 0..n_batches {
            let window_end = (b + 1) as f64 * self.config.common.batch_secs;
            let queries = generator.generate_until(window_end, self.universe);

            // --- 1. Membership events scheduled for this batch. ---
            // Pack-strategy re-homes re-pack by the demand the current
            // layout reflects (the rebalance signal) rather than static
            // sizes, so a membership event does not silently revert a
            // demand-driven layout and over-charge survivor-to-survivor
            // moves; before any demand exists, sizes are the signal.
            // Hash ignores the weights entirely.
            let mut membership_changes: Vec<MembershipChange> = Vec::new();
            let t_event = b as f64 * self.config.common.batch_secs;
            while sched_i < schedule.len() && schedule[sched_i].batch == b {
                let pack_weights: &[u64] = if cum_demand.iter().any(|&d| d > 0) {
                    &cum_demand
                } else {
                    &cached_sizes
                };
                let ev = schedule[sched_i];
                sched_i += 1;
                match ev.action {
                    MembershipAction::Add => {
                        let id = ev.shard;
                        let mut new_ids: Vec<usize> =
                            shards.iter().map(|s| s.id).collect();
                        new_ids.push(id);
                        new_ids.sort_unstable();
                        let next = placement.rehome_for_membership(
                            self.fed.placement,
                            &new_ids,
                            pack_weights,
                        );
                        let moved = apply_placement(
                            &mut placement,
                            next,
                            shards.iter_mut(),
                            &cached_sizes,
                            &mut rebalance_churn_bytes,
                            &mut replication_bytes,
                            tel,
                            t_event,
                            b as i64,
                        );
                        shards.push(Shard::new(
                            id,
                            exec_engine,
                            self.universe,
                            &self.tenants,
                            placement.shard_mask(id),
                            self.config.common.seed,
                            live_spec,
                            b + self.fed.warmup_batches,
                            self.fed.warm_start,
                        ));
                        tel.event(
                            t_event,
                            EventKind::MembershipAdd,
                            id as i64,
                            -1,
                            moved as f64,
                            "scheduled",
                            b as i64,
                        );
                        membership_changes.push(MembershipChange {
                            action: ev.action,
                            shard: id,
                            views_moved: moved,
                            bytes_drained: 0,
                            bytes_lost: 0,
                        });
                    }
                    MembershipAction::Remove | MembershipAction::Kill => {
                        let idx = shards
                            .iter()
                            .position(|s| s.id == ev.shard)
                            .expect("resolved membership target is live");
                        let sh = shards.remove(idx);
                        let (bytes_drained, bytes_lost) = match ev.action {
                            MembershipAction::Remove => {
                                // Planned decommission: contents migrate
                                // out — the drain preview is the churn.
                                let drained =
                                    sh.executor.cache().drain_delta().bytes_evicted;
                                rebalance_churn_bytes += drained;
                                (drained, 0)
                            }
                            _ => {
                                // Kill: no drain, the bytes are lost.
                                (0, sh.executor.cache().used_bytes())
                            }
                        };
                        // The leaver's replica copies vanish with it.
                        let rep_bytes: u64 =
                            sh.replicas.ones().map(|v| cached_sizes[v]).sum();
                        replication_bytes = replication_bytes.saturating_sub(rep_bytes);
                        dead.push(sh);
                        let new_ids: Vec<usize> = shards.iter().map(|s| s.id).collect();
                        let next = placement.rehome_for_membership(
                            self.fed.placement,
                            &new_ids,
                            pack_weights,
                        );
                        let moved = apply_placement(
                            &mut placement,
                            next,
                            shards.iter_mut(),
                            &cached_sizes,
                            &mut rebalance_churn_bytes,
                            &mut replication_bytes,
                            tel,
                            t_event,
                            b as i64,
                        );
                        let kind = match ev.action {
                            MembershipAction::Kill => EventKind::MembershipKill,
                            _ => EventKind::MembershipRemove,
                        };
                        tel.event(
                            t_event,
                            kind,
                            ev.shard as i64,
                            -1,
                            (bytes_drained + bytes_lost) as f64,
                            "scheduled",
                            b as i64,
                        );
                        membership_changes.push(MembershipChange {
                            action: ev.action,
                            shard: ev.shard,
                            views_moved: moved,
                            bytes_drained,
                            bytes_lost,
                        });
                    }
                }
                // Budget re-split across the new live set (both tiers
                // split together). Carried solver state is dropped
                // along with it: the budget change already voids the
                // warm shape signature, the explicit invalidation keeps
                // elastic events from ever trusting stale artifacts
                // even transiently.
                live_spec = total_spec.split(shards.len());
                for sh in shards.iter_mut() {
                    sh.executor.cache_mut().set_tier_budgets(live_spec.budgets);
                    if sh.invalidate_warm() {
                        tel.event(
                            t_event,
                            EventKind::WarmInvalidation,
                            sh.id as i64,
                            -1,
                            0.0,
                            reason::BUDGET_RESPLIT,
                            b as i64,
                        );
                    }
                }
            }

            // --- 3a. Hot-view replication, from the previous batch's
            // demand. ---
            let mut replicated_views = Vec::new();
            if shards.len() > 1 {
                if let Some(frac) = self.fed.replicate_hot {
                    let total: u64 = prev_demand.iter().sum();
                    if total > 0 {
                        for v in 0..n_views {
                            if prev_demand[v] as f64 > frac * total as f64 {
                                let mut added = 0u64;
                                for sh in shards.iter_mut() {
                                    if !sh.is_resident(v) {
                                        sh.replicas.set(v, true);
                                        added += 1;
                                    }
                                }
                                if added > 0 {
                                    replication_bytes += added * cached_sizes[v];
                                    replicated_views.push(v);
                                }
                            }
                        }
                    }
                }
            }

            // --- 3b. Replica decay: replicas whose demand share stayed
            // below the replication threshold for K consecutive batches
            // are evicted from their non-home holders. ---
            let mut decayed_views = Vec::new();
            if shards.len() > 1 {
                if let (Some(frac), Some(k)) =
                    (self.fed.replicate_hot, self.fed.replica_decay)
                {
                    let total: u64 = prev_demand.iter().sum();
                    let has_replica: Vec<bool> = (0..n_views)
                        .map(|v| shards.iter().any(|sh| sh.replicas.get(v)))
                        .collect();
                    for v in decay_due(
                        &mut decay_streaks,
                        &prev_demand,
                        total,
                        frac,
                        k,
                        &has_replica,
                    ) {
                        for sh in shards.iter_mut() {
                            if sh.replicas.get(v) {
                                sh.replicas.set(v, false);
                                replication_bytes =
                                    replication_bytes.saturating_sub(cached_sizes[v]);
                                if sh.executor.cache().is_cached(v) && !sh.home.get(v) {
                                    // Projected eviction: the solver
                                    // ages the copy out now that the
                                    // router stops feeding it.
                                    rebalance_churn_bytes += cached_sizes[v];
                                }
                            }
                        }
                        decayed_views.push(v);
                    }
                }
            }

            // --- 3c. Periodic demand-driven rebalance: re-home by
            // cumulative demand with the pack placer; preview the
            // eviction churn of each shard's no-longer-resident cached
            // views via delta_to. ---
            let mut rebalanced = false;
            if shards.len() > 1 {
                if let Some(kk) = self.fed.rebalance_every {
                    if kk > 0 && b > 0 && b % kk == 0 {
                        let live_ids: Vec<usize> = shards.iter().map(|s| s.id).collect();
                        let next = Placement::pack_weighted_for(&live_ids, &cum_demand);
                        if next != placement {
                            apply_placement(
                                &mut placement,
                                next,
                                shards.iter_mut(),
                                &cached_sizes,
                                &mut rebalance_churn_bytes,
                                &mut replication_bytes,
                                tel,
                                t_event,
                                b as i64,
                            );
                            rebalanced = true;
                        }
                    }
                }
            }

            // --- 4. Route the batch (order-preserving within each
            // shard) and record per-view demanded bytes for the
            // replication, decay, and rebalance signals. ---
            let max_id = shards.iter().map(|s| s.id).max().expect("live shards");
            id_to_idx.clear();
            id_to_idx.resize(max_id + 1, usize::MAX);
            for (i, sh) in shards.iter().enumerate() {
                id_to_idx[sh.id] = i;
            }
            batch_demand.fill(0);
            targets.clear();
            targets.extend(queries.iter().map(|q| {
                for v in &q.required_views {
                    batch_demand[v.0] += scan_sizes[v.0];
                }
                route(&shards, &placement, &id_to_idx, &cached_sizes, q)
            }));
            for (q, &s) in queries.into_iter().zip(&targets) {
                shards[s].inbox.push(q);
            }
            for v in 0..n_views {
                cum_demand[v] += batch_demand[v];
            }
            // batch_demand becomes the next batch's replication/decay
            // signal; the old signal buffer is refilled next batch.
            std::mem::swap(&mut prev_demand, &mut batch_demand);

            // Global-fairness feedback for this batch's solves: absent
            // on batch 0 (nothing observed) and while a single shard is
            // live (the bit-identical serial path). Every worker drops
            // its `Arc` clone before replying, so by fan-in the handle
            // is unique again and `make_mut` rewrites in place.
            let use_mults = shards.len() > 1 && b > 0;
            if use_mults {
                accountant.multipliers_into(&weights, Arc::make_mut(&mut mult_buf));
                for (i, &m) in mult_buf.iter().enumerate() {
                    if m >= self.fed.max_boost || m <= 1.0 / self.fed.max_boost {
                        tel.event(
                            t_event,
                            EventKind::MultiplierClamp,
                            -1,
                            i as i64,
                            m,
                            "boost_bound",
                            b as i64,
                        );
                    }
                }
            }

            // --- 5. Solve + execute every live shard on the worker
            // pool (fan-out/fan-in are channel sends; outcomes land in
            // shard order). ---
            pool.step_batch(
                &mut shards,
                b,
                window_end,
                live_spec.budgets.ram,
                tier_plan_of(&live_spec),
                use_mults.then_some(&mult_buf),
                &mut outcomes,
            );

            // --- 6. Aggregate federation-wide utilities. The records
            // keep the full reality (every live shard); the accountant
            // observes only warmed-up shards so a joiner's cold cache
            // does not crater its tenants' attained utility. ---
            let mut agg_u = vec![0.0; n_tenants];
            let mut agg_star = vec![0.0; n_tenants];
            obs_u.fill(0.0);
            obs_star.fill(0.0);
            for (sh, o) in shards.iter().zip(&outcomes) {
                let warm = !sh.is_warming(b);
                for i in 0..n_tenants {
                    agg_u[i] += o.utilities[i];
                    agg_star[i] += o.u_star[i];
                    if warm {
                        obs_u[i] += o.utilities[i];
                        obs_star[i] += o.u_star[i];
                    }
                }
            }
            accountant.observe(&obs_u, &obs_star);
            let warming_shards: Vec<usize> = shards
                .iter()
                .filter(|sh| sh.is_warming(b))
                .map(|sh| sh.id)
                .collect();

            records.push(ClusterRecord {
                index: b,
                multipliers: if use_mults {
                    mult_buf.as_ref().clone()
                } else {
                    vec![1.0; n_tenants]
                },
                replicated_views,
                rebalanced,
                membership: membership_changes,
                decayed_views,
                live_shards: shards.len(),
                shard_budget: live_spec.budgets.ram,
                warming_shards,
                tenant_attained: agg_u,
                tenant_attainable: agg_star,
            });
            tel.tick(window_end);
        }

        let host_wall_secs = t_run.elapsed().as_secs_f64();
        let mut all: Vec<Shard<'_>> = dead;
        all.extend(shards);
        all.sort_by_key(|sh| sh.id);
        let mut per_shard = Vec::with_capacity(all.len());
        let mut per_shard_budgets = Vec::with_capacity(all.len());
        for sh in all {
            let Shard {
                executor, budgets, ..
            } = sh;
            per_shard_budgets.push(budgets);
            per_shard.push(executor.into_result(
                policy.name(),
                &self.config,
                n_tenants,
                host_wall_secs,
            ));
        }
        ClusterResult::assemble(
            per_shard,
            per_shard_budgets,
            records,
            replication_bytes,
            rebalance_churn_bytes,
            host_wall_secs,
            n_batches,
        )
    }
}

/// Swap the federation onto a new placement — the one place every
/// re-home (membership add/remove/kill and demand rebalance, scheduled
/// or reactive — `cluster::serving` routes through here too) goes
/// through: diff the old→new maps, re-home every live shard (charging
/// previewed eviction churn), credit promoted-replica bytes back
/// against the replication ledger, and install the new map. Returns
/// the number of views whose home moved.
#[allow(clippy::too_many_arguments)]
pub(crate) fn apply_placement<'a, 'e: 'a>(
    placement: &mut Placement,
    next: Placement,
    shards: impl Iterator<Item = &'a mut Shard<'e>>,
    cached_sizes: &[u64],
    churn: &mut u64,
    replication_bytes: &mut u64,
    tel: &Telemetry,
    t: f64,
    batch: i64,
) -> usize {
    let moved = placement.moved_views(&next);
    let reclaimed = rehome(shards, &next, cached_sizes, churn, tel, t, batch);
    *replication_bytes = replication_bytes.saturating_sub(reclaimed);
    *placement = next;
    moved
}

/// Re-home every live shard to `next`'s map: reclassify replica bits
/// the new placement homes on their holder (the replica becomes the
/// primary — its replication charge is credited back via the returned
/// reclaimed bytes), and add the `delta_to`-previewed eviction bytes of
/// cached views each shard will no longer serve to `churn` (they age
/// out at the next solve; the preview quantifies the churn the re-home
/// causes). Replicas the new placement does *not* home stay in place —
/// replication is one-way until promotion or decay.
pub(crate) fn rehome<'a, 'e: 'a>(
    shards: impl Iterator<Item = &'a mut Shard<'e>>,
    next: &Placement,
    cached_sizes: &[u64],
    churn: &mut u64,
    tel: &Telemetry,
    t: f64,
    batch: i64,
) -> u64 {
    let mut reclaimed = 0u64;
    for sh in shards {
        let new_home = next.shard_mask(sh.id);
        for v in new_home.ones() {
            if sh.replicas.get(v) {
                sh.replicas.set(v, false);
                reclaimed += cached_sizes[v];
            }
        }
        let cached = sh.executor.cache().cached().clone();
        let mut keep = cached.clone();
        for v in cached.ones() {
            if !new_home.get(v) && !sh.replicas.get(v) {
                keep.set(v, false);
            }
        }
        *churn += sh.executor.cache().delta_to(&keep).bytes_evicted;
        sh.home = new_home;
        // A re-home changes what the router feeds this shard next batch;
        // carried solver state is stale by definition.
        if sh.invalidate_warm() {
            tel.event(
                t,
                EventKind::WarmInvalidation,
                sh.id as i64,
                -1,
                0.0,
                reason::REHOME,
                batch,
            );
        }
    }
    reclaimed
}

/// Advance the replica-decay streaks by one batch and return the views
/// due for decay: views with a live replica whose share of the
/// previous batch's demand stayed below `frac` for `k` consecutive
/// batches (a zero-demand batch counts as below for every view). Views
/// without replicas keep their streak at zero.
pub(crate) fn decay_due(
    streaks: &mut [usize],
    prev_demand: &[u64],
    total: u64,
    frac: f64,
    k: usize,
    has_replica: &[bool],
) -> Vec<usize> {
    let mut due = Vec::new();
    for v in 0..streaks.len() {
        if !has_replica[v] {
            streaks[v] = 0;
            continue;
        }
        let below = total == 0 || (prev_demand[v] as f64) < frac * total as f64;
        if below {
            streaks[v] += 1;
        } else {
            streaks[v] = 0;
        }
        if streaks[v] >= k.max(1) {
            due.push(v);
            streaks[v] = 0;
        }
    }
    due
}

/// The one routing policy both federation front-ends share — the
/// replay loop (per-batch routing over materialized [`Shard`]s, via
/// [`route`]) and the serving layer (admission-time routing over the
/// `ServeRouter`'s masks): prefer live shards serving every required
/// view (several holders → deterministic spread by query id), else the
/// home shard of the query's largest required view. `is_resident(i,
/// v)` asks whether live shard *index* `i` serves view `v` (home or
/// replica); `home_idx(v)` maps a view to its home shard's live index.
/// The `--shards 1` serve equivalence and the drain-conservation
/// contract both rely on the two call sites never diverging — which is
/// why there is exactly one implementation.
pub(crate) fn route_query(
    n_live: usize,
    is_resident: impl Fn(usize, usize) -> bool,
    home_idx: impl Fn(usize) -> usize,
    cached_sizes: &[u64],
    q: &Query,
) -> usize {
    // Allocation-free holder scan (this runs per *arrival* on the
    // serving path): count the holders, then walk to the chosen one —
    // identical to indexing the old collected holder list, since both
    // enumerate live indices in ascending order.
    let holds = |i: usize| q.required_views.iter().all(|v| is_resident(i, v.0));
    let n = (0..n_live).filter(|&i| holds(i)).count();
    match n {
        0 => q
            .required_views
            .iter()
            .map(|v| v.0)
            .max_by_key(|&v| (cached_sizes[v], std::cmp::Reverse(v)))
            .map(home_idx)
            .unwrap_or(0),
        _ => {
            let k = if n == 1 {
                0
            } else {
                (mix64(q.id.0) % n as u64) as usize
            };
            (0..n_live)
                .filter(|&i| holds(i))
                .nth(k)
                .expect("holder index within count")
        }
    }
}

/// Route one query of the replay federation. Returns an index into the
/// live `shards` slice.
fn route(
    shards: &[Shard<'_>],
    placement: &Placement,
    id_to_idx: &[usize],
    cached_sizes: &[u64],
    q: &Query,
) -> usize {
    route_query(
        shards.len(),
        |i, v| shards[i].is_resident(v),
        |v| id_to_idx[placement.home(v)],
        cached_sizes,
        q,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cluster::ClusterConfig;

    #[test]
    fn accountant_even_attainment_is_identity() {
        let mut acc = GlobalAccountant::new(3, 4.0);
        acc.observe(&[5.0, 5.0, 5.0], &[10.0, 10.0, 10.0]);
        acc.observe(&[2.0, 2.0, 2.0], &[4.0, 4.0, 4.0]);
        let m = acc.multipliers(&[1.0, 1.0, 1.0]);
        for (i, &mi) in m.iter().enumerate() {
            assert_eq!(mi, 1.0, "tenant {i} got multiplier {mi}");
        }
    }

    #[test]
    fn accountant_boosts_starved_tenant() {
        let mut acc = GlobalAccountant::new(2, 4.0);
        // Tenant 0 attains everything, tenant 1 almost nothing.
        for _ in 0..5 {
            acc.observe(&[10.0, 1.0], &[10.0, 10.0]);
        }
        let m = acc.multipliers(&[1.0, 1.0]);
        assert!(m[1] > 1.0, "starved tenant not boosted: {m:?}");
        assert!(m[0] < 1.0, "over-served tenant not damped: {m:?}");
        assert!(m[1] <= 4.0 && m[0] >= 0.25, "clamp violated: {m:?}");
    }

    #[test]
    fn accountant_ignores_inactive_tenants() {
        let mut acc = GlobalAccountant::new(2, 4.0);
        acc.observe(&[5.0, 0.0], &[10.0, 0.0]);
        let m = acc.multipliers(&[1.0, 1.0]);
        assert_eq!(m[1], 1.0, "inactive tenant must stay neutral");
    }

    #[test]
    fn accountant_empty_history_is_identity() {
        let acc = GlobalAccountant::new(4, 4.0);
        assert_eq!(acc.multipliers(&[1.0; 4]), vec![1.0; 4]);
    }

    #[test]
    fn accountant_respects_tenant_weights() {
        let mut acc = GlobalAccountant::new(2, 4.0);
        // Tenant 1 has double weight: the same attained utility means it
        // is *under*-served relative to entitlement → boosted.
        for _ in 0..3 {
            acc.observe(&[5.0, 5.0], &[10.0, 10.0]);
        }
        let m = acc.multipliers(&[1.0, 2.0]);
        assert!(m[1] > m[0], "heavier tenant should be favored: {m:?}");
    }

    /// Satellite regression (ISSUE 4): a re-home that promotes a
    /// replica to primary credits the replication charge back.
    #[test]
    fn rehome_promotion_reclaims_replica_bytes() {
        let universe = Universe::sales_only();
        let tenants = TenantSet::equal(2);
        let engine = SimEngine::new(ClusterConfig::default());
        let n_views = universe.views.len();
        let cached_sizes: Vec<u64> =
            universe.views.iter().map(|v| v.cached_bytes).collect();
        let start = Placement::hash(2, n_views);
        let spec = TierSpec::single(1000);
        let mut shards = vec![
            Shard::new(0, &engine, &universe, &tenants, start.shard_mask(0), 7, spec, 0, false),
            Shard::new(1, &engine, &universe, &tenants, start.shard_mask(1), 7, spec, 0, false),
        ];
        // Pick a view homed on shard 0 and replicate it onto shard 1.
        let v = (0..n_views).find(|&v| start.home(v) == 0).unwrap();
        shards[1].replicas.set(v, true);
        // New placement homes `v` on shard 1: the replica is promoted.
        let mut home: Vec<usize> = (0..n_views).map(|x| start.home(x)).collect();
        home[v] = 1;
        let next = Placement::from_home_map(vec![0, 1], home);
        let mut churn = 0u64;
        let tel = Telemetry::off();
        let reclaimed =
            rehome(shards.iter_mut(), &next, &cached_sizes, &mut churn, &tel, 0.0, -1);
        assert_eq!(reclaimed, cached_sizes[v], "promotion must credit the charge");
        assert!(!shards[1].replicas.get(v), "promoted replica bit cleared");
        assert!(shards[1].home.get(v), "view is now home on its holder");
        assert!(!shards[0].home.get(v));
        // Nothing was cached, so no eviction churn was previewed.
        assert_eq!(churn, 0);
    }

    #[test]
    fn decay_streaks_accumulate_and_reset() {
        let mut streaks = vec![0usize; 3];
        let has_replica = vec![true, true, false];
        // View 0 cold (below 10% of 100), view 1 hot, view 2 unreplicated.
        let demand = vec![1u64, 60, 39];
        let due = decay_due(&mut streaks, &demand, 100, 0.1, 2, &has_replica);
        assert!(due.is_empty());
        assert_eq!(streaks, vec![1, 0, 0]);
        // Second cold batch trips K=2 for view 0 and resets its streak.
        let due = decay_due(&mut streaks, &demand, 100, 0.1, 2, &has_replica);
        assert_eq!(due, vec![0]);
        assert_eq!(streaks, vec![0, 0, 0]);
        // A hot batch resets the streak.
        let hot = vec![50u64, 11, 39];
        let due = decay_due(&mut streaks, &hot, 100, 0.1, 2, &has_replica);
        assert!(due.is_empty());
        assert_eq!(streaks[0], 0);
        // Zero total demand counts as below for every replicated view.
        let due = decay_due(&mut streaks, &[0, 0, 0], 0, 0.1, 1, &has_replica);
        assert_eq!(due, vec![0, 1]);
    }
}
