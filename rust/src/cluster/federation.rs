//! The sharded federation coordinator: N single-node ROBUS
//! planner/executor pairs (one per cache shard) under a global fairness
//! accountant.
//!
//! Per batch window the federation:
//! 1. drains the *same* workload window a single-node coordinator would
//!    (identical arrivals — the scale-out changes routing, not demand);
//! 2. applies hot-view replication and periodic demand-driven rebalance
//!    decisions from the previous batch's observations;
//! 3. routes each query to a shard holding all its required views
//!    (replicated views spread deterministically across holders;
//!    spanning queries fall back to the home shard of their largest
//!    view);
//! 4. solves + executes every shard concurrently on scoped threads —
//!    each shard runs the unmodified PR-2 `SolveContext`/`BatchExecutor`
//!    machinery over its routed queries with its slice of the cache
//!    budget, under per-tenant weight multipliers from the accountant;
//! 5. aggregates attained/attainable per-tenant utilities across shards
//!    into the [`GlobalAccountant`], whose weighted-PF feedback boosts
//!    tenants starved anywhere in the federation on *every* shard next
//!    batch — fairness stays global per tenant, not per shard (Delta
//!    Fair Sharing's fleet-wide isolation, LERC's coordinated cache
//!    decisions).
//!
//! With `--shards 1` every step degenerates to the serial coordinator
//! (no reweighting, no replication, the identity placement), and the
//! run is bit-identical to `Coordinator::run` — asserted across the
//! §5.3 grid in `rust/tests/cluster_equivalence.rs`.

use std::time::Instant;

use crate::alloc::Policy;
use crate::cluster::metrics::{ClusterRecord, ClusterResult};
use crate::cluster::placement::{Placement, PlacementStrategy};
use crate::cluster::shard::{Shard, ShardBatchOutcome};
use crate::coordinator::loop_::{Coordinator, CoordinatorConfig, SolveContext};
use crate::domain::query::Query;
use crate::domain::tenant::TenantSet;
use crate::sim::engine::SimEngine;
use crate::util::rng::mix64;
use crate::workload::generator::WorkloadGenerator;
use crate::workload::universe::Universe;

/// Federation knobs (`robus cluster ...`).
#[derive(Debug, Clone)]
pub struct FederationConfig {
    pub n_shards: usize,
    pub placement: PlacementStrategy,
    /// Hot-view replication threshold: a view whose share of the
    /// previous batch's demanded bytes exceeds this fraction is
    /// replicated to every shard (replica bytes charged to each holder).
    /// `None` disables replication.
    pub replicate_hot: Option<f64>,
    /// Re-home views by cumulative demand (pack placer) every `k`
    /// batches; churn is previewed with `CacheManager::delta_to`.
    /// `None` disables rebalancing.
    pub rebalance_every: Option<usize>,
    /// Clamp on the global accountant's per-tenant weight multipliers
    /// (boosts live in `[1/max_boost, max_boost]`).
    pub max_boost: f64,
}

impl Default for FederationConfig {
    fn default() -> Self {
        Self {
            n_shards: 1,
            placement: PlacementStrategy::Hash,
            replicate_hot: None,
            rebalance_every: None,
            max_boost: 4.0,
        }
    }
}

impl FederationConfig {
    pub fn with_shards(n_shards: usize) -> Self {
        Self {
            n_shards,
            ..Self::default()
        }
    }
}

/// The global fairness accountant: folds every shard's per-batch
/// attained utility into one cumulative per-tenant ledger and emits the
/// weighted-PF weight multipliers for the next batch. A tenant whose
/// federation-wide attainment trails the mean gets boosted on every
/// shard — including shards where it is doing fine — so starvation on
/// one shard is compensated globally.
#[derive(Debug, Clone)]
pub struct GlobalAccountant {
    /// Cumulative attained global scaled utility per tenant
    /// (Σ over batches of ΣU_i across shards / ΣU*_i across shards).
    cum: Vec<f64>,
    /// Batches in which the tenant was active anywhere.
    active: Vec<usize>,
    max_boost: f64,
}

impl GlobalAccountant {
    pub fn new(n_tenants: usize, max_boost: f64) -> Self {
        assert!(max_boost >= 1.0, "max_boost must be ≥ 1");
        Self {
            cum: vec![0.0; n_tenants],
            active: vec![0; n_tenants],
            max_boost,
        }
    }

    /// Fold one batch: `utilities` and `u_star` are the per-tenant sums
    /// across all shards.
    pub fn observe(&mut self, utilities: &[f64], u_star: &[f64]) {
        for i in 0..self.cum.len() {
            if u_star[i] > 0.0 {
                self.cum[i] += utilities[i] / u_star[i];
                self.active[i] += 1;
            }
        }
    }

    /// Per-tenant weight multipliers for the next batch. Tenants at the
    /// mean attainment get exactly 1.0; starved tenants get boosted up
    /// to `max_boost`, over-served tenants damped down to `1/max_boost`.
    /// Inactive tenants stay at 1.0.
    pub fn multipliers(&self, weights: &[f64]) -> Vec<f64> {
        let norms: Vec<Option<f64>> = self
            .cum
            .iter()
            .zip(&self.active)
            .zip(weights)
            .map(|((&c, &a), &w)| {
                if a > 0 {
                    Some(c / a as f64 / w.max(1e-12))
                } else {
                    None
                }
            })
            .collect();
        let act: Vec<f64> = norms.iter().flatten().copied().collect();
        if act.is_empty() {
            return vec![1.0; self.cum.len()];
        }
        let mean = act.iter().sum::<f64>() / act.len() as f64;
        let eps = mean * 1e-3 + 1e-12;
        norms
            .into_iter()
            .map(|o| match o {
                None => 1.0,
                Some(x) => ((mean + eps) / (x + eps))
                    .clamp(1.0 / self.max_boost, self.max_boost),
            })
            .collect()
    }
}

/// The federation coordinator. Owns the same inputs as a single-node
/// [`Coordinator`] plus the [`FederationConfig`]; `engine` describes one
/// shard's cluster slice with the *total* cache budget (each shard gets
/// `budget / n_shards`).
pub struct ShardedCoordinator<'a> {
    pub universe: &'a Universe,
    pub tenants: TenantSet,
    pub engine: SimEngine,
    pub config: CoordinatorConfig,
    pub fed: FederationConfig,
}

impl<'a> ShardedCoordinator<'a> {
    pub fn new(
        universe: &'a Universe,
        tenants: TenantSet,
        engine: SimEngine,
        config: CoordinatorConfig,
        fed: FederationConfig,
    ) -> Self {
        assert!(fed.n_shards >= 1, "federation needs at least one shard");
        Self {
            universe,
            tenants,
            engine,
            config,
            fed,
        }
    }

    /// Each shard's slice of the total cache budget.
    pub fn shard_budget(&self) -> u64 {
        self.engine.config.cache_budget / self.fed.n_shards as u64
    }

    /// Run the federated loop with `policy` over a fresh workload from
    /// `generator`. Same determinism contract as the single-node
    /// drivers: the generator seed fixes arrivals, `config.seed` fixes
    /// every shard's policy randomization.
    pub fn run(&self, generator: &mut WorkloadGenerator, policy: &dyn Policy) -> ClusterResult {
        let t_run = Instant::now();
        let n_shards = self.fed.n_shards;
        let n_views = self.universe.views.len();
        let n_tenants = self.tenants.len();
        let cached_sizes: Vec<u64> = self
            .universe
            .views
            .iter()
            .map(|v| v.cached_bytes)
            .collect();
        let scan_sizes: Vec<u64> = self
            .universe
            .views
            .iter()
            .map(|v| v.scan_bytes)
            .collect();
        let weights = self.tenants.weights();

        let mut placement = Placement::build(self.fed.placement, n_shards, &cached_sizes);

        // Per-shard coordinators: identical knobs, the engine's budget
        // cut to the shard slice — `executor()` then builds each shard's
        // CacheManager with the right budget.
        let mut shard_engine = self.engine.clone();
        shard_engine.config.cache_budget = self.shard_budget();
        let shard_budget = shard_engine.config.cache_budget;
        let coordinators: Vec<Coordinator<'a>> = (0..n_shards)
            .map(|_| {
                Coordinator::new(
                    self.universe,
                    self.tenants.clone(),
                    shard_engine.clone(),
                    self.config.clone(),
                )
            })
            .collect();
        let mut shards: Vec<Shard<'_>> = coordinators
            .iter()
            .enumerate()
            .map(|(s, c)| Shard::new(s, c, placement.shard_mask(s), n_views, self.config.seed))
            .collect();

        let mut accountant = GlobalAccountant::new(n_tenants, self.fed.max_boost);
        let mut records: Vec<ClusterRecord> = Vec::with_capacity(self.config.n_batches);
        let mut replication_bytes = 0u64;
        let mut rebalance_churn = 0u64;
        // Previous batch's demanded bytes per view (replication signal)
        // and the whole-run cumulative demand (rebalance signal).
        let mut prev_demand = vec![0u64; n_views];
        let mut cum_demand = vec![0u64; n_views];

        for b in 0..self.config.n_batches {
            let window_end = (b + 1) as f64 * self.config.batch_secs;
            let queries = generator.generate_until(window_end, self.universe);

            // Hot-view replication, from the previous batch's demand.
            let mut replicated_views = Vec::new();
            if n_shards > 1 {
                if let Some(frac) = self.fed.replicate_hot {
                    let total: u64 = prev_demand.iter().sum();
                    if total > 0 {
                        for v in 0..n_views {
                            if prev_demand[v] as f64 > frac * total as f64 {
                                let mut added = 0u64;
                                for sh in shards.iter_mut() {
                                    if !sh.is_resident(v) {
                                        sh.replicas.set(v, true);
                                        added += 1;
                                    }
                                }
                                if added > 0 {
                                    replication_bytes += added * cached_sizes[v];
                                    replicated_views.push(v);
                                }
                            }
                        }
                    }
                }
            }

            // Periodic demand-driven rebalance: re-home by cumulative
            // demand with the pack placer; preview the eviction churn of
            // each shard's no-longer-resident cached views via delta_to.
            let mut rebalanced = false;
            if n_shards > 1 {
                if let Some(k) = self.fed.rebalance_every {
                    if k > 0 && b > 0 && b % k == 0 {
                        let next = Placement::pack_weighted(n_shards, &cum_demand);
                        if next != placement {
                            rebalance_churn += rehome(&mut shards, &next);
                            placement = next;
                            rebalanced = true;
                        }
                    }
                }
            }

            // Route the batch (order-preserving within each shard) and
            // record per-view demanded bytes for the replication and
            // rebalance signals.
            let mut batch_demand = vec![0u64; n_views];
            let targets: Vec<usize> = queries
                .iter()
                .map(|q| {
                    for v in &q.required_views {
                        batch_demand[v.0] += scan_sizes[v.0];
                    }
                    route(&shards, &placement, &cached_sizes, q)
                })
                .collect();
            for (q, s) in queries.into_iter().zip(targets) {
                shards[s].inbox.push(q);
            }
            for v in 0..n_views {
                cum_demand[v] += batch_demand[v];
            }
            prev_demand = batch_demand;

            // Global-fairness feedback for this batch's solves: None on
            // batch 0 (nothing observed) and for single-shard runs (the
            // bit-identical serial path).
            let mults: Option<Vec<f64>> = if n_shards > 1 && b > 0 {
                Some(accountant.multipliers(&weights))
            } else {
                None
            };

            // Solve + execute every shard concurrently.
            let outcomes: Vec<ShardBatchOutcome> = std::thread::scope(|scope| {
                let handles: Vec<_> = shards
                    .iter_mut()
                    .map(|sh| {
                        let ctx = SolveContext {
                            tenants: &self.tenants,
                            universe: self.universe,
                            budget: shard_budget,
                            stateful_gamma: self.config.stateful_gamma,
                            weight_mult: mults.as_deref(),
                        };
                        scope.spawn(move || sh.step(&ctx, policy, b, window_end))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard thread panicked"))
                    .collect()
            });

            // Aggregate federation-wide utilities into the accountant.
            let mut agg_u = vec![0.0; n_tenants];
            let mut agg_star = vec![0.0; n_tenants];
            for o in &outcomes {
                for i in 0..n_tenants {
                    agg_u[i] += o.utilities[i];
                    agg_star[i] += o.u_star[i];
                }
            }
            accountant.observe(&agg_u, &agg_star);

            records.push(ClusterRecord {
                index: b,
                multipliers: mults.unwrap_or_else(|| vec![1.0; n_tenants]),
                replicated_views,
                rebalanced,
            });
        }

        let host_wall_secs = t_run.elapsed().as_secs_f64();
        let per_shard = shards
            .into_iter()
            .map(|sh| {
                sh.executor
                    .into_result(policy.name(), &self.config, n_tenants, host_wall_secs)
            })
            .collect();
        ClusterResult::assemble(
            per_shard,
            records,
            replication_bytes,
            rebalance_churn,
            host_wall_secs,
        )
    }
}

/// Re-home every shard to `next`'s map, returning the summed
/// `delta_to`-previewed eviction bytes of cached views the shard will
/// no longer serve (they age out at the next solve; the preview
/// quantifies the churn the rebalance causes). Hot-view replicas are
/// preserved across the re-home — replication is one-way; a replica bit
/// promoted to home is just reclassified, never dropped.
fn rehome(shards: &mut [Shard<'_>], next: &Placement) -> u64 {
    let mut churn = 0u64;
    for sh in shards.iter_mut() {
        let new_home = next.shard_mask(sh.id);
        // Reclassify replica bits the new placement homes here.
        for v in new_home.ones() {
            if sh.replicas.get(v) {
                sh.replicas.set(v, false);
            }
        }
        let cached = sh.executor.cache().cached().clone();
        let mut keep = cached.clone();
        for v in cached.ones() {
            if !new_home.get(v) && !sh.replicas.get(v) {
                keep.set(v, false);
            }
        }
        churn += sh.executor.cache().delta_to(&keep).bytes_evicted;
        sh.home = new_home;
    }
    churn
}

/// Route one query: prefer shards holding every required view (several
/// holders → deterministic spread by query id), else the home shard of
/// the query's largest required view.
fn route(
    shards: &[Shard<'_>],
    placement: &Placement,
    cached_sizes: &[u64],
    q: &Query,
) -> usize {
    let holders: Vec<usize> = shards
        .iter()
        .filter(|sh| q.required_views.iter().all(|v| sh.is_resident(v.0)))
        .map(|sh| sh.id)
        .collect();
    match holders.len() {
        0 => q
            .required_views
            .iter()
            .map(|v| v.0)
            .max_by_key(|&v| (cached_sizes[v], std::cmp::Reverse(v)))
            .map(|v| placement.home(v))
            .unwrap_or(0),
        1 => holders[0],
        n => holders[(mix64(q.id.0) % n as u64) as usize],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accountant_even_attainment_is_identity() {
        let mut acc = GlobalAccountant::new(3, 4.0);
        acc.observe(&[5.0, 5.0, 5.0], &[10.0, 10.0, 10.0]);
        acc.observe(&[2.0, 2.0, 2.0], &[4.0, 4.0, 4.0]);
        let m = acc.multipliers(&[1.0, 1.0, 1.0]);
        for (i, &mi) in m.iter().enumerate() {
            assert_eq!(mi, 1.0, "tenant {i} got multiplier {mi}");
        }
    }

    #[test]
    fn accountant_boosts_starved_tenant() {
        let mut acc = GlobalAccountant::new(2, 4.0);
        // Tenant 0 attains everything, tenant 1 almost nothing.
        for _ in 0..5 {
            acc.observe(&[10.0, 1.0], &[10.0, 10.0]);
        }
        let m = acc.multipliers(&[1.0, 1.0]);
        assert!(m[1] > 1.0, "starved tenant not boosted: {m:?}");
        assert!(m[0] < 1.0, "over-served tenant not damped: {m:?}");
        assert!(m[1] <= 4.0 && m[0] >= 0.25, "clamp violated: {m:?}");
    }

    #[test]
    fn accountant_ignores_inactive_tenants() {
        let mut acc = GlobalAccountant::new(2, 4.0);
        acc.observe(&[5.0, 0.0], &[10.0, 0.0]);
        let m = acc.multipliers(&[1.0, 1.0]);
        assert_eq!(m[1], 1.0, "inactive tenant must stay neutral");
    }

    #[test]
    fn accountant_empty_history_is_identity() {
        let acc = GlobalAccountant::new(4, 4.0);
        assert_eq!(acc.multipliers(&[1.0; 4]), vec![1.0; 4]);
    }

    #[test]
    fn accountant_respects_tenant_weights() {
        let mut acc = GlobalAccountant::new(2, 4.0);
        // Tenant 1 has double weight: the same attained utility means it
        // is *under*-served relative to entitlement → boosted.
        for _ in 0..3 {
            acc.observe(&[5.0, 5.0], &[10.0, 10.0]);
        }
        let m = acc.multipliers(&[1.0, 2.0]);
        assert!(m[1] > m[0], "heavier tenant should be favored: {m:?}");
    }
}
