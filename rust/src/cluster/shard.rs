//! One cache shard of the federation: a `BatchExecutor` (the PR-2
//! execute half — incremental cache transition + simulated execution on
//! the shard's own cluster slice), a planner-style cache-contents
//! mirror, a policy RNG stream, and the home/replica routing masks.
//!
//! Each shard is deliberately the *same* machinery as a single-node
//! coordinator: `SolveContext::solve_accounted` for steps 1–2 and
//! `BatchExecutor::execute` for steps 3–5. The federation adds routing
//! and the global fairness accountant around it, nothing inside it —
//! which is what makes the `--shards 1` run bit-identical to
//! `Coordinator::run`.
//!
//! Elastic membership (PR 4) makes shards constructible mid-run: a
//! shard is built straight from the shared engine/universe/tenants
//! handles (no per-shard `Coordinator`), carries its current budget
//! history (`budgets[i]` is the budget at its i-th executed batch —
//! the merge weights utilization by it), and a `warmup_until` batch
//! before which a freshly joined shard's outcomes are excluded from
//! the global accountant so its cold cache does not read as tenant
//! starvation.

use std::time::Instant;

use crate::alloc::{ConfigMask, Policy, WarmState};
use crate::cache::tier::{TierAssignment, TierSpec};
use crate::coordinator::loop_::{BatchExecutor, PlannedBatch, SolveContext};
use crate::domain::query::Query;
use crate::domain::tenant::TenantSet;
use crate::domain::view::ViewId;
use crate::sim::engine::SimEngine;
use crate::telemetry::{SpanRecord, Telemetry};
use crate::util::rng::Pcg64;
use crate::workload::universe::Universe;

/// Per-batch, per-shard accounting handed back to the federation's
/// global fairness accountant.
pub(crate) struct ShardBatchOutcome {
    /// Raw per-tenant utility attained on this shard.
    pub utilities: Vec<f64>,
    /// Per-tenant solo optimum U* of this shard's batch problem.
    pub u_star: Vec<f64>,
}

/// The mutable state of one shard across its lifetime. All fields are
/// shard-local, so per-batch shard steps run on independent threads
/// with no shared mutability.
pub(crate) struct Shard<'a> {
    /// Stable shard id — survives membership changes around it; the
    /// consistent-hash ring and the RNG stream key off it.
    pub id: usize,
    /// Steps 3–5 (cache transition + simulated execution), reused
    /// verbatim from the coordinator loop.
    pub executor: BatchExecutor<'a>,
    /// Policy randomization stream. Shard 0 uses the exact planner
    /// stream of the serial coordinator, so a 1-shard federation samples
    /// identical configurations.
    pub rng: Pcg64,
    /// Planner-side mirror of this shard's cache contents (the stateful
    /// boost source). Re-synced from the live cache after every
    /// transition, so in tiered mode it also carries the SSD plane's
    /// demotion fill the solver never saw.
    pub mirror: TierAssignment,
    /// Views homed on this shard by the current placement — the
    /// federation router's map, not a constraint on the cache.
    pub home: ConfigMask,
    /// Hot-view replicas this shard additionally serves. Kept separate
    /// from `home` so a rebalance (which rewrites `home`) never wipes
    /// replicas; replicas leave only by promotion to home (re-home
    /// reclassification) or by replica decay.
    pub replicas: ConfigMask,
    /// Queries routed to this shard for the current batch window.
    pub inbox: Vec<Query>,
    /// First batch index at which the global accountant may observe
    /// this shard (join batch + warm-up window; 0 for initial shards).
    pub warmup_until: usize,
    /// Cache budget at each executed batch, aligned with the executor's
    /// batch records — the merge's utilization weights.
    pub budgets: Vec<u64>,
    /// Carried warm-start solver state (`Some` iff the federation runs
    /// with warm starts). Shard-local like everything else here; the
    /// federation invalidates it on membership changes, re-homes, and
    /// budget re-splits.
    pub warm: Option<WarmState>,
    /// Host seconds the driver spent routing/draining this shard's
    /// inbox for the upcoming batch — set by the serving loop before
    /// [`Shard::step`], consumed into that step's telemetry span (the
    /// replay federation routes in bulk and leaves it 0).
    pub last_drain_secs: f64,
}

/// The serial coordinator planner's RNG stream selector (see
/// `Coordinator::planner`); shard `s` uses `stream + s`.
const PLANNER_STREAM: u64 = 0x0b5;

impl<'a> Shard<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        engine: &'a SimEngine,
        universe: &Universe,
        tenants: &TenantSet,
        home: ConfigMask,
        seed: u64,
        spec: TierSpec,
        warmup_until: usize,
        warm_start: bool,
    ) -> Self {
        let n_views = universe.views.len();
        Self {
            id,
            executor: BatchExecutor::build(engine, universe, tenants, spec),
            rng: Pcg64::with_stream(seed, PLANNER_STREAM + id as u64),
            mirror: TierAssignment::single(ConfigMask::empty(n_views)),
            home,
            replicas: ConfigMask::empty(n_views),
            inbox: Vec::new(),
            warmup_until,
            budgets: Vec::new(),
            warm: warm_start.then(WarmState::new),
            last_drain_secs: 0.0,
        }
    }

    /// Drop carried solver state; the next solve runs fully cold.
    /// Called by the federation on membership changes, view re-homes,
    /// and budget re-splits. Returns whether warm starts are on (i.e.
    /// there was carried state to drop) so callers can emit a
    /// warm-invalidation trace event exactly when one happened.
    pub fn invalidate_warm(&mut self) -> bool {
        if let Some(w) = self.warm.as_mut() {
            w.invalidate();
            return true;
        }
        false
    }

    /// Does this shard serve `view` (home or replica)?
    pub fn is_resident(&self, view: usize) -> bool {
        self.home.get(view) || self.replicas.get(view)
    }

    /// Is this shard still inside its post-join warm-up at `batch`?
    pub fn is_warming(&self, batch: usize) -> bool {
        batch < self.warmup_until
    }

    /// Solve and execute one batch window over the routed inbox.
    /// Mirrors the serial loop exactly: empty inboxes keep the current
    /// configuration, the stateful boost comes from the mirror, and the
    /// executor stalls for the whole (shard-local) solve. `slot` is the
    /// shard's position in the live roster this batch (span labelling
    /// only); `tel` is the pure-observer telemetry handle, safe to
    /// share across worker threads.
    pub fn step(
        &mut self,
        ctx: &SolveContext<'_>,
        policy: &dyn Policy,
        index: usize,
        window_end: f64,
        slot: usize,
        tel: &Telemetry,
    ) -> ShardBatchOutcome {
        let queries = std::mem::take(&mut self.inbox);
        let n_queries = queries.len();
        let drain_secs = std::mem::take(&mut self.last_drain_secs);
        let t0 = Instant::now();
        let solved = ctx.solve_accounted_warm(
            &self.mirror,
            &queries,
            policy,
            &mut self.rng,
            self.warm.as_mut(),
        );
        let solve_secs = t0.elapsed().as_secs_f64();
        let mut config = solved.config;
        // Elastic budget shrink: a *kept* configuration (empty inbox
        // re-emits the mirror) can exceed a budget that was just
        // re-split smaller by a shard add. Policies always solve within
        // the current budget, so this trim only fires on the keep path;
        // evict largest views first (deterministic) until feasible.
        // Static runs never shrink budgets, so this is inert there.
        // Each tier plane is trimmed against its own budget (the SSD
        // plane can carry demotion fill from the mirror re-sync).
        let size_of = |v: usize| ctx.universe.views.get(ViewId(v)).cached_bytes;
        let trim = |plane: &mut ConfigMask, budget: u64| {
            let mut bytes: u64 = plane.ones().map(size_of).sum();
            if bytes > budget {
                let mut views: Vec<usize> = plane.ones().collect();
                views.sort_by_key(|&v| (std::cmp::Reverse(size_of(v)), v));
                for v in views {
                    if bytes <= budget {
                        break;
                    }
                    plane.set(v, false);
                    bytes -= size_of(v);
                }
            }
        };
        trim(&mut config.ram, ctx.budget);
        let ssd_budget = ctx.tier.map_or(0, |t| t.ssd_budget as u64);
        trim(&mut config.ssd, ssd_budget);
        self.budgets.push(ctx.budget);
        // Reclaim the routed batch's buffer: the cleared Vec (capacity
        // intact) becomes next batch's inbox, so a steady-state shard
        // allocates nothing per batch.
        self.inbox = self.executor.execute_reclaim(
            PlannedBatch {
                index,
                window_end,
                queries,
                config,
                solve_secs,
                drain_secs,
                boost_secs: solved.boost_secs,
                alloc_secs: solved.alloc_secs,
                sample_secs: solved.sample_secs,
                solve_kind: solved.kind,
            },
            0,
            solve_secs,
        );
        // Re-sync the mirror from the live cache: same thread, exact —
        // this picks up the SSD demotion fill chosen by the transition
        // (single-tier: identical to the emitted configuration).
        self.mirror = TierAssignment {
            ram: self.executor.cache().cached().clone(),
            ssd: self.executor.cache().ssd_contents().clone(),
        };
        let (transition_secs, execute_secs) = self.executor.last_phase_secs();
        tel.span(&SpanRecord {
            t: window_end,
            batch: index,
            shard: self.id as i64,
            slot: slot as i64,
            n_queries,
            drain_ms: drain_secs * 1e3,
            boost_ms: solved.boost_secs * 1e3,
            solve_ms: solved.alloc_secs * 1e3,
            sample_ms: solved.sample_secs * 1e3,
            transition_ms: transition_secs * 1e3,
            execute_ms: execute_secs * 1e3,
            solve_kind: solved.kind,
        });
        ShardBatchOutcome {
            utilities: solved.utilities,
            u_star: solved.u_star,
        }
    }
}
