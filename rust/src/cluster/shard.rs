//! One cache shard of the federation: a `BatchExecutor` (the PR-2
//! execute half — incremental cache transition + simulated execution on
//! the shard's own cluster slice), a planner-style cache-contents
//! mirror, a policy RNG stream, and the home/replica routing masks.
//!
//! Each shard is deliberately the *same* machinery as a single-node
//! coordinator: `SolveContext::solve_accounted` for steps 1–2 and
//! `BatchExecutor::execute` for steps 3–5. The federation adds routing
//! and the global fairness accountant around it, nothing inside it —
//! which is what makes the `--shards 1` run bit-identical to
//! `Coordinator::run`.

use std::time::Instant;

use crate::alloc::{ConfigMask, Policy};
use crate::coordinator::loop_::{BatchExecutor, Coordinator, PlannedBatch, SolveContext};
use crate::domain::query::Query;
use crate::util::rng::Pcg64;

/// Per-batch, per-shard accounting handed back to the federation's
/// global fairness accountant.
pub(crate) struct ShardBatchOutcome {
    /// Raw per-tenant utility attained on this shard.
    pub utilities: Vec<f64>,
    /// Per-tenant solo optimum U* of this shard's batch problem.
    pub u_star: Vec<f64>,
}

/// The mutable state of one shard across the run. All fields are
/// shard-local, so per-batch shard steps run on independent threads
/// with no shared mutability.
pub(crate) struct Shard<'a> {
    pub id: usize,
    /// Steps 3–5 (cache transition + simulated execution), reused
    /// verbatim from the coordinator loop.
    pub executor: BatchExecutor<'a>,
    /// Policy randomization stream. Shard 0 uses the exact planner
    /// stream of the serial coordinator, so a 1-shard federation samples
    /// identical configurations.
    pub rng: Pcg64,
    /// Planner-side mirror of this shard's cache contents (the stateful
    /// boost source — never reads the live cache mid-pipeline).
    pub mirror: ConfigMask,
    /// Views homed on this shard by the current placement — the
    /// federation router's map, not a constraint on the cache.
    pub home: ConfigMask,
    /// Hot-view replicas this shard additionally serves. Kept separate
    /// from `home` so a rebalance (which rewrites `home`) never wipes
    /// replicas — replication stays one-way until an explicit decay.
    pub replicas: ConfigMask,
    /// Queries routed to this shard for the current batch window.
    pub inbox: Vec<Query>,
}

/// The serial coordinator planner's RNG stream selector (see
/// `Coordinator::planner`); shard `s` uses `stream + s`.
const PLANNER_STREAM: u64 = 0x0b5;

impl<'a> Shard<'a> {
    pub fn new(
        id: usize,
        coordinator: &'a Coordinator<'a>,
        home: ConfigMask,
        n_views: usize,
        seed: u64,
    ) -> Self {
        Self {
            id,
            executor: coordinator.executor(),
            rng: Pcg64::with_stream(seed, PLANNER_STREAM + id as u64),
            mirror: ConfigMask::empty(n_views),
            home,
            replicas: ConfigMask::empty(n_views),
            inbox: Vec::new(),
        }
    }

    /// Does this shard serve `view` (home or replica)?
    pub fn is_resident(&self, view: usize) -> bool {
        self.home.get(view) || self.replicas.get(view)
    }

    /// Solve and execute one batch window over the routed inbox.
    /// Mirrors the serial loop exactly: empty inboxes keep the current
    /// configuration, the stateful boost comes from the mirror, and the
    /// executor stalls for the whole (shard-local) solve.
    pub fn step(
        &mut self,
        ctx: &SolveContext<'_>,
        policy: &dyn Policy,
        index: usize,
        window_end: f64,
    ) -> ShardBatchOutcome {
        let queries = std::mem::take(&mut self.inbox);
        let t0 = Instant::now();
        let solved = ctx.solve_accounted(&self.mirror, &queries, policy, &mut self.rng);
        let solve_secs = t0.elapsed().as_secs_f64();
        self.mirror = solved.config.clone();
        self.executor.execute(
            PlannedBatch {
                index,
                window_end,
                queries,
                config: solved.config,
                solve_secs,
            },
            0,
            solve_secs,
        );
        ShardBatchOutcome {
            utilities: solved.utilities,
            u_star: solved.u_star,
        }
    }
}
