//! The persistent shard runtime: a fixed worker pool created once per
//! federation run, over which every live shard's per-batch `step()`
//! multiplexes as a message — 64+ shards ride on ~`num_cpus` workers
//! with **no thread creation in steady state**. This replaces the
//! spawn-per-batch scoped-thread executor that made per-batch cost grow
//! with shard count (one OS thread spawn + join per shard per batch).
//!
//! Protocol: fan-out sends one [`StepJob`] per shard down a shared MPSC
//! channel (workers race to pull; whichever is free picks the next
//! shard up), fan-in collects one [`StepReply`] per job and restores
//! shards **in slot order**, so the coordinator observes exactly the
//! same shard ordering as the legacy `thread::scope` loop. Jobs own
//! their shard for the duration of the step (ownership transfer, not
//! `&mut` smuggling), which is also what keeps warm-start state
//! (`alloc::warm`) strictly shard-local: it travels with the shard into
//! whichever worker runs it.
//!
//! Determinism: `Shard::step` touches only shard-local state (its own
//! RNG stream, mirror, executor, warm state), so the simulated
//! quantities are independent of which worker runs the step or in what
//! real-time order steps complete. `workers = Some(0)` degenerates to
//! an inline sequential loop (no threads at all) and is pinned
//! bit-identical to the pooled path by the tests below and by
//! `rust/tests/scale_runtime.rs` at 64 shards.

use std::any::Any;
use std::sync::Arc;

use crate::util::sync::{mpsc, Mutex};

use crate::alloc::Policy;
use crate::cluster::shard::{Shard, ShardBatchOutcome};
use crate::coordinator::loop_::SolveContext;
use crate::domain::tenant::TenantSet;
use crate::domain::utility::TierPlan;
use crate::telemetry::Telemetry;
use crate::workload::universe::Universe;

/// The per-run solve inputs every worker shares. Everything a
/// [`SolveContext`] needs except the per-batch budget and multipliers,
/// which travel inside each [`StepJob`]. The telemetry handle rides
/// here (not per-job) because it is a pure observer: workers record
/// into lock-free registers and emit spans over a channel, never
/// touching control flow.
#[derive(Clone, Copy)]
pub(crate) struct StepCtx<'a> {
    pub tenants: &'a TenantSet,
    pub universe: &'a Universe,
    pub policy: &'a dyn Policy,
    pub stateful_gamma: Option<f64>,
    pub tel: &'a Telemetry,
}

/// Anything the pool can step: the replay federation steps [`Shard`]s
/// directly, the serving loop steps `LiveShard`s (a shard plus its
/// admission queue handle, which rides along untouched).
pub(crate) trait PoolItem<'e>: Send {
    fn shard_mut(&mut self) -> &mut Shard<'e>;
}

impl<'e> PoolItem<'e> for Shard<'e> {
    fn shard_mut(&mut self) -> &mut Shard<'e> {
        self
    }
}

/// One shard-step message. `slot` is the shard's index in the batch's
/// live vector; fan-in restores by slot so shard order is preserved.
struct StepJob<S> {
    slot: usize,
    item: S,
    batch: usize,
    window_end: f64,
    budget: u64,
    /// SSD-tier budget and discount for this batch (`None` single-tier).
    tier: Option<TierPlan>,
    /// Per-tenant weight multipliers for this batch, shared across the
    /// fan-out by refcount. Workers drop their clone *before* replying,
    /// so after fan-in the coordinator's handle is unique again and the
    /// next batch's `Arc::make_mut` reuses the buffer without cloning.
    mults: Option<Arc<Vec<f64>>>,
}

/// A finished (or died-trying) shard step.
enum StepReply<S> {
    Done {
        slot: usize,
        item: S,
        outcome: ShardBatchOutcome,
    },
    /// The step panicked; the payload is re-thrown on the coordinator
    /// thread (same observable behavior as the legacy `join().expect`).
    Panicked(Box<dyn Any + Send>),
}

enum PoolInner<S> {
    /// `--workers 0`: step shards inline on the calling thread.
    Inline,
    Threads {
        job_tx: mpsc::Sender<StepJob<S>>,
        done_rx: mpsc::Receiver<StepReply<S>>,
    },
}

/// Handle to the per-run worker pool. Created by [`with_shard_pool`];
/// dropping it closes the job channel, which is what terminates the
/// workers before the owning scope joins them.
pub(crate) struct ShardPool<'a, S> {
    inner: PoolInner<S>,
    ctx: StepCtx<'a>,
    /// Fan-in scratch, reused every batch (zero-alloc steady state).
    slots: Vec<Option<(S, ShardBatchOutcome)>>,
}

impl<'a, S> ShardPool<'a, S> {
    /// Step every item of `items` for one batch window and collect the
    /// outcomes **in item order** into `outcomes` (cleared first).
    /// Items are moved out for the duration of the step and restored in
    /// their original slots; `outcomes[i]` belongs to `items[i]`.
    pub fn step_batch<'e>(
        &mut self,
        items: &mut Vec<S>,
        batch: usize,
        window_end: f64,
        budget: u64,
        tier: Option<TierPlan>,
        mults: Option<&Arc<Vec<f64>>>,
        outcomes: &mut Vec<ShardBatchOutcome>,
    ) where
        S: PoolItem<'e>,
    {
        outcomes.clear();
        match &self.inner {
            PoolInner::Inline => {
                let solve_ctx = SolveContext {
                    tenants: self.ctx.tenants,
                    universe: self.ctx.universe,
                    budget,
                    tier,
                    stateful_gamma: self.ctx.stateful_gamma,
                    weight_mult: mults.map(|m| m.as_slice()),
                };
                for (slot, it) in items.iter_mut().enumerate() {
                    outcomes.push(it.shard_mut().step(
                        &solve_ctx,
                        self.ctx.policy,
                        batch,
                        window_end,
                        slot,
                        self.ctx.tel,
                    ));
                }
            }
            PoolInner::Threads { job_tx, done_rx } => {
                let n = items.len();
                self.slots.clear();
                self.slots.resize_with(n, || None);
                for (slot, item) in items.drain(..).enumerate() {
                    job_tx
                        .send(StepJob {
                            slot,
                            item,
                            batch,
                            window_end,
                            budget,
                            tier,
                            mults: mults.cloned(),
                        })
                        .expect("worker pool hung up mid-run");
                }
                for _ in 0..n {
                    match done_rx.recv().expect("worker pool hung up mid-run") {
                        StepReply::Done {
                            slot,
                            item,
                            outcome,
                        } => self.slots[slot] = Some((item, outcome)),
                        StepReply::Panicked(p) => std::panic::resume_unwind(p),
                    }
                }
                for s in self.slots.drain(..) {
                    let (item, outcome) = s.expect("every slot replied exactly once");
                    items.push(item);
                    outcomes.push(outcome);
                }
            }
        }
    }
}

/// Default pool width: one worker per available core (the `num_cpus`
/// the CLI's `--workers` help refers to).
pub(crate) fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Resolve a config's `workers: Option<usize>`: `None` = auto-size to
/// the host, `Some(0)` = inline sequential, `Some(n)` = n workers.
pub(crate) fn resolve_workers(workers: Option<usize>) -> usize {
    workers.unwrap_or_else(default_workers)
}

/// Run `f` with a live [`ShardPool`]: spawns `workers` pool threads
/// (once — this is the only thread creation of the whole run), hands
/// `f` the pool handle, then closes the job channel and joins the
/// workers. `workers == 0` skips thread creation entirely and steps
/// inline.
pub(crate) fn with_shard_pool<'a, 'e, S, R>(
    workers: usize,
    ctx: StepCtx<'a>,
    f: impl FnOnce(&mut ShardPool<'a, S>) -> R,
) -> R
where
    S: PoolItem<'e>,
{
    if workers == 0 {
        let mut pool = ShardPool {
            inner: PoolInner::Inline,
            ctx,
            slots: Vec::new(),
        };
        return f(&mut pool);
    }
    let (job_tx, job_rx) = mpsc::channel::<StepJob<S>>();
    let (done_tx, done_rx) = mpsc::channel::<StepReply<S>>();
    let job_rx = Arc::new(Mutex::new(job_rx));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let job_rx = Arc::clone(&job_rx);
            let done_tx = done_tx.clone();
            scope.spawn(move || worker_loop(ctx, job_rx, done_tx));
        }
        // The pool keeps the only non-worker `done_tx` alive through
        // `done_rx`'s pairing; drop ours so a dead pool is observable.
        drop(done_tx);
        let mut pool = ShardPool {
            inner: PoolInner::Threads { job_tx, done_rx },
            ctx,
            slots: Vec::new(),
        };
        let out = f(&mut pool);
        // Dropping the pool drops `job_tx`; every worker's next recv
        // errors and it exits, letting the scope join cleanly.
        drop(pool);
        out
    })
}

fn worker_loop<'a, 'e, S: PoolItem<'e>>(
    ctx: StepCtx<'a>,
    jobs: Arc<Mutex<mpsc::Receiver<StepJob<S>>>>,
    done: mpsc::Sender<StepReply<S>>,
) {
    loop {
        // Hold the shared-receiver lock only for the dequeue itself.
        let job = { jobs.lock().expect("job queue poisoned").recv() };
        let Ok(StepJob {
            slot,
            mut item,
            batch,
            window_end,
            budget,
            tier,
            mults,
        }) = job
        else {
            break; // channel closed: the run is over
        };
        // A panicking step must not strand the coordinator's fan-in
        // recv loop — catch it and re-throw on the coordinator thread.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let solve_ctx = SolveContext {
                tenants: ctx.tenants,
                universe: ctx.universe,
                budget,
                tier,
                stateful_gamma: ctx.stateful_gamma,
                weight_mult: mults.as_ref().map(|m| m.as_slice()),
            };
            item.shard_mut()
                .step(&solve_ctx, ctx.policy, batch, window_end, slot, ctx.tel)
        }));
        // Release our multiplier refcount before replying so the
        // coordinator's handle is unique by the time fan-in completes.
        drop(mults);
        let reply = match result {
            Ok(outcome) => StepReply::Done {
                slot,
                item,
                outcome,
            },
            Err(p) => StepReply::Panicked(p),
        };
        if done.send(reply).is_err() {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::PolicyKind;
    use crate::cluster::placement::Placement;
    use crate::sim::cluster::ClusterConfig;
    use crate::sim::engine::SimEngine;
    use crate::workload::generator::WorkloadGenerator;
    use crate::workload::spec::{AccessSpec, TenantSpec};

    /// The pre-refactor executor shape, kept verbatim as the
    /// equivalence reference: one scoped OS thread per shard per batch.
    fn step_batch_spawn<'e>(
        shards: &mut [Shard<'e>],
        ctx: StepCtx<'_>,
        batch: usize,
        window_end: f64,
        budget: u64,
        mults: Option<&[f64]>,
    ) -> Vec<ShardBatchOutcome> {
        std::thread::scope(|scope| {
            let handles: Vec<_> = shards
                .iter_mut()
                .enumerate()
                .map(|(slot, sh)| {
                    let solve_ctx = SolveContext {
                        tenants: ctx.tenants,
                        universe: ctx.universe,
                        budget,
                        tier: None,
                        stateful_gamma: ctx.stateful_gamma,
                        weight_mult: mults,
                    };
                    scope.spawn(move || {
                        sh.step(&solve_ctx, ctx.policy, batch, window_end, slot, ctx.tel)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard thread panicked"))
                .collect()
        })
    }

    fn build_shards<'e>(
        engine: &'e SimEngine,
        universe: &Universe,
        tenants: &TenantSet,
        n_shards: usize,
        budget: u64,
    ) -> Vec<Shard<'e>> {
        let cached_sizes: Vec<u64> =
            universe.views.iter().map(|v| v.cached_bytes).collect();
        let placement = Placement::hash(n_shards, cached_sizes.len());
        (0..n_shards)
            .map(|s| {
                Shard::new(
                    s,
                    engine,
                    universe,
                    tenants,
                    placement.shard_mask(s),
                    42,
                    crate::cache::tier::TierSpec::single(budget),
                    0,
                    false,
                )
            })
            .collect()
    }

    /// Route a batch of queries round-robin into shard inboxes (the
    /// routing policy is irrelevant here — both executors must agree on
    /// *whatever* inboxes they are handed).
    fn fill_inboxes(shards: &mut [Shard<'_>], batch_end: f64, gen: &mut WorkloadGenerator, universe: &Universe) {
        let n = shards.len();
        for (i, q) in gen.generate_until(batch_end, universe).into_iter().enumerate() {
            shards[i % n].inbox.push(q);
        }
    }

    /// Tentpole pin: the pooled executor is bit-identical to the legacy
    /// spawn-per-batch executor on every simulated quantity, across
    /// multiple batches and with more shards than workers.
    /// (Full multi-batch solves — outside the Miri subset for time; the
    /// pool's message protocol is Miri-covered by `worker_panic_propagates`
    /// and model-checked by `rust/tests/model_concurrency.rs`.)
    #[test]
    #[cfg_attr(miri, ignore)]
    fn pool_matches_spawn_per_batch_executor() {
        let universe = Universe::sales_only();
        let tenants = TenantSet::equal(3);
        let engine = SimEngine::new(ClusterConfig::default());
        let policy = PolicyKind::FastPf.build();
        let specs: Vec<TenantSpec> =
            (0..3).map(|i| TenantSpec::new(AccessSpec::g(1 + i % 4), 30.0)).collect();
        let budget = engine.config.cache_budget / 2;
        let n_shards = 6; // more shards than workers: real multiplexing
        let tel = Telemetry::off();
        let ctx = StepCtx {
            tenants: &tenants,
            universe: &universe,
            policy: policy.as_ref(),
            stateful_gamma: Some(2.0),
            tel: &tel,
        };

        let mut a = build_shards(&engine, &universe, &tenants, n_shards, budget);
        let mut b = build_shards(&engine, &universe, &tenants, n_shards, budget);
        let mut gen_a = WorkloadGenerator::new(specs.clone(), &universe, 42);
        let mut gen_b = WorkloadGenerator::new(specs, &universe, 42);

        let mults: Arc<Vec<f64>> = Arc::new(vec![1.3, 0.8, 1.0]);
        let mut pooled = Vec::new();
        with_shard_pool::<Shard<'_>, _>(2, ctx, |pool| {
            for batch in 0..3 {
                let end = (batch + 1) as f64 * 40.0;
                fill_inboxes(&mut a, end, &mut gen_a, &universe);
                let m = (batch > 0).then_some(&mults);
                let mut out = Vec::new();
                pool.step_batch(&mut a, batch, end, budget, None, m, &mut out);
                pooled.push(out);
            }
        });
        let mut spawned = Vec::new();
        for batch in 0..3 {
            let end = (batch + 1) as f64 * 40.0;
            fill_inboxes(&mut b, end, &mut gen_b, &universe);
            let m = (batch > 0).then(|| mults.as_slice());
            spawned.push(step_batch_spawn(&mut b, ctx, batch, end, budget, m));
        }

        for (pb, sb) in pooled.iter().zip(&spawned) {
            assert_eq!(pb.len(), sb.len());
            for (p, s) in pb.iter().zip(sb) {
                assert_eq!(p.utilities, s.utilities, "attained utilities diverged");
                assert_eq!(p.u_star, s.u_star, "solo optima diverged");
            }
        }
        for (sa, sb) in a.iter().zip(&b) {
            assert_eq!(sa.id, sb.id, "pool must restore shard order");
            assert_eq!(sa.mirror, sb.mirror, "cache mirrors diverged");
            assert_eq!(sa.budgets, sb.budgets);
            assert_eq!(
                sa.executor.cache().used_bytes(),
                sb.executor.cache().used_bytes(),
                "cache contents diverged"
            );
        }
    }

    /// `workers = 0` (inline) and a threaded pool agree — the CLI's
    /// escape hatch is not a second semantics.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn inline_pool_matches_threaded_pool() {
        let universe = Universe::sales_only();
        let tenants = TenantSet::equal(2);
        let engine = SimEngine::new(ClusterConfig::default());
        let policy = PolicyKind::Mmf.build();
        let specs: Vec<TenantSpec> =
            (0..2).map(|_| TenantSpec::new(AccessSpec::g(2), 25.0)).collect();
        let budget = engine.config.cache_budget / 3;
        let tel = Telemetry::off();
        let ctx = StepCtx {
            tenants: &tenants,
            universe: &universe,
            policy: policy.as_ref(),
            stateful_gamma: None,
            tel: &tel,
        };
        let run = |workers: usize| {
            let mut shards = build_shards(&engine, &universe, &tenants, 3, budget);
            let mut gen = WorkloadGenerator::new(specs.clone(), &universe, 7);
            let mut all = Vec::new();
            with_shard_pool::<Shard<'_>, _>(workers, ctx, |pool| {
                for batch in 0..2 {
                    let end = (batch + 1) as f64 * 40.0;
                    fill_inboxes(&mut shards, end, &mut gen, &universe);
                    let mut out = Vec::new();
                    pool.step_batch(&mut shards, batch, end, budget, None, None, &mut out);
                    all.push(out);
                }
            });
            (all, shards.iter().map(|s| s.mirror.clone()).collect::<Vec<_>>())
        };
        let (out0, mirrors0) = run(0);
        let (out4, mirrors4) = run(4);
        assert_eq!(mirrors0, mirrors4);
        for (a, b) in out0.iter().zip(&out4) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.utilities, y.utilities);
                assert_eq!(x.u_star, y.u_star);
            }
        }
    }

    /// A panicking shard step propagates to the coordinator thread
    /// instead of deadlocking the fan-in loop.
    #[test]
    fn worker_panic_propagates() {
        struct Bomb;
        impl<'e> PoolItem<'e> for Bomb {
            fn shard_mut(&mut self) -> &mut Shard<'e> {
                panic!("boom");
            }
        }
        let universe = Universe::sales_only();
        let tenants = TenantSet::equal(1);
        let policy = PolicyKind::Static.build();
        let tel = Telemetry::off();
        let ctx = StepCtx {
            tenants: &tenants,
            universe: &universe,
            policy: policy.as_ref(),
            stateful_gamma: None,
            tel: &tel,
        };
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            with_shard_pool::<Bomb, _>(2, ctx, |pool| {
                let mut items = vec![Bomb, Bomb];
                let mut out = Vec::new();
                pool.step_batch(&mut items, 0, 40.0, 1000, None, None, &mut out);
            })
        }));
        assert!(caught.is_err(), "panic must propagate out of the pool");
    }

    #[test]
    fn resolve_workers_semantics() {
        assert_eq!(resolve_workers(Some(0)), 0);
        assert_eq!(resolve_workers(Some(3)), 3);
        assert!(resolve_workers(None) >= 1);
    }
}
