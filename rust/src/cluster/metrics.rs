//! Federation-level metrics: per-batch [`ClusterRecord`]s (shard loads,
//! fairness multipliers, replication/rebalance events), per-shard
//! summaries, and the merged [`ClusterResult`] whose `run` field is a
//! plain [`RunResult`] — so every single-node metric (throughput,
//! fairness index, speedups, hit ratio) applies to the federation
//! unchanged, and the `--shards 1` equivalence check is a direct
//! `RunResult` comparison.

use crate::cache::CacheDelta;
use crate::coordinator::loop_::{BatchRecord, RunResult};
use crate::coordinator::metrics::per_tenant_speedups;
use crate::util::json::Json;

/// One batch of the federation: the global accountant's feedback plus
/// the replication/rebalance events that fired before it.
#[derive(Debug, Clone)]
pub struct ClusterRecord {
    pub index: usize,
    /// Per-tenant weight multipliers applied to every shard's solve this
    /// batch (all 1.0 for batch 0, single-shard runs, and perfectly even
    /// attainment).
    pub multipliers: Vec<f64>,
    /// Views replicated to additional shards before this batch.
    pub replicated_views: Vec<usize>,
    /// Whether a demand-driven rebalance re-homed views before this batch.
    pub rebalanced: bool,
}

/// Per-shard roll-up of a whole run.
#[derive(Debug, Clone)]
pub struct ShardSummary {
    pub shard: usize,
    pub queries: usize,
    /// Simulated queries per minute served by this shard (Eq. 4 scope:
    /// the shard's own timeline).
    pub throughput_per_min: f64,
    /// Host-side solve latency percentiles for this shard's solves.
    pub solve_ms_p50: f64,
    pub solve_ms_p99: f64,
    pub avg_cache_utilization: f64,
    pub bytes_loaded: u64,
    pub bytes_evicted: u64,
}

/// Result of a [`crate::cluster::ShardedCoordinator`] run.
#[derive(Debug, Clone)]
pub struct ClusterResult {
    /// The merged federation-level view: outcomes of every shard, one
    /// `BatchRecord` per batch (configs unioned, byte movement summed).
    /// For a 1-shard run this IS the shard's `RunResult`, bit-identical
    /// to the serial coordinator's.
    pub run: RunResult,
    /// Each shard's own run (its timeline, batches, outcomes).
    pub per_shard: Vec<RunResult>,
    pub records: Vec<ClusterRecord>,
    /// Bytes of hot-view replicas added across the run (each replica
    /// charged at the view's cached size per holding shard).
    pub replication_bytes: u64,
    /// Projected eviction churn of rebalance operations (from
    /// `CacheManager::delta_to` previews at re-home time).
    pub rebalance_churn: u64,
}

impl ClusterResult {
    pub(crate) fn assemble(
        per_shard: Vec<RunResult>,
        records: Vec<ClusterRecord>,
        replication_bytes: u64,
        rebalance_churn: u64,
        host_wall_secs: f64,
    ) -> Self {
        assert!(!per_shard.is_empty());
        let run = if per_shard.len() == 1 {
            per_shard[0].clone()
        } else {
            merge_runs(&per_shard, host_wall_secs)
        };
        Self {
            run,
            per_shard,
            records,
            replication_bytes,
            rebalance_churn,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.per_shard.len()
    }

    /// Federation batches retired per host second (the scaling figure
    /// `cluster_bench` tracks: shard solves run in parallel, so this
    /// grows with the shard count until routing overhead dominates).
    pub fn batches_per_sec(&self) -> f64 {
        self.run.batches_per_sec()
    }

    /// Cross-shard fairness spread: max/min weight-normalized per-tenant
    /// speedup versus a baseline run over the same workload. 1.0 is a
    /// perfectly even federation; the global accountant exists to keep
    /// this close to the single-node value.
    pub fn fairness_spread(&self, baseline: &RunResult) -> f64 {
        speedup_spread(&self.run, baseline)
    }

    pub fn shard_summaries(&self) -> Vec<ShardSummary> {
        self.per_shard
            .iter()
            .enumerate()
            .map(|(s, r)| {
                let (bytes_loaded, bytes_evicted) = r.cache_bytes_moved();
                ShardSummary {
                    shard: s,
                    queries: r.outcomes.len(),
                    throughput_per_min: r.throughput_per_min(),
                    solve_ms_p50: r.solve_ms_percentile(50.0),
                    solve_ms_p99: r.solve_ms_percentile(99.0),
                    avg_cache_utilization: r.avg_cache_utilization(),
                    bytes_loaded,
                    bytes_evicted,
                }
            })
            .collect()
    }

    /// Human-readable federation report for the CLI.
    pub fn render(&self, baseline: Option<&RunResult>) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "federation: {} shards, {} batches, {} queries, {:.2} batches/s\n",
            self.n_shards(),
            self.run.batches.len(),
            self.run.outcomes.len(),
            self.batches_per_sec()
        ));
        out.push_str(&format!(
            "replication: {} B added; rebalance churn: {} B\n",
            self.replication_bytes, self.rebalance_churn
        ));
        if let Some(base) = baseline {
            out.push_str(&format!(
                "global fairness: index {:.3}, spread {:.3} (vs {})\n",
                crate::coordinator::metrics::fairness_index(&self.run, base),
                self.fairness_spread(base),
                base.policy
            ));
        }
        out.push_str(
            "shard     queries   q/min   solve p50   solve p99   util    loaded B    evicted B\n",
        );
        for s in self.shard_summaries() {
            out.push_str(&format!(
                "{:<9} {:>7} {:>7.1} {:>8.1}ms {:>8.1}ms {:>6.2} {:>11} {:>11}\n",
                s.shard,
                s.queries,
                s.throughput_per_min,
                s.solve_ms_p50,
                s.solve_ms_p99,
                s.avg_cache_utilization,
                s.bytes_loaded,
                s.bytes_evicted
            ));
        }
        out
    }

    /// Machine-readable report (the `BENCH_cluster.json` building block).
    pub fn to_json(&self, baseline: Option<&RunResult>) -> Json {
        let shards = Json::Array(
            self.shard_summaries()
                .iter()
                .map(|s| {
                    Json::from_pairs(vec![
                        ("shard", Json::Number(s.shard as f64)),
                        ("queries", Json::Number(s.queries as f64)),
                        ("throughput_per_min", Json::Number(s.throughput_per_min)),
                        ("solve_ms_p50", Json::Number(s.solve_ms_p50)),
                        ("solve_ms_p99", Json::Number(s.solve_ms_p99)),
                        (
                            "avg_cache_utilization",
                            Json::Number(s.avg_cache_utilization),
                        ),
                        ("bytes_loaded", Json::Number(s.bytes_loaded as f64)),
                        ("bytes_evicted", Json::Number(s.bytes_evicted as f64)),
                    ])
                })
                .collect(),
        );
        let mut obj = Json::from_pairs(vec![
            ("n_shards", Json::Number(self.n_shards() as f64)),
            ("batches", Json::Number(self.run.batches.len() as f64)),
            ("queries", Json::Number(self.run.outcomes.len() as f64)),
            ("batches_per_sec", Json::Number(self.batches_per_sec())),
            ("host_wall_secs", Json::Number(self.run.host_wall_secs)),
            ("hit_ratio", Json::Number(self.run.hit_ratio())),
            (
                "replication_bytes",
                Json::Number(self.replication_bytes as f64),
            ),
            ("rebalance_churn", Json::Number(self.rebalance_churn as f64)),
            ("shards", shards),
        ]);
        if let Some(base) = baseline {
            obj.set(
                "fairness_index",
                Json::Number(crate::coordinator::metrics::fairness_index(&self.run, base)),
            );
            obj.set(
                "fairness_spread",
                Json::Number(self.fairness_spread(base)),
            );
        }
        obj
    }
}

/// Max/min weight-normalized per-tenant speedup of `run` vs `baseline`
/// (tenants with no joined queries excluded; 1.0 when fewer than two
/// tenants qualify, infinity when a tenant's speedup is zero).
pub fn speedup_spread(run: &RunResult, baseline: &RunResult) -> f64 {
    let x = per_tenant_speedups(run, baseline);
    let norm: Vec<f64> = x
        .iter()
        .zip(&run.weights)
        .filter(|(xi, _)| **xi > 0.0)
        .map(|(xi, l)| xi / l)
        .collect();
    if norm.len() < 2 {
        return 1.0;
    }
    let max = norm.iter().cloned().fold(f64::MIN, f64::max);
    let min = norm.iter().cloned().fold(f64::MAX, f64::min);
    if min <= 0.0 {
        f64::INFINITY
    } else {
        max / min
    }
}

/// Merge per-shard runs into one federation-level `RunResult`: outcomes
/// of all shards (sorted by query id — ids are globally unique), and
/// per-batch records with configs unioned, query counts and byte
/// movement summed, utilization averaged (shard budgets are equal
/// slices), and the host-side solve/stall figures taken as the max
/// across shards (the shards solve concurrently, so the slowest shard
/// is the batch's critical path).
fn merge_runs(per_shard: &[RunResult], host_wall_secs: f64) -> RunResult {
    let n_batches = per_shard[0].batches.len();
    assert!(
        per_shard.iter().all(|r| r.batches.len() == n_batches),
        "shards must step every batch"
    );
    let mut outcomes: Vec<_> = per_shard
        .iter()
        .flat_map(|r| r.outcomes.iter().cloned())
        .collect();
    outcomes.sort_by_key(|o| o.id);

    let mut batches = Vec::with_capacity(n_batches);
    for b in 0..n_batches {
        let rows: Vec<&BatchRecord> = per_shard.iter().map(|r| &r.batches[b]).collect();
        let mut config = rows[0].config.clone();
        for row in rows.iter().skip(1) {
            config.union_with(&row.config);
        }
        let mut delta = CacheDelta::default();
        for row in &rows {
            delta.loaded.extend(row.delta.loaded.iter().copied());
            delta.evicted.extend(row.delta.evicted.iter().copied());
            delta.bytes_loaded += row.delta.bytes_loaded;
            delta.bytes_evicted += row.delta.bytes_evicted;
        }
        // Distinct ascending view ids; byte totals keep counting every
        // replica's movement.
        delta.loaded.sort_unstable();
        delta.loaded.dedup();
        delta.evicted.sort_unstable();
        delta.evicted.dedup();
        batches.push(BatchRecord {
            index: b,
            n_queries: rows.iter().map(|r| r.n_queries).sum(),
            config,
            cache_utilization: rows.iter().map(|r| r.cache_utilization).sum::<f64>()
                / rows.len() as f64,
            window_end: rows[0].window_end,
            exec_start: rows
                .iter()
                .map(|r| r.exec_start)
                .fold(f64::INFINITY, f64::min),
            exec_end: rows
                .iter()
                .map(|r| r.exec_end)
                .fold(f64::NEG_INFINITY, f64::max),
            solve_secs: rows
                .iter()
                .map(|r| r.solve_secs)
                .fold(0.0, f64::max),
            queue_depth: 0,
            stall_secs: rows
                .iter()
                .map(|r| r.stall_secs)
                .fold(0.0, f64::max),
            delta,
        });
    }

    RunResult {
        policy: per_shard[0].policy,
        outcomes,
        batches,
        end_time: per_shard
            .iter()
            .map(|r| r.end_time)
            .fold(0.0, f64::max),
        n_tenants: per_shard[0].n_tenants,
        weights: per_shard[0].weights.clone(),
        host_wall_secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::ConfigMask;
    use crate::domain::query::QueryId;
    use crate::sim::engine::QueryOutcome;

    fn outcome(id: u64, tenant: usize, exec: f64) -> QueryOutcome {
        QueryOutcome {
            id: QueryId(id),
            tenant,
            arrival: 0.0,
            start: 0.0,
            finish: exec,
            from_cache: false,
            bytes: 0,
        }
    }

    fn shard_run(outcomes: Vec<QueryOutcome>, config_bits: &[bool], util: f64) -> RunResult {
        RunResult {
            policy: "TEST",
            outcomes,
            batches: vec![BatchRecord {
                index: 0,
                n_queries: 1,
                config: ConfigMask::from_bools(config_bits),
                cache_utilization: util,
                window_end: 40.0,
                exec_start: 40.0,
                exec_end: 50.0,
                solve_secs: 0.01,
                queue_depth: 0,
                stall_secs: 0.01,
                delta: CacheDelta {
                    loaded: vec![0],
                    evicted: vec![],
                    bytes_loaded: 10,
                    bytes_evicted: 0,
                },
            }],
            end_time: 50.0,
            n_tenants: 2,
            weights: vec![1.0, 1.0],
            host_wall_secs: 0.02,
        }
    }

    #[test]
    fn merge_unions_configs_and_sorts_outcomes() {
        let a = shard_run(vec![outcome(3, 0, 5.0)], &[true, false], 0.5);
        let b = shard_run(vec![outcome(1, 1, 5.0)], &[false, true], 0.7);
        let merged = merge_runs(&[a, b], 0.05);
        assert_eq!(
            merged.outcomes.iter().map(|o| o.id.0).collect::<Vec<_>>(),
            vec![1, 3]
        );
        let batch = &merged.batches[0];
        assert_eq!(batch.n_queries, 2);
        assert!(batch.config.get(0) && batch.config.get(1));
        assert!((batch.cache_utilization - 0.6).abs() < 1e-12);
        // Same view scheduled on both shards: listed once, bytes doubled.
        assert_eq!(batch.delta.loaded, vec![0]);
        assert_eq!(batch.delta.bytes_loaded, 20);
        assert_eq!(merged.host_wall_secs, 0.05);
    }

    #[test]
    fn single_shard_assembles_verbatim() {
        let a = shard_run(vec![outcome(1, 0, 5.0)], &[true, false], 0.5);
        let result = ClusterResult::assemble(vec![a.clone()], vec![], 0, 0, 9.9);
        // The merged run is the shard's run, untouched (including its
        // own host wall — the equivalence guarantee's metric surface).
        assert_eq!(result.run.outcomes.len(), a.outcomes.len());
        assert_eq!(result.run.batches[0].config, a.batches[0].config);
        assert_eq!(result.run.host_wall_secs, a.host_wall_secs);
        assert_eq!(result.n_shards(), 1);
    }

    #[test]
    fn speedup_spread_bounds() {
        let base = shard_run(
            vec![outcome(1, 0, 10.0), outcome(2, 1, 10.0)],
            &[true, false],
            0.5,
        );
        let even = shard_run(
            vec![outcome(1, 0, 5.0), outcome(2, 1, 5.0)],
            &[true, false],
            0.5,
        );
        assert!((speedup_spread(&even, &base) - 1.0).abs() < 1e-9);
        let skewed = shard_run(
            vec![outcome(1, 0, 2.0), outcome(2, 1, 10.0)],
            &[true, false],
            0.5,
        );
        assert!((speedup_spread(&skewed, &base) - 5.0).abs() < 1e-9);
    }
}
