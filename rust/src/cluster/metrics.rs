//! Federation-level metrics: per-batch [`ClusterRecord`]s (fairness
//! multipliers, replication/decay/rebalance events, membership changes,
//! per-tenant attainment), per-shard summaries, and the merged
//! [`ClusterResult`] whose `run` field is a plain [`RunResult`] — so
//! every single-node metric (throughput, fairness index, speedups, hit
//! ratio) applies to the federation unchanged, and the `--shards 1`
//! equivalence check is a direct `RunResult` comparison.
//!
//! Elastic membership (PR 4) generalizes the merge: shards may be born
//! or retired mid-run (ragged per-shard batch lists keyed by the global
//! batch index) and per-batch cache utilization is weighted by each
//! shard's actual budget bytes at that batch rather than assuming equal
//! slices. The per-batch per-tenant attainment stored on every record
//! powers the membership *transient* figures: fairness spread and
//! throughput before/during/after each add/remove/kill.

use crate::cache::CacheDelta;
use crate::cluster::membership::MembershipAction;
use crate::coordinator::loop_::{BatchRecord, ExecSummary, RunResult};
use crate::coordinator::metrics::per_tenant_speedups;
use crate::util::json::Json;

/// One membership change applied before a batch's routing.
#[derive(Debug, Clone)]
pub struct MembershipChange {
    pub action: MembershipAction,
    /// The joining shard (Add) or the victim (Remove/Kill).
    pub shard: usize,
    /// Views whose home moved in the old→new placement diff.
    pub views_moved: usize,
    /// Drain preview (`CacheManager::drain_delta`) — bytes the leaving
    /// shard migrates out. Remove only; 0 otherwise.
    pub bytes_drained: u64,
    /// Cached bytes dropped on the floor (no drain). Kill only.
    pub bytes_lost: u64,
}

/// One batch of the federation: the global accountant's feedback plus
/// the replication/rebalance/membership events that fired before it and
/// the per-tenant attainment it produced.
#[derive(Debug, Clone)]
pub struct ClusterRecord {
    pub index: usize,
    /// Per-tenant weight multipliers applied to every shard's solve this
    /// batch (all 1.0 for batch 0, single-shard runs, and perfectly even
    /// attainment).
    pub multipliers: Vec<f64>,
    /// Views replicated to additional shards before this batch.
    pub replicated_views: Vec<usize>,
    /// Whether a demand-driven rebalance re-homed views before this batch.
    pub rebalanced: bool,
    /// Membership changes applied before this batch's routing.
    pub membership: Vec<MembershipChange>,
    /// Hot-view replicas evicted by decay before this batch.
    pub decayed_views: Vec<usize>,
    /// Live shard count while this batch ran.
    pub live_shards: usize,
    /// Per-shard cache budget while this batch ran (`total / live`).
    pub shard_budget: u64,
    /// Shards still inside their post-join warm-up (excluded from the
    /// global accountant this batch).
    pub warming_shards: Vec<usize>,
    /// Federation-wide per-tenant attained utility this batch (summed
    /// over all live shards, warming or not — the recorded reality; the
    /// accountant sees the warm subset).
    pub tenant_attained: Vec<f64>,
    /// Federation-wide per-tenant attainable (solo-optimum) utility.
    pub tenant_attainable: Vec<f64>,
}

/// Per-shard roll-up of a whole run.
#[derive(Debug, Clone)]
pub struct ShardSummary {
    pub shard: usize,
    pub queries: usize,
    /// Batches this shard was alive for (ragged under elastic
    /// membership).
    pub batches: usize,
    /// Simulated queries per minute served by this shard (Eq. 4 scope:
    /// the shard's own timeline).
    pub throughput_per_min: f64,
    /// Host-side solve latency percentiles for this shard's solves.
    pub solve_ms_p50: f64,
    pub solve_ms_p99: f64,
    pub avg_cache_utilization: f64,
    pub bytes_loaded: u64,
    pub bytes_evicted: u64,
}

/// Fairness-spread and throughput transient around one membership
/// event (windows of `window` batches before / starting at / after it).
#[derive(Debug, Clone)]
pub struct TransientReport {
    pub batch: usize,
    pub window: usize,
    pub pre_spread: f64,
    pub during_spread: f64,
    pub post_spread: f64,
    pub pre_queries_per_batch: f64,
    pub during_queries_per_batch: f64,
    pub post_queries_per_batch: f64,
    /// Batches after the event until a `window`-wide sliding attainment
    /// spread first returned to ≤ 1.5× the pre-event spread (`None` =
    /// never within the run).
    pub recovery_batches: Option<usize>,
}

/// Result of a [`crate::cluster::ShardedCoordinator`] run.
#[derive(Debug, Clone)]
pub struct ClusterResult {
    /// The merged federation-level view: outcomes of every shard, one
    /// `BatchRecord` per global batch (configs unioned, byte movement
    /// summed). For a 1-shard run this IS the shard's `RunResult`,
    /// bit-identical to the serial coordinator's.
    pub run: RunResult,
    /// Each shard's own run (its timeline, batches, outcomes), in shard
    /// id order — including shards retired mid-run.
    pub per_shard: Vec<RunResult>,
    /// `per_shard_budgets[i][j]` = cache budget of `per_shard[i]` at its
    /// j-th batch record (the merge's utilization weights).
    pub per_shard_budgets: Vec<Vec<u64>>,
    pub records: Vec<ClusterRecord>,
    /// Net bytes of replica copies created by hot-view replication:
    /// charged per holder at creation, credited back when a re-home
    /// promotes the replica to primary, when decay evicts it, or when
    /// its holder leaves the federation.
    pub replication_bytes: u64,
    /// Projected eviction/migration churn of rebalances, decommission
    /// drains, and replica decay (from `CacheManager::delta_to` /
    /// `drain_delta` previews).
    pub rebalance_churn_bytes: u64,
}

impl ClusterResult {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn assemble(
        per_shard: Vec<RunResult>,
        per_shard_budgets: Vec<Vec<u64>>,
        records: Vec<ClusterRecord>,
        replication_bytes: u64,
        rebalance_churn_bytes: u64,
        host_wall_secs: f64,
        n_batches: usize,
    ) -> Self {
        assert!(!per_shard.is_empty());
        assert_eq!(per_shard.len(), per_shard_budgets.len());
        let run = if per_shard.len() == 1 {
            // The single-shard degeneracy: the merged run is the shard's
            // run verbatim (bit-identical to `Coordinator::run`).
            per_shard[0].clone()
        } else {
            merge_runs(&per_shard, &per_shard_budgets, n_batches, host_wall_secs)
        };
        Self {
            run,
            per_shard,
            per_shard_budgets,
            records,
            replication_bytes,
            rebalance_churn_bytes,
        }
    }

    /// Distinct shards that ever lived during the run (dead + live —
    /// the length of `per_shard`). Under an elastic plan this exceeds
    /// the live count; see [`ClusterResult::live_shards_final`].
    pub fn n_shards(&self) -> usize {
        self.per_shard.len()
    }

    /// Shards live at the end of the run (equals `n_shards()` for
    /// static federations).
    pub fn live_shards_final(&self) -> usize {
        self.records
            .last()
            .map(|r| r.live_shards)
            .unwrap_or_else(|| self.per_shard.len())
    }

    /// Federation batches retired per host second (the scaling figure
    /// `cluster_bench` tracks: shard solves run in parallel, so this
    /// grows with the shard count until routing overhead dominates).
    pub fn batches_per_sec(&self) -> f64 {
        self.run.batches_per_sec()
    }

    /// Cross-shard fairness spread: max/min weight-normalized per-tenant
    /// speedup versus a baseline run over the same workload. 1.0 is a
    /// perfectly even federation; the global accountant exists to keep
    /// this close to the single-node value.
    pub fn fairness_spread(&self, baseline: &RunResult) -> f64 {
        speedup_spread(&self.run, baseline)
    }

    /// Weight-normalized attainment spread over batches `[from, to)`:
    /// per tenant, attained/attainable summed over the window, divided
    /// by the tenant weight; spread = max/min over tenants that had
    /// attainable demand in the window. A tenant that demanded but
    /// attained *nothing* is fully starved → `f64::INFINITY`. Fewer
    /// than two active tenants → 1.0. This is the baseline-free,
    /// per-window spread the membership transients are measured with.
    pub fn attainment_spread_window(&self, from: usize, to: usize) -> f64 {
        let n = self.run.weights.len();
        let mut attained = vec![0.0; n];
        let mut attainable = vec![0.0; n];
        for r in &self.records {
            if r.index >= from && r.index < to {
                for i in 0..n {
                    attained[i] += r.tenant_attained.get(i).copied().unwrap_or(0.0);
                    attainable[i] += r.tenant_attainable.get(i).copied().unwrap_or(0.0);
                }
            }
        }
        let mut norm: Vec<f64> = Vec::with_capacity(n);
        for i in 0..n {
            if attainable[i] <= 0.0 {
                continue;
            }
            if attained[i] <= 0.0 {
                return f64::INFINITY;
            }
            norm.push(attained[i] / attainable[i] / self.run.weights[i].max(1e-12));
        }
        if norm.len() < 2 {
            return 1.0;
        }
        let max = norm.iter().cloned().fold(f64::MIN, f64::max);
        let min = norm.iter().cloned().fold(f64::MAX, f64::min);
        max / min
    }

    /// Mean queries routed per batch over `[from, to)` (the throughput
    /// transient proxy on the batch axis).
    pub fn queries_per_batch_window(&self, from: usize, to: usize) -> f64 {
        let rows: Vec<usize> = self
            .run
            .batches
            .iter()
            .filter(|b| b.index >= from && b.index < to)
            .map(|b| b.n_queries)
            .collect();
        if rows.is_empty() {
            return 0.0;
        }
        rows.iter().sum::<usize>() as f64 / rows.len() as f64
    }

    /// Fairness-spread and throughput transient around the membership
    /// event at `batch`, with `window`-batch comparison windows.
    pub fn transient(&self, batch: usize, window: usize) -> TransientReport {
        let w = window.max(1);
        let n = self.records.len();
        let pre_spread = self.attainment_spread_window(batch.saturating_sub(w), batch);
        let during_spread = self.attainment_spread_window(batch, (batch + w).min(n));
        let post_spread =
            self.attainment_spread_window((batch + w).min(n), (batch + 2 * w).min(n));
        // An infinite pre spread (a tenant already starved before the
        // event) gives no meaningful re-convergence target: report
        // "never recovered" instead of trivially matching at lag 0.
        let mut recovery_batches = None;
        if pre_spread.is_finite() {
            let threshold = pre_spread * 1.5 + 1e-9;
            let mut t = batch;
            while t + w <= n {
                if self.attainment_spread_window(t, t + w) <= threshold {
                    recovery_batches = Some(t - batch);
                    break;
                }
                t += 1;
            }
        }
        TransientReport {
            batch,
            window: w,
            pre_spread,
            during_spread,
            post_spread,
            pre_queries_per_batch: self
                .queries_per_batch_window(batch.saturating_sub(w), batch),
            during_queries_per_batch: self.queries_per_batch_window(batch, (batch + w).min(n)),
            post_queries_per_batch: self
                .queries_per_batch_window((batch + w).min(n), (batch + 2 * w).min(n)),
            recovery_batches,
        }
    }

    /// All membership changes with their batch indices, in batch order.
    pub fn membership_events(&self) -> Vec<(usize, &MembershipChange)> {
        self.records
            .iter()
            .flat_map(|r| r.membership.iter().map(move |c| (r.index, c)))
            .collect()
    }

    pub fn shard_summaries(&self) -> Vec<ShardSummary> {
        self.per_shard
            .iter()
            .enumerate()
            .map(|(s, r)| {
                let (bytes_loaded, bytes_evicted) = r.cache_bytes_moved();
                // One sort (or one histogram walk) for both quantiles.
                let ps = r.solve_ms_percentiles(&[50.0, 99.0]);
                ShardSummary {
                    shard: s,
                    queries: r.completed() as usize,
                    batches: r.n_batches(),
                    throughput_per_min: r.throughput_per_min(),
                    solve_ms_p50: ps[0],
                    solve_ms_p99: ps[1],
                    avg_cache_utilization: r.avg_cache_utilization(),
                    bytes_loaded,
                    bytes_evicted,
                }
            })
            .collect()
    }

    /// Human-readable federation report for the CLI.
    pub fn render(&self, baseline: Option<&RunResult>) -> String {
        let mut out = String::new();
        let live = self.live_shards_final();
        out.push_str(&format!(
            "federation: {} shard histories ({live} live at end), {} batches, {} queries, {:.2} batches/s\n",
            self.n_shards(),
            self.run.n_batches(),
            self.run.completed(),
            self.batches_per_sec()
        ));
        out.push_str(&format!(
            "replication: {} B net replicas; rebalance/drain churn: {} B\n",
            self.replication_bytes, self.rebalance_churn_bytes
        ));
        for (b, c) in self.membership_events() {
            out.push_str(&format!(
                "membership: {} shard {} @ batch {b} (moved {} views, drained {} B, lost {} B)\n",
                c.action.name(),
                c.shard,
                c.views_moved,
                c.bytes_drained,
                c.bytes_lost
            ));
        }
        if let Some(base) = baseline {
            out.push_str(&format!(
                "global fairness: index {:.3}, spread {:.3} (vs {})\n",
                crate::coordinator::metrics::fairness_index(&self.run, base),
                self.fairness_spread(base),
                base.policy
            ));
        }
        out.push_str(
            "shard     queries batches   q/min   solve p50   solve p99   util    loaded B    evicted B\n",
        );
        for s in self.shard_summaries() {
            out.push_str(&format!(
                "{:<9} {:>7} {:>7} {:>7.1} {:>8.1}ms {:>8.1}ms {:>6.2} {:>11} {:>11}\n",
                s.shard,
                s.queries,
                s.batches,
                s.throughput_per_min,
                s.solve_ms_p50,
                s.solve_ms_p99,
                s.avg_cache_utilization,
                s.bytes_loaded,
                s.bytes_evicted
            ));
        }
        out
    }

    /// Machine-readable report (the `BENCH_cluster.json` building block).
    pub fn to_json(&self, baseline: Option<&RunResult>) -> Json {
        let shards = Json::Array(
            self.shard_summaries()
                .iter()
                .map(|s| {
                    Json::from_pairs(vec![
                        ("shard", Json::Number(s.shard as f64)),
                        ("queries", Json::Number(s.queries as f64)),
                        ("batches", Json::Number(s.batches as f64)),
                        ("throughput_per_min", Json::Number(s.throughput_per_min)),
                        ("solve_ms_p50", Json::Number(s.solve_ms_p50)),
                        ("solve_ms_p99", Json::Number(s.solve_ms_p99)),
                        (
                            "avg_cache_utilization",
                            Json::Number(s.avg_cache_utilization),
                        ),
                        ("bytes_loaded", Json::Number(s.bytes_loaded as f64)),
                        ("bytes_evicted", Json::Number(s.bytes_evicted as f64)),
                    ])
                })
                .collect(),
        );
        let events = Json::Array(
            self.membership_events()
                .iter()
                .map(|(b, c)| {
                    Json::from_pairs(vec![
                        ("batch", Json::Number(*b as f64)),
                        ("action", Json::String(c.action.name().to_string())),
                        ("shard", Json::Number(c.shard as f64)),
                        ("views_moved", Json::Number(c.views_moved as f64)),
                        ("bytes_drained", Json::Number(c.bytes_drained as f64)),
                        ("bytes_lost", Json::Number(c.bytes_lost as f64)),
                    ])
                })
                .collect(),
        );
        let mut obj = Json::from_pairs(vec![
            // Total shard histories (dead + live); the live count at the
            // end of the run sits alongside for elastic plans.
            ("n_shards", Json::Number(self.n_shards() as f64)),
            (
                "live_shards_final",
                Json::Number(self.live_shards_final() as f64),
            ),
            ("batches", Json::Number(self.run.n_batches() as f64)),
            ("queries", Json::Number(self.run.completed() as f64)),
            ("batches_per_sec", Json::Number(self.batches_per_sec())),
            ("host_wall_secs", Json::Number(self.run.host_wall_secs)),
            ("hit_ratio", Json::Number(self.run.hit_ratio())),
            (
                "replication_bytes",
                Json::Number(self.replication_bytes as f64),
            ),
            (
                "rebalance_churn_bytes",
                Json::Number(self.rebalance_churn_bytes as f64),
            ),
            ("membership_events", events),
            ("shards", shards),
        ]);
        if let Some(base) = baseline {
            obj.set(
                "fairness_index",
                Json::Number(crate::coordinator::metrics::fairness_index(&self.run, base)),
            );
            obj.set(
                "fairness_spread",
                Json::Number(self.fairness_spread(base)),
            );
        }
        obj
    }
}

/// Max/min weight-normalized per-tenant speedup of `run` vs `baseline`.
/// Tenants with no queries in the baseline (never demanded anything)
/// are excluded; a tenant that *was* active in the baseline but
/// attained zero speedup — no joined queries retired in `run` — is
/// fully starved and drives the spread to `f64::INFINITY` rather than
/// being silently dropped. 1.0 when fewer than two tenants qualify.
pub fn speedup_spread(run: &RunResult, baseline: &RunResult) -> f64 {
    let x = per_tenant_speedups(run, baseline);
    let mut active = vec![false; x.len()];
    for o in &baseline.outcomes {
        if o.tenant < active.len() {
            active[o.tenant] = true;
        }
    }
    let mut norm: Vec<f64> = Vec::with_capacity(x.len());
    for (i, &xi) in x.iter().enumerate() {
        if !active[i] {
            continue;
        }
        if xi <= 0.0 {
            // Active in the baseline, zero attained speedup: starved.
            return f64::INFINITY;
        }
        norm.push(xi / run.weights[i]);
    }
    if norm.len() < 2 {
        return 1.0;
    }
    let max = norm.iter().cloned().fold(f64::MIN, f64::max);
    let min = norm.iter().cloned().fold(f64::MAX, f64::min);
    max / min
}

/// Merge per-shard runs into one federation-level `RunResult`: outcomes
/// of all shards (sorted by query id — ids are globally unique), and
/// per-global-batch records with configs unioned, query counts and byte
/// movement summed, utilization weighted by each shard's budget bytes
/// at that batch, and the host-side solve/stall figures taken as the
/// max across shards (the shards solve concurrently, so the slowest
/// shard is the batch's critical path). Shards born or retired mid-run
/// contribute only to the batches they were alive for.
///
/// Streaming shard runs (the real-clock federated service retains no
/// raw records — memory stays flat over an open-ended run) merge by
/// absorbing their [`ExecSummary`] aggregates instead; the merged run
/// then answers every report accessor from its own summary. The
/// absorbed summary rides along in the raw case too, with `batches`
/// pinned to the *global* batch count (per-shard counts overlap).
fn merge_runs(
    per_shard: &[RunResult],
    budgets: &[Vec<u64>],
    n_batches: usize,
    host_wall_secs: f64,
) -> RunResult {
    let mut summary = ExecSummary::default();
    for r in per_shard {
        summary.absorb(&r.summary);
    }
    summary.batches = n_batches as u64;

    let mut outcomes: Vec<_> = per_shard
        .iter()
        .flat_map(|r| r.outcomes.iter().cloned())
        .collect();
    outcomes.sort_by_key(|o| o.id);
    let streaming = outcomes.is_empty() && per_shard.iter().all(|r| r.batches.is_empty());
    let merge_batches = if streaming { 0 } else { n_batches };

    let mut batches = Vec::with_capacity(merge_batches);
    for b in 0..merge_batches {
        // Rows from the shards alive at batch b: each shard's records
        // are a contiguous index range starting at its birth batch.
        let mut rows: Vec<(&BatchRecord, u64)> = Vec::with_capacity(per_shard.len());
        for (r, buds) in per_shard.iter().zip(budgets) {
            let first = match r.batches.first() {
                Some(rec) => rec.index,
                None => continue,
            };
            if b < first {
                continue;
            }
            if let Some(rec) = r.batches.get(b - first) {
                debug_assert_eq!(rec.index, b, "shard batch records must be contiguous");
                rows.push((rec, buds.get(b - first).copied().unwrap_or(0)));
            }
        }
        assert!(!rows.is_empty(), "no live shard recorded batch {b}");

        let mut config = rows[0].0.config.clone();
        for (row, _) in rows.iter().skip(1) {
            config.union_with(&row.config);
        }
        let mut delta = CacheDelta::default();
        for (row, _) in &rows {
            delta.loaded.extend(row.delta.loaded.iter().copied());
            delta.evicted.extend(row.delta.evicted.iter().copied());
            delta.bytes_loaded += row.delta.bytes_loaded;
            delta.bytes_evicted += row.delta.bytes_evicted;
        }
        // Distinct ascending view ids; byte totals keep counting every
        // replica's movement.
        delta.loaded.sort_unstable();
        delta.loaded.dedup();
        delta.evicted.sort_unstable();
        delta.evicted.dedup();

        // Budget-weighted utilization. Equal budgets take the
        // plain-mean path so static federations stay bit-identical to
        // the unweighted merge. Today's federation re-splits every live
        // shard to the same total/N' each batch, so real runs always
        // take that path; the weighted branch makes the merge correct
        // by construction for any per-shard budget assignment (e.g. the
        // ROADMAP's warm-start ramps) instead of baking the equal-slice
        // assumption back in.
        let total_budget: u64 = rows.iter().map(|(_, w)| *w).sum();
        let equal = rows.iter().all(|(_, w)| *w == rows[0].1);
        let cache_utilization = if equal || total_budget == 0 {
            rows.iter().map(|(r, _)| r.cache_utilization).sum::<f64>() / rows.len() as f64
        } else {
            rows.iter()
                .map(|(r, w)| r.cache_utilization * *w as f64)
                .sum::<f64>()
                / total_budget as f64
        };

        batches.push(BatchRecord {
            index: b,
            n_queries: rows.iter().map(|(r, _)| r.n_queries).sum(),
            config,
            cache_utilization,
            window_end: rows[0].0.window_end,
            exec_start: rows
                .iter()
                .map(|(r, _)| r.exec_start)
                .fold(f64::INFINITY, f64::min),
            exec_end: rows
                .iter()
                .map(|(r, _)| r.exec_end)
                .fold(f64::NEG_INFINITY, f64::max),
            solve_secs: rows
                .iter()
                .map(|(r, _)| r.solve_secs)
                .fold(0.0, f64::max),
            queue_depth: 0,
            stall_secs: rows
                .iter()
                .map(|(r, _)| r.stall_secs)
                .fold(0.0, f64::max),
            delta,
        });
    }

    RunResult {
        policy: per_shard[0].policy,
        outcomes,
        batches,
        end_time: per_shard
            .iter()
            .map(|r| r.end_time)
            .fold(0.0, f64::max),
        n_tenants: per_shard[0].n_tenants,
        weights: per_shard[0].weights.clone(),
        host_wall_secs,
        summary,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::ConfigMask;
    use crate::domain::query::QueryId;
    use crate::sim::engine::QueryOutcome;

    fn outcome(id: u64, tenant: usize, exec: f64) -> QueryOutcome {
        QueryOutcome {
            id: QueryId(id),
            tenant,
            arrival: 0.0,
            start: 0.0,
            finish: exec,
            from_cache: false,
            bytes: 0,
        }
    }

    fn batch_record(index: usize, config_bits: &[bool], util: f64) -> BatchRecord {
        BatchRecord {
            index,
            n_queries: 1,
            config: ConfigMask::from_bools(config_bits),
            cache_utilization: util,
            window_end: 40.0 * (index + 1) as f64,
            exec_start: 40.0,
            exec_end: 50.0,
            solve_secs: 0.01,
            queue_depth: 0,
            stall_secs: 0.01,
            delta: CacheDelta {
                loaded: vec![0],
                evicted: vec![],
                bytes_loaded: 10,
                bytes_evicted: 0,
            },
        }
    }

    fn shard_run(outcomes: Vec<QueryOutcome>, config_bits: &[bool], util: f64) -> RunResult {
        RunResult {
            policy: "TEST",
            outcomes,
            batches: vec![batch_record(0, config_bits, util)],
            end_time: 50.0,
            n_tenants: 2,
            weights: vec![1.0, 1.0],
            host_wall_secs: 0.02,
            summary: ExecSummary::default(),
        }
    }

    #[test]
    fn merge_unions_configs_and_sorts_outcomes() {
        let a = shard_run(vec![outcome(3, 0, 5.0)], &[true, false], 0.5);
        let b = shard_run(vec![outcome(1, 1, 5.0)], &[false, true], 0.7);
        let merged = merge_runs(&[a, b], &[vec![10], vec![10]], 1, 0.05);
        assert_eq!(
            merged.outcomes.iter().map(|o| o.id.0).collect::<Vec<_>>(),
            vec![1, 3]
        );
        let batch = &merged.batches[0];
        assert_eq!(batch.n_queries, 2);
        assert!(batch.config.get(0) && batch.config.get(1));
        // Equal budgets → plain mean.
        assert!((batch.cache_utilization - 0.6).abs() < 1e-12);
        // Same view scheduled on both shards: listed once, bytes doubled.
        assert_eq!(batch.delta.loaded, vec![0]);
        assert_eq!(batch.delta.bytes_loaded, 20);
        assert_eq!(merged.host_wall_secs, 0.05);
    }

    /// Satellite regression (ISSUE 4): merged utilization is weighted by
    /// the shards' actual budget bytes, not an equal-slice average.
    #[test]
    fn merge_weights_utilization_by_budget() {
        let a = shard_run(vec![outcome(1, 0, 5.0)], &[true, false], 0.5);
        let b = shard_run(vec![outcome(2, 1, 5.0)], &[false, true], 0.7);
        let merged = merge_runs(&[a, b], &[vec![10], vec![30]], 1, 0.05);
        // (0.5·10 + 0.7·30) / 40 = 0.65, not the naive (0.5+0.7)/2 = 0.6.
        assert!(
            (merged.batches[0].cache_utilization - 0.65).abs() < 1e-12,
            "got {}",
            merged.batches[0].cache_utilization
        );
    }

    /// Elastic membership: shards born mid-run contribute only to the
    /// batches they were alive for.
    #[test]
    fn merge_handles_ragged_shard_lifetimes() {
        let mut a = shard_run(vec![outcome(1, 0, 5.0)], &[true, false], 0.5);
        a.batches.push(batch_record(1, &[true, false], 0.4));
        // Shard b joins at batch 1.
        let b = RunResult {
            policy: "TEST",
            outcomes: vec![outcome(2, 1, 5.0)],
            batches: vec![batch_record(1, &[false, true], 0.8)],
            end_time: 90.0,
            n_tenants: 2,
            weights: vec![1.0, 1.0],
            host_wall_secs: 0.02,
            summary: ExecSummary::default(),
        };
        let merged = merge_runs(&[a, b], &[vec![20, 10], vec![10]], 2, 0.05);
        assert_eq!(merged.batches.len(), 2);
        // Batch 0: shard a alone.
        assert_eq!(merged.batches[0].n_queries, 1);
        assert!((merged.batches[0].cache_utilization - 0.5).abs() < 1e-12);
        // Batch 1: both shards, equal budgets → plain mean of 0.4/0.8.
        assert_eq!(merged.batches[1].n_queries, 2);
        assert!((merged.batches[1].cache_utilization - 0.6).abs() < 1e-12);
        assert!(merged.batches[1].config.get(0) && merged.batches[1].config.get(1));
        assert_eq!(merged.end_time, 90.0);
    }

    /// Streaming shard runs (the real-clock federated service) carry
    /// no raw records; the merge must answer every report accessor
    /// from the absorbed summaries with `batches` pinned to the global
    /// count, not the per-shard sum.
    #[test]
    fn merge_streams_summaries_without_raw_records() {
        let streamed = |completed: u64, util: f64| {
            let mut r = shard_run(vec![], &[true], 0.0);
            r.batches.clear();
            r.summary.batches = 3;
            r.summary.util_batches = 3;
            r.summary.completed = completed;
            r.summary.util_sum = util * 3.0;
            r.summary.per_tenant_completed = vec![completed, 0];
            r.summary.bytes_loaded = 100;
            r.summary.solve_ms.record(2.0);
            r
        };
        let merged = merge_runs(
            &[streamed(10, 0.5), streamed(30, 0.7)],
            &[vec![10, 10, 10], vec![10, 10, 10]],
            3,
            0.05,
        );
        assert!(merged.batches.is_empty() && merged.outcomes.is_empty());
        assert_eq!(merged.completed(), 40);
        assert_eq!(merged.n_batches(), 3, "global batches, not 3 + 3");
        assert_eq!(merged.per_tenant_completed(), vec![40, 0]);
        // util_sum / util_batches: (0.5·3 + 0.7·3) / 6 = 0.6.
        assert!((merged.avg_cache_utilization() - 0.6).abs() < 1e-12);
        assert_eq!(merged.cache_bytes_moved(), (200, 0));
        assert!(merged.solve_ms_percentiles(&[50.0])[0] > 0.0);
    }

    #[test]
    fn single_shard_assembles_verbatim() {
        let a = shard_run(vec![outcome(1, 0, 5.0)], &[true, false], 0.5);
        let result =
            ClusterResult::assemble(vec![a.clone()], vec![vec![10]], vec![], 0, 0, 9.9, 1);
        // The merged run is the shard's run, untouched (including its
        // own host wall — the equivalence guarantee's metric surface).
        assert_eq!(result.run.outcomes.len(), a.outcomes.len());
        assert_eq!(result.run.batches[0].config, a.batches[0].config);
        assert_eq!(result.run.host_wall_secs, a.host_wall_secs);
        assert_eq!(result.n_shards(), 1);
    }

    #[test]
    fn speedup_spread_bounds() {
        let base = shard_run(
            vec![outcome(1, 0, 10.0), outcome(2, 1, 10.0)],
            &[true, false],
            0.5,
        );
        let even = shard_run(
            vec![outcome(1, 0, 5.0), outcome(2, 1, 5.0)],
            &[true, false],
            0.5,
        );
        assert!((speedup_spread(&even, &base) - 1.0).abs() < 1e-9);
        let skewed = shard_run(
            vec![outcome(1, 0, 2.0), outcome(2, 1, 10.0)],
            &[true, false],
            0.5,
        );
        assert!((speedup_spread(&skewed, &base) - 5.0).abs() < 1e-9);
    }

    /// Satellite regression (ISSUE 4): a tenant active in the baseline
    /// that attained zero speedup is counted as starved (spread = ∞),
    /// not silently excluded.
    #[test]
    fn speedup_spread_starved_tenant_is_infinite() {
        let base = shard_run(
            vec![outcome(1, 0, 10.0), outcome(2, 1, 10.0)],
            &[true, false],
            0.5,
        );
        // Tenant 1's query never retired in the policy run.
        let starved = shard_run(vec![outcome(1, 0, 5.0)], &[true, false], 0.5);
        assert!(speedup_spread(&starved, &base).is_infinite());
        // A tenant inactive in the baseline too is genuinely excluded:
        // with only one active tenant left the spread degenerates to 1.
        let base_single = shard_run(vec![outcome(1, 0, 10.0)], &[true, false], 0.5);
        let run_single = shard_run(vec![outcome(1, 0, 5.0)], &[true, false], 0.5);
        assert_eq!(speedup_spread(&run_single, &base_single), 1.0);
    }

    fn record_with_attainment(index: usize, u: Vec<f64>, star: Vec<f64>) -> ClusterRecord {
        ClusterRecord {
            index,
            multipliers: vec![1.0; u.len()],
            replicated_views: vec![],
            rebalanced: false,
            membership: vec![],
            decayed_views: vec![],
            live_shards: 2,
            shard_budget: 100,
            warming_shards: vec![],
            tenant_attained: u,
            tenant_attainable: star,
        }
    }

    /// The transient report's recovery scan: spread spikes at the event
    /// and the first sliding window back under 1.5× the pre level is
    /// reported as the recovery lag.
    #[test]
    fn transient_recovery_scan() {
        let even = |i| record_with_attainment(i, vec![4.0, 4.0], vec![4.0, 4.0]);
        let skewed = |i| record_with_attainment(i, vec![4.0, 1.0], vec![4.0, 4.0]);
        let mut records = Vec::new();
        // Batches 0–3 even (pre), 4–5 skewed (the transient), 6–9 even.
        for i in 0..4 {
            records.push(even(i));
        }
        for i in 4..6 {
            records.push(skewed(i));
        }
        for i in 6..10 {
            records.push(even(i));
        }
        let mut run = shard_run(vec![outcome(1, 0, 5.0), outcome(2, 1, 5.0)], &[true], 0.5);
        run.batches = (0..10).map(|i| batch_record(i, &[true], 0.5)).collect();
        let result = ClusterResult {
            run,
            per_shard: vec![],
            per_shard_budgets: vec![],
            records,
            replication_bytes: 0,
            rebalance_churn_bytes: 0,
        };
        let t = result.transient(4, 2);
        // Pre window [2,4) is even → spread 1; during [4,6) is skewed →
        // spread 4; post [6,8) is even again → spread 1.
        assert!((t.pre_spread - 1.0).abs() < 1e-9);
        assert!((t.during_spread - 4.0).abs() < 1e-9);
        assert!((t.post_spread - 1.0).abs() < 1e-9);
        // First 2-wide window from the event with spread ≤ 1.5×1.0 is
        // [6,8) → recovery after 2 batches.
        assert_eq!(t.recovery_batches, Some(2));
        // A run that never recovers reports None.
        let mut bad = result.clone();
        for r in bad.records.iter_mut().skip(4) {
            r.tenant_attained = vec![4.0, 1.0];
        }
        assert_eq!(bad.transient(4, 2).recovery_batches, None);
        // An infinite (starved) pre window has no re-convergence target:
        // None, not a trivial lag-0 match against an ∞ threshold.
        let mut starved_pre = result.clone();
        for r in starved_pre.records.iter_mut().take(4).skip(2) {
            r.tenant_attained = vec![0.0, 4.0];
        }
        let t = starved_pre.transient(4, 2);
        assert!(t.pre_spread.is_infinite());
        assert_eq!(t.recovery_batches, None);
    }

    #[test]
    fn attainment_spread_windows() {
        let base = shard_run(vec![outcome(1, 0, 5.0), outcome(2, 1, 5.0)], &[true], 0.5);
        let result = ClusterResult {
            run: base,
            per_shard: vec![],
            per_shard_budgets: vec![],
            records: vec![
                record_with_attainment(0, vec![4.0, 1.0], vec![4.0, 4.0]),
                record_with_attainment(1, vec![4.0, 4.0], vec![4.0, 4.0]),
                record_with_attainment(2, vec![0.0, 4.0], vec![4.0, 4.0]),
            ],
            replication_bytes: 0,
            rebalance_churn_bytes: 0,
        };
        // Batch 0 alone: tenant ratios 1.0 vs 0.25 → spread 4.
        assert!((result.attainment_spread_window(0, 1) - 4.0).abs() < 1e-9);
        // Batches 0–1 pooled: 1.0 vs 0.625 → spread 1.6.
        assert!((result.attainment_spread_window(0, 2) - 1.6).abs() < 1e-9);
        // Batch 2 alone: tenant 0 demanded but attained nothing → ∞.
        assert!(result.attainment_spread_window(2, 3).is_infinite());
        // Empty window: no active tenants → 1.0.
        assert_eq!(result.attainment_spread_window(5, 5), 1.0);
    }
}
