//! View → shard placement for the cache federation.
//!
//! The federation partitions the candidate-view universe across a live
//! set of cache shards; a view's *home* shard is where its queries are
//! routed by default. Two placers:
//!
//! - **consistent hash** (default): each shard contributes `VNODES`
//!   points to a hash ring keyed by its (stable) shard id; a view lands
//!   on the successor of its own hash. Because ring points depend only
//!   on the shard ids, a membership change moves exactly the views
//!   whose successor changed: adding a shard steals ~1/N of the views
//!   (all landing on the joiner), removing one relocates only the
//!   removed shard's views — which is what makes live add/remove/kill
//!   cheap at fleet scale ([`Placement::rehome_for_membership`]).
//! - **greedy bin packing** (size-aware): views in descending weight
//!   order onto the least-loaded shard. With weights = cached bytes it
//!   balances capacity; with weights = observed demand it is the
//!   rebalance placer (`ShardedCoordinator` feeds cumulative demanded
//!   bytes back through [`Placement::pack_weighted_for`]).
//!
//! Placement is pure routing state: it decides which shard *drains* a
//! query, not what a shard may cache — a shard's solver may cache any
//! view its routed queries demand (LERC-style coordinated decisions),
//! so a spanning query's off-home views become implicit replicas
//! charged to that shard's budget.

use std::cmp::Reverse;

use crate::util::mask::ConfigMask;
use crate::util::rng::mix64;

/// Virtual points per shard on the consistent-hash ring.
const VNODES: usize = 64;

/// Which placer builds the home map.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementStrategy {
    /// Consistent hashing over the view ids (ignores sizes).
    Hash,
    /// Greedy bin packing by cached size, largest first.
    Pack,
}

impl PlacementStrategy {
    pub fn parse(s: &str) -> Option<PlacementStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "hash" => Some(PlacementStrategy::Hash),
            "pack" => Some(PlacementStrategy::Pack),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PlacementStrategy::Hash => "hash",
            PlacementStrategy::Pack => "pack",
        }
    }
}

/// The home-shard map: view id → shard id, over an explicit live shard
/// set (ids need not be contiguous once membership changes retire
/// shards — a shard's id is stable for its whole life).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    /// Live shard ids, ascending.
    shards: Vec<usize>,
    home: Vec<usize>,
}

impl Placement {
    pub fn build(strategy: PlacementStrategy, n_shards: usize, view_sizes: &[u64]) -> Self {
        match strategy {
            PlacementStrategy::Hash => Self::hash(n_shards, view_sizes.len()),
            PlacementStrategy::Pack => Self::pack_weighted(n_shards, view_sizes),
        }
    }

    /// Consistent-hash placement over `n_views` view ids for the
    /// contiguous shard set `0..n_shards`.
    pub fn hash(n_shards: usize, n_views: usize) -> Self {
        let ids: Vec<usize> = (0..n_shards).collect();
        Self::hash_for(&ids, n_views)
    }

    /// Consistent-hash placement for an explicit live shard-id set.
    /// Ring points are a pure function of the shard id, so two
    /// placements over overlapping shard sets agree everywhere except
    /// where the membership diff changed a view's ring successor.
    pub fn hash_for(shard_ids: &[usize], n_views: usize) -> Self {
        assert!(!shard_ids.is_empty(), "placement needs at least one shard");
        let mut shards = shard_ids.to_vec();
        shards.sort_unstable();
        shards.dedup();
        let mut ring: Vec<(u64, usize)> = Vec::with_capacity(shards.len() * VNODES);
        for &s in &shards {
            for r in 0..VNODES {
                ring.push((mix64(((s as u64) << 16) | r as u64), s));
            }
        }
        ring.sort_unstable();
        let home = (0..n_views)
            .map(|v| {
                let h = mix64(0x5ca1_ab1e ^ ((v as u64) << 20));
                let idx = ring.partition_point(|&(p, _)| p < h);
                ring[idx % ring.len()].1
            })
            .collect();
        Self { shards, home }
    }

    /// Greedy bin packing over the contiguous shard set `0..n_shards`:
    /// views in descending `weights` order onto the least-loaded shard
    /// (ties → lower shard id). `weights` is cached bytes for the
    /// initial size-aware placement, or observed demanded bytes for a
    /// rebalance.
    pub fn pack_weighted(n_shards: usize, weights: &[u64]) -> Self {
        let ids: Vec<usize> = (0..n_shards).collect();
        Self::pack_weighted_for(&ids, weights)
    }

    /// Greedy bin packing for an explicit live shard-id set.
    pub fn pack_weighted_for(shard_ids: &[usize], weights: &[u64]) -> Self {
        assert!(!shard_ids.is_empty(), "placement needs at least one shard");
        let mut shards = shard_ids.to_vec();
        shards.sort_unstable();
        shards.dedup();
        let mut order: Vec<usize> = (0..weights.len()).collect();
        order.sort_by_key(|&v| (Reverse(weights[v]), v));
        let mut load = vec![0u64; shards.len()];
        let mut home = vec![shards[0]; weights.len()];
        for v in order {
            // Least-loaded shard, ties to the lower id (`shards` is
            // ascending, so position order is id order).
            let i = (0..shards.len()).min_by_key(|&i| (load[i], i)).unwrap();
            home[v] = shards[i];
            // Zero-weight views still occupy a routing slot; count one
            // byte so they round-robin instead of piling onto one shard.
            load[i] += weights[v].max(1);
        }
        Self { shards, home }
    }

    /// The placement after a membership change to `new_shards`,
    /// preserving the strategy's structure: `Hash` rebuilds the ring
    /// over the new shard set (the consistent-hash property: only views
    /// whose ring successor changed move — ~1/N per single add or
    /// remove), `Pack` re-packs by `weights`. Note that a hash re-home
    /// returns to pure ring homes, discarding any interim demand-driven
    /// rebalance; the next rebalance tick re-applies the demand layout.
    /// Diff against `self` (e.g. [`Placement::moved_views`]) to account
    /// the move set.
    pub fn rehome_for_membership(
        &self,
        strategy: PlacementStrategy,
        new_shards: &[usize],
        weights: &[u64],
    ) -> Placement {
        match strategy {
            PlacementStrategy::Hash => Self::hash_for(new_shards, self.home.len()),
            PlacementStrategy::Pack => Self::pack_weighted_for(new_shards, weights),
        }
    }

    /// Number of views whose home differs between `self` and `next`.
    pub fn moved_views(&self, next: &Placement) -> usize {
        assert_eq!(self.home.len(), next.home.len());
        self.home
            .iter()
            .zip(&next.home)
            .filter(|(a, b)| a != b)
            .count()
    }

    /// Test-only explicit construction from a home map.
    #[cfg(test)]
    pub(crate) fn from_home_map(shards: Vec<usize>, home: Vec<usize>) -> Self {
        debug_assert!(home.iter().all(|s| shards.contains(s)));
        Self { shards, home }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Live shard ids, ascending.
    pub fn shards(&self) -> &[usize] {
        &self.shards
    }

    pub fn n_views(&self) -> usize {
        self.home.len()
    }

    /// Home shard id of `view`.
    pub fn home(&self, view: usize) -> usize {
        self.home[view]
    }

    /// Mask of the views homed on shard id `shard`.
    pub fn shard_mask(&self, shard: usize) -> ConfigMask {
        let mut mask = ConfigMask::empty(self.home.len());
        for (v, &s) in self.home.iter().enumerate() {
            if s == shard {
                mask.set(v, true);
            }
        }
        mask
    }

    /// Total `weights` homed per shard, aligned with [`Placement::shards`]
    /// (balance diagnostics and tests).
    pub fn shard_load(&self, weights: &[u64]) -> Vec<u64> {
        let mut load = vec![0u64; self.shards.len()];
        for (v, &s) in self.home.iter().enumerate() {
            let i = self.shards.binary_search(&s).expect("home is a live shard");
            load[i] += weights[v];
        }
        load
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parse_roundtrip() {
        for s in [PlacementStrategy::Hash, PlacementStrategy::Pack] {
            assert_eq!(PlacementStrategy::parse(s.name()), Some(s));
        }
        assert_eq!(PlacementStrategy::parse("HASH"), Some(PlacementStrategy::Hash));
        assert_eq!(PlacementStrategy::parse("nope"), None);
    }

    #[test]
    fn single_shard_owns_everything() {
        for p in [
            Placement::hash(1, 30),
            Placement::pack_weighted(1, &[5u64; 30]),
        ] {
            assert!((0..30).all(|v| p.home(v) == 0));
            assert_eq!(p.shard_mask(0).count_ones(), 30);
        }
    }

    #[test]
    fn hash_is_deterministic_and_in_range() {
        let a = Placement::hash(4, 30);
        let b = Placement::hash(4, 30);
        assert_eq!(a, b);
        assert!((0..30).all(|v| a.home(v) < 4));
        // Shard masks partition the universe.
        let total: usize = (0..4).map(|s| a.shard_mask(s).count_ones()).sum();
        assert_eq!(total, 30);
    }

    #[test]
    fn hash_moves_few_views_on_shard_add() {
        // The consistent-hash property: going 4 → 5 shards relocates
        // roughly 1/5 of the views, not most of them.
        let n_views = 400;
        let a = Placement::hash(4, n_views);
        let b = Placement::hash(5, n_views);
        let moved = (0..n_views).filter(|&v| a.home(v) != b.home(v)).count();
        assert!(
            moved < n_views / 2,
            "consistent hash moved {moved}/{n_views} views"
        );
        assert!(moved > 0, "a fifth shard must take some views");
        assert_eq!(a.moved_views(&b), moved);
    }

    /// The elastic-membership contract (ISSUE 4 satellite): a single
    /// add or remove via `rehome_for_membership` moves at most 2/N of
    /// the views, every add-move lands on the joiner, every remove-move
    /// comes off the victim, and the transition is exactly reversible.
    #[test]
    fn rehome_for_membership_moves_bounded_fraction() {
        let n_views = 1000;
        for n in [2usize, 4, 8] {
            let ids: Vec<usize> = (0..n).collect();
            let a = Placement::hash_for(&ids, n_views);

            // Add shard `n`: only the joiner gains views, bounded by
            // 2/(N+1) of the universe.
            let plus: Vec<usize> = (0..=n).collect();
            let b = a.rehome_for_membership(PlacementStrategy::Hash, &plus, &[]);
            let moved: Vec<usize> =
                (0..n_views).filter(|&v| a.home(v) != b.home(v)).collect();
            assert!(!moved.is_empty(), "a joining shard must take views (n={n})");
            assert!(
                moved.iter().all(|&v| b.home(v) == n),
                "an add may only move views onto the new shard (n={n})"
            );
            assert!(
                moved.len() <= 2 * n_views / (n + 1),
                "add at n={n} moved {}/{n_views} views (> 2/{})",
                moved.len(),
                n + 1
            );

            // Removing it again restores the original map exactly.
            let c = b.rehome_for_membership(PlacementStrategy::Hash, &ids, &[]);
            assert_eq!(c, a, "ring placement is a pure function of the id set");

            // Remove a middle shard from the original set: only the
            // victim's views relocate, bounded by 2/N.
            let victim = n / 2;
            let minus: Vec<usize> = ids.iter().copied().filter(|&s| s != victim).collect();
            let d = a.rehome_for_membership(PlacementStrategy::Hash, &minus, &[]);
            let moved2: Vec<usize> =
                (0..n_views).filter(|&v| a.home(v) != d.home(v)).collect();
            assert!(
                moved2.iter().all(|&v| a.home(v) == victim),
                "a remove may only move the victim's views (n={n})"
            );
            assert!(
                moved2.len() <= 2 * n_views / n,
                "remove at n={n} moved {}/{n_views} views (> 2/{n})",
                moved2.len()
            );
            assert_eq!(a.moved_views(&d), moved2.len());
            // Survivor ids are reported ascending and exclude the victim.
            assert_eq!(d.shards(), &minus[..]);
        }
    }

    #[test]
    fn pack_balances_bytes() {
        let sizes: Vec<u64> = (1..=30u64).map(|k| k * 100).collect();
        let p = Placement::pack_weighted(4, &sizes);
        let load = p.shard_load(&sizes);
        let max = *load.iter().max().unwrap();
        let min = *load.iter().min().unwrap();
        let biggest = *sizes.iter().max().unwrap();
        // Greedy guarantee: spread ≤ the largest single item.
        assert!(
            max - min <= biggest,
            "pack imbalance {max}-{min} exceeds largest view {biggest}"
        );
    }

    #[test]
    fn pack_by_demand_follows_the_weights() {
        // Two dominant-demand views must land on different shards.
        let mut demand = vec![1u64; 10];
        demand[3] = 1_000_000;
        demand[7] = 1_000_000;
        let p = Placement::pack_weighted(2, &demand);
        assert_ne!(p.home(3), p.home(7));
    }

    #[test]
    fn pack_for_noncontiguous_ids() {
        // After a kill the live set can be e.g. {0, 2}: the packer must
        // spread over exactly those ids.
        let sizes: Vec<u64> = (1..=10u64).map(|k| k * 10).collect();
        let p = Placement::pack_weighted_for(&[0, 2], &sizes);
        assert_eq!(p.shards(), &[0, 2]);
        assert!((0..10).all(|v| p.home(v) == 0 || p.home(v) == 2));
        assert!(p.shard_mask(0).count_ones() > 0);
        assert!(p.shard_mask(2).count_ones() > 0);
        assert_eq!(p.shard_mask(1).count_ones(), 0);
    }
}
