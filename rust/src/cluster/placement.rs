//! View → shard placement for the cache federation.
//!
//! The federation partitions the candidate-view universe across N cache
//! shards; a view's *home* shard is where its queries are routed by
//! default. Two placers:
//!
//! - **consistent hash** (default): each shard contributes `VNODES`
//!   points to a hash ring; a view lands on the successor of its own
//!   hash. Adding/removing a shard moves only ~1/N of the views, which
//!   is what makes incremental resharding cheap at fleet scale.
//! - **greedy bin packing** (size-aware): views in descending weight
//!   order onto the least-loaded shard. With weights = cached bytes it
//!   balances capacity; with weights = observed demand it is the
//!   rebalance placer (`ShardedCoordinator` feeds cumulative demanded
//!   bytes back through [`Placement::pack_weighted`]).
//!
//! Placement is pure routing state: it decides which shard *drains* a
//! query, not what a shard may cache — a shard's solver may cache any
//! view its routed queries demand (LERC-style coordinated decisions),
//! so a spanning query's off-home views become implicit replicas
//! charged to that shard's budget.

use std::cmp::Reverse;

use crate::util::mask::ConfigMask;
use crate::util::rng::mix64;

/// Virtual points per shard on the consistent-hash ring.
const VNODES: usize = 64;

/// Which placer builds the home map.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementStrategy {
    /// Consistent hashing over the view ids (ignores sizes).
    Hash,
    /// Greedy bin packing by cached size, largest first.
    Pack,
}

impl PlacementStrategy {
    pub fn parse(s: &str) -> Option<PlacementStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "hash" => Some(PlacementStrategy::Hash),
            "pack" => Some(PlacementStrategy::Pack),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PlacementStrategy::Hash => "hash",
            PlacementStrategy::Pack => "pack",
        }
    }
}

/// The home-shard map: view id → shard id.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    n_shards: usize,
    home: Vec<usize>,
}

impl Placement {
    pub fn build(strategy: PlacementStrategy, n_shards: usize, view_sizes: &[u64]) -> Self {
        match strategy {
            PlacementStrategy::Hash => Self::hash(n_shards, view_sizes.len()),
            PlacementStrategy::Pack => Self::pack_weighted(n_shards, view_sizes),
        }
    }

    /// Consistent-hash placement over `n_views` view ids.
    pub fn hash(n_shards: usize, n_views: usize) -> Self {
        assert!(n_shards > 0, "placement needs at least one shard");
        let mut ring: Vec<(u64, usize)> = Vec::with_capacity(n_shards * VNODES);
        for s in 0..n_shards {
            for r in 0..VNODES {
                ring.push((mix64(((s as u64) << 16) | r as u64), s));
            }
        }
        ring.sort_unstable();
        let home = (0..n_views)
            .map(|v| {
                let h = mix64(0x5ca1_ab1e ^ ((v as u64) << 20));
                let idx = ring.partition_point(|&(p, _)| p < h);
                ring[idx % ring.len()].1
            })
            .collect();
        Self { n_shards, home }
    }

    /// Greedy bin packing: views in descending `weights` order onto the
    /// least-loaded shard (ties → lower shard id). `weights` is cached
    /// bytes for the initial size-aware placement, or observed demanded
    /// bytes for a rebalance.
    pub fn pack_weighted(n_shards: usize, weights: &[u64]) -> Self {
        assert!(n_shards > 0, "placement needs at least one shard");
        let mut order: Vec<usize> = (0..weights.len()).collect();
        order.sort_by_key(|&v| (Reverse(weights[v]), v));
        let mut load = vec![0u64; n_shards];
        let mut home = vec![0usize; weights.len()];
        for v in order {
            let s = (0..n_shards).min_by_key(|&s| (load[s], s)).unwrap();
            home[v] = s;
            // Zero-weight views still occupy a routing slot; count one
            // byte so they round-robin instead of piling onto shard 0.
            load[s] += weights[v].max(1);
        }
        Self { n_shards, home }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    pub fn n_views(&self) -> usize {
        self.home.len()
    }

    /// Home shard of `view`.
    pub fn home(&self, view: usize) -> usize {
        self.home[view]
    }

    /// Mask of the views homed on `shard`.
    pub fn shard_mask(&self, shard: usize) -> ConfigMask {
        let mut mask = ConfigMask::empty(self.home.len());
        for (v, &s) in self.home.iter().enumerate() {
            if s == shard {
                mask.set(v, true);
            }
        }
        mask
    }

    /// Total `weights` homed per shard (balance diagnostics and tests).
    pub fn shard_load(&self, weights: &[u64]) -> Vec<u64> {
        let mut load = vec![0u64; self.n_shards];
        for (v, &s) in self.home.iter().enumerate() {
            load[s] += weights[v];
        }
        load
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parse_roundtrip() {
        for s in [PlacementStrategy::Hash, PlacementStrategy::Pack] {
            assert_eq!(PlacementStrategy::parse(s.name()), Some(s));
        }
        assert_eq!(PlacementStrategy::parse("HASH"), Some(PlacementStrategy::Hash));
        assert_eq!(PlacementStrategy::parse("nope"), None);
    }

    #[test]
    fn single_shard_owns_everything() {
        for p in [
            Placement::hash(1, 30),
            Placement::pack_weighted(1, &[5u64; 30]),
        ] {
            assert!((0..30).all(|v| p.home(v) == 0));
            assert_eq!(p.shard_mask(0).count_ones(), 30);
        }
    }

    #[test]
    fn hash_is_deterministic_and_in_range() {
        let a = Placement::hash(4, 30);
        let b = Placement::hash(4, 30);
        assert_eq!(a, b);
        assert!((0..30).all(|v| a.home(v) < 4));
        // Shard masks partition the universe.
        let total: usize = (0..4).map(|s| a.shard_mask(s).count_ones()).sum();
        assert_eq!(total, 30);
    }

    #[test]
    fn hash_moves_few_views_on_shard_add() {
        // The consistent-hash property: going 4 → 5 shards relocates
        // roughly 1/5 of the views, not most of them.
        let n_views = 400;
        let a = Placement::hash(4, n_views);
        let b = Placement::hash(5, n_views);
        let moved = (0..n_views).filter(|&v| a.home(v) != b.home(v)).count();
        assert!(
            moved < n_views / 2,
            "consistent hash moved {moved}/{n_views} views"
        );
        assert!(moved > 0, "a fifth shard must take some views");
    }

    #[test]
    fn pack_balances_bytes() {
        let sizes: Vec<u64> = (1..=30u64).map(|k| k * 100).collect();
        let p = Placement::pack_weighted(4, &sizes);
        let load = p.shard_load(&sizes);
        let max = *load.iter().max().unwrap();
        let min = *load.iter().min().unwrap();
        let biggest = *sizes.iter().max().unwrap();
        // Greedy guarantee: spread ≤ the largest single item.
        assert!(
            max - min <= biggest,
            "pack imbalance {max}-{min} exceeds largest view {biggest}"
        );
    }

    #[test]
    fn pack_by_demand_follows_the_weights() {
        // Two dominant-demand views must land on different shards.
        let mut demand = vec![1u64; 10];
        demand[3] = 1_000_000;
        demand[7] = 1_000_000;
        let p = Placement::pack_weighted(2, &demand);
        assert_ne!(p.home(3), p.home(7));
    }
}
