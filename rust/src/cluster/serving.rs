//! Real-clock federated serving: the sharded federation (§2c–§2d) wired
//! into the live admission path of `robus serve` — per-shard
//! [`AdmissionQueue`]s fed by real-time producers through a
//! [`Placement`]-driven router, one planner/executor pair per shard
//! cutting batches on the wall clock, the [`GlobalAccountant`] feeding
//! weighted-PF multipliers between shards on live traffic, and
//! **reactive membership** (`--membership auto[:lo,hi]`): the
//! federation grows when sustained per-shard admission load exceeds
//! `hi` and drains its idlest shard when load stays below `lo`,
//! reusing the PR-4 drain→re-home→warm-up state machine with load as
//! the trigger instead of a batch-index schedule.
//!
//! Per batch window the serving loop:
//! 1. applies any reactive membership decision derived from the
//!    sliding-window load signal (see [`AutoMembership`]): an **add**
//!    re-homes ~1/N of the views onto a cold joiner (consistent-hash
//!    diff), re-splits every budget to `total/N'`, and excludes the
//!    joiner from the accountant for a warm-up window; a **drain**
//!    previews the victim's cache contents out (`drain_delta`,
//!    charged to churn), re-homes its views, and — the conservation
//!    contract — *re-routes its queued, already-admitted arrivals* to
//!    their new home queues ([`AdmissionQueue::requeue`]: no
//!    re-counting, no shedding) instead of dropping them;
//! 2. cuts each live shard's admission queue (sorted by arrival) —
//!    routing happened at admission time, per arrival, against the
//!    then-current placement;
//! 3. replicates views that dominated this cut's demanded bytes onto
//!    every shard (`--replicate-hot`), so *future* arrivals spread
//!    across holders (unlike the replay federation, routing here is on
//!    the admission path — replication cannot retroactively move a
//!    query that is already queued);
//! 4. solves + executes every live shard on the persistent worker pool
//!    ([`crate::cluster::runtime`]) — the unmodified
//!    `SolveContext`/`BatchExecutor` machinery, under the accountant's
//!    per-tenant weight multipliers, with no thread creation per batch;
//! 5. folds per-shard attained/attainable utilities into the
//!    [`GlobalAccountant`] (warming joiners excluded) and records a
//!    [`ClusterRecord`], so every federation metric (attainment
//!    spread windows, membership transients) applies to live serving
//!    unchanged.
//!
//! Both drivers share one loop, written against the [`Clock`] trait:
//! [`serve_federated`] paces it with a [`RealTimeClock`] and per-tenant
//! producer threads; [`serve_federated_sim`] drives the *same* loop
//! with a [`SimClock`] and inline arrival generation, making every
//! simulated quantity a pure function of the config. With one shard
//! and no auto membership the loop degenerates to the single-node
//! service semantics — `rust/tests/federated_serving.rs` pins
//! `--shards 1` against `coordinator::service::serve_sim` outcome by
//! outcome, and exercises a reactive add under sustained overload and
//! a reactive drain under idleness with workload conservation.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use crate::alloc::{ConfigMask, Policy};
use crate::cache::tier::TierSpec;
use crate::cluster::federation::{apply_placement, decay_due, route_query, GlobalAccountant};
use crate::cluster::membership::{AutoMembership, MembershipAction};
use crate::cluster::metrics::{ClusterRecord, ClusterResult, MembershipChange};
use crate::cluster::placement::{Placement, PlacementStrategy};
use crate::cluster::runtime::{
    resolve_workers, with_shard_pool, PoolItem, ShardPool, StepCtx,
};
use crate::cluster::shard::{Shard, ShardBatchOutcome};
use crate::coordinator::loop_::{tier_plan_of, CoordinatorConfig};
use crate::coordinator::service::{
    assemble_report, queue_counts, ServeConfig, ServeLoopStats, ServeReport,
};
use crate::domain::query::Query;
use crate::domain::tenant::TenantSet;
use crate::sim::engine::SimEngine;
use crate::telemetry::{EventKind, Metrics, Telemetry};
use crate::util::event::{Clock, RealTimeClock, SimClock};
use crate::util::ordf64::OrdF64;
use crate::util::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};
use crate::util::sync::Mutex;
use crate::workload::generator::TenantGenerator;
use crate::workload::queue::{AdmissionPolicy, AdmissionQueue};
use crate::workload::universe::Universe;

/// Knobs of one federated serve run (`robus serve --shards N ...`).
#[derive(Debug, Clone)]
pub struct ServeFederationConfig {
    /// The single-node serve knobs (duration, rate, tenants, batch
    /// window, queue capacity, admission policy, γ, seed).
    pub serve: ServeConfig,
    /// Initial shard count (reactive membership may change it).
    pub n_shards: usize,
    pub placement: PlacementStrategy,
    /// Replicate views above this fraction of a cut's demanded bytes
    /// to every shard (`None` disables; meaningless on a federation
    /// that can never exceed one shard).
    pub replicate_hot: Option<f64>,
    /// Replica decay (the replay federation's `--replica-decay`, on the
    /// live path): evict a hot-view replica from its non-home holders
    /// once its share of the cut demand stayed below `replicate_hot`
    /// for this many consecutive batches.
    pub replica_decay: Option<usize>,
    /// Re-home views by cumulative demand (pack placer) every `k`
    /// batches — the replay federation's `--rebalance-every` applied to
    /// future arrivals through the admission router.
    pub rebalance_every: Option<usize>,
    /// Reactive membership bounds (`--membership auto[:lo,hi]`);
    /// `None` keeps the shard set fixed.
    pub auto: Option<AutoMembership>,
    /// Ceiling on the live shard count reactive adds may reach — the
    /// backstop against unbounded growth when a skew-pinned hot shard
    /// keeps the overload signal up no matter how many shards join
    /// (an add re-homes ~1/N of the *views*; it cannot split one
    /// dominating view without `replicate_hot`).
    pub max_shards: usize,
    /// Batches a freshly added shard sits out the global accountant.
    pub warmup_batches: usize,
    /// Clamp on the accountant's per-tenant weight multipliers.
    pub max_boost: f64,
    /// Worker-pool width for the per-batch shard steps (`--workers`):
    /// `None` sizes the pool to the host's available parallelism,
    /// `Some(0)` steps shards inline on the serving thread, `Some(n)`
    /// pins `n` pool threads. Every simulated quantity is bit-identical
    /// across all settings (see `cluster::runtime`).
    pub workers: Option<usize>,
}

impl ServeFederationConfig {
    pub fn new(serve: ServeConfig, n_shards: usize) -> Self {
        let n_shards = n_shards.max(1);
        Self {
            serve,
            n_shards,
            placement: PlacementStrategy::Hash,
            replicate_hot: None,
            replica_decay: None,
            rebalance_every: None,
            auto: None,
            max_shards: (n_shards * 4).max(8),
            warmup_batches: 2,
            max_boost: 4.0,
            workers: None,
        }
    }
}

/// Result of a federated serve run: the same service-metric surface as
/// single-node serve (`serve`) plus the full federation roll-up
/// (`cluster` — per-shard runs, per-batch records, membership events,
/// attainment transients), so both the serving SLO checks and the
/// fairness analysis read from one report.
#[derive(Debug, Clone)]
pub struct FederatedServeReport {
    pub serve: ServeReport,
    pub cluster: ClusterResult,
    pub initial_shards: usize,
}

impl FederatedServeReport {
    /// Shards live when the run ended.
    pub fn live_shards_final(&self) -> usize {
        self.cluster.live_shards_final()
    }

    /// All reactive membership changes with their batch indices.
    pub fn membership_events(&self) -> Vec<(usize, &MembershipChange)> {
        self.cluster.membership_events()
    }

    /// Human-readable report for the CLI.
    pub fn render(&self) -> String {
        let mut out = self.serve.render();
        out.push_str(&format!(
            "federation: {} shard histories ({} live at end, {} initial), \
             {} B net replicas, {} B re-home/drain churn\n",
            self.cluster.n_shards(),
            self.live_shards_final(),
            self.initial_shards,
            self.cluster.replication_bytes,
            self.cluster.rebalance_churn_bytes,
        ));
        for (b, c) in self.membership_events() {
            out.push_str(&format!(
                "membership: reactive {} shard {} @ batch {b} \
                 (moved {} views, drained {} B)\n",
                c.action.name(),
                c.shard,
                c.views_moved,
                c.bytes_drained,
            ));
        }
        for (i, r) in self.cluster.per_shard.iter().enumerate() {
            out.push_str(&format!(
                "shard {:<3} served {:>6} queries over {:>4} batches\n",
                i,
                r.completed(),
                r.n_batches()
            ));
        }
        out
    }
}

/// Capacity of one shard's admission queue. A shard queue pools every
/// tenant routed to it, so the single-node *per-tenant* bound scales by
/// the tenant count — total admission capacity at `--shards 1` matches
/// the single-node path's `n_tenants × queue_capacity`, and growing the
/// federation never shrinks it. (Semantics under saturation still
/// differ by design: a pooled queue has no per-tenant isolation — one
/// hot tenant can displace another's arrivals on the same shard; the
/// equivalence contract is therefore pinned below the bound.)
fn shard_queue_capacity(cfg: &ServeConfig) -> usize {
    cfg.queue_capacity.saturating_mul(cfg.n_tenants.max(1))
}

/// One live shard of the serving federation: the replay federation's
/// [`Shard`] (planner mirror, executor, routing masks, RNG stream)
/// plus its admission queue and the reactive-membership load signal.
struct LiveShard<'e> {
    shard: Shard<'e>,
    queue: Arc<AdmissionQueue>,
    /// Admitted queries/sec of the last `window` cuts (the sliding
    /// load signal reactive membership watches).
    load: VecDeque<f64>,
    /// Consecutive cuts below `lo_qps` (the drain trigger clock).
    idle_streak: usize,
}

impl LiveShard<'_> {
    fn mean_load(&self) -> f64 {
        if self.load.is_empty() {
            0.0
        } else {
            self.load.iter().sum::<f64>() / self.load.len() as f64
        }
    }
}

/// The queue handle and load signal ride along with the shard into
/// whichever pool worker steps it; only `shard` is touched there.
impl<'e> PoolItem<'e> for LiveShard<'e> {
    fn shard_mut(&mut self) -> &mut Shard<'e> {
        &mut self.shard
    }
}

/// The admission-path router shared between producer threads and the
/// serving loop: placement + per-shard home/replica masks + the live
/// queue set, published RCU-style as immutable [`RouterEpoch`]s behind
/// one atomic pointer. Producers route each arrival against the current
/// epoch with a single `Acquire` load — the admission path takes no
/// lock — while the serving loop (the only writer) publishes a fresh
/// epoch on every membership, replication, decay, or rebalance change.
/// Retired epochs stay allocated until the router drops (a handful of
/// boxes per run: epochs change on reconfiguration events, not per
/// batch), which is what makes the borrow in [`ServeRouter::epoch`]
/// sound without deferred-reclamation machinery.
pub(crate) struct ServeRouter {
    /// The live epoch. Always points into one of the boxes owned by
    /// `epochs`, so the pointee outlives every reader of `&self`.
    current: AtomicPtr<RouterEpoch>,
    /// Every epoch ever published, in publication order. Append-only;
    /// owns the allocations `current` points into.
    epochs: Mutex<Vec<Box<RouterEpoch>>>,
    done_producers: AtomicUsize,
    n_producers: usize,
    cached_sizes: Vec<u64>,
    /// Registry handle for routing-anomaly counters
    /// (`robus_router_fallback_routes_total`).
    metrics: Arc<Metrics>,
}

/// One immutable snapshot of the routing state.
struct RouterEpoch {
    /// Live shard ids, ascending — all vectors below are index-aligned.
    ids: Vec<usize>,
    home_masks: Vec<ConfigMask>,
    replica_masks: Vec<ConfigMask>,
    queues: Vec<Arc<AdmissionQueue>>,
    placement: Option<Placement>,
}

impl ServeRouter {
    fn new(n_producers: usize, cached_sizes: Vec<u64>, metrics: Arc<Metrics>) -> Self {
        let router = Self {
            current: AtomicPtr::new(std::ptr::null_mut()),
            epochs: Mutex::new(Vec::new()),
            done_producers: AtomicUsize::new(0),
            n_producers,
            cached_sizes,
            metrics,
        };
        // Epoch 0: empty routing state, so `epoch()` never sees null.
        router.publish(RouterEpoch {
            ids: Vec::new(),
            home_masks: Vec::new(),
            replica_masks: Vec::new(),
            queues: Vec::new(),
            placement: None,
        });
        router
    }

    /// Publish a new epoch: box it, retain the box, swap the pointer.
    /// The `Release` store pairs with the `Acquire` load in
    /// [`ServeRouter::epoch`], so a reader that observes the new
    /// pointer observes the fully built epoch behind it.
    fn publish(&self, epoch: RouterEpoch) {
        let boxed = Box::new(epoch);
        let ptr: *const RouterEpoch = &*boxed;
        self.epochs.lock().unwrap().push(boxed);
        self.current.store(ptr as *mut RouterEpoch, Ordering::Release);
    }

    /// The current routing epoch — one atomic load, no lock.
    fn epoch(&self) -> &RouterEpoch {
        // ordering: Acquire pairs with the Release store in `publish`
        // — observing the pointer also makes the fully built epoch it
        // points at visible (model-checked: the Release→Relaxed
        // mutation of the publish is caught as a data race by
        // `rust/tests/model_concurrency.rs`).
        let ptr = self.current.load(Ordering::Acquire);
        // SAFETY: `publish` stores pointers only into boxes held by
        // `self.epochs`, which are append-only and dropped no earlier
        // than the router itself, so the pointee is valid and unmutated
        // for as long as this `&self` borrow lives.
        unsafe { &*ptr }
    }

    /// Route one query against `ep` — the replay federation's routing
    /// policy ([`route_query`], the single shared implementation),
    /// applied at admission time over the epoch's masks.
    fn idx(&self, ep: &RouterEpoch, q: &Query) -> usize {
        let placement = ep.placement.as_ref().expect("router synced");
        route_query(
            ep.ids.len(),
            |i, v| ep.home_masks[i].get(v) || ep.replica_masks[i].get(v),
            |v| match ep.ids.binary_search(&placement.home(v)) {
                Ok(i) => i,
                Err(_) => {
                    // Invariant: an epoch's placement only homes views
                    // on shards in that epoch's live set (`sync_router`
                    // builds both from the same `live` slice). A miss
                    // means a placement/epoch tear; fail loudly in
                    // debug, and in release fall back to the live set's
                    // first shard (never drop an arrival) while
                    // counting the anomaly so operators see misroutes
                    // instead of silent skew.
                    debug_assert!(
                        false,
                        "placement homes view {v} on shard {} absent from epoch {:?}",
                        placement.home(v),
                        ep.ids
                    );
                    self.metrics.router_fallback_routes.inc();
                    0
                }
            },
            &self.cached_sizes,
            q,
        )
    }

    /// Admit one arrival: route lock-free against the current epoch,
    /// then offer under `admission`. The queue handle is cloned out of
    /// the epoch, so a blocking offer never delays anything else.
    fn offer(&self, q: Query, admission: AdmissionPolicy) -> bool {
        let ep = self.epoch();
        let queue = ep.queues[self.idx(ep, &q)].clone();
        queue.offer(q, admission)
    }

    /// Index (into the live set) a query would route to right now —
    /// the drain path re-homes a retiring shard's backlog through this.
    fn route_index(&self, q: &Query) -> usize {
        self.idx(self.epoch(), q)
    }

    fn producer_done(&self) {
        // ordering: Release pairs with the Acquire load in
        // `producers_done` — kept at Release/Acquire in the PR 9
        // audit: the loop treats "all producers done" as "every offer
        // those producers made is visible", so draining the queues
        // after the flag observes the final count must also observe
        // the final arrivals.
        self.done_producers.fetch_add(1, Ordering::Release);
    }

    fn producers_done(&self) -> bool {
        // ordering: Acquire pairs with the Release fetch_add in
        // `producer_done` (see the reasoning there).
        self.done_producers.load(Ordering::Acquire) >= self.n_producers
    }
}

/// Publish the loop's authoritative placement/shard state as a fresh
/// router epoch (one pointer swap; producers mid-route finish against
/// the epoch they already loaded — same semantics as losing the old
/// lock race by a hair). Every publication is a trace event: `reason`
/// says what reconfiguration forced it, `value` carries the live shard
/// count the new epoch routes over.
fn sync_router(
    router: &ServeRouter,
    placement: &Placement,
    live: &[LiveShard<'_>],
    tel: &Telemetry,
    t: f64,
    batch: i64,
    reason: &'static str,
) {
    tel.event(
        t,
        EventKind::RouterEpoch,
        -1,
        -1,
        live.len() as f64,
        reason,
        batch,
    );
    router.publish(RouterEpoch {
        ids: live.iter().map(|ls| ls.shard.id).collect(),
        home_masks: live
            .iter()
            .map(|ls| placement.shard_mask(ls.shard.id))
            .collect(),
        replica_masks: live.iter().map(|ls| ls.shard.replicas.clone()).collect(),
        queues: live.iter().map(|ls| ls.queue.clone()).collect(),
        placement: Some(placement.clone()),
    });
}

/// Everything the serving loop borrows for its whole run.
struct ServingInputs<'a, 'e> {
    universe: &'a Universe,
    tenants: &'a TenantSet,
    exec_engine: &'e SimEngine,
    policy: &'a dyn Policy,
    fcfg: &'a ServeFederationConfig,
    /// The federation's *total* tier spec; each live shard runs on a
    /// `total_spec.split(N')` slice, re-split on membership changes.
    total_spec: TierSpec,
    /// Pure-observer telemetry handle, shared with pool workers and
    /// admission queues (via probes).
    tel: &'a Telemetry,
    /// Keep per-query outcome/batch records on every shard executor.
    /// The real-clock driver turns this off (open-ended runs stream
    /// into `ExecSummary` so memory stays flat); the sim driver keeps
    /// raw records — the equivalence tests compare them exactly.
    retain_raw: bool,
}

/// What the loop hands back to the drivers for report assembly.
struct LoopOut<'e> {
    /// Every shard that ever lived (retired + live).
    shards: Vec<Shard<'e>>,
    records: Vec<ClusterRecord>,
    replication_bytes: u64,
    churn_bytes: u64,
    stats: ServeLoopStats,
    /// Every admission queue ever created (retired shards' queues keep
    /// their admission counters for the conservation accounting).
    all_queues: Vec<Arc<AdmissionQueue>>,
    n_batches: usize,
}

fn build_initial<'e>(
    inp: &ServingInputs<'_, 'e>,
    cached_sizes: &[u64],
) -> (Placement, Vec<LiveShard<'e>>) {
    let fcfg = inp.fcfg;
    let placement = Placement::build(fcfg.placement, fcfg.n_shards, cached_sizes);
    let live_spec = inp.total_spec.split(fcfg.n_shards);
    let live: Vec<LiveShard<'e>> = (0..fcfg.n_shards)
        .map(|s| {
            let mut shard = Shard::new(
                s,
                inp.exec_engine,
                inp.universe,
                inp.tenants,
                placement.shard_mask(s),
                fcfg.serve.common.seed,
                live_spec,
                0,
                fcfg.serve.common.warm_start,
            );
            shard.executor.set_retain_raw(inp.retain_raw);
            LiveShard {
                shard,
                queue: Arc::new(AdmissionQueue::with_probe(
                    shard_queue_capacity(&fcfg.serve),
                    inp.tel.queue_probe(s as i64),
                )),
                load: VecDeque::new(),
                idle_streak: 0,
            }
        })
        .collect();
    (placement, live)
}

/// The shared serving loop — the tentpole's core. Both drivers call
/// this with their clock and their arrival pump; everything else
/// (membership, cut, replication, solve/execute, accounting) is
/// driver-independent. Spins up the per-run worker pool, then runs
/// [`run_loop_on_pool`] on it — the only thread creation of the loop's
/// whole lifetime.
#[allow(clippy::too_many_arguments)]
fn run_loop<'e, C: Clock>(
    inp: &ServingInputs<'_, 'e>,
    clock: &mut C,
    router: &ServeRouter,
    placement: Placement,
    live: Vec<LiveShard<'e>>,
    cached_sizes: &[u64],
    scan_sizes: &[u64],
    pump: impl FnMut(&mut C, f64) -> bool,
) -> LoopOut<'e> {
    let ctx = StepCtx {
        tenants: inp.tenants,
        universe: inp.universe,
        policy: inp.policy,
        stateful_gamma: inp.fcfg.serve.common.stateful_gamma,
        tel: inp.tel,
    };
    with_shard_pool(resolve_workers(inp.fcfg.workers), ctx, |pool| {
        run_loop_on_pool(
            inp, clock, router, placement, live, cached_sizes, scan_sizes, pump, pool,
        )
    })
}

/// One serving run on an already-live pool: every batch's shard steps
/// are messages to the fixed worker set — nothing in here spawns.
#[allow(clippy::too_many_arguments)]
fn run_loop_on_pool<'e, C: Clock>(
    inp: &ServingInputs<'_, 'e>,
    clock: &mut C,
    router: &ServeRouter,
    mut placement: Placement,
    mut live: Vec<LiveShard<'e>>,
    cached_sizes: &[u64],
    scan_sizes: &[u64],
    mut pump: impl FnMut(&mut C, f64) -> bool,
    pool: &mut ShardPool<'_, LiveShard<'e>>,
) -> LoopOut<'e> {
    let fcfg = inp.fcfg;
    let cfg = &fcfg.serve;
    let tel = inp.tel;
    let n_views = inp.universe.views.len();
    let n_tenants = inp.tenants.len();
    let weights = inp.tenants.weights();

    let mut accountant = GlobalAccountant::new(n_tenants, fcfg.max_boost);
    let mut records: Vec<ClusterRecord> = Vec::new();
    let mut dead: Vec<Shard<'e>> = Vec::new();
    let mut all_queues: Vec<Arc<AdmissionQueue>> =
        live.iter().map(|ls| ls.queue.clone()).collect();
    let mut stats = ServeLoopStats::default();
    let mut replication_bytes = 0u64;
    let mut churn = 0u64;
    // Whole-run demanded bytes per view: the pack placer's re-home
    // weights once any demand has been observed (before that, sizes).
    let mut cum_demand = vec![0u64; n_views];
    // Consecutive cold cuts per replicated view — the replica-decay
    // streaks (same machinery as the replay federation's).
    let mut decay_streaks = vec![0usize; n_views];
    let mut live_spec = inp.total_spec.split(fcfg.n_shards);
    let mut next_shard_id = fcfg.n_shards;
    // Reactive-membership state: consecutive batches the hottest
    // shard's load exceeded hi, and the batch of the last event.
    let mut overload_streak = 0usize;
    let mut last_event: Option<usize> = None;
    let mut b = 0usize;
    let mut last_report = 0u64;
    // Steady-state scratch, hoisted out of the batch loop so a settled
    // federation allocates nothing per batch (DESIGN.md §2g).
    let mut batch_demand = vec![0u64; n_views];
    let mut outcomes: Vec<ShardBatchOutcome> = Vec::new();
    let mut obs_u = vec![0.0; n_tenants];
    let mut obs_star = vec![0.0; n_tenants];
    // Multiplier buffer shared with the pool workers by refcount; the
    // workers drop their handles before replying, so `Arc::make_mut`
    // reuses this allocation every batch.
    let mut mult_buf: Arc<Vec<f64>> = Arc::new(vec![1.0; n_tenants]);

    loop {
        let window_end = (b + 1) as f64 * cfg.common.batch_secs;
        let now = clock.wait_until(window_end);
        let closed = pump(clock, now);

        // --- 1. Reactive membership, from the sustained load signal
        // of the *previous* windows. Add wins over drain (overload is
        // the user-visible failure); one event per batch, then a
        // cooldown so the re-home and warm-up settle before the signal
        // is trusted again. ---
        let mut membership_changes: Vec<MembershipChange> = Vec::new();
        if let Some(auto) = fcfg.auto {
            let cooled = match last_event {
                Some(e) => b >= e + auto.cooldown,
                None => true,
            };
            if cooled {
                let pack_weights: &[u64] = if cum_demand.iter().any(|&d| d > 0) {
                    &cum_demand
                } else {
                    cached_sizes
                };
                if overload_streak >= auto.window && live.len() < fcfg.max_shards {
                    // Reactive ADD: a cold shard joins under the next
                    // fresh id; ~1/N' of the views re-home onto it.
                    let id = next_shard_id;
                    next_shard_id += 1;
                    let mut new_ids: Vec<usize> =
                        live.iter().map(|ls| ls.shard.id).collect();
                    new_ids.push(id);
                    new_ids.sort_unstable();
                    let next = placement.rehome_for_membership(
                        fcfg.placement,
                        &new_ids,
                        pack_weights,
                    );
                    let moved = apply_placement(
                        &mut placement,
                        next,
                        live.iter_mut().map(|ls| &mut ls.shard),
                        cached_sizes,
                        &mut churn,
                        &mut replication_bytes,
                        tel,
                        now,
                        b as i64,
                    );
                    let queue = Arc::new(AdmissionQueue::with_probe(
                        shard_queue_capacity(cfg),
                        tel.queue_probe(id as i64),
                    ));
                    all_queues.push(queue.clone());
                    let mut joiner = Shard::new(
                        id,
                        inp.exec_engine,
                        inp.universe,
                        inp.tenants,
                        placement.shard_mask(id),
                        cfg.common.seed,
                        live_spec,
                        b + fcfg.warmup_batches,
                        cfg.common.warm_start,
                    );
                    joiner.executor.set_retain_raw(inp.retain_raw);
                    live.push(LiveShard {
                        shard: joiner,
                        queue,
                        load: VecDeque::new(),
                        idle_streak: 0,
                    });
                    live_spec = inp.total_spec.split(live.len());
                    for ls in live.iter_mut() {
                        ls.shard.executor.cache_mut().set_tier_budgets(live_spec.budgets);
                        ls.idle_streak = 0;
                    }
                    tel.event(
                        now,
                        EventKind::MembershipAdd,
                        id as i64,
                        -1,
                        moved as f64,
                        "reactive_overload",
                        b as i64,
                    );
                    membership_changes.push(MembershipChange {
                        action: MembershipAction::Add,
                        shard: id,
                        views_moved: moved,
                        bytes_drained: 0,
                        bytes_lost: 0,
                    });
                    overload_streak = 0;
                    last_event = Some(b);
                    sync_router(
                        router,
                        &placement,
                        &live,
                        tel,
                        now,
                        b as i64,
                        "membership_add",
                    );
                } else if live.len() > 1 {
                    // Reactive DRAIN: the idlest shard whose load
                    // stayed below lo for a full window retires.
                    let victim = live
                        .iter()
                        .enumerate()
                        .filter(|(_, ls)| {
                            ls.load.len() >= auto.window && ls.idle_streak >= auto.window
                        })
                        .min_by_key(|(_, ls)| (OrdF64(ls.mean_load()), ls.shard.id))
                        .map(|(i, _)| i);
                    if let Some(vidx) = victim {
                        let leaving = live.remove(vidx);
                        let leaving_id = leaving.shard.id;
                        // Planned decommission: contents migrate out —
                        // the drain preview is the churn; the leaver's
                        // replica copies vanish with it.
                        let drained =
                            leaving.shard.executor.cache().drain_delta().bytes_evicted;
                        churn += drained;
                        let rep_bytes: u64 = leaving
                            .shard
                            .replicas
                            .ones()
                            .map(|v| cached_sizes[v])
                            .sum();
                        replication_bytes = replication_bytes.saturating_sub(rep_bytes);
                        let new_ids: Vec<usize> =
                            live.iter().map(|ls| ls.shard.id).collect();
                        let next = placement.rehome_for_membership(
                            fcfg.placement,
                            &new_ids,
                            pack_weights,
                        );
                        let moved = apply_placement(
                            &mut placement,
                            next,
                            live.iter_mut().map(|ls| &mut ls.shard),
                            cached_sizes,
                            &mut churn,
                            &mut replication_bytes,
                            tel,
                            now,
                            b as i64,
                        );
                        live_spec = inp.total_spec.split(live.len());
                        for ls in live.iter_mut() {
                            ls.shard
                                .executor
                                .cache_mut()
                                .set_tier_budgets(live_spec.budgets);
                            ls.idle_streak = 0;
                        }
                        // New routing table first, then the final
                        // backlog transfer: close the retiring queue
                        // (late racing offers reject and are counted,
                        // never stranded), then re-home every queued
                        // arrival to its new home. `requeue` neither
                        // re-counts nor sheds — admitted work is
                        // conserved across the drain.
                        sync_router(
                            router,
                            &placement,
                            &live,
                            tel,
                            now,
                            b as i64,
                            "membership_drain",
                        );
                        leaving.queue.close();
                        for q in leaving.queue.drain() {
                            let idx = router.route_index(&q);
                            live[idx].queue.requeue(q);
                        }
                        dead.push(leaving.shard);
                        tel.event(
                            now,
                            EventKind::MembershipRemove,
                            leaving_id as i64,
                            -1,
                            drained as f64,
                            "reactive_idle",
                            b as i64,
                        );
                        membership_changes.push(MembershipChange {
                            action: MembershipAction::Remove,
                            shard: leaving_id,
                            views_moved: moved,
                            bytes_drained: drained,
                            bytes_lost: 0,
                        });
                        overload_streak = 0;
                        last_event = Some(b);
                    }
                }
            }
        }

        // --- 2. Cut each live shard's queue (routing happened at
        // admission time); update the load signal. ---
        let mut total_cut = 0usize;
        batch_demand.fill(0);
        let mut max_shard_qps = 0.0f64;
        for ls in live.iter_mut() {
            // Cut into the shard's recycled inbox (emptied, capacity
            // intact, by the executor's buffer reclaim last step).
            let t_cut = Instant::now();
            ls.queue.drain_into(&mut ls.shard.inbox);
            ls.shard.inbox.sort_by_key(|q| OrdF64(q.arrival));
            // Host cost of this shard's cut, consumed into the span the
            // shard emits when it steps this batch.
            ls.shard.last_drain_secs = t_cut.elapsed().as_secs_f64();
            for q in &ls.shard.inbox {
                let wait = (now - q.arrival).max(0.0);
                stats.admit_wait_sum += wait;
                tel.admit_wait(wait * 1e3);
                for v in &q.required_views {
                    batch_demand[v.0] += scan_sizes[v.0];
                }
            }
            let qps = ls.shard.inbox.len() as f64 / cfg.common.batch_secs;
            max_shard_qps = max_shard_qps.max(qps);
            if let Some(auto) = fcfg.auto {
                if ls.load.len() >= auto.window {
                    ls.load.pop_front();
                }
                ls.load.push_back(qps);
            }
            total_cut += ls.shard.inbox.len();
        }
        // Trigger streaks accumulate only *outside* the cooldown — the
        // whole point of the cooldown is that the signal is not trusted
        // until the re-home and warm-up have settled, so the earliest
        // back-to-back event is last_event + cooldown + window, not
        // last_event + cooldown.
        if let Some(auto) = fcfg.auto {
            let cooled = match last_event {
                Some(e) => b >= e + auto.cooldown,
                None => true,
            };
            for ls in live.iter_mut() {
                let qps = ls.load.back().copied().unwrap_or(0.0);
                if cooled && qps < auto.lo_qps {
                    ls.idle_streak += 1;
                } else {
                    ls.idle_streak = 0;
                }
            }
            overload_streak = if cooled && max_shard_qps > auto.hi_qps {
                overload_streak + 1
            } else {
                0
            };
        }
        for v in 0..n_views {
            cum_demand[v] += batch_demand[v];
        }
        if total_cut > 0 {
            stats.served_until = now;
        }

        // --- 3. Hot-view replication from this cut's demand: future
        // arrivals to a dominating view spread across all shards. ---
        let mut replicated_views = Vec::new();
        if live.len() > 1 {
            if let Some(frac) = fcfg.replicate_hot {
                let total: u64 = batch_demand.iter().sum();
                if total > 0 {
                    for v in 0..n_views {
                        if batch_demand[v] as f64 > frac * total as f64 {
                            let mut added = 0u64;
                            for ls in live.iter_mut() {
                                if !ls.shard.is_resident(v) {
                                    ls.shard.replicas.set(v, true);
                                    added += 1;
                                }
                            }
                            if added > 0 {
                                replication_bytes += added * cached_sizes[v];
                                replicated_views.push(v);
                            }
                        }
                    }
                    if !replicated_views.is_empty() {
                        sync_router(
                            router,
                            &placement,
                            &live,
                            tel,
                            now,
                            b as i64,
                            "replicate_hot",
                        );
                    }
                }
            }
        }

        // --- 3b. Replica decay, the replay federation's step on the
        // live path: a replica whose share of the cut demand stayed
        // below the hot threshold for `k` consecutive cuts leaves its
        // non-home holders. The signal is the current cut — the same
        // one replication keys off — so a view that just replicated
        // starts its streak at zero. ---
        let mut decayed_views = Vec::new();
        if live.len() > 1 {
            if let (Some(frac), Some(k)) = (fcfg.replicate_hot, fcfg.replica_decay) {
                let total: u64 = batch_demand.iter().sum();
                let has_replica: Vec<bool> = (0..n_views)
                    .map(|v| live.iter().any(|ls| ls.shard.replicas.get(v)))
                    .collect();
                for v in decay_due(
                    &mut decay_streaks,
                    &batch_demand,
                    total,
                    frac,
                    k,
                    &has_replica,
                ) {
                    for ls in live.iter_mut() {
                        if ls.shard.replicas.get(v) {
                            ls.shard.replicas.set(v, false);
                            replication_bytes =
                                replication_bytes.saturating_sub(cached_sizes[v]);
                            if ls.shard.executor.cache().is_cached(v)
                                && !ls.shard.home.get(v)
                            {
                                // Projected eviction: the solver ages
                                // the copy out once the router stops
                                // feeding it.
                                churn += cached_sizes[v];
                            }
                        }
                    }
                    decayed_views.push(v);
                }
                if !decayed_views.is_empty() {
                    sync_router(
                        router,
                        &placement,
                        &live,
                        tel,
                        now,
                        b as i64,
                        "replica_decay",
                    );
                }
            }
        }

        // --- 3c. Periodic demand-driven re-home (`--rebalance-every`
        // on the live path): future arrivals follow the new homes
        // through the admission router. ---
        let mut rebalanced = false;
        if live.len() > 1 {
            if let Some(kk) = fcfg.rebalance_every {
                if kk > 0 && b > 0 && b % kk == 0 {
                    let live_ids: Vec<usize> =
                        live.iter().map(|ls| ls.shard.id).collect();
                    let next = Placement::pack_weighted_for(&live_ids, &cum_demand);
                    if next != placement {
                        apply_placement(
                            &mut placement,
                            next,
                            live.iter_mut().map(|ls| &mut ls.shard),
                            cached_sizes,
                            &mut churn,
                            &mut replication_bytes,
                            tel,
                            now,
                            b as i64,
                        );
                        rebalanced = true;
                        sync_router(
                            router,
                            &placement,
                            &live,
                            tel,
                            now,
                            b as i64,
                            "rebalance",
                        );
                    }
                }
            }
        }

        // --- 4. Solve + execute every live shard on the worker pool,
        // under the accountant's feedback (no multipliers while a
        // single shard is live — the single-node-equivalent path). ---
        let use_mults = live.len() > 1 && b > 0;
        if use_mults {
            accountant.multipliers_into(&weights, Arc::make_mut(&mut mult_buf));
            // A multiplier sitting on either clamp bound means the
            // accountant wanted to push harder — worth a trace event
            // per clamped tenant (observation only; the clamp itself
            // happened inside the accountant).
            for (i, &m) in mult_buf.iter().enumerate() {
                if m >= fcfg.max_boost || m <= 1.0 / fcfg.max_boost {
                    tel.event(
                        now,
                        EventKind::MultiplierClamp,
                        -1,
                        i as i64,
                        m,
                        "boost_bound",
                        b as i64,
                    );
                }
            }
        }
        pool.step_batch(
            &mut live,
            b,
            window_end,
            live_spec.budgets.ram,
            tier_plan_of(&live_spec),
            use_mults.then_some(&mult_buf),
            &mut outcomes,
        );

        // --- 5. Global fairness accounting (warming joiners excluded
        // from the accountant; records keep the full reality). ---
        let mut agg_u = vec![0.0; n_tenants];
        let mut agg_star = vec![0.0; n_tenants];
        obs_u.fill(0.0);
        obs_star.fill(0.0);
        for (ls, o) in live.iter().zip(&outcomes) {
            let warm = !ls.shard.is_warming(b);
            for i in 0..n_tenants {
                agg_u[i] += o.utilities[i];
                agg_star[i] += o.u_star[i];
                if warm {
                    obs_u[i] += o.utilities[i];
                    obs_star[i] += o.u_star[i];
                }
            }
        }
        accountant.observe(&obs_u, &obs_star);
        let warming_shards: Vec<usize> = live
            .iter()
            .filter(|ls| ls.shard.is_warming(b))
            .map(|ls| ls.shard.id)
            .collect();
        records.push(ClusterRecord {
            index: b,
            multipliers: if use_mults {
                mult_buf.as_ref().clone()
            } else {
                vec![1.0; n_tenants]
            },
            replicated_views,
            rebalanced,
            membership: membership_changes,
            decayed_views,
            live_shards: live.len(),
            shard_budget: live_spec.budgets.ram,
            warming_shards,
            tenant_attained: agg_u,
            tenant_attainable: agg_star,
        });

        // Registry gauges + periodic trace snapshot: pure observation,
        // after the batch's accounting is folded.
        tel.metrics().live_shards.set(live.len() as u64);
        tel.metrics()
            .queue_depth
            .set(live.iter().map(|ls| ls.queue.len() as u64).sum());
        tel.tick(now);

        // Live metrics line, once per second — real-time driver only.
        if cfg.verbose && clock.is_real_time() && now as u64 > last_report {
            last_report = now as u64;
            let (adm, rej) = queue_counts(all_queues.iter().map(|q| q.as_ref()));
            println!(
                "[t={now:6.2}s] shards={} admitted={adm} rejected={rej} \
                 last_batch={total_cut}",
                live.len()
            );
        }

        b += 1;
        // Done once production has ended and a cut came up empty.
        if closed && total_cut == 0 {
            break;
        }
    }

    let mut shards = dead;
    shards.extend(live.into_iter().map(|ls| ls.shard));
    LoopOut {
        shards,
        records,
        replication_bytes,
        churn_bytes: churn,
        stats,
        all_queues,
        n_batches: b,
    }
}

fn validate(fcfg: &ServeFederationConfig, tenants: &TenantSet) {
    let cfg = &fcfg.serve;
    assert!(fcfg.n_shards >= 1, "federated serve needs at least one shard");
    assert!(cfg.n_tenants > 0, "serve needs at least one tenant");
    assert!(cfg.common.batch_secs > 0.0 && cfg.duration_secs > 0.0);
    assert_eq!(tenants.len(), cfg.n_tenants, "tenant set size mismatch");
}

/// Assemble the final report from the loop output: per-shard runs fold
/// into a [`ClusterResult`] (ragged lifetimes, budget-weighted merge —
/// the PR-4 machinery unchanged), whose merged run feeds the shared
/// serve-report assembly.
fn finish<'e>(
    out: LoopOut<'e>,
    inp: &ServingInputs<'_, 'e>,
    host_wall_secs: f64,
) -> FederatedServeReport {
    let fcfg = inp.fcfg;
    let cfg = &fcfg.serve;
    let coord_cfg = CoordinatorConfig {
        common: cfg.common.clone(),
        n_batches: 0, // open-ended, like the single-node service
    };
    let mut all = out.shards;
    all.sort_by_key(|sh| sh.id);
    let mut per_shard = Vec::with_capacity(all.len());
    let mut per_shard_budgets = Vec::with_capacity(all.len());
    for sh in all {
        let Shard {
            executor, budgets, ..
        } = sh;
        per_shard_budgets.push(budgets);
        per_shard.push(executor.into_result(
            inp.policy.name(),
            &coord_cfg,
            cfg.n_tenants,
            host_wall_secs,
        ));
    }
    let cluster = ClusterResult::assemble(
        per_shard,
        per_shard_budgets,
        out.records,
        out.replication_bytes,
        out.churn_bytes,
        host_wall_secs,
        out.n_batches,
    );
    let (admitted, rejected) = queue_counts(out.all_queues.iter().map(|q| q.as_ref()));
    let peak = out
        .all_queues
        .iter()
        .map(|q| q.peak_depth())
        .max()
        .unwrap_or(0);
    let serve = assemble_report(
        &cluster.run,
        admitted,
        rejected,
        peak,
        out.stats,
        host_wall_secs,
        inp.tenants,
        cfg.n_tenants,
    );
    FederatedServeReport {
        serve,
        cluster,
        initial_shards: fcfg.n_shards,
    }
}

/// Run the federated online service on the real clock: per-tenant
/// producer threads feed the router while the calling thread runs the
/// serving loop. Returns when the duration has elapsed and all
/// admitted traffic has been served.
#[deprecated(
    since = "0.2.0",
    note = "construct through `session::Session::serve_federated(..).run(..)`"
)]
pub fn serve_federated(
    universe: &Universe,
    tenants: &TenantSet,
    engine: &SimEngine,
    policy: &dyn Policy,
    fcfg: &ServeFederationConfig,
) -> FederatedServeReport {
    serve_federated_impl(universe, tenants, engine, policy, fcfg, &Telemetry::off())
}

/// [`serve_federated`] with telemetry.
#[deprecated(
    since = "0.2.0",
    note = "construct through `session::Session::serve_federated(..).telemetry(..).run(..)`"
)]
pub fn serve_federated_with(
    universe: &Universe,
    tenants: &TenantSet,
    engine: &SimEngine,
    policy: &dyn Policy,
    fcfg: &ServeFederationConfig,
    tel: &Telemetry,
) -> FederatedServeReport {
    serve_federated_impl(universe, tenants, engine, policy, fcfg, tel)
}

/// The federation's total tier spec: the configured `common.tiers`
/// when tiered, else single-tier over the engine's whole cache budget.
fn fed_total_spec(fcfg: &ServeFederationConfig, engine: &SimEngine) -> TierSpec {
    fcfg.serve
        .common
        .tiers
        .unwrap_or_else(|| TierSpec::single(engine.config.cache_budget))
}

/// The real-clock federated driver behind [`serve_federated`]/
/// [`serve_federated_with`] and the Session API. The open-ended
/// real-clock run streams per-shard execution into [`ExecSummary`]
/// aggregates (`retain_raw = false`): a soak's memory stays flat no
/// matter how long it runs, and every report field reads from the
/// summaries.
///
/// [`ExecSummary`]: crate::coordinator::loop_::ExecSummary
pub(crate) fn serve_federated_impl(
    universe: &Universe,
    tenants: &TenantSet,
    engine: &SimEngine,
    policy: &dyn Policy,
    fcfg: &ServeFederationConfig,
    tel: &Telemetry,
) -> FederatedServeReport {
    validate(fcfg, tenants);
    let cfg = &fcfg.serve;
    tel.meta("serve-federated", cfg.n_tenants, fcfg.n_shards, fcfg.max_boost);
    let total_spec = fed_total_spec(fcfg, engine);
    let cached_sizes: Vec<u64> = universe.views.iter().map(|v| v.cached_bytes).collect();
    let scan_sizes: Vec<u64> = universe.views.iter().map(|v| v.scan_bytes).collect();
    // One engine clone serves every shard executor; budgets are handed
    // to executors explicitly and re-split on membership changes.
    let mut exec_engine = engine.clone();
    exec_engine.config.cache_budget = total_spec.split(fcfg.n_shards).budgets.ram;
    let exec_engine = exec_engine;
    let inputs = ServingInputs {
        universe,
        tenants,
        exec_engine: &exec_engine,
        policy,
        fcfg,
        total_spec,
        tel,
        retain_raw: false,
    };
    let (placement, live) = build_initial(&inputs, &cached_sizes);
    let router = ServeRouter::new(cfg.n_tenants, cached_sizes.clone(), tel.metrics_arc());
    sync_router(&router, &placement, &live, tel, 0.0, -1, "initial");

    let clock = RealTimeClock::new();
    let t_start = Instant::now();
    let out = std::thread::scope(|scope| {
        // Producers: one real-time Poisson generator per tenant,
        // routing each arrival through the shared placement.
        for i in 0..cfg.n_tenants {
            let mut tgen = cfg.tenant_generator(i, universe);
            let mut clk = clock.handle();
            let duration = cfg.duration_secs;
            let admission = cfg.admission;
            let router = &router;
            scope.spawn(move || {
                // Disjoint id ranges per producer.
                let mut next_id = (i as u64) << 32;
                let poll = 0.002f64;
                loop {
                    let now = clk.now();
                    if now >= duration {
                        break;
                    }
                    for q in tgen.generate_until(now, universe, &mut next_id) {
                        router.offer(q, admission);
                    }
                    clk.wait_until(now + poll);
                }
                router.producer_done();
            });
        }
        let mut clk = clock.handle();
        run_loop(
            &inputs,
            &mut clk,
            &router,
            placement,
            live,
            &cached_sizes,
            &scan_sizes,
            |_, _| router.producers_done(),
        )
    });
    for q in &out.all_queues {
        q.close();
    }
    finish(out, &inputs, t_start.elapsed().as_secs_f64())
}

/// The deterministic driver: the *same* serving loop on a [`SimClock`]
/// with arrivals generated inline — every simulated quantity is a pure
/// function of the config. This is what makes the federated serving
/// path testable: `--shards 1` equivalence against the single-node
/// `serve_sim`, reactive add/drain firing, and workload conservation
/// are all pinned in `rust/tests/federated_serving.rs`. Like
/// `serve_sim`, only [`AdmissionPolicy::Drop`] is supported (a blocked
/// offer would deadlock a single-threaded driver).
#[deprecated(
    since = "0.2.0",
    note = "construct through `session::Session::serve_federated(..).sim().run(..)`"
)]
pub fn serve_federated_sim(
    universe: &Universe,
    tenants: &TenantSet,
    engine: &SimEngine,
    policy: &dyn Policy,
    fcfg: &ServeFederationConfig,
) -> FederatedServeReport {
    serve_federated_sim_impl(universe, tenants, engine, policy, fcfg, &Telemetry::off())
}

/// [`serve_federated_sim`] with telemetry.
#[deprecated(
    since = "0.2.0",
    note = "construct through `session::Session::serve_federated(..).telemetry(..).sim().run(..)`"
)]
pub fn serve_federated_sim_with(
    universe: &Universe,
    tenants: &TenantSet,
    engine: &SimEngine,
    policy: &dyn Policy,
    fcfg: &ServeFederationConfig,
    tel: &Telemetry,
) -> FederatedServeReport {
    serve_federated_sim_impl(universe, tenants, engine, policy, fcfg, tel)
}

/// The deterministic federated driver behind [`serve_federated_sim`]/
/// [`serve_federated_sim_with`] and the Session API. Unlike the
/// real-clock driver this keeps raw per-query records
/// (`retain_raw = true`): the equivalence and conservation tests
/// compare them exactly, and a sim run's length is bounded by its
/// config.
pub(crate) fn serve_federated_sim_impl(
    universe: &Universe,
    tenants: &TenantSet,
    engine: &SimEngine,
    policy: &dyn Policy,
    fcfg: &ServeFederationConfig,
    tel: &Telemetry,
) -> FederatedServeReport {
    validate(fcfg, tenants);
    let cfg = &fcfg.serve;
    assert_eq!(
        cfg.admission,
        AdmissionPolicy::Drop,
        "the sim driver is single-threaded: block admission would deadlock"
    );
    tel.meta(
        "serve-federated-sim",
        cfg.n_tenants,
        fcfg.n_shards,
        fcfg.max_boost,
    );
    let total_spec = fed_total_spec(fcfg, engine);
    let cached_sizes: Vec<u64> = universe.views.iter().map(|v| v.cached_bytes).collect();
    let scan_sizes: Vec<u64> = universe.views.iter().map(|v| v.scan_bytes).collect();
    let mut exec_engine = engine.clone();
    exec_engine.config.cache_budget = total_spec.split(fcfg.n_shards).budgets.ram;
    let exec_engine = exec_engine;
    let inputs = ServingInputs {
        universe,
        tenants,
        exec_engine: &exec_engine,
        policy,
        fcfg,
        total_spec,
        tel,
        retain_raw: true,
    };
    let (placement, live) = build_initial(&inputs, &cached_sizes);
    let router = ServeRouter::new(cfg.n_tenants, cached_sizes.clone(), tel.metrics_arc());
    sync_router(&router, &placement, &live, tel, 0.0, -1, "initial");

    // Inline producers: same generators, seeds, and disjoint id ranges
    // as the real-time driver's threads.
    let mut gens: Vec<TenantGenerator> = (0..cfg.n_tenants)
        .map(|i| cfg.tenant_generator(i, universe))
        .collect();
    let mut next_ids: Vec<u64> = (0..cfg.n_tenants).map(|i| (i as u64) << 32).collect();
    let duration = cfg.duration_secs;
    let admission = cfg.admission;

    let t_start = Instant::now();
    let mut clock = SimClock::new();
    let out = run_loop(
        &inputs,
        &mut clock,
        &router,
        placement,
        live,
        &cached_sizes,
        &scan_sizes,
        |_, now| {
            let t_end = now.min(duration);
            // Offer in global arrival order (stable sort: ties keep
            // tenant order) so per-shard FIFO matches arrival order.
            let mut arrivals: Vec<Query> = Vec::new();
            for (i, g) in gens.iter_mut().enumerate() {
                arrivals.extend(g.generate_until(t_end, universe, &mut next_ids[i]));
            }
            arrivals.sort_by_key(|q| OrdF64(q.arrival));
            for q in arrivals {
                router.offer(q, admission);
            }
            now >= duration
        },
    );
    for q in &out.all_queues {
        q.close();
    }
    finish(out, &inputs, t_start.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::PolicyKind;
    use crate::coordinator::loop_::CommonConfig;
    use crate::sim::cluster::ClusterConfig;

    fn base_cfg() -> ServeConfig {
        ServeConfig {
            common: CommonConfig {
                batch_secs: 0.25,
                seed: 17,
                warm_start: true,
                ..CommonConfig::default()
            },
            duration_secs: 1.0,
            rate_per_sec: 300.0,
            n_tenants: 2,
            queue_capacity: 8192,
            admission: AdmissionPolicy::Drop,
            verbose: false,
        }
    }

    fn run_sim(fcfg: &ServeFederationConfig) -> FederatedServeReport {
        let universe = Universe::sales_only();
        let tenants = TenantSet::equal(fcfg.serve.n_tenants);
        let engine = SimEngine::new(ClusterConfig::default());
        let policy = PolicyKind::FastPf.build();
        serve_federated_sim_impl(
            &universe,
            &tenants,
            &engine,
            policy.as_ref(),
            fcfg,
            &Telemetry::off(),
        )
    }

    #[test]
    fn static_two_shard_sim_serve_conserves_and_records() {
        let fcfg = ServeFederationConfig::new(base_cfg(), 2);
        let r = run_sim(&fcfg);
        assert!(r.serve.completed > 50, "completed={}", r.serve.completed);
        // Conservation: everything admitted was served.
        assert_eq!(r.serve.completed, r.serve.admitted);
        assert_eq!(r.live_shards_final(), 2);
        assert_eq!(r.cluster.n_shards(), 2);
        assert!(r.membership_events().is_empty());
        assert_eq!(r.cluster.records.len(), r.serve.batches);
        // Per-shard runs partition the merged outcomes.
        let per: usize = r.cluster.per_shard.iter().map(|s| s.outcomes.len()).sum();
        assert_eq!(per as u64, r.serve.completed);
        assert!(!r.render().is_empty());
    }

    #[test]
    fn steady_load_inside_auto_bounds_keeps_membership_stable() {
        // Default bounds bracket the fair share: a federation serving
        // exactly its configured rate must neither grow nor drain.
        let mut fcfg = ServeFederationConfig::new(base_cfg(), 2);
        fcfg.auto = Some(
            crate::cluster::membership::AutoMembership::parse("auto")
                .unwrap()
                .resolve(fcfg.serve.rate_per_sec, fcfg.n_shards)
                .unwrap(),
        );
        let r = run_sim(&fcfg);
        assert!(
            r.membership_events().is_empty(),
            "steady load fired events: {:?}",
            r.membership_events()
        );
        assert_eq!(r.live_shards_final(), 2);
        assert_eq!(r.serve.completed, r.serve.admitted);
    }

    #[test]
    fn replication_spreads_future_arrivals() {
        let mut cfg = base_cfg();
        cfg.duration_secs = 1.5;
        let mut fcfg = ServeFederationConfig::new(cfg, 2);
        fcfg.replicate_hot = Some(0.05);
        let r = run_sim(&fcfg);
        // The Zipf-skewed Sales workload always has a dominating view.
        assert!(
            r.cluster.records.iter().any(|rec| !rec.replicated_views.is_empty()),
            "no view crossed the 5% replication threshold"
        );
        assert!(r.cluster.replication_bytes > 0);
        assert_eq!(r.serve.completed, r.serve.admitted);
    }

    #[test]
    fn replica_decay_retires_cold_replicas_on_live_path() {
        // A low threshold replicates marginal views that fluctuate
        // around it across cuts; with a one-batch streak any of them
        // going cold for a single cut must decay back out.
        let mut cfg = base_cfg();
        cfg.duration_secs = 2.0;
        let mut fcfg = ServeFederationConfig::new(cfg, 2);
        fcfg.replicate_hot = Some(0.05);
        fcfg.replica_decay = Some(1);
        let r = run_sim(&fcfg);
        assert!(
            r.cluster.records.iter().any(|rec| !rec.replicated_views.is_empty()),
            "no view ever replicated"
        );
        assert!(
            r.cluster.records.iter().any(|rec| !rec.decayed_views.is_empty()),
            "no replica ever decayed under a one-batch streak"
        );
        assert_eq!(r.serve.completed, r.serve.admitted);
    }

    #[test]
    fn periodic_rebalance_rehomes_by_demand_on_live_path() {
        // Initial homes are hash-placed; cumulative Zipf-skewed demand
        // packs differently, so a per-batch rebalance must fire at
        // least once and admitted work must still be conserved.
        let mut cfg = base_cfg();
        cfg.duration_secs = 1.5;
        let mut fcfg = ServeFederationConfig::new(cfg, 2);
        fcfg.rebalance_every = Some(1);
        let r = run_sim(&fcfg);
        assert!(
            r.cluster.records.iter().any(|rec| rec.rebalanced),
            "demand-driven rebalance never fired"
        );
        assert_eq!(r.serve.completed, r.serve.admitted);
    }
}

// Model-checked protocols over the *real* router (twin protocols with
// payload race detection live in `rust/tests/model_concurrency.rs`;
// these drive the production type itself through the `util::sync`
// shim). Compiled only under `--features model`.
#[cfg(all(test, feature = "model"))]
mod model_tests {
    use super::*;
    use crate::util::model;

    fn epoch_of(n: usize) -> RouterEpoch {
        RouterEpoch {
            ids: (0..n).collect(),
            home_masks: Vec::new(),
            replica_masks: Vec::new(),
            queues: Vec::new(),
            placement: None,
        }
    }

    /// Every interleaving of publish vs epoch-read on the real
    /// [`ServeRouter`]: the reader never sees null, never sees a torn
    /// live set, and the size it observes is monotone — the RCU
    /// append-only retention argument behind the `unsafe` deref in
    /// `epoch()`, machine-explored instead of hand-waved.
    #[test]
    fn model_router_epoch_reads_never_tear() {
        let report = model::check(|| {
            let router = Arc::new(ServeRouter::new(0, Vec::new(), Arc::new(Metrics::new())));
            let r = Arc::clone(&router);
            let reader = model::spawn(move || {
                let mut last = 0usize;
                for _ in 0..2 {
                    let ep = r.epoch();
                    assert!(ep.ids.len() <= 2, "torn epoch: {:?}", ep.ids);
                    assert!(ep.ids.len() >= last, "live set went backwards");
                    assert!(ep.ids.iter().enumerate().all(|(i, &id)| id == i));
                    last = ep.ids.len();
                }
            });
            router.publish(epoch_of(1));
            router.publish(epoch_of(2));
            reader.join().unwrap();
        });
        assert!(report.complete, "router model must explore exhaustively");
    }

    /// The `done_producers` Release/Acquire contract: an observer that
    /// sees the final producer count also sees everything the producer
    /// wrote before checking out (here: a race-detected cell standing
    /// in for the producer's last offered arrivals).
    #[test]
    fn model_producers_done_publishes_producer_writes() {
        let report = model::check(|| {
            let router = Arc::new(ServeRouter::new(1, Vec::new(), Arc::new(Metrics::new())));
            let work = Arc::new(model::RaceCell::new(0u64));
            let (r1, w1) = (Arc::clone(&router), Arc::clone(&work));
            let p1 = model::spawn(move || {
                w1.write(7);
                r1.producer_done();
            });
            // One observation, not a spin: in every interleaving where
            // the flag reports all producers done, their prior writes
            // must be visible — a race here fails the exploration.
            if router.producers_done() {
                assert_eq!(work.read(), 7);
            }
            p1.join().unwrap();
        });
        assert!(report.complete);
    }
}
