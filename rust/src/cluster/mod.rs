//! Sharded cache federation: multi-shard ROBUS coordinators with
//! global per-tenant fairness accounting (distinct from the
//! discrete-event `sim::cluster` executor model, which describes *one*
//! cluster's hardware).
//!
//! The view universe is partitioned across N cache shards
//! ([`placement`]); each shard runs the unmodified single-node
//! planner/executor machinery over the queries routed to it
//! ([`shard`]); the [`federation`] layer routes, replicates hot views,
//! rebalances homes by demand, and closes the loop with a
//! [`GlobalAccountant`] that turns cross-shard per-tenant utilities
//! into per-shard weight boosts — so sharing incentive and envy bounds
//! hold per tenant across the whole federation, not per shard.
//! [`metrics`] rolls the shards up into one `RunResult`-compatible view
//! plus federation-specific figures (fairness spread, replication
//! bytes, rebalance churn).
//!
//! Entry points: `robus cluster --shards N [--placement hash|pack]
//! [--replicate-hot T]` on the CLI,
//! [`crate::experiments::runner::run_federated`] programmatically, and
//! the `cluster_bench` bench target (`BENCH_cluster.json`).

pub mod federation;
pub mod metrics;
pub mod placement;
pub(crate) mod shard;

pub use federation::{FederationConfig, GlobalAccountant, ShardedCoordinator};
pub use metrics::{speedup_spread, ClusterRecord, ClusterResult, ShardSummary};
pub use placement::{Placement, PlacementStrategy};
