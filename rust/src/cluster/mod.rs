//! Sharded cache federation: multi-shard ROBUS coordinators with
//! global per-tenant fairness accounting and **elastic membership**
//! (distinct from the discrete-event `sim::cluster` executor model,
//! which describes *one* cluster's hardware).
//!
//! The view universe is partitioned across a live set of cache shards
//! ([`placement`]); each shard runs the unmodified single-node
//! planner/executor machinery over the queries routed to it
//! ([`shard`]); the [`federation`] layer routes, replicates hot views
//! (with replica decay), rebalances homes by demand, applies the
//! [`membership`] schedule — live shard add (with warm-up accounting),
//! drain-and-re-home remove, and fault-injection kill, each re-splitting
//! the cache budget to `total/N'` — and closes the loop with a
//! [`GlobalAccountant`] that turns cross-shard per-tenant utilities
//! into per-shard weight boosts, so sharing incentive and envy bounds
//! hold per tenant across the whole federation *through* membership
//! churn, not per shard. [`metrics`] rolls the (possibly ragged) shard
//! histories up into one `RunResult`-compatible view plus federation-
//! specific figures (fairness spread, attainment transients around
//! membership events, replication bytes, rebalance/drain churn).
//!
//! The [`serving`] layer wires the same federation into the real-time
//! admission path (`robus serve --shards N --membership auto[:lo,hi]`):
//! per-shard admission queues, live routing at arrival time, wall-clock
//! batch cuts, and *reactive* membership driven by sustained per-shard
//! load instead of a batch-index schedule — see DESIGN.md §2e.
//!
//! Both drivers execute their per-batch shard steps on the persistent
//! worker pool in [`runtime`]: a fixed set of `--workers` threads
//! created once per run over which every live shard's step multiplexes
//! as a message, so steady state spawns no threads at all (DESIGN.md
//! §2g).
//!
//! Entry points: `robus cluster --shards N [--placement hash|pack]
//! [--replicate-hot T] [--replica-decay K] [--membership
//! "add@40,kill@80"]` and `robus serve --shards N [--membership
//! auto[:lo,hi]]` on the CLI,
//! [`crate::experiments::runner::run_federated`] /
//! [`serving::serve_federated`] programmatically, and the
//! `cluster_bench` bench target (`BENCH_cluster.json`, including
//! the elasticity transient figures).

pub mod federation;
pub mod membership;
pub mod metrics;
pub mod placement;
pub(crate) mod runtime;
pub mod serving;
pub(crate) mod shard;

pub use federation::{FederationConfig, GlobalAccountant, ShardedCoordinator};
pub use membership::{
    AutoMembership, AutoMembershipSpec, BatchPoint, MembershipAction, MembershipEvent,
    MembershipPlan, ResolvedEvent,
};
pub use metrics::{
    speedup_spread, ClusterRecord, ClusterResult, MembershipChange, ShardSummary,
    TransientReport,
};
pub use placement::{Placement, PlacementStrategy};
// The free-function entry points stay re-exported for callers mid-
// migration; the deprecation they carry still reaches users through
// the original items.
#[allow(deprecated)]
pub use serving::{
    serve_federated, serve_federated_sim, serve_federated_sim_with, serve_federated_with,
    FederatedServeReport, ServeFederationConfig,
};
