//! Artifact loading and executable caching.
//!
//! Interchange is HLO *text* (see python/compile/aot.py):
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile`, executables compiled once per process and
//! cached; one `execute` call per batch solve.
//!
//! **Stub backend.** The offline build environment has no `xla` crate,
//! so this file ships the registry *interface* with a backend that
//! always reports itself unavailable: [`ArtifactRegistry::open`]
//! validates the artifacts directory and then returns a clear error, and
//! [`ArtifactRegistry::run_f32`] errors if ever reached. All call sites
//! (benches, examples, tests) treat an `Err` from `open` as "use the
//! native solvers", so the crate builds and tests green with no
//! artifacts and no PJRT toolchain. Restoring real execution means
//! re-adding the `xla` dependency and replacing the two `Err` bodies
//! with the compile/execute calls sketched in the comments.

use std::path::{Path, PathBuf};

use crate::runtime::{Result, RuntimeError};

/// The padded shapes every artifact was lowered with — must match
/// python/compile/kernels/__init__.py (validated via manifest.json).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PaddedShapes {
    pub nt: usize,
    pub nc: usize,
    pub nq: usize,
    pub nv: usize,
}

pub const SHAPES: PaddedShapes = PaddedShapes {
    nt: 16,
    nc: 64,
    nq: 128,
    nv: 64,
};

/// Artifact registry over one (would-be) PJRT CPU client.
#[derive(Debug)]
pub struct ArtifactRegistry {
    /// Artifacts directory the registry was opened against.
    pub dir: PathBuf,
}

impl ArtifactRegistry {
    /// Open the registry rooted at an artifacts directory. Fails if the
    /// directory does not exist (run `make artifacts`) — and, in this
    /// stub build, fails afterwards too because no PJRT backend is
    /// compiled in.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            return Err(RuntimeError::new(format!(
                "artifacts directory {} not found — run `make artifacts`",
                dir.display()
            )));
        }
        // Real backend: xla::PjRtClient::cpu() here.
        Err(RuntimeError::new(
            "PJRT backend unavailable in this build (no `xla` crate in the \
             offline registry) — compiled solvers disabled, native solvers in use",
        ))
    }

    /// Locate the default artifacts directory: $ROBUS_ARTIFACTS or
    /// ./artifacts (walking up from the current directory helps tests
    /// run from target subdirs).
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("ROBUS_ARTIFACTS") {
            return PathBuf::from(p);
        }
        let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let candidate = cur.join("artifacts");
            if candidate.is_dir() {
                return candidate;
            }
            if !cur.pop() {
                return PathBuf::from("artifacts");
            }
        }
    }

    /// Open at the default location.
    pub fn open_default() -> Result<Self> {
        Self::open(Self::default_dir())
    }

    /// Execute an entry point on f32 input buffers (each a flat vector
    /// with its dimensions). Returns the flat f32 outputs of the result
    /// tuple. Real backend: compile-and-cache the `{name}.hlo.txt`
    /// module, then one `execute` per call.
    pub fn run_f32(&self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let _ = inputs;
        Err(RuntimeError::new(format!(
            "cannot execute artifact {name:?}: PJRT backend unavailable in this build"
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_is_an_error() {
        assert!(ArtifactRegistry::open("/nonexistent/robus").is_err());
    }

    #[test]
    fn stub_backend_reports_unavailable() {
        // Even with a valid directory, the stub refuses to open with a
        // message pointing at the missing PJRT backend.
        let dir = std::env::temp_dir();
        let err = ArtifactRegistry::open(&dir).unwrap_err();
        assert!(err.to_string().contains("PJRT backend unavailable"), "{err}");
    }

    #[test]
    fn default_dir_falls_back_to_relative() {
        // No artifacts/ anywhere up the tree in the test environment and
        // no env override → the relative fallback path.
        let d = ArtifactRegistry::default_dir();
        assert!(d.as_os_str().to_string_lossy().contains("artifacts"));
    }

    #[test]
    fn shapes_are_the_lowered_padding() {
        assert_eq!(SHAPES.nt, 16);
        assert_eq!(SHAPES.nc, 64);
        assert_eq!(SHAPES.nq, 128);
        assert_eq!(SHAPES.nv, 64);
    }
}
