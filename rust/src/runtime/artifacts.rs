//! Artifact loading and executable caching.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile`. Executables are
//! compiled once per process and cached; one `execute` call per batch
//! solve.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

/// The padded shapes every artifact was lowered with — must match
/// python/compile/kernels/__init__.py (validated via manifest.json).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PaddedShapes {
    pub nt: usize,
    pub nc: usize,
    pub nq: usize,
    pub nv: usize,
}

pub const SHAPES: PaddedShapes = PaddedShapes {
    nt: 16,
    nc: 64,
    nq: 128,
    nv: 64,
};

/// Lazily compiled artifact registry over one PJRT CPU client.
pub struct ArtifactRegistry {
    client: xla::PjRtClient,
    dir: PathBuf,
    executables: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl ArtifactRegistry {
    /// Open the registry rooted at an artifacts directory. Fails if the
    /// directory does not exist (run `make artifacts`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            return Err(anyhow!(
                "artifacts directory {} not found — run `make artifacts`",
                dir.display()
            ));
        }
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self {
            client,
            dir,
            executables: Mutex::new(HashMap::new()),
        })
    }

    /// Locate the default artifacts directory: $ROBUS_ARTIFACTS or
    /// ./artifacts (walking up from the current directory helps tests
    /// run from target subdirs).
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("ROBUS_ARTIFACTS") {
            return PathBuf::from(p);
        }
        let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let candidate = cur.join("artifacts");
            if candidate.is_dir() {
                return candidate;
            }
            if !cur.pop() {
                return PathBuf::from("artifacts");
            }
        }
    }

    /// Open at the default location.
    pub fn open_default() -> Result<Self> {
        Self::open(Self::default_dir())
    }

    /// Compile (or fetch the cached) executable for an entry point.
    pub fn executable(
        &self,
        name: &str,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let mut cache = self.executables.lock().unwrap();
        if let Some(exe) = cache.get(name) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parse HLO text {path_str}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compile artifact {name}"))?,
        );
        cache.insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an entry point on f32 input buffers (each a flat vector
    /// with its dimensions). Returns the flat f32 outputs of the result
    /// tuple.
    pub fn run_f32(
        &self,
        name: &str,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<Vec<f32>>> {
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| -> Result<xla::Literal> {
                Ok(xla::Literal::vec1(data).reshape(dims)?)
            })
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let elements = result.to_tuple()?;
        elements
            .into_iter()
            .map(|lit| {
                lit.to_vec::<f32>()
                    .context("read f32 output")
            })
            .collect()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> ArtifactRegistry {
        ArtifactRegistry::open_default().expect("artifacts present (make artifacts)")
    }

    #[test]
    fn missing_dir_is_an_error() {
        assert!(ArtifactRegistry::open("/nonexistent/robus").is_err());
    }

    #[test]
    fn compile_cache_reuses_executable() {
        let reg = registry();
        let a = reg.executable("config_utils").unwrap();
        let b = reg.executable("config_utils").unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn config_utils_round_trip() {
        let reg = registry();
        let (nt, nc, nq, nv) = (SHAPES.nt, SHAPES.nc, SHAPES.nq, SHAPES.nv);
        let mut needs = vec![0f32; nq * nv];
        needs[0] = 1.0; // query 0 needs view 0
        let mut count = vec![0f32; nq];
        count[0] = 1.0;
        let mut qutil = vec![0f32; nq];
        qutil[0] = 5.0;
        let mut qtenant = vec![0f32; nt * nq];
        qtenant[0] = 1.0; // tenant 0 owns query 0
        let mut configs = vec![0f32; nv * nc];
        configs[0] = 1.0; // config 0 caches view 0
        let mut ustar = vec![0f32; nt];
        ustar[0] = 5.0;

        let outs = reg
            .run_f32(
                "config_utils",
                &[
                    (&needs, &[nq as i64, nv as i64]),
                    (&count, &[nq as i64]),
                    (&qutil, &[nq as i64]),
                    (&qtenant, &[nt as i64, nq as i64]),
                    (&configs, &[nv as i64, nc as i64]),
                    (&ustar, &[nt as i64]),
                ],
            )
            .unwrap();
        assert_eq!(outs.len(), 1);
        let v = &outs[0];
        assert_eq!(v.len(), nt * nc);
        // V[0, 0] = 1.0 (tenant 0 fully satisfied by config 0).
        assert!((v[0] - 1.0).abs() < 1e-6, "v00={}", v[0]);
        // All other live entries zero.
        assert!(v[1..].iter().all(|&x| x.abs() < 1e-6));
    }
}
