//! Compiled-solver policies: FASTPF and SIMPLEMMF backed by the
//! AOT-compiled JAX/Pallas artifacts. Configuration pruning (the exact
//! WELFARE knapsacks) stays on the Rust side; the per-batch convex solve
//! — the numeric hot loop — is one PJRT `execute` of a fori_loop'd
//! kernel (see python/compile/model.py).
//!
//! The native implementations in `alloc::fastpf` / `alloc::mmf_mw`
//! remain the correctness oracles: integration tests assert that the
//! compiled allocations match them within tolerance whenever a backend
//! is available (with the stub backend of `runtime::artifacts`,
//! `open_default` fails and every consumer falls back to the native
//! solvers).

use std::sync::Arc;

use crate::alloc::config_space::{ConfigId, ConfigSpace};
use crate::alloc::{Allocation, ConfigMask, Policy};
use crate::cache::tier::TierAssignment;
use crate::domain::utility::BatchUtilities;
use crate::runtime::artifacts::{ArtifactRegistry, SHAPES};
use crate::runtime::Result;
use crate::util::rng::Pcg64;

/// Shared handle to the registry plus pruning parameters.
#[derive(Clone)]
pub struct CompiledSolvers {
    registry: Arc<ArtifactRegistry>,
    /// Random weight vectors for pruning (≤ NC − a few, so the space
    /// fits the padded artifact shape).
    pub prune_vectors: usize,
}

impl CompiledSolvers {
    pub fn new(registry: Arc<ArtifactRegistry>) -> Self {
        Self {
            registry,
            prune_vectors: 40,
        }
    }

    pub fn open_default() -> Result<Self> {
        Ok(Self::new(Arc::new(ArtifactRegistry::open_default()?)))
    }

    /// Build the pruned space and the padded V matrix (+ masks). Spaces
    /// larger than NC are truncated to the NC highest-uniform-welfare
    /// configurations (keeping the per-tenant optima first).
    fn padded_problem(
        &self,
        batch: &BatchUtilities,
        rng: &mut Pcg64,
    ) -> (ConfigSpace, Vec<f32>, Vec<f32>, Vec<f32>) {
        assert!(
            batch.n_tenants <= SHAPES.nt,
            "batch has {} tenants > padded {}",
            batch.n_tenants,
            SHAPES.nt
        );
        let mut space = ConfigSpace::pruned(batch, self.prune_vectors, rng);
        if space.len() > SHAPES.nc {
            // Rank configs by total scaled utility, keep the best NC.
            let mut idx: Vec<usize> = (0..space.len()).collect();
            idx.sort_by(|&a, &b| {
                let sa: f64 = space.v_row(a).iter().sum();
                let sb: f64 = space.v_row(b).iter().sum();
                sb.partial_cmp(&sa).unwrap()
            });
            idx.truncate(SHAPES.nc);
            let configs: Vec<TierAssignment> =
                idx.iter().map(|&i| space.pair(ConfigId(i))).collect();
            space = ConfigSpace::from_pairs(batch, configs);
        }

        let mut v = vec![0f32; SHAPES.nt * SHAPES.nc];
        for (s, row) in space.rows().enumerate() {
            for (i, &vi) in row.iter().enumerate() {
                // Inactive tenants have V ≡ 1 in scaled_utilities; mask
                // them to 0 here (weights are 0 anyway).
                let val = if batch.u_star[i] > 0.0 { vi } else { 0.0 };
                v[i * SHAPES.nc + s] = val as f32;
            }
        }
        let mut wl = vec![0f32; SHAPES.nt];
        for i in 0..batch.n_tenants {
            if batch.u_star[i] > 0.0 {
                wl[i] = batch.weights[i] as f32;
            }
        }
        let mut cmask = vec![0f32; SHAPES.nc];
        for c in cmask.iter_mut().take(space.len()) {
            *c = 1.0;
        }
        (space, v, wl, cmask)
    }

    /// Execute one of the two solver artifacts and return the allocation
    /// vector over the space.
    fn run_solver(
        &self,
        entry: &str,
        v: &[f32],
        wl: &[f32],
        cmask: &[f32],
    ) -> Result<Vec<f64>> {
        let outs = self.registry.run_f32(
            entry,
            &[
                (v, &[SHAPES.nt as i64, SHAPES.nc as i64]),
                (wl, &[SHAPES.nt as i64]),
                (cmask, &[SHAPES.nc as i64]),
            ],
        )?;
        Ok(outs[0].iter().map(|&x| x as f64).collect())
    }

    fn allocate_with(
        &self,
        entry: &str,
        batch: &BatchUtilities,
        rng: &mut Pcg64,
    ) -> Allocation {
        if batch.active_tenants().is_empty() {
            return Allocation::deterministic(ConfigMask::empty(batch.n_views()));
        }
        let (space, v, wl, cmask) = self.padded_problem(batch, rng);
        let x = self
            .run_solver(entry, &v, &wl, &cmask)
            .expect("compiled solver execution failed");
        let pairs: Vec<(TierAssignment, f64)> =
            space.pairs().zip(x.iter().copied()).collect();
        if pairs.iter().map(|(_, p)| p).sum::<f64>() <= 0.0 {
            return Allocation::deterministic(ConfigMask::empty(batch.n_views()));
        }
        Allocation::from_weighted_pairs(pairs)
    }
}

impl CompiledSolvers {
    /// Batched restricted WELFARE via the compiled `welfare_batch`
    /// artifact: for each weight vector row, the index (within `space`)
    /// of the winning configuration. Cross-validated against
    /// [`ConfigSpace::restricted_welfare`] in tests.
    pub fn welfare_batch_picks(
        &self,
        space: &ConfigSpace,
        batch: &BatchUtilities,
        weights: &[Vec<f64>],
    ) -> Result<Vec<usize>> {
        const KW: usize = 64;
        assert!(weights.len() <= KW, "at most {KW} weight vectors per call");
        assert!(space.len() <= SHAPES.nc);
        let mut v = vec![0f32; SHAPES.nt * SHAPES.nc];
        for (s_idx, row) in space.rows().enumerate() {
            for (i, &vi) in row.iter().enumerate() {
                let val = if batch.u_star[i] > 0.0 { vi } else { 0.0 };
                v[i * SHAPES.nc + s_idx] = val as f32;
            }
        }
        let mut w = vec![0f32; KW * SHAPES.nt];
        for (k, row) in weights.iter().enumerate() {
            for (i, &wi) in row.iter().enumerate() {
                w[k * SHAPES.nt + i] = wi as f32;
            }
        }
        let mut cmask = vec![0f32; SHAPES.nc];
        for c in cmask.iter_mut().take(space.len()) {
            *c = 1.0;
        }
        let outs = self.registry.run_f32(
            "welfare_batch",
            &[
                (&w, &[KW as i64, SHAPES.nt as i64]),
                (&v, &[SHAPES.nt as i64, SHAPES.nc as i64]),
                (&cmask, &[SHAPES.nc as i64]),
            ],
        )?;
        let onehot = &outs[0];
        Ok(weights
            .iter()
            .enumerate()
            .map(|(k, _)| {
                onehot[k * SHAPES.nc..(k + 1) * SHAPES.nc]
                    .iter()
                    .position(|&x| x > 0.5)
                    .unwrap_or(0)
            })
            .collect())
    }
}

/// FASTPF via the compiled `pf_solve` artifact.
pub struct AcceleratedFastPf(pub CompiledSolvers);

impl Policy for AcceleratedFastPf {
    fn name(&self) -> &'static str {
        "FASTPF-XLA"
    }

    fn allocate(&self, batch: &BatchUtilities, rng: &mut Pcg64) -> Allocation {
        self.0.allocate_with("pf_solve", batch, rng)
    }
}

/// SIMPLEMMF via the compiled `mmf_mw` artifact.
pub struct AcceleratedSimpleMmf(pub CompiledSolvers);

impl Policy for AcceleratedSimpleMmf {
    fn name(&self) -> &'static str {
        "MMF-XLA"
    }

    fn allocate(&self, batch: &BatchUtilities, rng: &mut Pcg64) -> Allocation {
        self.0.allocate_with("mmf_mw", batch, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::fastpf::FastPf;
    use crate::alloc::testing::{table2, table4, table5};
    use crate::alloc::Policy;

    /// None when no PJRT backend/artifacts are available (the stub
    /// build): every test below then passes vacuously — the native
    /// solvers are the only implementation to validate.
    fn solvers() -> Option<CompiledSolvers> {
        CompiledSolvers::open_default().ok()
    }

    #[test]
    fn compiled_pf_matches_native_on_tables() {
        let Some(s) = solvers() else { return };
        let native = FastPf::default();
        for (name, b) in [
            ("table2", table2()),
            ("table4", table4(4)),
            ("table5", table5()),
        ] {
            let a_c = AcceleratedFastPf(s.clone()).allocate(&b, &mut Pcg64::new(1));
            let a_n = native.allocate(&b, &mut Pcg64::new(1));
            let vc = a_c.expected_scaled_utilities(&b);
            let vn = a_n.expected_scaled_utilities(&b);
            for (i, (c, n)) in vc.iter().zip(&vn).enumerate() {
                assert!(
                    (c - n).abs() < 2e-2,
                    "{name} tenant {i}: compiled {c} vs native {n}"
                );
            }
        }
    }

    #[test]
    fn compiled_mmf_reaches_maxmin_floor() {
        let Some(s) = solvers() else { return };
        let b = table4(4);
        let a = AcceleratedSimpleMmf(s).allocate(&b, &mut Pcg64::new(2));
        let v = a.expected_scaled_utilities(&b);
        let min = v.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min >= 0.5 * 0.8, "v={v:?}");
    }

    #[test]
    fn welfare_batch_matches_native_argmax() {
        let Some(s) = solvers() else { return };
        let b = table4(4);
        let mut rng = Pcg64::new(4);
        let space = ConfigSpace::pruned(&b, 20, &mut rng);
        let weights: Vec<Vec<f64>> = (0..10)
            .map(|_| rng.unit_weight_vector(b.n_tenants))
            .collect();
        let picks = s.welfare_batch_picks(&space, &b, &weights).unwrap();
        for (w, &pick) in weights.iter().zip(&picks) {
            let native = space.restricted_welfare(w).0;
            // Scores can tie; require equal score rather than equal index.
            let score = |s_idx: usize| -> f64 {
                w.iter()
                    .zip(space.v_row(s_idx))
                    .map(|(wi, vi)| wi * vi)
                    .sum()
            };
            assert!(
                (score(pick) - score(native)).abs() < 1e-5,
                "pick {pick} score {} vs native {native} score {}",
                score(pick),
                score(native)
            );
        }
    }

    #[test]
    fn compiled_allocations_are_normalized_and_feasible() {
        let Some(s) = solvers() else { return };
        let b = table2();
        for policy in [
            &AcceleratedFastPf(s.clone()) as &dyn Policy,
            &AcceleratedSimpleMmf(s.clone()) as &dyn Policy,
        ] {
            let a = policy.allocate(&b, &mut Pcg64::new(3));
            assert!((a.total_probability() - 1.0).abs() < 1e-6);
            for c in &a.configs {
                assert!(b.size_of(c) <= b.budget + 1e-6);
            }
        }
    }
}
