//! PJRT runtime: load the AOT-compiled solver artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and execute
//! them from the L3 hot path. Python never runs at request time.
//!
//! The execution backend needs the external `xla` crate (PJRT CPU
//! client + HLO text loading), which the offline build environment does
//! not provide. This module therefore compiles as a dependency-free
//! *stub*: the registry API, padded shapes, and the accelerated policy
//! wrappers are all real (and exercised by the marshalling code paths),
//! but opening the registry reports the backend as unavailable, and
//! every caller — benches, the e2e example, the cross-validation tests —
//! degrades gracefully to the native Rust solvers. Wiring a PJRT-enabled
//! toolchain back in only touches `artifacts.rs` (see DESIGN.md §3).

pub mod artifacts;
pub mod solvers;

pub use artifacts::{ArtifactRegistry, PaddedShapes, SHAPES};
pub use solvers::{AcceleratedFastPf, AcceleratedSimpleMmf, CompiledSolvers};

/// Runtime error type (the offline build has no `anyhow`).
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

impl RuntimeError {
    pub fn new(msg: impl Into<String>) -> Self {
        Self(msg.into())
    }
}

/// Result alias for runtime operations.
pub type Result<T> = std::result::Result<T, RuntimeError>;
