//! PJRT runtime: load the AOT-compiled solver artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and execute
//! them from the L3 hot path. Python never runs at request time.

pub mod artifacts;
pub mod solvers;

pub use artifacts::{ArtifactRegistry, PaddedShapes};
pub use solvers::{AcceleratedFastPf, AcceleratedSimpleMmf, CompiledSolvers};
