//! Empirical fairness-property verification (Table 6): Sharing
//! Incentive, Pareto Efficiency, and the randomized core (Definition 3).

pub mod properties;

pub use properties::{
    find_blocking_coalition, find_pareto_improvement, sharing_incentive_violations,
    PropertyReport,
};
