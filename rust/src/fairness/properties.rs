//! Checkers for the fairness desiderata of §3. All checks are relative
//! to an explicit configuration space (LP-searchable deviations): a
//! violation found is a real violation; absence of a violation certifies
//! the property *within the given space* (use a richly pruned space).
//!
//! - **SI** (§3.2): V_i(x) ≥ λ_i/Σλ for every active tenant.
//! - **PE** (§3.2): no allocation y over the space with U_i(y) ≥ U_i(x)
//!   for all i and > for one — found via LP maximizing total utility
//!   subject to no-tenant-worse.
//! - **Core** (Definition 3): no coalition T and allocation y with
//!   ‖y‖ = Σ_{i∈T} λ_i / Σλ improving every member (one strictly) —
//!   searched by LP over all 2^N−1 coalitions.

use crate::alloc::config_space::ConfigSpace;
use crate::alloc::Allocation;
use crate::domain::utility::BatchUtilities;
use crate::solver::simplex::{Cmp, Lp, LpResult};

/// Outcome summary for Table 6-style reporting.
#[derive(Debug, Clone)]
pub struct PropertyReport {
    pub sharing_incentive: bool,
    pub pareto_efficient: bool,
    pub core: bool,
}

/// Tenants whose expected scaled utility falls below their entitled
/// share (active tenants only). Empty ⇒ SI holds.
pub fn sharing_incentive_violations(
    alloc: &Allocation,
    batch: &BatchUtilities,
    tol: f64,
) -> Vec<(usize, f64, f64)> {
    let v = alloc.expected_scaled_utilities(batch);
    let total_w: f64 = batch.weights.iter().sum();
    batch
        .active_tenants()
        .into_iter()
        .filter_map(|i| {
            let entitled = batch.weights[i] / total_w;
            if v[i] + tol < entitled {
                Some((i, v[i], entitled))
            } else {
                None
            }
        })
        .collect()
}

/// Search the space for a Pareto improvement on `alloc`. Returns the
/// improving allocation vector (over `space`) if one exists.
///
/// LP: max Σ_i V_i(y) s.t. V_i(y) ≥ V_i(x) ∀ active i, ‖y‖ ≤ 1, y ≥ 0.
/// An optimum exceeding Σ_i V_i(x) by more than `tol` implies some tenant
/// strictly improved with none hurt.
pub fn find_pareto_improvement(
    alloc: &Allocation,
    batch: &BatchUtilities,
    space: &ConfigSpace,
    tol: f64,
) -> Option<Vec<f64>> {
    let active = batch.active_tenants();
    if active.is_empty() || space.is_empty() {
        return None;
    }
    let current = alloc.expected_scaled_utilities(batch);
    let m = space.len();
    let mut obj = vec![0.0; m];
    for &i in &active {
        for (o, row) in obj.iter_mut().zip(space.rows()) {
            *o += row[i];
        }
    }
    let mut lp = Lp::new(obj);
    for &i in &active {
        let row: Vec<f64> = space.rows().map(|r| r[i]).collect();
        lp.constrain(row, Cmp::Ge, current[i]);
    }
    lp.constrain(vec![1.0; m], Cmp::Le, 1.0);
    match lp.solve() {
        LpResult::Optimal { value, x } => {
            let base: f64 = active.iter().map(|&i| current[i]).sum();
            if value > base + tol {
                Some(x)
            } else {
                None
            }
        }
        _ => None,
    }
}

/// Search all coalitions for a blocking deviation (Definition 3 with the
/// §3.4 weighted endowments). Returns the first blocking coalition and
/// its deviation allocation.
pub fn find_blocking_coalition(
    alloc: &Allocation,
    batch: &BatchUtilities,
    space: &ConfigSpace,
    tol: f64,
) -> Option<(Vec<usize>, Vec<f64>)> {
    let active = batch.active_tenants();
    let n = active.len();
    if n == 0 || n > 16 || space.is_empty() {
        return None;
    }
    let current = alloc.expected_scaled_utilities(batch);
    let total_w: f64 = batch.weights.iter().sum();
    let m = space.len();

    for mask in 1u32..(1 << n) {
        let coalition: Vec<usize> = (0..n)
            .filter(|j| mask & (1 << j) != 0)
            .map(|j| active[j])
            .collect();
        let endowment: f64 =
            coalition.iter().map(|&i| batch.weights[i]).sum::<f64>() / total_w;

        // LP: max Σ_{i∈T} V_i(y) s.t. V_i(y) ≥ V_i(x) ∀ i∈T,
        //     ‖y‖ ≤ endowment, y ≥ 0.
        let mut obj = vec![0.0; m];
        for &i in &coalition {
            for (o, row) in obj.iter_mut().zip(space.rows()) {
                *o += row[i];
            }
        }
        let mut lp = Lp::new(obj);
        for &i in &coalition {
            let row: Vec<f64> = space.rows().map(|r| r[i]).collect();
            lp.constrain(row, Cmp::Ge, current[i]);
        }
        lp.constrain(vec![1.0; m], Cmp::Le, endowment);
        if let LpResult::Optimal { value, x } = lp.solve() {
            let base: f64 = coalition.iter().map(|&i| current[i]).sum();
            if value > base + tol {
                return Some((coalition, x));
            }
        }
    }
    None
}

/// Full Table 6-style property report for an allocation.
pub fn property_report(
    alloc: &Allocation,
    batch: &BatchUtilities,
    space: &ConfigSpace,
    tol: f64,
) -> PropertyReport {
    PropertyReport {
        sharing_incentive: sharing_incentive_violations(alloc, batch, tol).is_empty(),
        pareto_efficient: find_pareto_improvement(alloc, batch, space, tol).is_none(),
        core: find_blocking_coalition(alloc, batch, space, tol).is_none(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::testing::{table3, table4, table5};
    use crate::alloc::{
        fastpf::FastPf, mmf::MaxMinFair, optp::UtilityMax, rsd::RandomSerialDictatorship,
        Policy,
    };
    use crate::util::rng::Pcg64;

    const TOL: f64 = 1e-4;

    fn rich_space(batch: &BatchUtilities) -> ConfigSpace {
        ConfigSpace::pruned(batch, 100, &mut Pcg64::new(12345))
    }

    #[test]
    fn rsd_si_but_not_pe_on_table3() {
        // Table 6 row 1: RSD is SI; Table 3 shows it is not PE (caching S
        // with probability 1 dominates).
        let b = table3();
        let a = RandomSerialDictatorship::default().allocate(&b, &mut Pcg64::new(0));
        let space = rich_space(&b);
        assert!(sharing_incentive_violations(&a, &b, TOL).is_empty());
        assert!(
            find_pareto_improvement(&a, &b, &space, TOL).is_some(),
            "RSD on Table 3 must admit a Pareto improvement"
        );
    }

    #[test]
    fn optp_pe_but_not_si_on_table5() {
        // Table 6 row 2: utility maximization is PE but not SI.
        let b = table5();
        let a = UtilityMax.allocate(&b, &mut Pcg64::new(0));
        let space = rich_space(&b);
        let viol = sharing_incentive_violations(&a, &b, TOL);
        assert!(!viol.is_empty(), "OPTP must violate SI on Table 5");
        assert!(find_pareto_improvement(&a, &b, &space, TOL).is_none());
    }

    #[test]
    fn mmf_si_pe_but_not_core_on_table4() {
        // Table 6 row 3: MMF is SI+PE; §3.3 shows its Table 4 allocation
        // (½R, ½S) is outside the core — the N−1 R-tenants can pool their
        // (N−1)/N endowment and all get (N−1)/N > ½.
        let b = table4(4);
        let a = MaxMinFair::default().allocate(&b, &mut Pcg64::new(0));
        let space = rich_space(&b);
        assert!(sharing_incentive_violations(&a, &b, TOL).is_empty());
        assert!(find_pareto_improvement(&a, &b, &space, TOL).is_none());
        let blocking = find_blocking_coalition(&a, &b, &space, 1e-3);
        assert!(blocking.is_some(), "MMF on Table 4 must be blocked");
        let (coalition, _) = blocking.unwrap();
        // The blocking coalition is (a subset of) the R-tenants {0,1,2}.
        assert!(coalition.iter().all(|&i| i < 3), "coalition={coalition:?}");
        assert!(coalition.len() >= 2);
    }

    #[test]
    fn fastpf_satisfies_all_three() {
        // Table 6 row 4: PF is SI + PE + core (Theorem 2).
        for b in [table3(), table4(4), table5()] {
            let a = FastPf::default().allocate(&b, &mut Pcg64::new(0));
            let space = rich_space(&b);
            let report = property_report(&a, &b, &space, 2e-3);
            assert!(report.sharing_incentive, "PF must be SI");
            assert!(report.pareto_efficient, "PF must be PE");
            assert!(report.core, "PF must be in the core");
        }
    }

    #[test]
    fn core_implies_si_and_pe_relationships() {
        // Singleton coalitions encode SI; the grand coalition encodes PE.
        // An allocation violating SI must therefore be blocked.
        let b = table5();
        let a = UtilityMax.allocate(&b, &mut Pcg64::new(0));
        let space = rich_space(&b);
        let blocked = find_blocking_coalition(&a, &b, &space, TOL);
        assert!(blocked.is_some());
        let (coalition, _) = blocked.unwrap();
        assert_eq!(coalition, vec![0], "tenant A alone blocks OPTP");
    }
}
