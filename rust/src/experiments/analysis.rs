//! Non-simulation analyses: the §4.3 pruning-error sweep, the Lemma 1/2
//! utility comparisons, the Table 6 property audit, and the Figure 3/7
//! data series.

use crate::alloc::config_space::ConfigSpace;
use crate::alloc::fastpf::FastPf;
use crate::alloc::ConfigMask;
use crate::alloc::mmf::MaxMinFair;
use crate::alloc::mmf_mw::SimpleMmfMw;
use crate::domain::query::{Query, QueryId};
use crate::domain::sales::SalesCatalog;
use crate::domain::tenant::{TenantId, TenantSet};
use crate::domain::utility::BatchUtilities;
use crate::domain::view::ViewId;
use crate::solver::gradient::GradientConfig;
use crate::solver::simplex::{Cmp, Lp, LpResult};
use crate::util::rng::{Pcg64, Zipf};

/// A random batch problem mimicking a Sales batch: `n_tenants` tenants,
/// Zipf access over the 30-view catalog, Poisson-ish query counts.
pub fn random_sales_batch(n_tenants: usize, rng: &mut Pcg64) -> BatchUtilities {
    let catalog = SalesCatalog::build();
    let tenants = TenantSet::equal(n_tenants);
    let zipfs: Vec<Zipf> = (0..n_tenants)
        .map(|_| Zipf::randomized(30, 1.0, rng))
        .collect();
    let mut queries = Vec::new();
    let mut qid = 0u64;
    for t in 0..n_tenants {
        let n_queries = 1 + rng.poisson(2.0) as usize;
        for _ in 0..n_queries {
            let d = zipfs[t].sample(rng);
            let view = catalog.view_of_dataset[d];
            qid += 1;
            queries.push(Query {
                id: QueryId(qid),
                tenant: TenantId(t),
                arrival: 0.0,
                template: format!("scan-{d}"),
                required_views: vec![ViewId(view.0)],
                bytes_read: catalog.views.get(view).scan_bytes,
                compute_cost: 0.0,
            });
        }
    }
    let budget = 6.0 * (1u64 << 30) as f64;
    BatchUtilities::build(&tenants, &catalog.views, budget, &queries, None)
}

/// Max-min objective of the restricted LP (Program 3) over a space.
pub fn restricted_maxmin_value(space: &ConfigSpace, batch: &BatchUtilities) -> f64 {
    let active = batch.active_tenants();
    if active.is_empty() || space.is_empty() {
        return 0.0;
    }
    let m = space.len();
    let mut obj = vec![0.0; m + 1];
    obj[m] = 1.0;
    let mut lp = Lp::new(obj);
    for &i in &active {
        let mut row: Vec<f64> = space.rows().map(|r| r[i]).collect();
        row.push(-1.0);
        lp.constrain(row, Cmp::Ge, 0.0);
    }
    let mut norm = vec![1.0; m];
    norm.push(0.0);
    lp.constrain(norm, Cmp::Le, 1.0);
    match lp.solve() {
        LpResult::Optimal { value, .. } => value,
        _ => 0.0,
    }
}

/// The §4.3 approximation-error experiment: over `n_batches` random
/// 5-tenant batches, the mean relative error of the restricted-LP
/// SIMPLEMMF objective using `m` random weight vectors vs Algorithm 2's
/// objective. The paper reports 10.4% / 1.4% / 0.6% for m = 5 / 25 / 50.
pub fn pruning_error(m_vectors: usize, n_batches: usize, seed: u64) -> f64 {
    let mut rng = Pcg64::new(seed);
    let reference = SimpleMmfMw {
        epsilon: 0.1,
        max_iters: 800,
    };
    let mut total_err = 0.0;
    let mut counted = 0usize;
    for _ in 0..n_batches {
        let batch = random_sales_batch(5, &mut rng);
        if batch.active_tenants().len() < 2 {
            continue;
        }
        // Reference objective: Algorithm 2's achieved min rate.
        let ref_alloc = crate::alloc::Allocation::from_weighted_pairs(reference.solve(&batch));
        let v_ref = ref_alloc.expected_scaled_utilities(&batch);
        let ref_min = batch
            .active_tenants()
            .iter()
            .map(|&i| v_ref[i])
            .fold(f64::INFINITY, f64::min);
        if ref_min <= 1e-9 {
            continue;
        }
        // Restricted LP on a pruned space WITHOUT the per-tenant solo
        // optima shortcut (pure random vectors, as in the paper's sweep).
        let mut space =
            ConfigSpace::from_configs(&batch, vec![ConfigMask::empty(batch.n_views())]);
        let mut welfare = batch.welfare_template();
        for _ in 0..m_vectors {
            let w = rng.unit_weight_vector(batch.n_tenants);
            let sol = welfare.solve(&w);
            space.push(&batch, ConfigMask::from_bools(&sol.selected));
        }
        let lp_min = restricted_maxmin_value(&space, &batch);
        let err = ((ref_min - lp_min) / ref_min).max(0.0);
        total_err += err;
        counted += 1;
    }
    if counted == 0 {
        0.0
    } else {
        total_err / counted as f64
    }
}

/// Lemma 1: on a grouped instance with group sizes `n_i`, total utility
/// of PF (= Σ n_i²/N) vs MMF (= N/k). Returns (pf_total, mmf_total),
/// both computed by the actual solvers (not the closed forms).
pub fn grouped_instance_totals(group_sizes: &[usize]) -> (f64, f64) {
    let k = group_sizes.len();
    let rows: Vec<Vec<u64>> = group_sizes
        .iter()
        .enumerate()
        .flat_map(|(g, &n)| {
            std::iter::repeat_with(move || {
                let mut r = vec![0u64; k];
                r[g] = 1;
                r
            })
            .take(n)
        })
        .collect();
    let refs: Vec<&[u64]> = rows.iter().map(|r| r.as_slice()).collect();
    let batch = crate::alloc::instances::matrix_instance(&refs, 1.0);
    let mut rng = Pcg64::new(7);
    let space = ConfigSpace::pruned(&batch, 50, &mut rng);
    let x_pf = FastPf::solve_over(&space, &batch, &GradientConfig::default());
    let (x_mmf, _) = MaxMinFair::solve_over(&space, &batch);
    let total = |x: &[f64]| -> f64 {
        (0..batch.n_tenants)
            .map(|i| space.scaled_utility(i, x))
            .sum()
    };
    (total(&x_pf), total(&x_mmf))
}

/// Figure 3 series: the 30 candidate Sales view sizes in MB, descending.
pub fn figure3_view_sizes_mb() -> Vec<(String, f64)> {
    let catalog = SalesCatalog::build();
    catalog
        .views
        .iter()
        .map(|v| (v.name.clone(), v.cached_bytes as f64 / (1u64 << 20) as f64))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pruning_error_decreases_with_more_vectors() {
        // Scaled-down version of the paper's 5/25/50 sweep.
        let e5 = pruning_error(5, 25, 11);
        let e25 = pruning_error(25, 25, 11);
        let e50 = pruning_error(50, 25, 11);
        assert!(e5 >= e25 - 0.02, "e5={e5} e25={e25}");
        assert!(e25 >= e50 - 0.01, "e25={e25} e50={e50}");
        assert!(e50 < 0.05, "e50={e50}");
        assert!(e5 < 0.5, "e5={e5}");
    }

    #[test]
    fn lemma1_pf_dominates_mmf_on_grouped() {
        // k = 3 groups of sizes 3, 2, 1 (N = 6): PF total = Σn²/N = 14/6,
        // MMF total = N/k = 2.
        let (pf, mmf) = grouped_instance_totals(&[3, 2, 1]);
        assert!(pf >= mmf - 1e-3, "pf={pf} mmf={mmf}");
        assert!((mmf - 2.0).abs() < 0.05, "mmf={mmf}");
        assert!((pf - 14.0 / 6.0).abs() < 0.05, "pf={pf}");
    }

    #[test]
    fn lemma2_two_tenants_random_instances() {
        use crate::util::proptest::{check, no_shrink};
        check(
            20,
            |rng| {
                let rows: Vec<Vec<u64>> = (0..2)
                    .map(|_| (0..3).map(|_| rng.below(5)).collect())
                    .collect();
                rows
            },
            no_shrink,
            |rows| {
                if rows.iter().all(|r| r.iter().all(|&u| u == 0)) {
                    return Ok(());
                }
                let refs: Vec<&[u64]> = rows.iter().map(|r| r.as_slice()).collect();
                let batch = crate::alloc::instances::matrix_instance(&refs, 1.0);
                if batch.active_tenants().len() < 2 {
                    return Ok(());
                }
                let mut rng = Pcg64::new(3);
                let space = ConfigSpace::pruned(&batch, 60, &mut rng);
                let x_pf = FastPf::solve_over(&space, &batch, &GradientConfig::default());
                let (x_mmf, _) = MaxMinFair::solve_over(&space, &batch);
                let total = |x: &[f64]| -> f64 {
                    (0..2).map(|i| space.scaled_utility(i, x)).sum()
                };
                let (pf, mmf) = (total(&x_pf), total(&x_mmf));
                if pf + 5e-3 < mmf {
                    return Err(format!("Lemma 2 violated: pf={pf} mmf={mmf}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn figure3_range() {
        let sizes = figure3_view_sizes_mb();
        assert_eq!(sizes.len(), 30);
        let max = sizes.iter().map(|(_, s)| *s).fold(0.0, f64::max);
        let min = sizes.iter().map(|(_, s)| *s).fold(f64::INFINITY, f64::min);
        assert!((max - 3686.0).abs() < 1.0);
        assert!((min - 118.0).abs() < 1.0);
    }
}
