//! Report formatting: paper-style text tables and machine-readable JSON
//! for EXPERIMENTS.md and the results/ directory.

use crate::coordinator::metrics::MetricsSummary;
use crate::experiments::runner::ExperimentOutput;
use crate::util::json::Json;

/// Paper-style appendix table (e.g. Table 15) for one experiment: rows
/// are metrics, columns are policies.
pub fn appendix_table(out: &ExperimentOutput) -> String {
    let mut s = format!("## {}\n\n", out.setup.name);
    s.push_str(&format!(
        "| Metric | {} |\n",
        out.summaries
            .iter()
            .map(|m| m.policy)
            .collect::<Vec<_>>()
            .join(" | ")
    ));
    s.push_str(&format!(
        "|---|{}\n",
        "---|".repeat(out.summaries.len())
    ));
    let row = |name: &str, f: &dyn Fn(&MetricsSummary) -> f64| -> String {
        format!(
            "| {} | {} |\n",
            name,
            out.summaries
                .iter()
                .map(|m| format!("{:.2}", f(m)))
                .collect::<Vec<_>>()
                .join(" | ")
        )
    };
    s.push_str(&row("Throughput(/min)", &|m| m.throughput_per_min));
    s.push_str(&row("Avg cache util.", &|m| m.avg_cache_utilization));
    s.push_str(&row("Hit ratio", &|m| m.hit_ratio));
    s.push_str(&row("Fairness index", &|m| m.fairness_index));
    s
}

/// JSON record of one experiment (all summaries + per-batch series).
pub fn to_json(out: &ExperimentOutput) -> Json {
    let summaries = Json::Array(
        out.summaries
            .iter()
            .map(|m| {
                Json::from_pairs(vec![
                    ("policy", Json::String(m.policy.to_string())),
                    ("throughput_per_min", Json::Number(m.throughput_per_min)),
                    ("avg_cache_util", Json::Number(m.avg_cache_utilization)),
                    ("hit_ratio", Json::Number(m.hit_ratio)),
                    ("fairness_index", Json::Number(m.fairness_index)),
                ])
            })
            .collect(),
    );
    let runs = Json::Array(
        out.runs
            .iter()
            .map(|r| {
                Json::from_pairs(vec![
                    ("policy", Json::String(r.policy.to_string())),
                    ("queries", Json::Number(r.outcomes.len() as f64)),
                    ("end_time", Json::Number(r.end_time)),
                    ("mean_wait", Json::Number(r.mean_wait())),
                    (
                        "mean_solve_ms",
                        Json::Number(
                            1e3 * r
                                .batches
                                .iter()
                                .map(|b| b.solve_secs)
                                .sum::<f64>()
                                / r.batches.len().max(1) as f64,
                        ),
                    ),
                ])
            })
            .collect(),
    );
    Json::from_pairs(vec![
        ("experiment", Json::String(out.setup.name.clone())),
        ("batches", Json::Number(out.setup.n_batches as f64)),
        ("batch_secs", Json::Number(out.setup.batch_secs)),
        ("seed", Json::Number(out.setup.seed as f64)),
        ("summaries", summaries),
        ("runs", runs),
    ])
}

/// Write a JSON report under `dir` (created if needed).
pub fn write_json(out: &ExperimentOutput, dir: &str) -> std::io::Result<String> {
    std::fs::create_dir_all(dir)?;
    let path = format!("{dir}/{}.json", out.setup.name);
    std::fs::write(&path, to_json(out).to_string_pretty())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::runner::run_experiment;
    use crate::experiments::setups;

    #[test]
    fn table_and_json_render() {
        let setup = setups::tenant_scaling()[0].clone().quick(4);
        let out = run_experiment(&setup);
        let table = appendix_table(&out);
        assert!(table.contains("Throughput(/min)"));
        assert!(table.contains("STATIC"));
        assert!(table.contains("FASTPF"));
        let json = to_json(&out);
        assert_eq!(
            json.get("experiment").unwrap().as_str().unwrap(),
            "tenants-2"
        );
        assert_eq!(json.get("summaries").unwrap().as_array().unwrap().len(), 4);
        // Round-trips through the parser.
        let text = json.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), json);
    }
}
