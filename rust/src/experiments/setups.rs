//! The evaluation setups of §5.3 (Tables 8–14), expressed declaratively.

use crate::cache::tier::TierSpec;
use crate::workload::spec::{AccessSpec, TenantSpec, WindowSpec};

/// Sales tenants use the §5.1 hot/cold local-window mechanism: every
/// ~2 simulated minutes a tenant drills into a small candidate subset
/// drawn from its global Zipf (the [31]/[53] re-access pattern). This is
/// what creates per-batch cache contention between tenants.
fn sales_tenant(g: usize, mean_interarrival: f64) -> TenantSpec {
    TenantSpec::new(AccessSpec::g(g), mean_interarrival).with_window(WindowSpec {
        mean_secs: 120.0,
        std_secs: 30.0,
        // Wide enough that one tenant's working set (~5 GB) exceeds its
        // STATIC partition and the tenants' combined demand exceeds the
        // 6 GB budget — the contention regime the paper evaluates.
        candidates: 8,
    })
}

/// Which data universe a setup runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UniverseKind {
    Mixed,
    SalesOnly,
}

/// One experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentSetup {
    pub name: String,
    pub universe: UniverseKind,
    pub tenant_specs: Vec<TenantSpec>,
    /// Tenant weights (equal in all §5.3 experiments).
    pub weights: Vec<f64>,
    pub batch_secs: f64,
    pub n_batches: usize,
    pub stateful_gamma: Option<f64>,
    pub seed: u64,
    /// Carry solver state across batches (see `alloc::WarmState`). Off
    /// by default so every published table replays bit-identically.
    pub warm_start: bool,
    /// Two-tier (RAM + SSD) cache spec. `None` (the default) runs the
    /// bit-identical single-tier path over the engine's cache budget.
    pub tiers: Option<TierSpec>,
}

impl ExperimentSetup {
    fn new(
        name: &str,
        universe: UniverseKind,
        specs: Vec<TenantSpec>,
        batch_secs: f64,
        n_batches: usize,
    ) -> Self {
        let n = specs.len();
        Self {
            name: name.to_string(),
            universe,
            tenant_specs: specs,
            weights: vec![1.0; n],
            batch_secs,
            n_batches,
            stateful_gamma: None,
            seed: 42,
            warm_start: false,
            tiers: None,
        }
    }

    /// Scale batches down for quick runs/tests.
    pub fn quick(mut self, n_batches: usize) -> Self {
        self.n_batches = n_batches;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_warm_start(mut self, on: bool) -> Self {
        self.warm_start = on;
        self
    }

    pub fn with_tiers(mut self, tiers: Option<TierSpec>) -> Self {
        self.tiers = tiers;
        self
    }
}

/// Table 8: mixed-workload data-sharing setups 𝒢₁–𝒢₄ (4 tenants, batch
/// 40 s, Poisson(20), 30 batches).
pub fn data_sharing_mixed() -> Vec<ExperimentSetup> {
    let dist_sets: [Vec<AccessSpec>; 4] = [
        vec![AccessSpec::h1(), AccessSpec::h1(), AccessSpec::h1(), AccessSpec::h1()],
        vec![AccessSpec::h1(), AccessSpec::h1(), AccessSpec::h1(), AccessSpec::g(1)],
        vec![AccessSpec::h1(), AccessSpec::h1(), AccessSpec::g(1), AccessSpec::g(2)],
        vec![AccessSpec::h1(), AccessSpec::g(1), AccessSpec::g(2), AccessSpec::g(3)],
    ];
    dist_sets
        .into_iter()
        .enumerate()
        .map(|(i, dists)| {
            let specs = dists
                .into_iter()
                .map(|d| match d {
                    AccessSpec::SalesZipf { skew_seed, .. } => {
                        sales_tenant((skew_seed - 1000) as usize, 20.0)
                    }
                    h => TenantSpec::new(h, 20.0),
                })
                .collect();
            ExperimentSetup::new(
                &format!("mixed-G{}", i + 1),
                UniverseKind::Mixed,
                specs,
                40.0,
                30,
            )
        })
        .collect()
}

/// Table 9/10: Sales-only data-sharing setups 𝒢₁–𝒢₄.
pub fn data_sharing_sales() -> Vec<ExperimentSetup> {
    let dist_sets: [[usize; 4]; 4] = [
        [1, 1, 1, 1],
        [1, 1, 1, 2],
        [1, 1, 2, 3],
        [1, 2, 3, 4],
    ];
    dist_sets
        .into_iter()
        .enumerate()
        .map(|(i, gs)| {
            let specs = gs.into_iter().map(|g| sales_tenant(g, 20.0)).collect();
            ExperimentSetup::new(
                &format!("sales-G{}", i + 1),
                UniverseKind::SalesOnly,
                specs,
                40.0,
                30,
            )
        })
        .collect()
}

/// Tables 11/12: arrival-rate variance setups low/mid/high (2 tenants,
/// {g₁, g₂}, batch 72 s, 30 batches).
pub fn arrival_rates() -> Vec<ExperimentSetup> {
    [("low", 12.0, 12.0), ("mid", 18.0, 8.0), ("high", 24.0, 6.0)]
        .into_iter()
        .map(|(name, l1, l2)| {
            let specs = vec![sales_tenant(1, l1), sales_tenant(2, l2)];
            ExperimentSetup::new(
                &format!("arrival-{name}"),
                UniverseKind::SalesOnly,
                specs,
                72.0,
                30,
            )
        })
        .collect()
}

/// Tables 13/14: tenant-count scaling (2/4/8 tenants, all g₁, arrival
/// rate scaled to keep per-batch query count constant, batch 40 s).
pub fn tenant_scaling() -> Vec<ExperimentSetup> {
    [(2usize, 10.0), (4, 20.0), (8, 40.0)]
        .into_iter()
        .map(|(n, mean)| {
            let specs = (0..n).map(|_| sales_tenant(1, mean)).collect();
            ExperimentSetup::new(
                &format!("tenants-{n}"),
                UniverseKind::SalesOnly,
                specs,
                40.0,
                30,
            )
        })
        .collect()
}

/// Ablation (DESIGN.md §Calibration): sweep the hot/cold window width.
/// Narrow windows fit inside STATIC's partitions (no contention); wide
/// windows exceed the shared budget — the regime where fair shared
/// allocation matters. Validates the candidates=8 calibration choice.
pub fn window_ablation() -> Vec<(usize, ExperimentSetup)> {
    [2usize, 4, 8, 16]
        .into_iter()
        .map(|cands| {
            let specs: Vec<TenantSpec> = (1..=4)
                .map(|g| {
                    TenantSpec::new(AccessSpec::g(g), 20.0).with_window(WindowSpec {
                        mean_secs: 120.0,
                        std_secs: 30.0,
                        candidates: cands,
                    })
                })
                .collect();
            (
                cands,
                ExperimentSetup::new(
                    &format!("window-{cands}"),
                    UniverseKind::SalesOnly,
                    specs,
                    40.0,
                    30,
                ),
            )
        })
        .collect()
}

/// Figure 11: convergence run (4 tenants, 50 batches).
pub fn convergence() -> ExperimentSetup {
    let specs = (1..=4).map(|g| sales_tenant(g, 20.0)).collect();
    ExperimentSetup::new("convergence", UniverseKind::SalesOnly, specs, 40.0, 50)
}

/// Figure 12: batch-size × cache-state sweep (4 equi-paced tenants).
pub fn batch_size_sweep() -> Vec<(ExperimentSetup, Option<f64>)> {
    let mut out = Vec::new();
    for &batch in &[20.0, 40.0, 80.0, 160.0] {
        for &gamma in &[None, Some(2.0)] {
            let specs: Vec<TenantSpec> =
                (1..=4).map(|g| sales_tenant(g, 20.0)).collect();
            let mut s = ExperimentSetup::new(
                &format!(
                    "batch-{}s-{}",
                    batch,
                    if gamma.is_some() { "stateful" } else { "stateless" }
                ),
                UniverseKind::SalesOnly,
                specs,
                batch,
                30,
            );
            s.stateful_gamma = gamma;
            out.push((s, gamma));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table8_shapes() {
        let setups = data_sharing_mixed();
        assert_eq!(setups.len(), 4);
        for s in &setups {
            assert_eq!(s.tenant_specs.len(), 4);
            assert_eq!(s.batch_secs, 40.0);
            assert_eq!(s.n_batches, 30);
        }
        // G1 is all-TPC-H; G4 has one TPC-H + three distinct Sales skews.
        assert!(setups[0]
            .tenant_specs
            .iter()
            .all(|t| t.access == AccessSpec::h1()));
        let g4: Vec<_> = setups[3].tenant_specs.iter().map(|t| &t.access).collect();
        assert_eq!(g4[0], &AccessSpec::h1());
        assert_ne!(g4[1], g4[2]);
    }

    #[test]
    fn arrival_setups_match_table11() {
        let setups = arrival_rates();
        assert_eq!(setups.len(), 3);
        assert_eq!(setups[2].tenant_specs[0].mean_interarrival, 24.0);
        assert_eq!(setups[2].tenant_specs[1].mean_interarrival, 6.0);
        assert!(setups.iter().all(|s| s.batch_secs == 72.0));
    }

    #[test]
    fn tenant_scaling_keeps_batch_load_constant() {
        for s in tenant_scaling() {
            let rate: f64 = s
                .tenant_specs
                .iter()
                .map(|t| 1.0 / t.mean_interarrival)
                .sum();
            assert!((rate - 0.2).abs() < 1e-12, "{}: rate={rate}", s.name);
        }
    }

    #[test]
    fn batch_sweep_has_eight_cells() {
        let cells = batch_size_sweep();
        assert_eq!(cells.len(), 8);
        assert!(cells.iter().any(|(s, g)| s.batch_secs == 160.0 && g.is_some()));
    }
}
