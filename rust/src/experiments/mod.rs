//! Experiment definitions and runners regenerating every table and
//! figure of the paper's evaluation (§5, Appendix A). See DESIGN.md §4
//! for the experiment index.

pub mod analysis;
pub mod report;
pub mod runner;
pub mod setups;

pub use runner::{run_experiment, ExperimentOutput};
pub use setups::{ExperimentSetup, UniverseKind};
