//! Experiment execution: run one setup under every compared policy on
//! an identical workload (same generator seed), compute the §5.2 metrics
//! against the STATIC baseline, and return table-ready rows.
//!
//! Every run of the policy × seed grid is deterministic (fixed generator
//! and policy seeds) and independent, so [`run_with_policies`] fans the
//! policies across `std::thread::scope` workers; [`run_seed_grid`]
//! additionally fans whole setups per seed. The parallel runner is
//! output-identical to [`run_with_policies_serial`] — same seeds ⇒ same
//! simulated outcomes, configurations, and metrics — which the tests
//! assert. The one exception is `BatchRecord::solve_secs`: it is *host*
//! wall-clock and can read higher under thread contention, so profile
//! solve latency with the serial runner (or `solver_bench`).

use crate::alloc::{Policy, PolicyKind};
use crate::cluster::{ClusterResult, FederationConfig};
use crate::coordinator::loop_::{CommonConfig, Coordinator, CoordinatorConfig, RunResult};
use crate::coordinator::metrics::{fairness_index, MetricsSummary};
use crate::domain::tenant::TenantSet;
use crate::experiments::setups::{ExperimentSetup, UniverseKind};
use crate::sim::cluster::ClusterConfig;
use crate::sim::engine::SimEngine;
use crate::telemetry::Telemetry;
use crate::workload::generator::WorkloadGenerator;
use crate::workload::universe::Universe;

/// The four policies compared throughout §5.3.
pub fn default_policies() -> Vec<PolicyKind> {
    vec![
        PolicyKind::Static,
        PolicyKind::Mmf,
        PolicyKind::FastPf,
        PolicyKind::Optp,
    ]
}

/// All runs of one experiment plus derived summaries.
pub struct ExperimentOutput {
    pub setup: ExperimentSetup,
    pub runs: Vec<RunResult>,
    pub summaries: Vec<MetricsSummary>,
}

impl ExperimentOutput {
    pub fn run_for(&self, policy: &str) -> Option<&RunResult> {
        self.runs.iter().find(|r| r.policy == policy)
    }
}

pub fn build_universe(kind: UniverseKind) -> Universe {
    match kind {
        UniverseKind::Mixed => Universe::mixed(),
        UniverseKind::SalesOnly => Universe::sales_only(),
    }
}

/// Everything a `Coordinator` is built from, derived from one setup.
/// (The coordinator itself borrows the universe, so callers assemble it
/// on their own stack frame.)
fn coordinator_parts(
    setup: &ExperimentSetup,
) -> (Universe, TenantSet, SimEngine, CoordinatorConfig) {
    let universe = build_universe(setup.universe);
    let mut tenants = TenantSet::new();
    for (i, w) in setup.weights.iter().enumerate() {
        tenants.add(&format!("tenant-{i}"), *w);
    }
    let engine = SimEngine::new(ClusterConfig::default());
    let config = CoordinatorConfig {
        common: CommonConfig {
            batch_secs: setup.batch_secs,
            stateful_gamma: setup.stateful_gamma,
            seed: setup.seed,
            warm_start: setup.warm_start,
            tiers: setup.tiers,
        },
        n_batches: setup.n_batches,
    };
    (universe, tenants, engine, config)
}

fn summarize(setup: &ExperimentSetup, runs: Vec<RunResult>) -> ExperimentOutput {
    let baseline = &runs[0];
    let summaries = runs
        .iter()
        .map(|r| MetricsSummary::compute(r, baseline))
        .collect();
    ExperimentOutput {
        setup: setup.clone(),
        runs,
        summaries,
    }
}

/// Run a setup under explicit policies, one worker thread per policy;
/// the first run is the fairness baseline (pass STATIC first for the
/// paper's Equation 5 semantics). Each worker builds its own workload
/// generator from the setup seed, so arrivals are identical across
/// policies and across serial/parallel execution.
pub fn run_with_policies(
    setup: &ExperimentSetup,
    policies: &[Box<dyn Policy>],
) -> ExperimentOutput {
    run_with_policies_tel(setup, policies, &Telemetry::off())
}

/// [`run_with_policies`] with telemetry. `Telemetry` is `Sync`, so the
/// per-policy worker threads share one handle; spans carry the batch
/// index, and ticks ride whichever worker crosses a snapshot boundary
/// first.
pub fn run_with_policies_tel(
    setup: &ExperimentSetup,
    policies: &[Box<dyn Policy>],
    tel: &Telemetry,
) -> ExperimentOutput {
    let (universe, tenants, engine, config) = coordinator_parts(setup);
    let coordinator = Coordinator::new(&universe, tenants, engine, config);

    let runs: Vec<RunResult> = std::thread::scope(|scope| {
        let handles: Vec<_> = policies
            .iter()
            .map(|p| {
                let coordinator = &coordinator;
                let universe = &universe;
                scope.spawn(move || {
                    // Fresh generator with the same seed → identical
                    // workload for every policy.
                    let mut gen = WorkloadGenerator::new(
                        setup.tenant_specs.clone(),
                        universe,
                        setup.seed,
                    );
                    coordinator.run_impl(&mut gen, p.as_ref(), tel)
                })
            })
            .collect();
        // Join in spawn order: output order matches the policy order.
        handles
            .into_iter()
            .map(|h| h.join().expect("policy run thread panicked"))
            .collect()
    });

    summarize(setup, runs)
}

/// The pre-parallelism reference runner: identical outputs to
/// [`run_with_policies`], one policy at a time. Kept for equivalence
/// tests and for profiling single solves without thread noise.
pub fn run_with_policies_serial(
    setup: &ExperimentSetup,
    policies: &[Box<dyn Policy>],
) -> ExperimentOutput {
    let (universe, tenants, engine, config) = coordinator_parts(setup);
    let coordinator = Coordinator::new(&universe, tenants, engine, config);

    let runs: Vec<RunResult> = policies
        .iter()
        .map(|p| {
            let mut gen = WorkloadGenerator::new(
                setup.tenant_specs.clone(),
                &universe,
                setup.seed,
            );
            coordinator.run_impl(&mut gen, p.as_ref(), &Telemetry::off())
        })
        .collect();

    summarize(setup, runs)
}

/// Like [`run_with_policies_serial`], but each policy's run uses the
/// pipelined solve/execute coordinator (solver thread runs `depth`
/// batches ahead of execution). Bit-identical simulated outputs to the
/// serial reference — `rust/tests/pipeline_equivalence.rs` asserts this
/// over the whole experiment grid.
pub fn run_with_policies_pipelined(
    setup: &ExperimentSetup,
    policies: &[Box<dyn Policy>],
    depth: usize,
) -> ExperimentOutput {
    run_with_policies_pipelined_tel(setup, policies, depth, &Telemetry::off())
}

/// [`run_with_policies_pipelined`] with telemetry (one span per retired
/// batch, executor-side).
pub fn run_with_policies_pipelined_tel(
    setup: &ExperimentSetup,
    policies: &[Box<dyn Policy>],
    depth: usize,
    tel: &Telemetry,
) -> ExperimentOutput {
    let (universe, tenants, engine, config) = coordinator_parts(setup);
    let coordinator = Coordinator::new(&universe, tenants, engine, config);

    let runs: Vec<RunResult> = policies
        .iter()
        .map(|p| {
            let mut gen = WorkloadGenerator::new(
                setup.tenant_specs.clone(),
                &universe,
                setup.seed,
            );
            coordinator.run_pipelined_impl(&mut gen, p.as_ref(), depth, tel)
        })
        .collect();

    summarize(setup, runs)
}

/// Run one setup through the sharded federation (`cluster::`): same
/// workload and policy seeds as the single-node runners, so a 1-shard
/// federation is bit-identical to [`Coordinator::run`] and multi-shard
/// runs are directly comparable to the serial baseline. The federation
/// config may carry an elastic [`crate::cluster::MembershipPlan`];
/// validate it against the setup with [`validate_membership`] first —
/// an invalid schedule panics inside the run.
pub fn run_federated(
    setup: &ExperimentSetup,
    fed: &FederationConfig,
    policy: &dyn Policy,
) -> ClusterResult {
    run_federated_tel(setup, fed, policy, &Telemetry::off())
}

/// [`run_federated`] with telemetry (per-shard spans, membership and
/// clamp events, warm-invalidation audit trail).
pub fn run_federated_tel(
    setup: &ExperimentSetup,
    fed: &FederationConfig,
    policy: &dyn Policy,
    tel: &Telemetry,
) -> ClusterResult {
    let (universe, tenants, engine, config) = coordinator_parts(setup);
    let mut gen = WorkloadGenerator::new(setup.tenant_specs.clone(), &universe, setup.seed);
    crate::session::Session::federated(&universe, tenants, engine)
        .config(config)
        .federation(fed.clone())
        .telemetry(tel)
        .run(&mut gen, policy)
}

/// Resolve a federation config's membership plan against a setup's
/// batch count (the CLI/bench front door): surfaces schedule errors —
/// events past the run, dead targets, dropping below one live shard —
/// as `Err` instead of a panic inside [`run_federated`].
pub fn validate_membership(
    setup: &ExperimentSetup,
    fed: &FederationConfig,
) -> Result<(), String> {
    fed.membership
        .resolve(fed.n_shards, setup.n_batches)
        .map(|_| ())
}

/// Run with the default §5.3 policy set (policies fanned across threads).
pub fn run_experiment(setup: &ExperimentSetup) -> ExperimentOutput {
    let policies: Vec<Box<dyn Policy>> = default_policies()
        .into_iter()
        .map(|k| k.build())
        .collect();
    run_with_policies(setup, &policies)
}

/// Fan one setup across a seed grid, one worker thread per seed, with
/// the default policy set run serially inside each worker (the grid is
/// the outer parallelism axis; seeds × policies cells total). Output
/// order matches `seeds`.
pub fn run_seed_grid(setup: &ExperimentSetup, seeds: &[u64]) -> Vec<ExperimentOutput> {
    std::thread::scope(|scope| {
        let handles: Vec<_> = seeds
            .iter()
            .map(|&seed| {
                let setup = setup.clone().with_seed(seed);
                scope.spawn(move || {
                    let policies: Vec<Box<dyn Policy>> = default_policies()
                        .into_iter()
                        .map(|k| k.build())
                        .collect();
                    run_with_policies_serial(&setup, &policies)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("seed grid thread panicked"))
            .collect()
    })
}

/// Figure 11 series: fairness index as a function of batch count for one
/// policy (computed on prefixes of the run).
pub fn convergence_series(
    policy_run: &RunResult,
    baseline: &RunResult,
    every: usize,
) -> Vec<(usize, f64)> {
    let n = policy_run.batches.len();
    let mut series = Vec::new();
    let mut b = every.max(1);
    while b <= n {
        series.push((
            b,
            crate::coordinator::metrics::fairness_index_prefix(policy_run, baseline, b),
        ));
        b += every.max(1);
    }
    series
}

/// Convenience wrapper used by tests: fairness of run vs baseline.
pub fn fairness_of(output: &ExperimentOutput, policy: &str) -> f64 {
    let run = output.run_for(policy).expect("policy present");
    fairness_index(run, &output.runs[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::setups;

    /// One quick Sales G1 run exercising the full stack; checks the
    /// paper's qualitative shape: shared policies beat STATIC on
    /// throughput, cache utilization, and hit ratio.
    #[test]
    fn sales_g1_shape_holds() {
        let setup = setups::data_sharing_sales()[0].clone().quick(8);
        let out = run_experiment(&setup);
        assert_eq!(out.summaries.len(), 4);
        let by_name = |n: &str| {
            out.summaries
                .iter()
                .find(|s| s.policy == n)
                .unwrap()
                .clone()
        };
        let stat = by_name("STATIC");
        let pf = by_name("FASTPF");
        let optp = by_name("OPTP");
        assert!(
            pf.throughput_per_min >= stat.throughput_per_min,
            "FASTPF {} < STATIC {}",
            pf.throughput_per_min,
            stat.throughput_per_min
        );
        assert!(pf.hit_ratio > stat.hit_ratio);
        assert!(pf.avg_cache_utilization > stat.avg_cache_utilization);
        assert!(optp.hit_ratio > stat.hit_ratio);
        // STATIC is the fairness baseline → index 1 by definition.
        assert!((stat.fairness_index - 1.0).abs() < 1e-9);
    }

    #[test]
    fn convergence_series_monotone_length() {
        let setup = setups::convergence().quick(10);
        let out = run_experiment(&setup);
        let pf = out.run_for("FASTPF").unwrap();
        let series = convergence_series(pf, &out.runs[0], 2);
        assert_eq!(series.len(), 5);
        assert!(series.iter().all(|(_, j)| (0.0..=1.0 + 1e-9).contains(j)));
    }

    /// The tentpole guarantee: the threaded runner is bit-identical to
    /// the serial reference — same seeds ⇒ same sampled configurations,
    /// same outcomes, same metrics.
    #[test]
    fn parallel_runner_matches_serial_exactly() {
        let setup = setups::data_sharing_sales()[1].clone().quick(5);
        let policies = || -> Vec<Box<dyn crate::alloc::Policy>> {
            default_policies().into_iter().map(|k| k.build()).collect()
        };
        let par = run_with_policies(&setup, &policies());
        let ser = run_with_policies_serial(&setup, &policies());
        assert_eq!(par.runs.len(), ser.runs.len());
        for (p, s) in par.runs.iter().zip(&ser.runs) {
            assert_eq!(p.policy, s.policy);
            assert_eq!(p.end_time, s.end_time);
            assert_eq!(p.outcomes.len(), s.outcomes.len());
            for (po, so) in p.outcomes.iter().zip(&s.outcomes) {
                assert_eq!(po.id, so.id);
                assert_eq!(po.start, so.start);
                assert_eq!(po.finish, so.finish);
                assert_eq!(po.from_cache, so.from_cache);
            }
            for (pb, sb) in p.batches.iter().zip(&s.batches) {
                assert_eq!(pb.config, sb.config);
                assert_eq!(pb.cache_utilization, sb.cache_utilization);
            }
        }
        for (p, s) in par.summaries.iter().zip(&ser.summaries) {
            assert_eq!(p.throughput_per_min, s.throughput_per_min);
            assert_eq!(p.hit_ratio, s.hit_ratio);
            assert_eq!(p.fairness_index, s.fairness_index);
        }
    }

    /// Seed-grid fan-out: one output per seed, in seed order, each
    /// identical to a direct run with that seed.
    #[test]
    fn seed_grid_matches_direct_runs() {
        let setup = setups::tenant_scaling()[0].clone().quick(3);
        let seeds = [11u64, 12];
        let grid = run_seed_grid(&setup, &seeds);
        assert_eq!(grid.len(), 2);
        for (out, &seed) in grid.iter().zip(&seeds) {
            assert_eq!(out.setup.seed, seed);
            let direct = run_experiment(&setup.clone().with_seed(seed));
            for (g, d) in out.runs.iter().zip(&direct.runs) {
                assert_eq!(g.policy, d.policy);
                assert_eq!(g.outcomes.len(), d.outcomes.len());
                for (go, d_o) in g.outcomes.iter().zip(&d.outcomes) {
                    assert_eq!(go.id, d_o.id);
                    assert_eq!(go.finish, d_o.finish);
                }
            }
        }
    }
}
