//! Experiment execution: run one setup under every compared policy on
//! an identical workload (same generator seed), compute the §5.2 metrics
//! against the STATIC baseline, and return table-ready rows.

use crate::alloc::{Policy, PolicyKind};
use crate::coordinator::loop_::{Coordinator, CoordinatorConfig, RunResult};
use crate::coordinator::metrics::{fairness_index, MetricsSummary};
use crate::domain::tenant::TenantSet;
use crate::experiments::setups::{ExperimentSetup, UniverseKind};
use crate::sim::cluster::ClusterConfig;
use crate::sim::engine::SimEngine;
use crate::workload::generator::WorkloadGenerator;
use crate::workload::universe::Universe;

/// The four policies compared throughout §5.3.
pub fn default_policies() -> Vec<PolicyKind> {
    vec![
        PolicyKind::Static,
        PolicyKind::Mmf,
        PolicyKind::FastPf,
        PolicyKind::Optp,
    ]
}

/// All runs of one experiment plus derived summaries.
pub struct ExperimentOutput {
    pub setup: ExperimentSetup,
    pub runs: Vec<RunResult>,
    pub summaries: Vec<MetricsSummary>,
}

impl ExperimentOutput {
    pub fn run_for(&self, policy: &str) -> Option<&RunResult> {
        self.runs.iter().find(|r| r.policy == policy)
    }
}

pub fn build_universe(kind: UniverseKind) -> Universe {
    match kind {
        UniverseKind::Mixed => Universe::mixed(),
        UniverseKind::SalesOnly => Universe::sales_only(),
    }
}

/// Run a setup under explicit policies; the first run is the fairness
/// baseline (pass STATIC first for the paper's Equation 5 semantics).
pub fn run_with_policies(
    setup: &ExperimentSetup,
    policies: &[Box<dyn Policy>],
) -> ExperimentOutput {
    let universe = build_universe(setup.universe);
    let mut tenants = TenantSet::new();
    for (i, w) in setup.weights.iter().enumerate() {
        tenants.add(&format!("tenant-{i}"), *w);
    }
    let engine = SimEngine::new(ClusterConfig::default());
    let config = CoordinatorConfig {
        batch_secs: setup.batch_secs,
        n_batches: setup.n_batches,
        stateful_gamma: setup.stateful_gamma,
        seed: setup.seed,
    };
    let coordinator = Coordinator::new(&universe, tenants, engine, config);

    let runs: Vec<RunResult> = policies
        .iter()
        .map(|p| {
            // Fresh generator with the same seed → identical workload.
            let mut gen = WorkloadGenerator::new(
                setup.tenant_specs.clone(),
                &universe,
                setup.seed,
            );
            coordinator.run(&mut gen, p.as_ref())
        })
        .collect();

    let baseline = &runs[0];
    let summaries = runs
        .iter()
        .map(|r| MetricsSummary::compute(r, baseline))
        .collect();

    ExperimentOutput {
        setup: setup.clone(),
        runs,
        summaries,
    }
}

/// Run with the default §5.3 policy set.
pub fn run_experiment(setup: &ExperimentSetup) -> ExperimentOutput {
    let policies: Vec<Box<dyn Policy>> = default_policies()
        .into_iter()
        .map(|k| k.build())
        .collect();
    run_with_policies(setup, &policies)
}

/// Figure 11 series: fairness index as a function of batch count for one
/// policy (computed on prefixes of the run).
pub fn convergence_series(
    policy_run: &RunResult,
    baseline: &RunResult,
    every: usize,
) -> Vec<(usize, f64)> {
    let n = policy_run.batches.len();
    let mut series = Vec::new();
    let mut b = every.max(1);
    while b <= n {
        series.push((
            b,
            crate::coordinator::metrics::fairness_index_prefix(policy_run, baseline, b),
        ));
        b += every.max(1);
    }
    series
}

/// Convenience wrapper used by tests: fairness of run vs baseline.
pub fn fairness_of(output: &ExperimentOutput, policy: &str) -> f64 {
    let run = output.run_for(policy).expect("policy present");
    fairness_index(run, &output.runs[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::setups;

    /// One quick Sales G1 run exercising the full stack; checks the
    /// paper's qualitative shape: shared policies beat STATIC on
    /// throughput, cache utilization, and hit ratio.
    #[test]
    fn sales_g1_shape_holds() {
        let setup = setups::data_sharing_sales()[0].clone().quick(8);
        let out = run_experiment(&setup);
        assert_eq!(out.summaries.len(), 4);
        let by_name = |n: &str| {
            out.summaries
                .iter()
                .find(|s| s.policy == n)
                .unwrap()
                .clone()
        };
        let stat = by_name("STATIC");
        let pf = by_name("FASTPF");
        let optp = by_name("OPTP");
        assert!(
            pf.throughput_per_min >= stat.throughput_per_min,
            "FASTPF {} < STATIC {}",
            pf.throughput_per_min,
            stat.throughput_per_min
        );
        assert!(pf.hit_ratio > stat.hit_ratio);
        assert!(pf.avg_cache_utilization > stat.avg_cache_utilization);
        assert!(optp.hit_ratio > stat.hit_ratio);
        // STATIC is the fairness baseline → index 1 by definition.
        assert!((stat.fairness_index - 1.0).abs() < 1e-9);
    }

    #[test]
    fn convergence_series_monotone_length() {
        let setup = setups::convergence().quick(10);
        let out = run_experiment(&setup);
        let pf = out.run_for("FASTPF").unwrap();
        let series = convergence_series(pf, &out.runs[0], 2);
        assert_eq!(series.len(), 5);
        assert!(series.iter().all(|(_, j)| (0.0..=1.0 + 1e-9).contains(j)));
    }
}
