//! The two-tier (RAM + SSD) cache model: per-tier capacities, the
//! per-tier cost model (hit latency, load cost, write/demotion cost per
//! byte), and the `(view, tier)` assignment type the solver emits.
//!
//! The degenerate configuration — SSD capacity 0 — is the correctness
//! anchor of the whole tier feature: every code path that takes a
//! [`TierSpec`] with `ssd == 0` must route through exactly the
//! single-tier logic that existed before tiers, bit for bit (same float
//! operations, same RNG consumption). `rust/tests/tier_equivalence.rs`
//! pins this.
//!
//! Production framing (ROADMAP): a RAM tier sized for the hot 5% backed
//! by a ~20× larger SSD tier. An SSD hit is slower than a RAM hit but
//! far faster than a disk scan; the solver prices that with the
//! [`TierCostModel::ssd_discount`] factor — the fraction of the
//! disk-vs-RAM I/O saving an SSD hit still captures.

use crate::util::mask::ConfigMask;

/// Bytes per GB as f64, for the ms-per-GB cost conversions.
const GB_F: f64 = (1u64 << 30) as f64;

/// Which tier a resident view occupies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    Ram,
    Ssd,
}

/// Per-tier byte capacities. `ssd == 0` selects single-tier mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierBudgets {
    pub ram: u64,
    pub ssd: u64,
}

impl TierBudgets {
    /// The pre-tier configuration: everything in RAM, no SSD.
    pub fn single(ram: u64) -> Self {
        Self { ram, ssd: 0 }
    }

    /// True when the SSD tier is absent (the bit-identical legacy path).
    pub fn is_single_tier(&self) -> bool {
        self.ssd == 0
    }

    pub fn total(&self) -> u64 {
        self.ram + self.ssd
    }

    /// Per-shard slice: both tiers split `total/N` exactly like the
    /// federation's existing single budget.
    pub fn split(&self, n_shards: usize) -> Self {
        let n = n_shards.max(1) as u64;
        Self {
            ram: self.ram / n,
            ssd: self.ssd / n,
        }
    }
}

/// Per-tier cost model, in milliseconds per GB moved/scanned. The
/// defaults mirror the paper's Table 7 testbed per-core bandwidths
/// (2500 MB/s cache and 25 MB/s effective disk scan per node, 8 cores)
/// with an SSD pegged 20× slower than RAM and 20× faster than disk.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierCostModel {
    /// RAM hit latency (scan cost), ms per GB per core.
    pub ram_hit_ms_per_gb: f64,
    /// SSD hit latency (scan cost), ms per GB per core.
    pub ssd_hit_ms_per_gb: f64,
    /// Disk scan cost, ms per GB per core — the miss path both tiers
    /// are priced against.
    pub disk_ms_per_gb: f64,
    /// Write-path charge for loading a view from disk into a tier.
    pub load_ms_per_gb: f64,
    /// Write-path charge for demoting a view RAM→SSD.
    pub demote_ms_per_gb: f64,
}

impl Default for TierCostModel {
    fn default() -> Self {
        // Per-core: cache 2500/8 MB/s → 3276.8 ms/GB; disk 25/8 MB/s →
        // 327680 ms/GB. SSD 20× slower than RAM, 5× faster than disk.
        Self {
            ram_hit_ms_per_gb: 3_276.8,
            ssd_hit_ms_per_gb: 65_536.0,
            disk_ms_per_gb: 327_680.0,
            load_ms_per_gb: 327_680.0,
            demote_ms_per_gb: 65_536.0,
        }
    }
}

impl TierCostModel {
    /// Seconds for one core to scan `bytes` from the SSD tier.
    pub fn ssd_secs(&self, bytes: u64) -> f64 {
        bytes as f64 / GB_F * self.ssd_hit_ms_per_gb * 1e-3
    }

    /// Fraction of the disk-vs-RAM I/O saving an SSD hit retains:
    /// `(disk − ssd) / (disk − ram)`, clamped to [0, 1]. This is the
    /// tier discount the FASTPF/MMF/PF-MW utility oracles apply to a
    /// query class whose views are resident but not all in RAM.
    pub fn ssd_discount(&self) -> f64 {
        let denom = self.disk_ms_per_gb - self.ram_hit_ms_per_gb;
        if denom <= 0.0 {
            return 0.0;
        }
        ((self.disk_ms_per_gb - self.ssd_hit_ms_per_gb) / denom).clamp(0.0, 1.0)
    }

    /// Write-path charge (seconds) for demoting `bytes` RAM→SSD.
    pub fn demote_secs(&self, bytes: u64) -> f64 {
        bytes as f64 / GB_F * self.demote_ms_per_gb * 1e-3
    }
}

/// The full tier specification a driver runs under: budgets + costs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierSpec {
    pub budgets: TierBudgets,
    pub cost: TierCostModel,
}

impl TierSpec {
    /// Single-tier spec (no SSD) with the default cost model — the
    /// pre-tier behaviour for a given RAM budget.
    pub fn single(ram: u64) -> Self {
        Self {
            budgets: TierBudgets::single(ram),
            cost: TierCostModel::default(),
        }
    }

    pub fn is_single_tier(&self) -> bool {
        self.budgets.is_single_tier()
    }

    /// Per-shard slice (both tiers split `total/N`), costs unchanged.
    pub fn split(&self, n_shards: usize) -> Self {
        Self {
            budgets: self.budgets.split(n_shards),
            cost: self.cost,
        }
    }
}

/// A solved `(view, tier)` configuration: disjoint RAM and SSD planes
/// over the same view universe. The RAM plane is exactly the legacy
/// [`ConfigMask`] configuration; the SSD plane is empty in single-tier
/// mode.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TierAssignment {
    pub ram: ConfigMask,
    pub ssd: ConfigMask,
}

impl TierAssignment {
    /// Lift a legacy single-tier configuration: everything in RAM.
    pub fn single(ram: ConfigMask) -> Self {
        let n = ram.n_bits();
        Self {
            ram,
            ssd: ConfigMask::empty(n),
        }
    }

    pub fn n_bits(&self) -> usize {
        self.ram.n_bits()
    }

    /// All resident views regardless of tier.
    pub fn union(&self) -> ConfigMask {
        let mut u = self.ram.clone();
        u.union_with(&self.ssd);
        u
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budgets_split_and_single_tier() {
        let b = TierBudgets { ram: 100, ssd: 2000 };
        assert!(!b.is_single_tier());
        assert_eq!(b.total(), 2100);
        let s = b.split(4);
        assert_eq!(s, TierBudgets { ram: 25, ssd: 500 });
        assert!(TierBudgets::single(64).is_single_tier());
        assert_eq!(TierBudgets::single(64).split(3).ram, 21);
    }

    #[test]
    fn discount_between_zero_and_one() {
        let c = TierCostModel::default();
        let d = c.ssd_discount();
        assert!((0.0..=1.0).contains(&d), "d={d}");
        // Faster SSD → larger discount (closer to a RAM hit's value).
        let fast = TierCostModel {
            ssd_hit_ms_per_gb: 10_000.0,
            ..c
        };
        assert!(fast.ssd_discount() > d);
        // SSD as slow as disk → worthless.
        let slow = TierCostModel {
            ssd_hit_ms_per_gb: c.disk_ms_per_gb,
            ..c
        };
        assert!(slow.ssd_discount() < 1e-12);
    }

    #[test]
    fn assignment_union_and_single() {
        let ram = ConfigMask::from_bools(&[true, false, false]);
        let ssd = ConfigMask::from_bools(&[false, true, false]);
        let t = TierAssignment { ram, ssd };
        assert_eq!(t.union(), ConfigMask::from_bools(&[true, true, false]));
        let single = TierAssignment::single(ConfigMask::from_bools(&[true, false]));
        assert!(single.ssd.none_set());
    }

    #[test]
    fn spec_split_keeps_cost() {
        let spec = TierSpec {
            budgets: TierBudgets { ram: 80, ssd: 1600 },
            cost: TierCostModel::default(),
        };
        let s = spec.split(8);
        assert_eq!(s.budgets, TierBudgets { ram: 10, ssd: 200 });
        assert_eq!(s.cost, spec.cost);
    }
}
