//! Cache state management (Figure 2 step 3 and the §5.4 stateful mode):
//! incremental delta-based transitions with materialization accounting,
//! over one RAM tier or a two-tier RAM + SSD hierarchy (`tier`).

pub mod manager;
pub mod tier;

pub use manager::{CacheDelta, CacheManager, TransitionStats};
pub use tier::{Tier, TierAssignment, TierBudgets, TierCostModel, TierSpec};
