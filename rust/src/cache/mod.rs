//! Cache state management (Figure 2 step 3 and the §5.4 stateful mode):
//! incremental delta-based transitions with materialization accounting.

pub mod manager;

pub use manager::{CacheDelta, CacheManager, TransitionStats};
