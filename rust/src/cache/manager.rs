//! The cache manager: tracks which candidate views are materialized,
//! applies per-batch configuration updates (lazily — Spark materializes
//! a marked view when the first query touches it, §5.1), and produces
//! the stateful utility boost of §5.4 (already-cached views get their
//! estimated benefit multiplied by γ > 1, making them likelier to stay).
//!
//! Cache contents and pending-materialization state are [`ConfigMask`]
//! bitsets, matching the configuration representation the policies emit.

use crate::util::mask::ConfigMask;

/// Views loaded/evicted by one update.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheDelta {
    pub loaded: Vec<usize>,
    pub evicted: Vec<usize>,
}

/// Cache state across batches.
#[derive(Debug, Clone)]
pub struct CacheManager {
    /// Usable cache budget in bytes.
    budget: u64,
    /// Cached size per candidate view.
    sizes: Vec<u64>,
    /// Current contents.
    cached: ConfigMask,
    /// Marked-for-caching but not yet materialized (first access pays
    /// the disk read + materialization penalty).
    pending_load: ConfigMask,
}

impl CacheManager {
    pub fn new(budget: u64, sizes: Vec<u64>) -> Self {
        let n = sizes.len();
        Self {
            budget,
            sizes,
            cached: ConfigMask::empty(n),
            pending_load: ConfigMask::empty(n),
        }
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    pub fn n_views(&self) -> usize {
        self.sizes.len()
    }

    pub fn cached(&self) -> &ConfigMask {
        &self.cached
    }

    pub fn is_cached(&self, view: usize) -> bool {
        self.cached.get(view)
    }

    pub fn used_bytes(&self) -> u64 {
        self.cached.ones().map(|v| self.sizes[v]).sum()
    }

    /// Fraction of the budget occupied.
    pub fn utilization(&self) -> f64 {
        if self.budget == 0 {
            return 0.0;
        }
        self.used_bytes() as f64 / self.budget as f64
    }

    /// Apply a target configuration (Figure 2 step 3): evict views
    /// leaving the config, mark entering views for lazy materialization.
    /// Panics if the target exceeds the budget — policies must produce
    /// feasible configurations.
    pub fn update(&mut self, target: &ConfigMask) -> CacheDelta {
        assert_eq!(target.n_bits(), self.sizes.len());
        let target_bytes: u64 = target.ones().map(|v| self.sizes[v]).sum();
        assert!(
            target_bytes <= self.budget,
            "target config {target_bytes}B exceeds budget {}B",
            self.budget
        );
        let mut delta = CacheDelta {
            loaded: Vec::new(),
            evicted: Vec::new(),
        };
        for v in 0..self.sizes.len() {
            match (self.cached.get(v), target.get(v)) {
                (false, true) => {
                    self.cached.set(v, true);
                    self.pending_load.set(v, true);
                    delta.loaded.push(v);
                }
                (true, false) => {
                    self.cached.set(v, false);
                    self.pending_load.set(v, false);
                    delta.evicted.push(v);
                }
                _ => {}
            }
        }
        delta
    }

    /// True exactly once per loaded view: the first accessor materializes
    /// it (pays disk bandwidth + penalty); later accesses hit memory.
    pub fn consume_materialization(&mut self, view: usize) -> bool {
        if self.cached.get(view) && self.pending_load.get(view) {
            self.pending_load.set(view, false);
            true
        } else {
            false
        }
    }

    /// The §5.4 stateful boost vector: γ for currently cached views,
    /// 1.0 otherwise. Feed to [`crate::domain::BatchUtilities::build`].
    pub fn boost_vector(&self, gamma: f64) -> Vec<f64> {
        (0..self.sizes.len())
            .map(|v| if self.cached.get(v) { gamma } else { 1.0 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mask(bits: &[bool]) -> ConfigMask {
        ConfigMask::from_bools(bits)
    }

    #[test]
    fn update_loads_and_evicts() {
        let mut cm = CacheManager::new(100, vec![40, 50, 30]);
        let d1 = cm.update(&mask(&[true, true, false]));
        assert_eq!(d1.loaded, vec![0, 1]);
        assert!(d1.evicted.is_empty());
        assert_eq!(cm.used_bytes(), 90);
        assert!((cm.utilization() - 0.9).abs() < 1e-12);

        let d2 = cm.update(&mask(&[true, false, true]));
        assert_eq!(d2.loaded, vec![2]);
        assert_eq!(d2.evicted, vec![1]);
        assert_eq!(cm.used_bytes(), 70);
    }

    #[test]
    #[should_panic]
    fn over_budget_rejected() {
        let mut cm = CacheManager::new(100, vec![60, 60]);
        cm.update(&mask(&[true, true]));
    }

    #[test]
    fn lazy_materialization_consumed_once() {
        let mut cm = CacheManager::new(100, vec![50]);
        cm.update(&mask(&[true]));
        assert!(cm.consume_materialization(0));
        assert!(!cm.consume_materialization(0));
        // Re-loading after eviction resets the flag.
        cm.update(&mask(&[false]));
        cm.update(&mask(&[true]));
        assert!(cm.consume_materialization(0));
    }

    #[test]
    fn eviction_clears_pending() {
        let mut cm = CacheManager::new(100, vec![50]);
        cm.update(&mask(&[true]));
        cm.update(&mask(&[false]));
        assert!(!cm.consume_materialization(0));
    }

    #[test]
    fn boost_vector_gamma() {
        let mut cm = CacheManager::new(100, vec![40, 50]);
        cm.update(&mask(&[true, false]));
        assert_eq!(cm.boost_vector(2.0), vec![2.0, 1.0]);
    }

    #[test]
    fn zero_budget_utilization() {
        let cm = CacheManager::new(0, vec![]);
        assert_eq!(cm.utilization(), 0.0);
    }
}
