//! The cache manager: tracks which candidate views are materialized,
//! applies per-batch configuration updates (lazily — Spark materializes
//! a marked view when the first query touches it, §5.1), and produces
//! the stateful utility boost of §5.4 (already-cached views get their
//! estimated benefit multiplied by γ > 1, making them likelier to stay).

/// Views loaded/evicted by one update.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheDelta {
    pub loaded: Vec<usize>,
    pub evicted: Vec<usize>,
}

/// Cache state across batches.
#[derive(Debug, Clone)]
pub struct CacheManager {
    /// Usable cache budget in bytes.
    budget: u64,
    /// Cached size per candidate view.
    sizes: Vec<u64>,
    /// Current contents.
    cached: Vec<bool>,
    /// Marked-for-caching but not yet materialized (first access pays
    /// the disk read + materialization penalty).
    pending_load: Vec<bool>,
}

impl CacheManager {
    pub fn new(budget: u64, sizes: Vec<u64>) -> Self {
        let n = sizes.len();
        Self {
            budget,
            sizes,
            cached: vec![false; n],
            pending_load: vec![false; n],
        }
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    pub fn n_views(&self) -> usize {
        self.sizes.len()
    }

    pub fn cached(&self) -> &[bool] {
        &self.cached
    }

    pub fn is_cached(&self, view: usize) -> bool {
        self.cached[view]
    }

    pub fn used_bytes(&self) -> u64 {
        self.sizes
            .iter()
            .zip(&self.cached)
            .filter(|(_, &c)| c)
            .map(|(s, _)| *s)
            .sum()
    }

    /// Fraction of the budget occupied.
    pub fn utilization(&self) -> f64 {
        if self.budget == 0 {
            return 0.0;
        }
        self.used_bytes() as f64 / self.budget as f64
    }

    /// Apply a target configuration (Figure 2 step 3): evict views
    /// leaving the config, mark entering views for lazy materialization.
    /// Panics if the target exceeds the budget — policies must produce
    /// feasible configurations.
    pub fn update(&mut self, target: &[bool]) -> CacheDelta {
        assert_eq!(target.len(), self.sizes.len());
        let target_bytes: u64 = self
            .sizes
            .iter()
            .zip(target)
            .filter(|(_, &t)| t)
            .map(|(s, _)| *s)
            .sum();
        assert!(
            target_bytes <= self.budget,
            "target config {target_bytes}B exceeds budget {}B",
            self.budget
        );
        let mut delta = CacheDelta {
            loaded: Vec::new(),
            evicted: Vec::new(),
        };
        for v in 0..self.sizes.len() {
            match (self.cached[v], target[v]) {
                (false, true) => {
                    self.cached[v] = true;
                    self.pending_load[v] = true;
                    delta.loaded.push(v);
                }
                (true, false) => {
                    self.cached[v] = false;
                    self.pending_load[v] = false;
                    delta.evicted.push(v);
                }
                _ => {}
            }
        }
        delta
    }

    /// True exactly once per loaded view: the first accessor materializes
    /// it (pays disk bandwidth + penalty); later accesses hit memory.
    pub fn consume_materialization(&mut self, view: usize) -> bool {
        if self.cached[view] && self.pending_load[view] {
            self.pending_load[view] = false;
            true
        } else {
            false
        }
    }

    /// The §5.4 stateful boost vector: γ for currently cached views,
    /// 1.0 otherwise. Feed to [`crate::domain::BatchUtilities::build`].
    pub fn boost_vector(&self, gamma: f64) -> Vec<f64> {
        self.cached
            .iter()
            .map(|&c| if c { gamma } else { 1.0 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn update_loads_and_evicts() {
        let mut cm = CacheManager::new(100, vec![40, 50, 30]);
        let d1 = cm.update(&[true, true, false]);
        assert_eq!(d1.loaded, vec![0, 1]);
        assert!(d1.evicted.is_empty());
        assert_eq!(cm.used_bytes(), 90);
        assert!((cm.utilization() - 0.9).abs() < 1e-12);

        let d2 = cm.update(&[true, false, true]);
        assert_eq!(d2.loaded, vec![2]);
        assert_eq!(d2.evicted, vec![1]);
        assert_eq!(cm.used_bytes(), 70);
    }

    #[test]
    #[should_panic]
    fn over_budget_rejected() {
        let mut cm = CacheManager::new(100, vec![60, 60]);
        cm.update(&[true, true]);
    }

    #[test]
    fn lazy_materialization_consumed_once() {
        let mut cm = CacheManager::new(100, vec![50]);
        cm.update(&[true]);
        assert!(cm.consume_materialization(0));
        assert!(!cm.consume_materialization(0));
        // Re-loading after eviction resets the flag.
        cm.update(&[false]);
        cm.update(&[true]);
        assert!(cm.consume_materialization(0));
    }

    #[test]
    fn eviction_clears_pending() {
        let mut cm = CacheManager::new(100, vec![50]);
        cm.update(&[true]);
        cm.update(&[false]);
        assert!(!cm.consume_materialization(0));
    }

    #[test]
    fn boost_vector_gamma() {
        let mut cm = CacheManager::new(100, vec![40, 50]);
        cm.update(&[true, false]);
        assert_eq!(cm.boost_vector(2.0), vec![2.0, 1.0]);
    }

    #[test]
    fn zero_budget_utilization() {
        let cm = CacheManager::new(0, vec![]);
        assert_eq!(cm.utilization(), 0.0);
    }
}
