//! The cache manager: tracks which candidate views are materialized and
//! applies per-batch configuration updates as **incremental transitions**
//! — each update is a [`CacheDelta`] (what loads, what evicts, how many
//! bytes move) rather than a whole-configuration swap, with cumulative
//! [`TransitionStats`] so the stateful mode (§5.4) and the Figure 12
//! batch-size sweep reflect actual churn. Loads stay lazy (Spark
//! materializes a marked view when the first query touches it, §5.1):
//! the in-flight set scheduled by the deltas is what the simulator
//! charges materialization costs from.
//!
//! Cache contents and in-flight-load state are [`ConfigMask`] bitsets,
//! matching the configuration representation the policies emit.

use crate::util::mask::ConfigMask;

/// One incremental cache transition: the views (and bytes) that enter
/// and leave on an update. `loaded`/`evicted` are ascending view ids.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CacheDelta {
    pub loaded: Vec<usize>,
    pub evicted: Vec<usize>,
    /// Bytes scheduled for (lazy) materialization by this transition.
    pub bytes_loaded: u64,
    /// Bytes freed by this transition.
    pub bytes_evicted: u64,
}

impl CacheDelta {
    /// No views moved.
    pub fn is_empty(&self) -> bool {
        self.loaded.is_empty() && self.evicted.is_empty()
    }

    /// Number of views that changed state (the per-batch churn count).
    pub fn churn(&self) -> usize {
        self.loaded.len() + self.evicted.len()
    }
}

/// Cumulative transition accounting across a run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TransitionStats {
    /// Updates applied.
    pub updates: usize,
    /// Views scheduled for load / evicted, summed over all deltas.
    pub views_loaded: usize,
    pub views_evicted: usize,
    pub bytes_loaded: u64,
    pub bytes_evicted: u64,
    /// Materialization charges actually consumed by the executor (first
    /// touch of an in-flight view).
    pub materializations: usize,
    pub bytes_materialized: u64,
    /// Loads evicted again before any query touched them — pure wasted
    /// churn (the cost the stateful γ boost exists to suppress).
    pub cancelled_loads: usize,
}

/// Cache state across batches.
#[derive(Debug, Clone)]
pub struct CacheManager {
    /// Usable cache budget in bytes.
    budget: u64,
    /// Cached size per candidate view.
    sizes: Vec<u64>,
    /// Current contents.
    cached: ConfigMask,
    /// Scheduled by a transition but not yet materialized (first access
    /// pays the disk read + materialization penalty).
    in_flight: ConfigMask,
    /// Cumulative transition accounting.
    stats: TransitionStats,
}

impl CacheManager {
    pub fn new(budget: u64, sizes: Vec<u64>) -> Self {
        let n = sizes.len();
        Self {
            budget,
            sizes,
            cached: ConfigMask::empty(n),
            in_flight: ConfigMask::empty(n),
            stats: TransitionStats::default(),
        }
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Re-set the usable budget (the federation's elastic membership
    /// re-splits `total/N'` on every shard add/remove/kill). Contents
    /// may transiently exceed a shrunken budget — `utilization()` then
    /// reads above 1.0 until the next `update` applies a configuration
    /// feasible under the new budget (policies solve with the new value,
    /// so the very next transition restores feasibility).
    pub fn set_budget(&mut self, budget: u64) {
        self.budget = budget;
    }

    pub fn n_views(&self) -> usize {
        self.sizes.len()
    }

    pub fn cached(&self) -> &ConfigMask {
        &self.cached
    }

    /// Views scheduled for load whose materialization has not been
    /// charged yet.
    pub fn pending_loads(&self) -> &ConfigMask {
        &self.in_flight
    }

    /// Cumulative transition accounting since construction.
    pub fn transition_stats(&self) -> &TransitionStats {
        &self.stats
    }

    pub fn is_cached(&self, view: usize) -> bool {
        self.cached.get(view)
    }

    pub fn used_bytes(&self) -> u64 {
        self.cached.ones().map(|v| self.sizes[v]).sum()
    }

    /// Fraction of the budget occupied.
    pub fn utilization(&self) -> f64 {
        if self.budget == 0 {
            return 0.0;
        }
        self.used_bytes() as f64 / self.budget as f64
    }

    /// The transition `update(target)` would apply, without applying it
    /// (planner-side lookahead and tests).
    pub fn delta_to(&self, target: &ConfigMask) -> CacheDelta {
        assert_eq!(target.n_bits(), self.sizes.len());
        let mut delta = CacheDelta::default();
        for v in 0..self.sizes.len() {
            match (self.cached.get(v), target.get(v)) {
                (false, true) => {
                    delta.loaded.push(v);
                    delta.bytes_loaded += self.sizes[v];
                }
                (true, false) => {
                    delta.evicted.push(v);
                    delta.bytes_evicted += self.sizes[v];
                }
                _ => {}
            }
        }
        delta
    }

    /// The transition that would drain this cache entirely — the
    /// decommission ("RemoveShard") preview: everything cached migrates
    /// out, nothing loads. Pure, like [`CacheManager::delta_to`].
    pub fn drain_delta(&self) -> CacheDelta {
        self.delta_to(&ConfigMask::empty(self.sizes.len()))
    }

    /// Apply a target configuration (Figure 2 step 3) as an incremental
    /// transition: evict views leaving the config, schedule entering
    /// views for lazy materialization, and account the byte movement.
    /// Panics if the target exceeds the budget — policies must produce
    /// feasible configurations.
    pub fn update(&mut self, target: &ConfigMask) -> CacheDelta {
        assert_eq!(target.n_bits(), self.sizes.len());
        let target_bytes: u64 = target.ones().map(|v| self.sizes[v]).sum();
        assert!(
            target_bytes <= self.budget,
            "target config {target_bytes}B exceeds budget {}B",
            self.budget
        );
        let delta = self.delta_to(target);
        for &v in &delta.loaded {
            self.cached.set(v, true);
            self.in_flight.set(v, true);
        }
        for &v in &delta.evicted {
            self.cached.set(v, false);
            if self.in_flight.get(v) {
                // Scheduled load never touched by a query: wasted churn.
                self.in_flight.set(v, false);
                self.stats.cancelled_loads += 1;
            }
        }
        self.stats.updates += 1;
        self.stats.views_loaded += delta.loaded.len();
        self.stats.views_evicted += delta.evicted.len();
        self.stats.bytes_loaded += delta.bytes_loaded;
        self.stats.bytes_evicted += delta.bytes_evicted;
        delta
    }

    /// Charge the materialization cost of `view` from the scheduled
    /// transition: true exactly once per loaded view — the first
    /// accessor materializes it (pays disk bandwidth + penalty); later
    /// accesses hit memory.
    pub fn charge_materialization(&mut self, view: usize) -> bool {
        if self.cached.get(view) && self.in_flight.get(view) {
            self.in_flight.set(view, false);
            self.stats.materializations += 1;
            self.stats.bytes_materialized += self.sizes[view];
            true
        } else {
            false
        }
    }

    /// The §5.4 stateful boost vector for a cache contents mask: γ for
    /// cached views, 1.0 otherwise. Feed to
    /// [`crate::domain::utility::BatchUtilities::build`]. An associated
    /// function (not a method) because the pipelined planner boosts from
    /// its contents *mirror* without holding a manager; a live manager
    /// passes `cm.cached()`. This is the single boost implementation.
    pub fn boost_vector(cached: &ConfigMask, gamma: f64) -> Vec<f64> {
        (0..cached.n_bits())
            .map(|v| if cached.get(v) { gamma } else { 1.0 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mask(bits: &[bool]) -> ConfigMask {
        ConfigMask::from_bools(bits)
    }

    #[test]
    fn update_loads_and_evicts() {
        let mut cm = CacheManager::new(100, vec![40, 50, 30]);
        let d1 = cm.update(&mask(&[true, true, false]));
        assert_eq!(d1.loaded, vec![0, 1]);
        assert!(d1.evicted.is_empty());
        assert_eq!(d1.bytes_loaded, 90);
        assert_eq!(d1.bytes_evicted, 0);
        assert_eq!(cm.used_bytes(), 90);
        assert!((cm.utilization() - 0.9).abs() < 1e-12);

        let d2 = cm.update(&mask(&[true, false, true]));
        assert_eq!(d2.loaded, vec![2]);
        assert_eq!(d2.evicted, vec![1]);
        assert_eq!(d2.bytes_loaded, 30);
        assert_eq!(d2.bytes_evicted, 50);
        assert_eq!(d2.churn(), 2);
        assert_eq!(cm.used_bytes(), 70);
    }

    #[test]
    fn delta_preview_matches_update_and_is_pure() {
        let mut cm = CacheManager::new(100, vec![40, 50, 30]);
        cm.update(&mask(&[true, true, false]));
        let target = mask(&[false, true, true]);
        let used_before = cm.used_bytes();
        let pending_before = cm.pending_loads().clone();
        let preview = cm.delta_to(&target);
        // The preview mutated nothing.
        assert_eq!(cm.used_bytes(), used_before);
        assert_eq!(cm.pending_loads(), &pending_before);
        let applied = cm.update(&target);
        assert_eq!(preview, applied);
    }

    #[test]
    #[should_panic]
    fn over_budget_rejected() {
        let mut cm = CacheManager::new(100, vec![60, 60]);
        cm.update(&mask(&[true, true]));
    }

    #[test]
    fn lazy_materialization_charged_once() {
        let mut cm = CacheManager::new(100, vec![50]);
        cm.update(&mask(&[true]));
        assert!(cm.charge_materialization(0));
        assert!(!cm.charge_materialization(0));
        // Re-loading after eviction resets the charge.
        cm.update(&mask(&[false]));
        cm.update(&mask(&[true]));
        assert!(cm.charge_materialization(0));
        let s = cm.transition_stats();
        assert_eq!(s.materializations, 2);
        assert_eq!(s.bytes_materialized, 100);
    }

    #[test]
    fn eviction_clears_pending_and_counts_cancelled() {
        let mut cm = CacheManager::new(100, vec![50]);
        cm.update(&mask(&[true]));
        cm.update(&mask(&[false]));
        assert!(!cm.charge_materialization(0));
        assert_eq!(cm.transition_stats().cancelled_loads, 1);
        // A load that WAS touched does not count as cancelled.
        cm.update(&mask(&[true]));
        assert!(cm.charge_materialization(0));
        cm.update(&mask(&[false]));
        assert_eq!(cm.transition_stats().cancelled_loads, 1);
    }

    #[test]
    fn stats_accumulate_across_transitions() {
        let mut cm = CacheManager::new(100, vec![40, 50, 30]);
        cm.update(&mask(&[true, false, false]));
        cm.update(&mask(&[false, true, false]));
        cm.update(&mask(&[false, true, true]));
        let s = cm.transition_stats().clone();
        assert_eq!(s.updates, 3);
        assert_eq!(s.views_loaded, 3); // v0, v1, v2
        assert_eq!(s.views_evicted, 1); // v0
        assert_eq!(s.bytes_loaded, 40 + 50 + 30);
        assert_eq!(s.bytes_evicted, 40);
        assert_eq!(s.cancelled_loads, 1); // v0 never touched
    }

    #[test]
    fn boost_vector_gamma() {
        let mut cm = CacheManager::new(100, vec![40, 50]);
        cm.update(&mask(&[true, false]));
        assert_eq!(CacheManager::boost_vector(cm.cached(), 2.0), vec![2.0, 1.0]);
        // A detached mirror mask produces the identical boost.
        let mirror = cm.cached().clone();
        assert_eq!(
            CacheManager::boost_vector(&mirror, 2.0),
            CacheManager::boost_vector(cm.cached(), 2.0)
        );
    }

    #[test]
    fn cancelled_loads_consistent_under_flip_flops() {
        // Repeated target flip-flops: schedule a load, cancel it before
        // any query touches it, reschedule — the byte totals must stay
        // consistent (loaded − evicted == bytes currently cached) and
        // every untouched load must count as cancelled exactly once.
        let mut cm = CacheManager::new(100, vec![60, 40]);
        let on = mask(&[true, false]);
        let off = mask(&[false, false]);
        for k in 1..=3u64 {
            cm.update(&on);
            cm.update(&off);
            let s = cm.transition_stats();
            assert_eq!(s.cancelled_loads, k as usize, "cycle {k}");
            assert_eq!(s.bytes_loaded, 60 * k);
            assert_eq!(s.bytes_evicted, 60 * k);
            assert_eq!(s.materializations, 0);
            assert_eq!(cm.used_bytes(), 0);
            assert!(cm.pending_loads().none_set());
        }
        // A rescheduled load that IS touched does not count as cancelled,
        // and its materialization is charged exactly once.
        cm.update(&on);
        assert!(cm.charge_materialization(0));
        cm.update(&off);
        let s = cm.transition_stats().clone();
        assert_eq!(s.cancelled_loads, 3);
        assert_eq!(s.bytes_loaded, 240);
        assert_eq!(s.bytes_evicted, 240);
        assert_eq!(s.materializations, 1);
        assert_eq!(s.bytes_materialized, 60);
        assert_eq!(s.updates, 8);
        // Loaded minus evicted equals current contents (empty here); a
        // final reschedule restores the in-flight state cleanly.
        assert_eq!(s.bytes_loaded - s.bytes_evicted, cm.used_bytes());
        cm.update(&on);
        assert!(cm.pending_loads().get(0));
        assert!(cm.charge_materialization(0));
        assert!(!cm.charge_materialization(0));
    }

    #[test]
    fn zero_budget_utilization() {
        let cm = CacheManager::new(0, vec![]);
        assert_eq!(cm.utilization(), 0.0);
    }

    #[test]
    fn set_budget_resplits_and_allows_transient_overflow() {
        let mut cm = CacheManager::new(100, vec![40, 50, 30]);
        cm.update(&mask(&[true, true, false]));
        assert_eq!(cm.used_bytes(), 90);
        // Budget shrinks under the contents (a shard joined): the state
        // is preserved, utilization reads above 1 until the next update.
        cm.set_budget(60);
        assert_eq!(cm.budget(), 60);
        assert_eq!(cm.used_bytes(), 90);
        assert!(cm.utilization() > 1.0);
        // The next (feasible) target transitions down normally.
        let d = cm.update(&mask(&[false, true, false]));
        assert_eq!(d.evicted, vec![0]);
        assert_eq!(cm.used_bytes(), 50);
        // Budget grows (a shard died): larger targets become legal.
        cm.set_budget(120);
        cm.update(&mask(&[true, true, true]));
        assert_eq!(cm.used_bytes(), 120);
    }

    #[test]
    fn drain_delta_previews_full_eviction() {
        let mut cm = CacheManager::new(100, vec![40, 50, 30]);
        cm.update(&mask(&[true, false, true]));
        let used = cm.used_bytes();
        let drain = cm.drain_delta();
        assert_eq!(drain.bytes_evicted, used);
        assert_eq!(drain.evicted, vec![0, 2]);
        assert!(drain.loaded.is_empty());
        // Pure: nothing changed.
        assert_eq!(cm.used_bytes(), used);
        // An empty cache drains nothing.
        assert!(CacheManager::new(10, vec![5]).drain_delta().is_empty());
    }
}
