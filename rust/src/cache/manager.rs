//! The cache manager: tracks which candidate views are materialized and
//! applies per-batch configuration updates as **incremental transitions**
//! — each update is a [`CacheDelta`] (what loads, what evicts, how many
//! bytes move) rather than a whole-configuration swap, with cumulative
//! [`TransitionStats`] so the stateful mode (§5.4) and the Figure 12
//! batch-size sweep reflect actual churn. Loads stay lazy (Spark
//! materializes a marked view when the first query touches it, §5.1):
//! the in-flight set scheduled by the deltas is what the simulator
//! charges materialization costs from.
//!
//! Cache contents and in-flight-load state are [`ConfigMask`] bitsets,
//! matching the configuration representation the policies emit.

use crate::cache::tier::{Tier, TierAssignment, TierBudgets, TierCostModel, TierSpec};
use crate::util::mask::ConfigMask;

/// One incremental cache transition: the views (and bytes) that enter
/// and leave on an update. All view lists are ascending view ids.
///
/// The tier fields (`ssd_loaded`, `demoted`, `promoted` and their byte
/// counters) are empty/zero on every single-tier transition, so the
/// replay-equality comparisons that predate tiers (`delta == delta`)
/// keep holding bit for bit.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CacheDelta {
    /// Views entering RAM from disk.
    pub loaded: Vec<usize>,
    /// Views leaving residency entirely (dropped from both tiers).
    pub evicted: Vec<usize>,
    /// Views entering SSD from disk.
    pub ssd_loaded: Vec<usize>,
    /// Views moved RAM→SSD (eviction-as-demotion).
    pub demoted: Vec<usize>,
    /// Views moved SSD→RAM.
    pub promoted: Vec<usize>,
    /// Bytes scheduled for (lazy) materialization into RAM.
    pub bytes_loaded: u64,
    /// Bytes freed by this transition (both tiers).
    pub bytes_evicted: u64,
    /// Bytes scheduled for (lazy) materialization into SSD.
    pub bytes_ssd_loaded: u64,
    /// Inter-tier bytes written RAM→SSD, charged like loads.
    pub bytes_demoted: u64,
    /// Inter-tier bytes copied SSD→RAM, charged like loads.
    pub bytes_promoted: u64,
}

impl CacheDelta {
    /// No views moved.
    pub fn is_empty(&self) -> bool {
        self.loaded.is_empty()
            && self.evicted.is_empty()
            && self.ssd_loaded.is_empty()
            && self.demoted.is_empty()
            && self.promoted.is_empty()
    }

    /// Number of views that changed state (the per-batch churn count).
    pub fn churn(&self) -> usize {
        self.loaded.len()
            + self.evicted.len()
            + self.ssd_loaded.len()
            + self.demoted.len()
            + self.promoted.len()
    }
}

/// Cumulative transition accounting across a run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TransitionStats {
    /// Updates applied.
    pub updates: usize,
    /// Views scheduled for load / evicted, summed over all deltas.
    pub views_loaded: usize,
    pub views_evicted: usize,
    pub bytes_loaded: u64,
    pub bytes_evicted: u64,
    /// Materialization charges actually consumed by the executor (first
    /// touch of an in-flight view).
    pub materializations: usize,
    pub bytes_materialized: u64,
    /// Loads evicted again before any query touched them — pure wasted
    /// churn (the cost the stateful γ boost exists to suppress).
    pub cancelled_loads: usize,
    /// Tier traffic (all zero in single-tier mode): loads into SSD from
    /// disk, demotions RAM→SSD, promotions SSD→RAM — inter-tier bytes
    /// are charged exactly the way `bytes_loaded` charges disk loads.
    pub ssd_views_loaded: usize,
    pub bytes_ssd_loaded: u64,
    pub views_demoted: usize,
    pub bytes_demoted: u64,
    pub views_promoted: usize,
    pub bytes_promoted: u64,
}

/// Cache state across batches.
#[derive(Debug, Clone)]
pub struct CacheManager {
    /// Usable RAM-tier budget in bytes (the legacy single budget).
    budget: u64,
    /// SSD-tier budget in bytes; 0 selects single-tier mode, whose
    /// every path is bit-identical to the pre-tier manager.
    ssd_budget: u64,
    /// Per-tier cost model (only consulted in tiered mode).
    cost: TierCostModel,
    /// Cached size per candidate view.
    sizes: Vec<u64>,
    /// Current RAM contents.
    cached: ConfigMask,
    /// Current SSD contents (always empty in single-tier mode).
    ssd: ConfigMask,
    /// Scheduled by a transition but not yet materialized (first access
    /// pays the disk read + materialization penalty).
    in_flight: ConfigMask,
    /// Cumulative transition accounting.
    stats: TransitionStats,
}

impl CacheManager {
    pub fn new(budget: u64, sizes: Vec<u64>) -> Self {
        Self::new_tiered(TierSpec::single(budget), sizes)
    }

    /// Tiered constructor: RAM + SSD capacities and the cost model. With
    /// `spec.is_single_tier()` this is exactly [`CacheManager::new`].
    pub fn new_tiered(spec: TierSpec, sizes: Vec<u64>) -> Self {
        let n = sizes.len();
        Self {
            budget: spec.budgets.ram,
            ssd_budget: spec.budgets.ssd,
            cost: spec.cost,
            sizes,
            cached: ConfigMask::empty(n),
            ssd: ConfigMask::empty(n),
            in_flight: ConfigMask::empty(n),
            stats: TransitionStats::default(),
        }
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    pub fn tier_budgets(&self) -> TierBudgets {
        TierBudgets {
            ram: self.budget,
            ssd: self.ssd_budget,
        }
    }

    pub fn cost_model(&self) -> &TierCostModel {
        &self.cost
    }

    pub fn is_single_tier(&self) -> bool {
        self.ssd_budget == 0
    }

    /// Re-set the usable budget (the federation's elastic membership
    /// re-splits `total/N'` on every shard add/remove/kill). Contents
    /// may transiently exceed a shrunken budget — `utilization()` then
    /// reads above 1.0 until the next `update` applies a configuration
    /// feasible under the new budget (policies solve with the new value,
    /// so the very next transition restores feasibility).
    pub fn set_budget(&mut self, budget: u64) {
        self.budget = budget;
    }

    /// Tier-aware budget re-split (elastic membership): both tiers
    /// shrink or grow together; contents may transiently overflow like
    /// [`CacheManager::set_budget`].
    pub fn set_tier_budgets(&mut self, budgets: TierBudgets) {
        self.budget = budgets.ram;
        self.ssd_budget = budgets.ssd;
    }

    pub fn n_views(&self) -> usize {
        self.sizes.len()
    }

    pub fn cached(&self) -> &ConfigMask {
        &self.cached
    }

    /// Views scheduled for load whose materialization has not been
    /// charged yet.
    pub fn pending_loads(&self) -> &ConfigMask {
        &self.in_flight
    }

    /// Cumulative transition accounting since construction.
    pub fn transition_stats(&self) -> &TransitionStats {
        &self.stats
    }

    pub fn is_cached(&self, view: usize) -> bool {
        self.cached.get(view)
    }

    /// Current SSD contents (empty in single-tier mode).
    pub fn ssd_contents(&self) -> &ConfigMask {
        &self.ssd
    }

    /// Residency tier of a view, if any. In single-tier mode this is
    /// `Some(Ram)` exactly when [`CacheManager::is_cached`] is true.
    pub fn tier_of(&self, view: usize) -> Option<Tier> {
        if self.cached.get(view) {
            Some(Tier::Ram)
        } else if self.ssd.get(view) {
            Some(Tier::Ssd)
        } else {
            None
        }
    }

    pub fn used_bytes(&self) -> u64 {
        self.cached.ones().map(|v| self.sizes[v]).sum()
    }

    pub fn ssd_used_bytes(&self) -> u64 {
        self.ssd.ones().map(|v| self.sizes[v]).sum()
    }

    /// Fraction of the budget occupied.
    pub fn utilization(&self) -> f64 {
        if self.budget == 0 {
            return 0.0;
        }
        self.used_bytes() as f64 / self.budget as f64
    }

    /// The transition `update(target)` would apply, without applying it
    /// (planner-side lookahead and tests).
    pub fn delta_to(&self, target: &ConfigMask) -> CacheDelta {
        assert_eq!(target.n_bits(), self.sizes.len());
        let mut delta = CacheDelta::default();
        for v in 0..self.sizes.len() {
            match (self.cached.get(v), target.get(v)) {
                (false, true) => {
                    delta.loaded.push(v);
                    delta.bytes_loaded += self.sizes[v];
                }
                (true, false) => {
                    delta.evicted.push(v);
                    delta.bytes_evicted += self.sizes[v];
                }
                _ => {}
            }
        }
        delta
    }

    /// The transition that would drain this cache entirely — the
    /// decommission ("RemoveShard") preview: everything cached migrates
    /// out, nothing loads. Pure, like [`CacheManager::delta_to`]. A
    /// drain is a true eviction of both tiers — demotion does not apply
    /// (the shard is going away, there is no SSD to keep).
    pub fn drain_delta(&self) -> CacheDelta {
        let mut delta = self.delta_to(&ConfigMask::empty(self.sizes.len()));
        for v in self.ssd.ones() {
            delta.evicted.push(v);
            delta.bytes_evicted += self.sizes[v];
        }
        delta.evicted.sort_unstable();
        delta
    }

    /// The transition `update_tiered(target)` would apply, without
    /// applying it — includes the demotion-before-drop fill, so the
    /// preview matches the applied delta exactly.
    pub fn delta_to_tiered(&self, target: &TierAssignment) -> CacheDelta {
        self.plan_tiered(target).0
    }

    /// Classify the tiered transition to `target` and resolve the final
    /// SSD plane. **Demotion before drop:** RAM-resident views the
    /// solver dropped entirely fill the SSD tier's spare capacity (after
    /// the solver's own SSD plane is placed) in ascending view-id order
    /// instead of being discarded — a deterministic rule, so the
    /// preview/apply pair and any replaying twin agree bit for bit.
    fn plan_tiered(&self, target: &TierAssignment) -> (CacheDelta, ConfigMask) {
        assert_eq!(target.ram.n_bits(), self.sizes.len());
        assert_eq!(target.ssd.n_bits(), self.sizes.len());
        debug_assert!(
            !target.ram.intersects(&target.ssd),
            "tier planes must be disjoint"
        );
        let new_ssd =
            Self::resolve_ssd_plane(&self.cached, target, &self.sizes, self.ssd_budget);
        let mut delta = CacheDelta::default();
        for v in 0..self.sizes.len() {
            let (was_ram, was_ssd) = (self.cached.get(v), self.ssd.get(v));
            let (now_ram, now_ssd) = (target.ram.get(v), new_ssd.get(v));
            let sz = self.sizes[v];
            match (was_ram || was_ssd, now_ram || now_ssd) {
                (false, true) if now_ram => {
                    delta.loaded.push(v);
                    delta.bytes_loaded += sz;
                }
                (false, true) => {
                    delta.ssd_loaded.push(v);
                    delta.bytes_ssd_loaded += sz;
                }
                (true, false) => {
                    delta.evicted.push(v);
                    delta.bytes_evicted += sz;
                }
                (true, true) if was_ram && !now_ram => {
                    delta.demoted.push(v);
                    delta.bytes_demoted += sz;
                }
                (true, true) if was_ssd && now_ram => {
                    delta.promoted.push(v);
                    delta.bytes_promoted += sz;
                }
                _ => {}
            }
        }
        (delta, new_ssd)
    }

    /// The SSD plane a tiered transition to `target` resolves to, given
    /// the previous RAM contents: the solver's own SSD plane plus the
    /// demotion-before-drop fill (dropped RAM residents pack into spare
    /// SSD capacity in ascending view-id order). An associated function
    /// so planner-side mirrors (which never read the live cache) can
    /// reproduce the cache contents bit for bit — the tiered analogue
    /// of [`CacheManager::boost_vector`]'s contract.
    pub(crate) fn resolve_ssd_plane(
        prev_ram: &ConfigMask,
        target: &TierAssignment,
        sizes: &[u64],
        ssd_budget: u64,
    ) -> ConfigMask {
        let mut new_ssd = target.ssd.clone();
        let mut ssd_used: u64 = new_ssd.ones().map(|v| sizes[v]).sum();
        for v in prev_ram.ones() {
            if !target.ram.get(v) && !new_ssd.get(v) && ssd_used + sizes[v] <= ssd_budget {
                new_ssd.set(v, true);
                ssd_used += sizes[v];
            }
        }
        new_ssd
    }

    /// Apply a tiered `(view, tier)` target. With an SSD budget of 0 and
    /// an empty SSD plane this delegates to [`CacheManager::update`] —
    /// the bit-identical degenerate path `tier_equivalence.rs` pins.
    /// Panics if either plane exceeds its tier budget.
    pub fn update_tiered(&mut self, target: &TierAssignment) -> CacheDelta {
        if self.is_single_tier() && target.ssd.none_set() {
            return self.update(&target.ram);
        }
        let ram_bytes: u64 = target.ram.ones().map(|v| self.sizes[v]).sum();
        assert!(
            ram_bytes <= self.budget,
            "RAM plane {ram_bytes}B exceeds budget {}B",
            self.budget
        );
        let ssd_bytes: u64 = target.ssd.ones().map(|v| self.sizes[v]).sum();
        assert!(
            ssd_bytes <= self.ssd_budget,
            "SSD plane {ssd_bytes}B exceeds budget {}B",
            self.ssd_budget
        );
        let (delta, new_ssd) = self.plan_tiered(target);
        for &v in delta.loaded.iter().chain(&delta.ssd_loaded) {
            self.in_flight.set(v, true);
        }
        for &v in &delta.evicted {
            if self.in_flight.get(v) {
                // Scheduled load never touched by a query: wasted churn.
                self.in_flight.set(v, false);
                self.stats.cancelled_loads += 1;
            }
        }
        self.cached = target.ram.clone();
        self.ssd = new_ssd;
        self.stats.updates += 1;
        self.stats.views_loaded += delta.loaded.len();
        self.stats.views_evicted += delta.evicted.len();
        self.stats.bytes_loaded += delta.bytes_loaded;
        self.stats.bytes_evicted += delta.bytes_evicted;
        self.stats.ssd_views_loaded += delta.ssd_loaded.len();
        self.stats.bytes_ssd_loaded += delta.bytes_ssd_loaded;
        self.stats.views_demoted += delta.demoted.len();
        self.stats.bytes_demoted += delta.bytes_demoted;
        self.stats.views_promoted += delta.promoted.len();
        self.stats.bytes_promoted += delta.bytes_promoted;
        delta
    }

    /// Apply a target configuration (Figure 2 step 3) as an incremental
    /// transition: evict views leaving the config, schedule entering
    /// views for lazy materialization, and account the byte movement.
    /// Panics if the target exceeds the budget — policies must produce
    /// feasible configurations.
    pub fn update(&mut self, target: &ConfigMask) -> CacheDelta {
        assert_eq!(target.n_bits(), self.sizes.len());
        let target_bytes: u64 = target.ones().map(|v| self.sizes[v]).sum();
        assert!(
            target_bytes <= self.budget,
            "target config {target_bytes}B exceeds budget {}B",
            self.budget
        );
        let delta = self.delta_to(target);
        for &v in &delta.loaded {
            self.cached.set(v, true);
            self.in_flight.set(v, true);
        }
        for &v in &delta.evicted {
            self.cached.set(v, false);
            if self.in_flight.get(v) {
                // Scheduled load never touched by a query: wasted churn.
                self.in_flight.set(v, false);
                self.stats.cancelled_loads += 1;
            }
        }
        self.stats.updates += 1;
        self.stats.views_loaded += delta.loaded.len();
        self.stats.views_evicted += delta.evicted.len();
        self.stats.bytes_loaded += delta.bytes_loaded;
        self.stats.bytes_evicted += delta.bytes_evicted;
        delta
    }

    /// Charge the materialization cost of `view` from the scheduled
    /// transition: true exactly once per loaded view — the first
    /// accessor materializes it (pays disk bandwidth + penalty); later
    /// accesses hit memory.
    pub fn charge_materialization(&mut self, view: usize) -> bool {
        if (self.cached.get(view) || self.ssd.get(view)) && self.in_flight.get(view) {
            self.in_flight.set(view, false);
            self.stats.materializations += 1;
            self.stats.bytes_materialized += self.sizes[view];
            true
        } else {
            false
        }
    }

    /// The §5.4 stateful boost vector for a cache contents mask: γ for
    /// cached views, 1.0 otherwise. Feed to
    /// [`crate::domain::utility::BatchUtilities::build`]. An associated
    /// function (not a method) because the pipelined planner boosts from
    /// its contents *mirror* without holding a manager; a live manager
    /// passes `cm.cached()`. This is the single boost implementation.
    pub fn boost_vector(cached: &ConfigMask, gamma: f64) -> Vec<f64> {
        (0..cached.n_bits())
            .map(|v| if cached.get(v) { gamma } else { 1.0 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mask(bits: &[bool]) -> ConfigMask {
        ConfigMask::from_bools(bits)
    }

    #[test]
    fn update_loads_and_evicts() {
        let mut cm = CacheManager::new(100, vec![40, 50, 30]);
        let d1 = cm.update(&mask(&[true, true, false]));
        assert_eq!(d1.loaded, vec![0, 1]);
        assert!(d1.evicted.is_empty());
        assert_eq!(d1.bytes_loaded, 90);
        assert_eq!(d1.bytes_evicted, 0);
        assert_eq!(cm.used_bytes(), 90);
        assert!((cm.utilization() - 0.9).abs() < 1e-12);

        let d2 = cm.update(&mask(&[true, false, true]));
        assert_eq!(d2.loaded, vec![2]);
        assert_eq!(d2.evicted, vec![1]);
        assert_eq!(d2.bytes_loaded, 30);
        assert_eq!(d2.bytes_evicted, 50);
        assert_eq!(d2.churn(), 2);
        assert_eq!(cm.used_bytes(), 70);
    }

    #[test]
    fn delta_preview_matches_update_and_is_pure() {
        let mut cm = CacheManager::new(100, vec![40, 50, 30]);
        cm.update(&mask(&[true, true, false]));
        let target = mask(&[false, true, true]);
        let used_before = cm.used_bytes();
        let pending_before = cm.pending_loads().clone();
        let preview = cm.delta_to(&target);
        // The preview mutated nothing.
        assert_eq!(cm.used_bytes(), used_before);
        assert_eq!(cm.pending_loads(), &pending_before);
        let applied = cm.update(&target);
        assert_eq!(preview, applied);
    }

    #[test]
    #[should_panic]
    fn over_budget_rejected() {
        let mut cm = CacheManager::new(100, vec![60, 60]);
        cm.update(&mask(&[true, true]));
    }

    #[test]
    fn lazy_materialization_charged_once() {
        let mut cm = CacheManager::new(100, vec![50]);
        cm.update(&mask(&[true]));
        assert!(cm.charge_materialization(0));
        assert!(!cm.charge_materialization(0));
        // Re-loading after eviction resets the charge.
        cm.update(&mask(&[false]));
        cm.update(&mask(&[true]));
        assert!(cm.charge_materialization(0));
        let s = cm.transition_stats();
        assert_eq!(s.materializations, 2);
        assert_eq!(s.bytes_materialized, 100);
    }

    #[test]
    fn eviction_clears_pending_and_counts_cancelled() {
        let mut cm = CacheManager::new(100, vec![50]);
        cm.update(&mask(&[true]));
        cm.update(&mask(&[false]));
        assert!(!cm.charge_materialization(0));
        assert_eq!(cm.transition_stats().cancelled_loads, 1);
        // A load that WAS touched does not count as cancelled.
        cm.update(&mask(&[true]));
        assert!(cm.charge_materialization(0));
        cm.update(&mask(&[false]));
        assert_eq!(cm.transition_stats().cancelled_loads, 1);
    }

    #[test]
    fn stats_accumulate_across_transitions() {
        let mut cm = CacheManager::new(100, vec![40, 50, 30]);
        cm.update(&mask(&[true, false, false]));
        cm.update(&mask(&[false, true, false]));
        cm.update(&mask(&[false, true, true]));
        let s = cm.transition_stats().clone();
        assert_eq!(s.updates, 3);
        assert_eq!(s.views_loaded, 3); // v0, v1, v2
        assert_eq!(s.views_evicted, 1); // v0
        assert_eq!(s.bytes_loaded, 40 + 50 + 30);
        assert_eq!(s.bytes_evicted, 40);
        assert_eq!(s.cancelled_loads, 1); // v0 never touched
    }

    #[test]
    fn boost_vector_gamma() {
        let mut cm = CacheManager::new(100, vec![40, 50]);
        cm.update(&mask(&[true, false]));
        assert_eq!(CacheManager::boost_vector(cm.cached(), 2.0), vec![2.0, 1.0]);
        // A detached mirror mask produces the identical boost.
        let mirror = cm.cached().clone();
        assert_eq!(
            CacheManager::boost_vector(&mirror, 2.0),
            CacheManager::boost_vector(cm.cached(), 2.0)
        );
    }

    #[test]
    fn cancelled_loads_consistent_under_flip_flops() {
        // Repeated target flip-flops: schedule a load, cancel it before
        // any query touches it, reschedule — the byte totals must stay
        // consistent (loaded − evicted == bytes currently cached) and
        // every untouched load must count as cancelled exactly once.
        let mut cm = CacheManager::new(100, vec![60, 40]);
        let on = mask(&[true, false]);
        let off = mask(&[false, false]);
        for k in 1..=3u64 {
            cm.update(&on);
            cm.update(&off);
            let s = cm.transition_stats();
            assert_eq!(s.cancelled_loads, k as usize, "cycle {k}");
            assert_eq!(s.bytes_loaded, 60 * k);
            assert_eq!(s.bytes_evicted, 60 * k);
            assert_eq!(s.materializations, 0);
            assert_eq!(cm.used_bytes(), 0);
            assert!(cm.pending_loads().none_set());
        }
        // A rescheduled load that IS touched does not count as cancelled,
        // and its materialization is charged exactly once.
        cm.update(&on);
        assert!(cm.charge_materialization(0));
        cm.update(&off);
        let s = cm.transition_stats().clone();
        assert_eq!(s.cancelled_loads, 3);
        assert_eq!(s.bytes_loaded, 240);
        assert_eq!(s.bytes_evicted, 240);
        assert_eq!(s.materializations, 1);
        assert_eq!(s.bytes_materialized, 60);
        assert_eq!(s.updates, 8);
        // Loaded minus evicted equals current contents (empty here); a
        // final reschedule restores the in-flight state cleanly.
        assert_eq!(s.bytes_loaded - s.bytes_evicted, cm.used_bytes());
        cm.update(&on);
        assert!(cm.pending_loads().get(0));
        assert!(cm.charge_materialization(0));
        assert!(!cm.charge_materialization(0));
    }

    #[test]
    fn zero_budget_utilization() {
        let cm = CacheManager::new(0, vec![]);
        assert_eq!(cm.utilization(), 0.0);
    }

    #[test]
    fn set_budget_resplits_and_allows_transient_overflow() {
        let mut cm = CacheManager::new(100, vec![40, 50, 30]);
        cm.update(&mask(&[true, true, false]));
        assert_eq!(cm.used_bytes(), 90);
        // Budget shrinks under the contents (a shard joined): the state
        // is preserved, utilization reads above 1 until the next update.
        cm.set_budget(60);
        assert_eq!(cm.budget(), 60);
        assert_eq!(cm.used_bytes(), 90);
        assert!(cm.utilization() > 1.0);
        // The next (feasible) target transitions down normally.
        let d = cm.update(&mask(&[false, true, false]));
        assert_eq!(d.evicted, vec![0]);
        assert_eq!(cm.used_bytes(), 50);
        // Budget grows (a shard died): larger targets become legal.
        cm.set_budget(120);
        cm.update(&mask(&[true, true, true]));
        assert_eq!(cm.used_bytes(), 120);
    }

    #[test]
    fn drain_delta_previews_full_eviction() {
        let mut cm = CacheManager::new(100, vec![40, 50, 30]);
        cm.update(&mask(&[true, false, true]));
        let used = cm.used_bytes();
        let drain = cm.drain_delta();
        assert_eq!(drain.bytes_evicted, used);
        assert_eq!(drain.evicted, vec![0, 2]);
        assert!(drain.loaded.is_empty());
        // Pure: nothing changed.
        assert_eq!(cm.used_bytes(), used);
        // An empty cache drains nothing.
        assert!(CacheManager::new(10, vec![5]).drain_delta().is_empty());
    }

    // ---- tiered mode ----

    use crate::cache::tier::{Tier, TierAssignment, TierBudgets, TierCostModel, TierSpec};

    fn tiered(ram: u64, ssd: u64, sizes: &[u64]) -> CacheManager {
        CacheManager::new_tiered(
            TierSpec {
                budgets: TierBudgets { ram, ssd },
                cost: TierCostModel::default(),
            },
            sizes.to_vec(),
        )
    }

    fn assign(ram: &[bool], ssd: &[bool]) -> TierAssignment {
        TierAssignment {
            ram: mask(ram),
            ssd: mask(ssd),
        }
    }

    #[test]
    fn degenerate_tiered_update_is_single_tier_update() {
        // SSD budget 0 + empty SSD plane delegates to `update` exactly.
        let mut a = CacheManager::new(100, vec![40, 50, 30]);
        let mut b = CacheManager::new(100, vec![40, 50, 30]);
        let targets = [
            assign(&[true, true, false], &[false; 3]),
            assign(&[true, false, true], &[false; 3]),
            assign(&[false, false, false], &[false; 3]),
        ];
        for t in &targets {
            let da = a.update(&t.ram);
            let db = b.update_tiered(t);
            assert_eq!(da, db);
            assert_eq!(a.cached(), b.cached());
            assert_eq!(a.transition_stats(), b.transition_stats());
            assert!(b.ssd_contents().none_set());
        }
    }

    #[test]
    fn eviction_becomes_demotion_before_drop() {
        let mut cm = tiered(100, 100, &[40, 50, 30]);
        cm.update_tiered(&assign(&[true, true, false], &[false; 3]));
        // Both RAM views leave the RAM plane; the solver asked for
        // nothing on SSD — demotion fills SSD in ascending id order.
        let d = cm.update_tiered(&assign(&[false, false, true], &[false; 3]));
        assert_eq!(d.demoted, vec![0, 1]);
        assert_eq!(d.bytes_demoted, 90);
        assert!(d.evicted.is_empty());
        assert_eq!(d.loaded, vec![2]);
        assert_eq!(cm.tier_of(0), Some(Tier::Ssd));
        assert_eq!(cm.tier_of(1), Some(Tier::Ssd));
        assert_eq!(cm.tier_of(2), Some(Tier::Ram));
        assert_eq!(cm.ssd_used_bytes(), 90);
    }

    #[test]
    fn demotion_respects_ssd_capacity() {
        let mut cm = tiered(100, 45, &[40, 50, 30]);
        cm.update_tiered(&assign(&[true, true, false], &[false; 3]));
        // Only view 0 (40B) fits the 45B SSD; view 1 (50B) is dropped.
        let d = cm.update_tiered(&assign(&[false, false, false], &[false; 3]));
        assert_eq!(d.demoted, vec![0]);
        assert_eq!(d.evicted, vec![1]);
        assert_eq!(d.bytes_evicted, 50);
        assert_eq!(cm.ssd_used_bytes(), 40);
    }

    #[test]
    fn ssd_loads_promotions_and_conservation() {
        let mut cm = tiered(100, 100, &[40, 50, 30]);
        // Solver places view 2 straight onto SSD.
        let d1 = cm.update_tiered(&assign(&[true, false, false], &[false, false, true]));
        assert_eq!(d1.loaded, vec![0]);
        assert_eq!(d1.ssd_loaded, vec![2]);
        assert_eq!(d1.bytes_ssd_loaded, 30);
        // Promotion SSD→RAM; the old RAM view demotes.
        let d2 = cm.update_tiered(&assign(&[false, false, true], &[true, false, false]));
        assert_eq!(d2.promoted, vec![2]);
        assert_eq!(d2.bytes_promoted, 30);
        assert_eq!(d2.demoted, vec![0]);
        // Conservation: resident bytes = Σ loads − Σ evictions
        // (demotions/promotions are internal moves, net zero).
        let s = cm.transition_stats();
        let resident = cm.used_bytes() + cm.ssd_used_bytes();
        assert_eq!(
            s.bytes_loaded + s.bytes_ssd_loaded - s.bytes_evicted,
            resident
        );
    }

    #[test]
    fn tiered_preview_matches_apply_and_materialization_covers_ssd() {
        let mut cm = tiered(100, 100, &[40, 50, 30]);
        let t = assign(&[true, false, false], &[false, true, false]);
        let preview = cm.delta_to_tiered(&t);
        let applied = cm.update_tiered(&t);
        assert_eq!(preview, applied);
        // SSD loads materialize lazily like RAM loads.
        assert!(cm.charge_materialization(1));
        assert!(!cm.charge_materialization(1));
        assert_eq!(cm.transition_stats().materializations, 1);
    }

    #[test]
    fn tiered_drain_evicts_both_planes() {
        let mut cm = tiered(100, 100, &[40, 50, 30]);
        cm.update_tiered(&assign(&[true, false, false], &[false, true, true]));
        let d = cm.drain_delta();
        assert_eq!(d.evicted, vec![0, 1, 2]);
        assert_eq!(d.bytes_evicted, 120);
    }

    #[test]
    #[should_panic]
    fn ssd_plane_over_budget_rejected() {
        let mut cm = tiered(100, 20, &[40, 50, 30]);
        cm.update_tiered(&assign(&[false; 3], &[false, false, true]));
    }

    #[test]
    fn tier_budget_resplit() {
        let mut cm = tiered(100, 200, &[40, 50, 30]);
        cm.set_tier_budgets(TierBudgets { ram: 50, ssd: 100 });
        assert_eq!(cm.tier_budgets(), TierBudgets { ram: 50, ssd: 100 });
    }
}
