//! The discrete-event execution engine: runs one batch of queries on the
//! simulated cluster (Figure 2 step 5). Each query is a wave of
//! data-parallel tasks; task service time is its partition's scan time
//! (cache or disk bandwidth, with a one-time materialization penalty for
//! freshly cached views) plus its share of the query's compute cost.

use crate::cache::tier::{Tier, TierCostModel};
use crate::cache::CacheManager;
use crate::domain::query::{Query, QueryId};
use crate::sim::cluster::ClusterConfig;
use crate::sim::scheduler::{FairScheduler, Task};
use crate::util::event::EventQueue;

/// Task-completion event payload: `(query index, tenant)`. Tuple `Ord`
/// reproduces the legacy `(time, query, tenant)` heap ordering exactly,
/// so the refactor onto [`EventQueue`] is bit-identical.
type Completion = (usize, usize);

/// Result for one executed query.
#[derive(Debug, Clone)]
pub struct QueryOutcome {
    pub id: QueryId,
    pub tenant: usize,
    pub arrival: f64,
    /// First task launch time.
    pub start: f64,
    /// Last task completion time.
    pub finish: f64,
    /// True iff all required views were cached (the hit-ratio event).
    pub from_cache: bool,
    pub bytes: u64,
}

impl QueryOutcome {
    pub fn wait_time(&self) -> f64 {
        self.start - self.arrival
    }

    pub fn execution_time(&self) -> f64 {
        self.finish - self.start
    }

    pub fn flow_time(&self) -> f64 {
        self.finish - self.arrival
    }
}

/// Result of one batch execution.
#[derive(Debug, Clone)]
pub struct BatchExecution {
    pub outcomes: Vec<QueryOutcome>,
    /// Time the last task of the batch finished (== batch makespan end).
    pub end_time: f64,
}

/// The engine: stateless besides the cluster config.
#[derive(Debug, Clone, Default)]
pub struct SimEngine {
    pub config: ClusterConfig,
}

impl SimEngine {
    pub fn new(config: ClusterConfig) -> Self {
        Self { config }
    }

    /// Service time (core-seconds) to read view `v`'s scan bytes given
    /// its residency tier; consumes the materialization flag when this
    /// is the first touch of a freshly cached view (charged at disk
    /// speed plus penalty regardless of the destination tier). SSD
    /// residents read at the cost model's SSD bandwidth — slower than
    /// RAM, much faster than disk.
    fn view_io_secs(
        &self,
        scan_bytes: u64,
        tier: Option<Tier>,
        materialize: bool,
        cost: &TierCostModel,
    ) -> f64 {
        match tier {
            None => self.config.disk_secs(scan_bytes),
            Some(_) if materialize => {
                self.config.disk_secs(scan_bytes) * self.config.materialize_penalty
            }
            Some(Tier::Ram) => self.config.cache_secs(scan_bytes),
            Some(Tier::Ssd) => cost.ssd_secs(scan_bytes),
        }
    }

    /// Execute a batch starting at `start_time`. `view_scan_bytes` maps
    /// ViewId → per-query scan bytes; `cache` is consulted and its
    /// pending materializations are consumed; `weights` drives the fair
    /// scheduler pools.
    pub fn execute_batch(
        &self,
        start_time: f64,
        queries: &[Query],
        view_scan_bytes: &[u64],
        cache: &mut CacheManager,
        weights: &[f64],
    ) -> BatchExecution {
        if queries.is_empty() {
            return BatchExecution {
                outcomes: Vec::new(),
                end_time: start_time,
            };
        }

        // Build per-query task lists.
        struct QState {
            remaining: usize,
            started: Option<f64>,
            finish: f64,
            from_cache: bool,
        }
        let mut states: Vec<QState> = Vec::with_capacity(queries.len());
        let mut scheduler = FairScheduler::new(weights);

        for (qi, q) in queries.iter().enumerate() {
            // Total I/O time (core-seconds) across the query's views.
            let mut io_secs = 0.0;
            let mut all_cached = true;
            for v in &q.required_views {
                // Residency in either tier counts as a hit; in
                // single-tier mode the SSD plane is empty and this is
                // exactly the legacy `is_cached` check.
                let tier = cache.tier_of(v.0);
                all_cached &= tier.is_some();
                let materialize = tier.is_some() && cache.charge_materialization(v.0);
                io_secs += self.view_io_secs(
                    view_scan_bytes[v.0],
                    tier,
                    materialize,
                    cache.cost_model(),
                );
            }
            let n_tasks = (q.bytes_read.div_ceil(self.config.partition_bytes)).max(1) as usize;
            let per_task =
                io_secs / n_tasks as f64 + q.compute_cost / n_tasks as f64 + self.config.task_overhead;
            for _ in 0..n_tasks {
                scheduler.submit(Task {
                    query: qi,
                    tenant: q.tenant.0,
                    duration: per_task,
                });
            }
            states.push(QState {
                remaining: n_tasks,
                started: None,
                finish: start_time,
                from_cache: all_cached,
            });
        }

        // Event loop: task completions on the shared ordered queue;
        // free cores launch tasks immediately.
        let cores = self.config.total_cores();
        let mut events: EventQueue<Completion> = EventQueue::new();
        let mut now = start_time;
        let mut free = cores;

        let mut launch = |now: f64,
                          free: &mut usize,
                          scheduler: &mut FairScheduler,
                          states: &mut Vec<QState>,
                          events: &mut EventQueue<Completion>| {
            while *free > 0 {
                let Some(task) = scheduler.next_task() else {
                    break;
                };
                *free -= 1;
                let st = &mut states[task.query];
                st.started.get_or_insert(now);
                events.push(now + task.duration, (task.query, task.tenant));
            }
        };

        launch(now, &mut free, &mut scheduler, &mut states, &mut events);
        while let Some((t, (qi, tenant))) = events.pop() {
            now = t;
            free += 1;
            scheduler.task_done(tenant);
            let st = &mut states[qi];
            st.remaining -= 1;
            if st.remaining == 0 {
                st.finish = now;
            }
            launch(now, &mut free, &mut scheduler, &mut states, &mut events);
        }

        let outcomes: Vec<QueryOutcome> = queries
            .iter()
            .zip(states.iter())
            .map(|(q, st)| QueryOutcome {
                id: q.id,
                tenant: q.tenant.0,
                arrival: q.arrival,
                start: st.started.unwrap_or(start_time),
                finish: st.finish,
                from_cache: st.from_cache,
                bytes: q.bytes_read,
            })
            .collect();
        let end_time = outcomes
            .iter()
            .map(|o| o.finish)
            .fold(start_time, f64::max);
        BatchExecution { outcomes, end_time }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::domain::dataset::{GB, MB};
    use crate::domain::tenant::TenantId;
    use crate::domain::view::ViewId;
    use crate::util::mask::ConfigMask;

    fn query(id: u64, tenant: usize, views: Vec<usize>, bytes: u64) -> Query {
        Query {
            id: QueryId(id),
            tenant: TenantId(tenant),
            arrival: 0.0,
            template: "t".into(),
            required_views: views.into_iter().map(ViewId).collect(),
            bytes_read: bytes,
            compute_cost: 0.0,
        }
    }

    fn setup(cache_views: &[bool], sizes: &[u64]) -> CacheManager {
        let mut cm = CacheManager::new(100 * GB, sizes.to_vec());
        cm.update(&ConfigMask::from_bools(cache_views));
        // Drain scheduled materialization charges so tests measure
        // steady-state cache reads unless they opt in.
        for v in 0..sizes.len() {
            cm.charge_materialization(v);
        }
        cm
    }

    #[test]
    fn cached_queries_run_much_faster() {
        let engine = SimEngine::default();
        let sizes = [2 * GB];
        let q = vec![query(1, 0, vec![0], 2 * GB)];

        let mut cold = setup(&[false], &sizes);
        let cold_exec = engine.execute_batch(0.0, &q, &sizes, &mut cold, &[1.0]);

        let mut warm = setup(&[true], &sizes);
        let warm_exec = engine.execute_batch(0.0, &q, &sizes, &mut warm, &[1.0]);

        let cold_t = cold_exec.outcomes[0].execution_time();
        let warm_t = warm_exec.outcomes[0].execution_time();
        assert!(
            cold_t > 5.0 * warm_t,
            "cold={cold_t} warm={warm_t} (expect ≫)"
        );
        assert!(cold_exec.outcomes[0].from_cache == false);
        assert!(warm_exec.outcomes[0].from_cache);
    }

    #[test]
    fn materialization_penalty_applies_once() {
        let engine = SimEngine::default();
        let sizes = [GB];
        let mut cm = CacheManager::new(100 * GB, sizes.to_vec());
        cm.update(&ConfigMask::from_bools(&[true])); // freshly marked, not yet materialized

        let q1 = vec![query(1, 0, vec![0], GB)];
        let first = engine.execute_batch(0.0, &q1, &sizes, &mut cm, &[1.0]);
        let q2 = vec![query(2, 0, vec![0], GB)];
        let second = engine.execute_batch(first.end_time, &q2, &sizes, &mut cm, &[1.0]);
        // First access ≈ disk speed × penalty; second ≈ cache speed.
        assert!(
            first.outcomes[0].execution_time() > 5.0 * second.outcomes[0].execution_time()
        );
    }

    #[test]
    fn partial_cache_is_a_miss_for_hit_ratio() {
        let engine = SimEngine::default();
        let sizes = [GB, GB];
        let mut cm = setup(&[true, false], &sizes);
        let q = vec![query(1, 0, vec![0, 1], 2 * GB)];
        let exec = engine.execute_batch(0.0, &q, &sizes, &mut cm, &[1.0]);
        assert!(!exec.outcomes[0].from_cache);
        // But it still reads view 0 from memory: faster than all-disk.
        let mut cold = setup(&[false, false], &sizes);
        let cold_exec = engine.execute_batch(0.0, &q, &sizes, &mut cold, &[1.0]);
        assert!(
            exec.outcomes[0].execution_time() < cold_exec.outcomes[0].execution_time()
        );
    }

    #[test]
    fn parallelism_bounded_by_cores() {
        // One giant query: 80 cores on 10×8 config; 160 partitions ⇒ two
        // full waves. Makespan ≈ 2 × per-task time.
        let engine = SimEngine::default();
        let bytes = 160 * 128 * MB;
        let sizes = [bytes];
        let mut cm = setup(&[true], &sizes);
        let q = vec![query(1, 0, vec![0], bytes)];
        let exec = engine.execute_batch(0.0, &q, &sizes, &mut cm, &[1.0]);
        let per_task = engine.config.cache_secs(bytes) / 160.0 + engine.config.task_overhead;
        let expect = 2.0 * per_task;
        let got = exec.outcomes[0].execution_time();
        assert!((got - expect).abs() < 0.2 * expect, "got={got} expect={expect}");
    }

    #[test]
    fn fair_sharing_between_tenants() {
        // Two tenants with identical single-query workloads: finish times
        // should be close (interleaved waves), not serial.
        let engine = SimEngine::default();
        let bytes = 80 * 128 * MB; // one full wave each
        let sizes = [bytes, bytes];
        let mut cm = setup(&[true, true], &sizes);
        let qs = vec![query(1, 0, vec![0], bytes), query(2, 1, vec![1], bytes)];
        let exec = engine.execute_batch(0.0, &qs, &sizes, &mut cm, &[1.0, 1.0]);
        let f0 = exec.outcomes[0].finish;
        let f1 = exec.outcomes[1].finish;
        assert!((f0 - f1).abs() < 0.3 * f0.max(f1), "f0={f0} f1={f1}");
    }

    #[test]
    fn ssd_resident_reads_between_ram_and_disk() {
        use crate::cache::tier::{TierAssignment, TierBudgets, TierCostModel, TierSpec};
        let engine = SimEngine::default();
        let sizes = [2 * GB];
        let mk = |ram: bool, ssd: bool| {
            let mut cm = CacheManager::new_tiered(
                TierSpec {
                    budgets: TierBudgets {
                        ram: 100 * GB,
                        ssd: 100 * GB,
                    },
                    cost: TierCostModel::default(),
                },
                sizes.to_vec(),
            );
            cm.update_tiered(&TierAssignment {
                ram: ConfigMask::from_bools(&[ram]),
                ssd: ConfigMask::from_bools(&[ssd]),
            });
            cm.charge_materialization(0);
            cm
        };
        let q = vec![query(1, 0, vec![0], 2 * GB)];
        let run = |cm: &mut CacheManager| {
            engine.execute_batch(0.0, &q, &sizes, cm, &[1.0]).outcomes[0].clone()
        };
        let ram = run(&mut mk(true, false));
        let ssd = run(&mut mk(false, true));
        let disk = run(&mut mk(false, false));
        let (t_ram, t_ssd, t_disk) = (
            ram.execution_time(),
            ssd.execution_time(),
            disk.execution_time(),
        );
        assert!(
            t_ram < t_ssd && t_ssd < t_disk,
            "ram={t_ram} ssd={t_ssd} disk={t_disk}"
        );
        // Residency in the SSD tier counts as a cache hit.
        assert!(ssd.from_cache);
        assert!(!disk.from_cache);
    }

    #[test]
    fn empty_batch() {
        let engine = SimEngine::default();
        let mut cm = CacheManager::new(GB, vec![]);
        let exec = engine.execute_batch(5.0, &[], &[], &mut cm, &[1.0]);
        assert_eq!(exec.end_time, 5.0);
        assert!(exec.outcomes.is_empty());
    }

    #[test]
    fn wait_and_flow_times() {
        let engine = SimEngine::default();
        let sizes = [GB];
        let mut cm = setup(&[true], &sizes);
        let mut q = query(1, 0, vec![0], GB);
        q.arrival = 2.0;
        let exec = engine.execute_batch(10.0, &[q], &sizes, &mut cm, &[1.0]);
        let o = &exec.outcomes[0];
        assert!((o.wait_time() - 8.0).abs() < 1e-9);
        assert!(o.flow_time() > o.wait_time());
    }
}
