//! Cluster hardware model, defaulting to the paper's Table 7 testbed:
//! 10 × c3.2xlarge (8 cores each), 80 GB executor memory, 8 GB RDD cache
//! of which 6 GB is used for optimization (§5.1).

use crate::domain::dataset::{GB, MB};

/// Static description of the simulated cluster.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub nodes: usize,
    pub cores_per_node: usize,
    /// Total executor memory (bytes).
    pub executor_memory: u64,
    /// Total cache size (bytes) — 10% of executor memory in the paper.
    pub cache_total: u64,
    /// Usable cache budget for optimization (bytes) — 6 of 8 GB (§5.1).
    pub cache_budget: u64,
    /// Aggregate effective disk scan bandwidth per node (bytes/sec).
    pub disk_bw_per_node: f64,
    /// In-memory scan bandwidth per node (bytes/sec); the 10-100× gap of
    /// §1 comes from the ratio of these two.
    pub cache_bw_per_node: f64,
    /// Input partition size: one task scans one partition (Spark-style).
    pub partition_bytes: u64,
    /// Fixed per-task scheduling/launch overhead (seconds).
    pub task_overhead: f64,
    /// First access to a freshly cached view materializes it: it reads at
    /// disk bandwidth times this penalty factor (lazy caching, §5.1).
    pub materialize_penalty: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            nodes: 10,
            cores_per_node: 8,
            executor_memory: 80 * GB,
            cache_total: 8 * GB,
            cache_budget: 6 * GB,
            // Effective per-node scan bandwidth through the SparkSQL
            // stack (calibrated so the uncached service rate sits below
            // the §5.3 arrival rates, reproducing the paper's backlog
            // behaviour for STATIC — see EXPERIMENTS.md §Calibration).
            disk_bw_per_node: 25.0 * MB as f64,
            cache_bw_per_node: 2500.0 * MB as f64,
            partition_bytes: 128 * MB,
            task_overhead: 0.05,
            materialize_penalty: 1.15,
        }
    }
}

impl ClusterConfig {
    pub fn total_cores(&self) -> usize {
        self.nodes * self.cores_per_node
    }

    /// Seconds for one core to scan `bytes` from disk. Per-core share of
    /// a node's bandwidth: concurrent tasks on one node contend; we model
    /// steady state as each core sustaining bw/cores.
    pub fn disk_secs(&self, bytes: u64) -> f64 {
        bytes as f64 / (self.disk_bw_per_node / self.cores_per_node as f64)
    }

    /// Seconds for one core to scan `bytes` from the in-memory cache.
    pub fn cache_secs(&self, bytes: u64) -> f64 {
        bytes as f64 / (self.cache_bw_per_node / self.cores_per_node as f64)
    }

    /// Cache-to-disk speed ratio (sanity: the paper's 10-100×).
    pub fn speedup_ratio(&self) -> f64 {
        self.cache_bw_per_node / self.disk_bw_per_node
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_defaults() {
        let c = ClusterConfig::default();
        assert_eq!(c.total_cores(), 80);
        assert_eq!(c.executor_memory, 80 * GB);
        assert_eq!(c.cache_total, 8 * GB);
        assert_eq!(c.cache_budget, 6 * GB);
        let ratio = c.speedup_ratio();
        assert!((10.0..=100.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn scan_times_scale_linearly() {
        let c = ClusterConfig::default();
        assert!((c.disk_secs(2 * MB) / c.disk_secs(MB) - 2.0).abs() < 1e-9);
        assert!(c.cache_secs(GB) < c.disk_secs(GB));
    }
}
