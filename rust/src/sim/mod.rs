//! A discrete-event Spark-like cluster simulator — the stand-in for the
//! paper's 10-node EC2 testbed (Table 7). Queries become waves of
//! data-parallel tasks; a weighted fair scheduler assigns tasks to cores
//! per tenant pool; task service times are I/O-bound reads at disk or
//! cache bandwidth plus a compute term. See DESIGN.md §1 for why this
//! substitution preserves the paper's metrics.

pub mod cluster;
pub mod engine;
pub mod scheduler;

pub use cluster::ClusterConfig;
pub use engine::{BatchExecution, QueryOutcome, SimEngine};
