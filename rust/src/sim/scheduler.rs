//! Weighted fair slot scheduler — the simulator's stand-in for Spark's
//! fair scheduler with one pool per tenant queue whose fair-share
//! properties are proportional to queue weight (§5.1).
//!
//! When a core frees up, the pending task of the tenant with the lowest
//! weighted running-share (running_tasks / weight) is launched; ties go
//! to the tenant with fewer running tasks, then lower id (deterministic).

use std::collections::VecDeque;

/// One schedulable task.
#[derive(Debug, Clone)]
pub struct Task {
    pub query: usize,
    pub tenant: usize,
    /// Service time in seconds once started.
    pub duration: f64,
}

/// Per-tenant FIFO pools with weighted fair sharing.
#[derive(Debug)]
pub struct FairScheduler {
    weights: Vec<f64>,
    pools: Vec<VecDeque<Task>>,
    running: Vec<usize>,
}

impl FairScheduler {
    pub fn new(weights: &[f64]) -> Self {
        Self {
            weights: weights.to_vec(),
            pools: weights.iter().map(|_| VecDeque::new()).collect(),
            running: vec![0; weights.len()],
        }
    }

    pub fn submit(&mut self, task: Task) {
        assert!(task.tenant < self.pools.len());
        self.pools[task.tenant].push_back(task);
    }

    pub fn pending(&self) -> usize {
        self.pools.iter().map(|p| p.len()).sum()
    }

    pub fn running(&self) -> usize {
        self.running.iter().sum()
    }

    /// Pick and launch the next task (marks it running). None if all
    /// pools are empty.
    pub fn next_task(&mut self) -> Option<Task> {
        let mut best: Option<usize> = None;
        for t in 0..self.pools.len() {
            if self.pools[t].is_empty() {
                continue;
            }
            best = match best {
                None => Some(t),
                Some(b) => {
                    let share_t = self.running[t] as f64 / self.weights[t];
                    let share_b = self.running[b] as f64 / self.weights[b];
                    if share_t < share_b - 1e-12
                        || (share_t < share_b + 1e-12 && self.running[t] < self.running[b])
                    {
                        Some(t)
                    } else {
                        Some(b)
                    }
                }
            };
        }
        let t = best?;
        let task = self.pools[t].pop_front().unwrap();
        self.running[t] += 1;
        Some(task)
    }

    /// Mark a task of `tenant` finished.
    pub fn task_done(&mut self, tenant: usize) {
        assert!(self.running[tenant] > 0, "no running task for tenant {tenant}");
        self.running[tenant] -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(tenant: usize) -> Task {
        Task {
            query: 0,
            tenant,
            duration: 1.0,
        }
    }

    #[test]
    fn equal_weights_round_robin() {
        let mut s = FairScheduler::new(&[1.0, 1.0]);
        for _ in 0..4 {
            s.submit(task(0));
            s.submit(task(1));
        }
        let mut launched = Vec::new();
        for _ in 0..8 {
            launched.push(s.next_task().unwrap().tenant);
        }
        // Alternates between tenants while both have equal running counts.
        assert_eq!(&launched[..4], &[0, 1, 0, 1]);
    }

    #[test]
    fn weights_bias_share() {
        let mut s = FairScheduler::new(&[1.0, 3.0]);
        for _ in 0..8 {
            s.submit(task(0));
            s.submit(task(1));
        }
        let mut counts = [0usize; 2];
        for _ in 0..8 {
            counts[s.next_task().unwrap().tenant] += 1;
        }
        // With weight 3 vs 1, tenant 1 gets ~3/4 of the first 8 slots.
        assert_eq!(counts[1], 6, "counts={counts:?}");
    }

    #[test]
    fn completion_rebalances() {
        let mut s = FairScheduler::new(&[1.0, 1.0]);
        for _ in 0..3 {
            s.submit(task(0));
        }
        s.submit(task(1));
        assert_eq!(s.next_task().unwrap().tenant, 0);
        assert_eq!(s.next_task().unwrap().tenant, 1);
        // Tenant 1 has no more tasks; tenant 0 keeps getting slots.
        assert_eq!(s.next_task().unwrap().tenant, 0);
        s.task_done(0);
        s.task_done(0);
        assert_eq!(s.next_task().unwrap().tenant, 0);
        assert_eq!(s.pending(), 0);
        assert_eq!(s.running(), 2);
    }

    #[test]
    #[should_panic]
    fn done_without_running_panics() {
        let mut s = FairScheduler::new(&[1.0]);
        s.task_done(0);
    }
}
