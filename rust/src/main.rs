//! `robus` — the leader entrypoint / CLI launcher.
//!
//! Subcommands:
//!   run              one coordinator run with explicit knobs
//!   serve            online real-time service mode (live admission)
//!   cluster          sharded cache federation run (multi-shard + global fairness)
//!   experiment NAME  regenerate a paper table/figure (see `list`)
//!   list             list available experiments
//!   audit            Table 6 fairness-property audit
//!   fig3             candidate Sales view sizes (Figure 3)
//!   pruning-error    §4.3 random-weight-vector approximation sweep

use robus::alloc::PolicyKind;
use robus::coordinator::metrics::MetricsSummary;
use robus::experiments::report::{appendix_table, write_json};
use robus::experiments::runner::{
    convergence_series, run_experiment, run_with_policies,
};
use robus::experiments::{analysis, setups};
use robus::util::cli::{render_help, Args, OptSpec};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("run") => fallible(cmd_run(&args)),
        Some("serve") => fallible(cmd_serve(&args)),
        Some("cluster") => fallible(cmd_cluster(&args)),
        Some("experiment") => cmd_experiment(&args),
        Some("list") => {
            print_experiment_list();
            0
        }
        Some("audit") => cmd_audit(),
        Some("fig3") => cmd_fig3(),
        Some("pruning-error") => fallible(cmd_pruning_error(&args)),
        _ => {
            print!(
                "{}",
                render_help(
                    "robus",
                    "fair cache allocation for multi-tenant data-parallel workloads (SIGMOD'17 reproduction)",
                    &[
                        ("run", "one coordinator run (see --policy/--tenants/...)"),
                        ("serve", "online service mode (--duration/--rate/--shards/--membership auto)"),
                        ("cluster", "sharded federation (--shards/--placement/--replicate-hot)"),
                        ("experiment <name>", "regenerate a paper table/figure"),
                        ("list", "list available experiments"),
                        ("audit", "Table 6 fairness-property audit"),
                        ("fig3", "candidate Sales view sizes"),
                        ("pruning-error", "§4.3 approximation-error sweep"),
                    ],
                    &[
                        OptSpec { name: "policy", help: "STATIC|RSD|OPTP|MMF|FASTPF|MMF-MW|PF-MW", default: Some("FASTPF") },
                        OptSpec { name: "tenants", help: "number of tenants", default: Some("4") },
                        OptSpec { name: "batches", help: "number of batches", default: Some("30") },
                        OptSpec { name: "batch-secs", help: "batch interval (sim seconds)", default: Some("40") },
                        OptSpec { name: "seed", help: "rng seed", default: Some("42") },
                        OptSpec { name: "gamma", help: "stateful cache boost γ (omit = stateless)", default: None },
                        OptSpec { name: "quick", help: "cut batches down for a fast smoke run", default: None },
                        OptSpec { name: "pipeline", help: "run: overlap solve(b+1) with execute(b)", default: None },
                        OptSpec { name: "warm-start", help: "on|off: carry solver state across batches (serve default on; run/cluster off)", default: None },
                        OptSpec { name: "ram-budget", help: "run/serve/cluster: RAM cache-tier budget in GB (absent = engine default, single tier)", default: None },
                        OptSpec { name: "ssd-budget", help: "run/serve/cluster: SSD cache-tier budget in GB (requires --ram-budget; 0 = single tier)", default: None },
                        OptSpec { name: "ssd-hit-ms", help: "run/serve/cluster: SSD scan/demote cost, ms per GB per core (requires --ssd-budget)", default: None },
                        OptSpec { name: "out-dir", help: "write JSON reports here", default: Some("results") },
                        OptSpec { name: "duration", help: "serve: wall-clock seconds to accept traffic", default: Some("5") },
                        OptSpec { name: "rate", help: "serve: aggregate arrival rate (queries/sec)", default: Some("1000") },
                        OptSpec { name: "batch-ms", help: "serve: real-time batch window (ms)", default: Some("250") },
                        OptSpec { name: "queue-cap", help: "serve: per-tenant admission bound (federated: per-shard pool of tenants×bound)", default: Some("8192") },
                        OptSpec { name: "admission", help: "serve: drop|block at the queue bound", default: Some("drop") },
                        OptSpec { name: "min-qps", help: "serve: exit 1 if sustained q/s falls below", default: None },
                        OptSpec { name: "shards", help: "cluster/serve: number of cache shards (serve default 1)", default: Some("4") },
                        OptSpec { name: "placement", help: "cluster/serve: view placement, hash|pack", default: Some("hash") },
                        OptSpec { name: "replicate-hot", help: "cluster/serve: replicate views above this demand fraction", default: None },
                        OptSpec { name: "replica-decay", help: "cluster/serve: evict replicas below the threshold for K batches", default: None },
                        OptSpec { name: "rebalance-every", help: "cluster/serve: re-home views by demand every K batches", default: None },
                        OptSpec { name: "membership", help: "cluster: schedule \"add@40,kill@80\"; serve: reactive auto[:lo,hi]", default: None },
                        OptSpec { name: "warmup", help: "cluster/serve: accountant warm-up batches for added shards", default: Some("2") },
                        OptSpec { name: "workers", help: "cluster/serve: shard-step worker threads (0 = inline; default: host cores)", default: None },
                        OptSpec { name: "sim", help: "serve: drive the loop on a simulated clock (deterministic, drop admission only)", default: None },
                        OptSpec { name: "setup", help: "cluster: §5.3 workload, sales-g1..sales-g4", default: Some("sales-g2") },
                        OptSpec { name: "trace-out", help: "run/serve/cluster: write a JSONL batch trace here (spans, events, snapshots)", default: None },
                        OptSpec { name: "metrics-addr", help: "run/serve/cluster: serve live Prometheus /metrics on HOST:PORT", default: None },
                        OptSpec { name: "snapshot-secs", help: "run/serve/cluster: emit a counter snapshot into the trace every N run-clock seconds", default: None },
                    ],
                )
            );
            0
        }
    };
    std::process::exit(code);
}

/// Surface option-parse errors (`--seed abc` and friends) as exit 2
/// instead of silently running with defaults.
fn fallible(result: Result<i32, String>) -> i32 {
    match result {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    }
}

/// Parse `--warm-start on|off` strictly; absent takes the mode's
/// default (on for serve, off for run/cluster so replays stay
/// bit-identical to the historical path).
fn opt_warm_start(args: &Args, default: bool) -> Result<bool, String> {
    match args.opt("warm-start") {
        None => Ok(default),
        Some("on" | "true" | "1") => Ok(true),
        Some("off" | "false" | "0") => Ok(false),
        Some(s) => Err(format!("--warm-start expects on|off, got '{s}'")),
    }
}

/// Parse `--gamma` strictly: present-but-malformed is an error, absent
/// means stateless.
fn opt_gamma(args: &Args) -> Result<Option<f64>, String> {
    match args.opt("gamma") {
        None => Ok(None),
        Some(s) => s
            .parse::<f64>()
            .map(Some)
            .map_err(|_| format!("--gamma expects a number, got '{s}'")),
    }
}

/// Build the run's telemetry from the uniform observability flags
/// (`--trace-out FILE`, `--metrics-addr HOST:PORT`,
/// `--snapshot-secs N`), shared verbatim by `run`, `serve`, and
/// `cluster`. Flag hygiene: an unwritable trace path or unbindable
/// metrics address is a *startup* error (exit 2), never a mid-run
/// surprise.
fn telemetry_from_args(args: &Args) -> Result<robus::telemetry::Telemetry, String> {
    let mut tel = robus::telemetry::Telemetry::off();
    if let Some(path) = args.opt("trace-out") {
        tel.trace_to_file(path)
            .map_err(|e| format!("--trace-out {path}: {e}"))?;
    }
    if let Some(addr) = args.opt("metrics-addr") {
        let bound = tel
            .serve_metrics(addr)
            .map_err(|e| format!("--metrics-addr {addr}: {e}"))?;
        eprintln!("metrics: serving http://{bound}/metrics");
    }
    if let Some(s) = args.opt("snapshot-secs") {
        let secs = s
            .parse::<f64>()
            .map_err(|_| format!("--snapshot-secs expects a number, got '{s}'"))?;
        tel.snapshot_every(secs);
    }
    Ok(tel)
}

/// Parse the tier flags (`--ram-budget GB`, `--ssd-budget GB`,
/// `--ssd-hit-ms MS`) strictly, in one place for every subcommand.
/// Absent means `None`: the bit-identical single-tier path over the
/// engine's default cache budget. Flag hygiene mirrors the rest of the
/// CLI — an inconsistent combination is a startup error (exit 2), not
/// a silently-inert knob.
fn opt_tiers(args: &Args) -> Result<Option<robus::cache::tier::TierSpec>, String> {
    use robus::cache::tier::{TierBudgets, TierCostModel, TierSpec};
    let gb = |name: &str| -> Result<Option<f64>, String> {
        match args.opt(name) {
            None => Ok(None),
            Some(s) => match s.parse::<f64>() {
                Ok(v) if v >= 0.0 => Ok(Some(v)),
                _ => Err(format!("--{name} expects GB (a non-negative number), got '{s}'")),
            },
        }
    };
    let ram = gb("ram-budget")?;
    let ssd = gb("ssd-budget")?;
    let ssd_hit_ms = match args.opt("ssd-hit-ms") {
        None => None,
        Some(s) => match s.parse::<f64>() {
            Ok(v) if v > 0.0 => Some(v),
            _ => {
                return Err(format!(
                    "--ssd-hit-ms expects ms per GB (a positive number), got '{s}'"
                ))
            }
        },
    };
    if ssd_hit_ms.is_some() && ssd.is_none() {
        return Err("--ssd-hit-ms requires --ssd-budget (it prices the SSD tier)".to_string());
    }
    if ssd.is_some() && ram.is_none() {
        return Err("--ssd-budget requires --ram-budget (the RAM tier it backs)".to_string());
    }
    let Some(ram_gb) = ram else {
        return Ok(None);
    };
    if ram_gb <= 0.0 {
        return Err("--ram-budget must be positive".to_string());
    }
    let to_bytes = |g: f64| (g * (1u64 << 30) as f64) as u64;
    let mut cost = TierCostModel::default();
    if let Some(ms) = ssd_hit_ms {
        // Demotions write at the same device speed the tier reads at.
        cost.ssd_hit_ms_per_gb = ms;
        cost.demote_ms_per_gb = ms;
    }
    Ok(Some(TierSpec {
        budgets: TierBudgets {
            ram: to_bytes(ram_gb),
            ssd: ssd.map_or(0, to_bytes),
        },
        cost,
    }))
}

/// Parse `--workers` strictly; absent means auto-size the shard-step
/// pool to the host, 0 means step shards inline (no pool threads).
fn opt_workers(args: &Args) -> Result<Option<usize>, String> {
    match args.opt("workers") {
        None => Ok(None),
        Some(s) => s
            .parse::<usize>()
            .map(Some)
            .map_err(|_| format!("--workers expects an integer, got '{s}'")),
    }
}

fn cmd_run(args: &Args) -> Result<i32, String> {
    let policy_name = args.opt_or("policy", "FASTPF");
    let Some(kind) = PolicyKind::parse(policy_name) else {
        return Err(format!("unknown policy {policy_name}"));
    };
    let n_tenants = args.opt_usize("tenants", 4)?;
    let batches = args.opt_usize("batches", 30)?;
    let batch_secs = args.opt_f64("batch-secs", 40.0)?;
    let seed = args.opt_u64("seed", 42)?;
    let gamma = opt_gamma(args)?;

    use robus::workload::spec::{AccessSpec, TenantSpec};
    let specs: Vec<TenantSpec> = (0..n_tenants)
        .map(|i| TenantSpec::new(AccessSpec::g(1 + i % 4), 20.0))
        .collect();
    let mut setup = robus::experiments::ExperimentSetup {
        name: format!("run-{policy_name}"),
        universe: robus::experiments::UniverseKind::SalesOnly,
        tenant_specs: specs,
        weights: vec![1.0; n_tenants],
        batch_secs,
        n_batches: batches,
        stateful_gamma: gamma,
        seed,
        warm_start: opt_warm_start(args, false)?,
        tiers: opt_tiers(args)?,
    };
    if args.flag("quick") {
        setup.n_batches = setup.n_batches.min(6);
    }
    let policies: Vec<Box<dyn robus::alloc::Policy>> =
        vec![PolicyKind::Static.build(), kind.build()];
    let mut tel = telemetry_from_args(args)?;
    let pipeline = args.flag("pipeline");
    tel.meta(
        if pipeline { "run-pipelined" } else { "run" },
        n_tenants,
        1,
        1.0,
    );
    let out = if pipeline {
        robus::experiments::runner::run_with_policies_pipelined_tel(
            &setup,
            &policies,
            robus::coordinator::DEFAULT_PIPELINE_DEPTH,
            &tel,
        )
    } else {
        robus::experiments::runner::run_with_policies_tel(&setup, &policies, &tel)
    };
    tel.shutdown();
    println!("{}", MetricsSummary::header());
    for s in &out.summaries {
        println!("{}", s.row());
    }
    Ok(0)
}

fn cmd_serve(args: &Args) -> Result<i32, String> {
    use robus::cluster::{AutoMembership, PlacementStrategy, ServeFederationConfig};

    let policy_name = args.opt_or("policy", "FASTPF");
    let Some(kind) = PolicyKind::parse(policy_name) else {
        return Err(format!("unknown policy {policy_name}"));
    };
    let admission_name = args.opt_or("admission", "drop");
    let Some(admission) = robus::workload::AdmissionPolicy::parse(admission_name) else {
        return Err(format!(
            "unknown admission policy {admission_name} (use drop|block)"
        ));
    };
    let cfg = robus::coordinator::ServeConfig {
        common: robus::coordinator::loop_::CommonConfig {
            batch_secs: args.opt_f64("batch-ms", 250.0)? / 1e3,
            stateful_gamma: opt_gamma(args)?,
            seed: args.opt_u64("seed", 42)?,
            warm_start: opt_warm_start(args, true)?,
            tiers: opt_tiers(args)?,
        },
        duration_secs: args.opt_f64("duration", 5.0)?,
        rate_per_sec: args.opt_f64("rate", 1000.0)?,
        n_tenants: args.opt_usize("tenants", 4)?.max(1),
        queue_capacity: args.opt_usize("queue-cap", 8192)?,
        admission,
        verbose: !args.flag("quiet"),
    };
    let n_shards = args.opt_usize("shards", 1)?;
    if n_shards == 0 {
        return Err("--shards must be at least 1".to_string());
    }
    // Serve accepts only the reactive form (`auto[:lo,hi]`); resolve
    // validates the bounds (both positive, lo < hi) against the
    // configured rate before any work happens.
    let auto = match args.opt("membership") {
        None => None,
        Some(s) => Some(
            AutoMembership::parse(s)
                .and_then(|spec| spec.resolve(cfg.rate_per_sec, n_shards))
                .map_err(|e| format!("--membership: {e}"))?,
        ),
    };
    let replicate_hot = match args.opt("replicate-hot") {
        None => None,
        Some(s) => Some(s.parse::<f64>().map_err(|_| {
            format!("--replicate-hot expects a fraction, got '{s}'")
        })?),
    };
    let placement = match args.opt("placement") {
        None => PlacementStrategy::Hash,
        Some(s) => PlacementStrategy::parse(s)
            .ok_or_else(|| format!("unknown placement {s} (use hash|pack)"))?,
    };
    let replica_decay = match args.opt("replica-decay") {
        None => None,
        Some(s) => Some(s.parse::<usize>().map_err(|_| {
            format!("--replica-decay expects an integer, got '{s}'")
        })?),
    };
    if replica_decay.is_some() && replicate_hot.is_none() {
        return Err(
            "--replica-decay requires --replicate-hot (decay ages out hot-view replicas)"
                .to_string(),
        );
    }
    let rebalance_every = match args.opt("rebalance-every") {
        None => None,
        Some(s) => Some(s.parse::<usize>().map_err(|_| {
            format!("--rebalance-every expects an integer, got '{s}'")
        })?),
    };
    let workers = opt_workers(args)?;
    // The deterministic driver is single-threaded on the arrival side;
    // a blocked offer would deadlock it (see serve_federated_sim).
    let sim = args.flag("sim");
    if sim && admission != robus::workload::AdmissionPolicy::Drop {
        return Err("--sim supports only --admission drop".to_string());
    }
    // With one shard and no way to ever gain another, the federation
    // knobs are meaningless: warn rather than silently no-op.
    if n_shards == 1 && auto.is_none() {
        for (name, present) in [
            ("replicate-hot", replicate_hot.is_some()),
            ("replica-decay", replica_decay.is_some()),
            ("rebalance-every", rebalance_every.is_some()),
            ("placement", args.opt("placement").is_some()),
            ("warmup", args.opt("warmup").is_some()),
            ("workers", workers.is_some()),
        ] {
            if present {
                eprintln!(
                    "warning: --{name} has no effect on a single-shard serve \
                     without --membership auto; ignoring"
                );
            }
        }
    }

    let universe = robus::workload::Universe::sales_only();
    let tenants = robus::domain::tenant::TenantSet::equal(cfg.n_tenants);
    let engine = robus::sim::SimEngine::new(robus::sim::ClusterConfig::default());
    let policy = kind.build();
    let min_qps = args.opt_f64("min-qps", 0.0)?;
    let mut tel = telemetry_from_args(args)?;

    let queries_per_sec = if n_shards == 1 && auto.is_none() {
        // The single-node service path, byte-for-byte the pre-federated
        // semantics (pinned against the sharded path in
        // rust/tests/federated_serving.rs).
        println!(
            "robus serve: {} tenants, target {:.0} q/s, W={:.0}ms, admission={}, policy={} ({}s run)",
            cfg.n_tenants,
            cfg.rate_per_sec,
            cfg.common.batch_secs * 1e3,
            cfg.admission.name(),
            kind.name(),
            cfg.duration_secs,
        );
        let sess = robus::session::Session::serve(&universe, &tenants, &engine)
            .config(cfg.clone())
            .telemetry(&tel);
        let report = if sim {
            sess.sim().run(policy.as_ref()).0
        } else {
            sess.run(policy.as_ref())
        };
        print!("{}", report.render());
        report.queries_per_sec
    } else {
        let fcfg = ServeFederationConfig {
            replicate_hot,
            replica_decay,
            rebalance_every,
            auto,
            placement,
            warmup_batches: args.opt_usize("warmup", 2)?,
            workers,
            ..ServeFederationConfig::new(cfg.clone(), n_shards)
        };
        println!(
            "robus serve: {} shards ({} placement), {} tenants, target {:.0} q/s, \
             W={:.0}ms, admission={}, policy={}, membership={} ({}s run)",
            fcfg.n_shards,
            fcfg.placement.name(),
            cfg.n_tenants,
            cfg.rate_per_sec,
            cfg.common.batch_secs * 1e3,
            cfg.admission.name(),
            kind.name(),
            match fcfg.auto {
                Some(a) => format!("auto[{:.0},{:.0}]", a.lo_qps, a.hi_qps),
                None => "static".to_string(),
            },
            cfg.duration_secs,
        );
        let sess = robus::session::Session::serve_federated(&universe, &tenants, &engine, fcfg)
            .telemetry(&tel);
        let report = if sim {
            sess.sim().run(policy.as_ref())
        } else {
            sess.run(policy.as_ref())
        };
        print!("{}", report.render());
        report.serve.queries_per_sec
    };
    tel.shutdown();

    // Optional service-level objective: fail (exit 1) if the sustained
    // throughput fell short — this is what makes the CI smoke and the
    // nightly soak real assertions rather than crash tests.
    if queries_per_sec < min_qps {
        eprintln!(
            "FAIL: sustained {queries_per_sec:.0} q/s < required --min-qps {min_qps:.0}"
        );
        return Ok(1);
    }
    Ok(0)
}

fn cmd_cluster(args: &Args) -> Result<i32, String> {
    use robus::cluster::{FederationConfig, MembershipPlan, PlacementStrategy};
    use robus::experiments::runner::{
        run_federated_tel, run_with_policies_serial, validate_membership,
    };

    let policy_name = args.opt_or("policy", "FASTPF");
    let Some(kind) = PolicyKind::parse(policy_name) else {
        return Err(format!("unknown policy {policy_name}"));
    };
    let placement_name = args.opt_or("placement", "hash");
    let Some(placement) = PlacementStrategy::parse(placement_name) else {
        return Err(format!(
            "unknown placement {placement_name} (use hash|pack)"
        ));
    };
    let n_shards = args.opt_usize("shards", 4)?;
    if n_shards == 0 {
        return Err("--shards must be at least 1".to_string());
    }
    let replicate_hot = match args.opt("replicate-hot") {
        None => None,
        Some(s) => Some(s.parse::<f64>().map_err(|_| {
            format!("--replicate-hot expects a fraction, got '{s}'")
        })?),
    };
    let replica_decay = match args.opt("replica-decay") {
        None => None,
        Some(s) => Some(s.parse::<usize>().map_err(|_| {
            format!("--replica-decay expects an integer, got '{s}'")
        })?),
    };
    // Decay ages out replicas created by replication; without a
    // threshold there is nothing to decay — reject rather than letting
    // the flag be silently inert.
    if replica_decay.is_some() && replicate_hot.is_none() {
        return Err(
            "--replica-decay requires --replicate-hot (decay ages out hot-view replicas)"
                .to_string(),
        );
    }
    let rebalance_every = match args.opt("rebalance-every") {
        None => None,
        Some(s) => Some(s.parse::<usize>().map_err(|_| {
            format!("--rebalance-every expects an integer, got '{s}'")
        })?),
    };
    let membership = match args.opt("membership") {
        None => MembershipPlan::empty(),
        Some(s) => MembershipPlan::parse(s).map_err(|e| format!("--membership: {e}"))?,
    };
    let fed = FederationConfig {
        n_shards,
        placement,
        replicate_hot,
        rebalance_every,
        membership,
        replica_decay,
        warmup_batches: args.opt_usize("warmup", 2)?,
        warm_start: opt_warm_start(args, false)?,
        workers: opt_workers(args)?,
        ..FederationConfig::default()
    };

    // The §5.3 Sales sweeps are the federation's driving workloads.
    // (Setup names are "sales-G1".."sales-G4"; match case-insensitively.)
    let setup_name = args.opt_or("setup", "sales-g2");
    let mut setup = setups::data_sharing_sales()
        .into_iter()
        .find(|s| s.name.eq_ignore_ascii_case(setup_name))
        .ok_or_else(|| format!("unknown setup {setup_name} (use sales-g1..sales-g4)"))?;
    setup.seed = args.opt_u64("seed", 42)?;
    setup.n_batches = args.opt_usize("batches", setup.n_batches)?;
    setup.tiers = opt_tiers(args)?;
    if args.flag("quick") {
        setup.n_batches = setup.n_batches.min(6);
    }
    // Surface impossible schedules (past-the-run events, dead targets,
    // dropping below one shard) before any work happens.
    validate_membership(&setup, &fed).map_err(|e| format!("--membership: {e}"))?;

    println!(
        "robus cluster: {} shards ({} placement), {} on {}, {} batches, seed {}{}",
        fed.n_shards,
        fed.placement.name(),
        kind.name(),
        setup.name,
        setup.n_batches,
        setup.seed,
        if fed.membership.is_empty() {
            String::new()
        } else {
            format!(", membership {} events", fed.membership.events.len())
        },
    );

    // STATIC single-node serial run = the Eq. 5 speedup baseline.
    let baseline = run_with_policies_serial(&setup, &[PolicyKind::Static.build()]);
    let policy = kind.build();
    let mut tel = telemetry_from_args(args)?;
    let result = run_federated_tel(&setup, &fed, policy.as_ref(), &tel);
    tel.shutdown();
    print!("{}", result.render(Some(&baseline.runs[0])));

    // Elasticity transients: spread/throughput before, during, and
    // after each membership event, and how long the fairness spread
    // took to re-converge to ≤1.5× its pre-event level.
    let window = (setup.n_batches / 6).clamp(2, 5);
    for (b, change) in result.membership_events() {
        let t = result.transient(b, window);
        println!(
            "transient {}@{b}: spread {:.3} → {:.3} → {:.3}, q/batch {:.1} → {:.1} → {:.1}, {}",
            change.action.name(),
            t.pre_spread,
            t.during_spread,
            t.post_spread,
            t.pre_queries_per_batch,
            t.during_queries_per_batch,
            t.post_queries_per_batch,
            match t.recovery_batches {
                Some(d) => format!("re-converged after {d} batches"),
                None => "did not re-converge in-run".to_string(),
            },
        );
    }

    // Single-node same-policy reference for the scale-out comparison.
    let single = run_with_policies_serial(&setup, &[kind.build()]);
    println!(
        "single-node {}: {:.2} batches/s → federation {:.2} batches/s ({:.2}x)",
        kind.name(),
        single.runs[0].batches_per_sec(),
        result.batches_per_sec(),
        result.batches_per_sec() / single.runs[0].batches_per_sec().max(1e-12),
    );
    Ok(0)
}

fn print_experiment_list() {
    println!("experiments (use: robus experiment <name> [--quick]):");
    for (name, what) in [
        ("data-sharing-mixed", "Fig 5 + Tables 15-18 (mixed G1-G4)"),
        ("data-sharing-sales", "Fig 6 + Tables 19-22 (Sales G1-G4)"),
        ("fig7", "Fig 7 (popular-view cache-time fractions, Sales G2)"),
        ("arrival-rates", "Fig 8 + Tables 23-25 (low/mid/high)"),
        ("fig9", "Fig 9 (per-tenant speedups, setup high)"),
        ("tenant-scaling", "Fig 10 + Tables 26-28 (2/4/8 tenants)"),
        ("convergence", "Fig 11 (fairness index vs batches)"),
        ("batch-size", "Fig 12 (batch size × stateful/stateless)"),
        ("ablation-windows", "calibration ablation: hot/cold window width"),
    ] {
        println!("  {name:<22} {what}");
    }
}

fn cmd_experiment(args: &Args) -> i32 {
    let Some(name) = args.positional.first().map(|s| s.as_str()) else {
        eprintln!("usage: robus experiment <name> [--quick] [--out-dir DIR]");
        print_experiment_list();
        return 2;
    };
    let quick = args.flag("quick");
    let out_dir = args.opt_or("out-dir", "results").to_string();
    let scale = |s: setups::ExperimentSetup| if quick { s.quick(6) } else { s };

    let run_group = |list: Vec<setups::ExperimentSetup>| -> i32 {
        for setup in list {
            let setup = scale(setup);
            let out = run_experiment(&setup);
            println!("{}", appendix_table(&out));
            match write_json(&out, &out_dir) {
                Ok(p) => println!("(wrote {p})\n"),
                Err(e) => eprintln!("warn: could not write report: {e}"),
            }
        }
        0
    };

    match name {
        "data-sharing-mixed" => run_group(setups::data_sharing_mixed()),
        "data-sharing-sales" => run_group(setups::data_sharing_sales()),
        "arrival-rates" => run_group(setups::arrival_rates()),
        "tenant-scaling" => run_group(setups::tenant_scaling()),
        "fig7" => cmd_fig7(quick),
        "fig9" => cmd_fig9(quick),
        "convergence" => cmd_convergence(quick),
        "batch-size" => cmd_batch_size(quick),
        "ablation-windows" => cmd_window_ablation(quick),
        other => {
            eprintln!("unknown experiment {other}");
            print_experiment_list();
            2
        }
    }
}

fn cmd_fig7(quick: bool) -> i32 {
    // Setup G2 of the Sales sweep: three tenants on g1, one on g2.
    let mut setup = setups::data_sharing_sales()[1].clone();
    if quick {
        setup = setup.quick(6);
    }
    let out = run_experiment(&setup);
    let universe = robus::workload::Universe::sales_only();
    // Top-3 views of g1 and g2 by construction of the seeded Zipfs.
    use robus::util::rng::{Pcg64, Zipf};
    let top = |skew_seed: u64| -> Vec<usize> {
        let mut rng = Pcg64::with_stream(skew_seed, 7);
        let z = Zipf::randomized(30, 1.0, &mut rng);
        z.items_by_rank()[..3].to_vec()
    };
    println!(
        "## fig7: fraction of batches the popular views were cached ({})",
        setup.name
    );
    println!("\n| policy | g1#1 | g1#2 | g1#3 | g2#1 | g2#2 | g2#3 |");
    println!("|---|---|---|---|---|---|---|");
    for run in &out.runs {
        let frac = run.view_cache_fraction(universe.n_views());
        let mut row = format!("| {} |", run.policy);
        for seed in [1001u64, 1002] {
            for &d in &top(seed) {
                let v = universe.sales_views[d].0;
                row.push_str(&format!(" {:.2} |", frac[v]));
            }
        }
        println!("{row}");
    }
    0
}

fn cmd_fig9(quick: bool) -> i32 {
    let mut setup = setups::arrival_rates()[2].clone(); // high
    if quick {
        setup = setup.quick(6);
    }
    let out = run_experiment(&setup);
    println!("## fig9: per-tenant mean speedups over STATIC (setup high)\n");
    println!("| policy | tenant-1 | tenant-2 |");
    println!("|---|---|---|");
    for run in out.runs.iter().skip(1) {
        let x = robus::coordinator::metrics::per_tenant_speedups(run, &out.runs[0]);
        println!("| {} | {:.2} | {:.2} |", run.policy, x[0], x[1]);
    }
    0
}

fn cmd_convergence(quick: bool) -> i32 {
    let mut setup = setups::convergence();
    if quick {
        setup = setup.quick(12);
    }
    let out = run_experiment(&setup);
    println!("## fig11: fairness index vs number of batches\n");
    println!("| batches | MMF | FASTPF |");
    println!("|---|---|---|");
    let mmf = out.run_for("MMF").unwrap();
    let pf = out.run_for("FASTPF").unwrap();
    let s_mmf = convergence_series(mmf, &out.runs[0], 2);
    let s_pf = convergence_series(pf, &out.runs[0], 2);
    for ((b, jm), (_, jp)) in s_mmf.iter().zip(&s_pf) {
        println!("| {b} | {jm:.3} | {jp:.3} |");
    }
    0
}

fn cmd_batch_size(quick: bool) -> i32 {
    println!("## fig12: batch size × cache state (MMF / FASTPF, γ=2)\n");
    println!("| batch | policy | state | throughput/min | fairness |");
    println!("|---|---|---|---|---|");
    for (setup, gamma) in setups::batch_size_sweep() {
        let setup = if quick { setup.quick(6) } else { setup };
        let policies: Vec<Box<dyn robus::alloc::Policy>> = vec![
            PolicyKind::Static.build(),
            PolicyKind::Mmf.build(),
            PolicyKind::FastPf.build(),
        ];
        let out = run_with_policies(&setup, &policies);
        for s in out.summaries.iter().skip(1) {
            println!(
                "| {}s | {} | {} | {:.2} | {:.2} |",
                setup.batch_secs,
                s.policy,
                if gamma.is_some() { "stateful" } else { "stateless" },
                s.throughput_per_min,
                s.fairness_index
            );
        }
    }
    0
}

fn cmd_window_ablation(quick: bool) -> i32 {
    println!("## ablation: hot/cold window width (working-set size vs contention)\n");
    println!("| candidates | STATIC util | FASTPF util | STATIC hit | FASTPF hit |");
    println!("|---|---|---|---|---|");
    for (cands, setup) in setups::window_ablation() {
        let setup = if quick { setup.quick(6) } else { setup };
        let policies: Vec<Box<dyn robus::alloc::Policy>> =
            vec![PolicyKind::Static.build(), PolicyKind::FastPf.build()];
        let out = run_with_policies(&setup, &policies);
        let s = &out.summaries[0];
        let f = &out.summaries[1];
        println!(
            "| {} | {:.2} | {:.2} | {:.2} | {:.2} |",
            cands, s.avg_cache_utilization, f.avg_cache_utilization, s.hit_ratio, f.hit_ratio
        );
    }
    println!("\nWider windows → larger working sets → STATIC's partitions cover");
    println!("less of them while the shared policies keep adapting.");
    0
}

fn cmd_audit() -> i32 {
    use robus::alloc::instances::{table2, table3, table4, table5};
    use robus::alloc::ConfigSpace;
    use robus::fairness::properties::property_report;
    use robus::util::rng::Pcg64;

    println!("## Table 6: fairness properties of mechanisms\n");
    println!("| Algorithm | SI | PE | CORE |");
    println!("|---|---|---|---|");
    for kind in [
        PolicyKind::Lru,
        PolicyKind::Rsd,
        PolicyKind::Optp,
        PolicyKind::Mmf,
        PolicyKind::FastPf,
    ] {
        let policy = kind.build();
        let mut si = true;
        let mut pe = true;
        let mut core = true;
        for batch in [table2(), table3(), table4(4), table5()] {
            let mut rng = Pcg64::new(0);
            let alloc = policy.allocate(&batch, &mut rng);
            let space = ConfigSpace::pruned(&batch, 100, &mut Pcg64::new(1));
            let rep = property_report(&alloc, &batch, &space, 2e-3);
            si &= rep.sharing_incentive;
            pe &= rep.pareto_efficient;
            core &= rep.core;
        }
        let mark = |b: bool| if b { "yes" } else { "-" };
        println!(
            "| {} | {} | {} | {} |",
            kind.name(),
            mark(si),
            mark(pe),
            mark(core)
        );
    }
    0
}

fn cmd_fig3() -> i32 {
    println!("## fig3: cache size estimates of candidate Sales views (MB)\n");
    for (name, mb) in analysis::figure3_view_sizes_mb() {
        let bar = "#".repeat((mb / 60.0).ceil() as usize);
        println!("{name:<22} {mb:>8.0}  {bar}");
    }
    0
}

fn cmd_pruning_error(args: &Args) -> Result<i32, String> {
    let batches = args.opt_usize("batches", 200)?;
    let seed = args.opt_u64("seed", 11)?;
    println!("## §4.3 pruning approximation error ({batches} batches, 5 tenants)\n");
    println!("| random vectors | mean error |");
    println!("|---|---|");
    for m in [5usize, 25, 50] {
        let err = analysis::pruning_error(m, batches, seed);
        println!("| {m} | {:.1}% |", err * 100.0);
    }
    println!("\n(paper: 10.4% / 1.4% / 0.6%)");
    Ok(0)
}
