//! A small command-line argument parser (the offline registry has no
//! `clap`). Supports subcommands, `--flag`, `--key value`, `--key=value`,
//! and positional arguments, with typed accessors and generated help.

use std::collections::BTreeMap;

/// Declarative description of one option for help output.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// Parsed arguments: subcommand, options, flags, positionals.
///
/// Note: without an option spec, `--name value` is always parsed as an
/// option with a value; a boolean flag is a `--name` that is last or
/// followed by another `--option`. Put positionals before flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw arguments (without `argv[0]`). The first non-dashed token
    /// becomes the subcommand; later non-dashed tokens are positional.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: everything after is positional.
                    args.positional.extend(iter);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.opts.insert(body.to_string(), v);
                } else {
                    args.flags.push(body.to_string());
                }
            } else if tok.starts_with('-') && tok.len() > 1 {
                return Err(format!("short options not supported: {tok}"));
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got '{s}'")),
        }
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        self.opt_u64(name, default as u64).map(|v| v as usize)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| format!("--{name} expects a number, got '{s}'")),
        }
    }

    /// Reject any option/flag name not in `known` (catches typos early).
    pub fn check_known(&self, known: &[&str]) -> Result<(), String> {
        for k in self.opts.keys().map(|s| s.as_str()).chain(self.flags.iter().map(|s| s.as_str()))
        {
            if !known.contains(&k) {
                return Err(format!("unknown option --{k}"));
            }
        }
        Ok(())
    }
}

/// Render a help block for a subcommand.
pub fn render_help(program: &str, about: &str, subcommands: &[(&str, &str)], opts: &[OptSpec]) -> String {
    let mut out = String::new();
    out.push_str(&format!("{program} — {about}\n\nUSAGE:\n  {program} <command> [options]\n"));
    if !subcommands.is_empty() {
        out.push_str("\nCOMMANDS:\n");
        for (name, help) in subcommands {
            out.push_str(&format!("  {name:<22} {help}\n"));
        }
    }
    if !opts.is_empty() {
        out.push_str("\nOPTIONS:\n");
        for o in opts {
            let default = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            out.push_str(&format!("  --{:<20} {}{}\n", o.name, o.help, default));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["experiment", "fig5", "--seed", "7", "--policy=FASTPF", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.opt("seed"), Some("7"));
        assert_eq!(a.opt("policy"), Some("FASTPF"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["fig5"]);
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["run", "--batches", "30", "--gamma", "2.5"]);
        assert_eq!(a.opt_u64("batches", 0).unwrap(), 30);
        assert_eq!(a.opt_f64("gamma", 1.0).unwrap(), 2.5);
        assert_eq!(a.opt_u64("missing", 9).unwrap(), 9);
        assert!(parse(&["run", "--batches", "x"]).opt_u64("batches", 0).is_err());
    }

    #[test]
    fn trailing_flag_is_flag() {
        let a = parse(&["run", "--stateful"]);
        assert!(a.flag("stateful"));
        assert_eq!(a.opt("stateful"), None);
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["run", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
        assert!(!a.flag("not-a-flag"));
    }

    #[test]
    fn unknown_option_detection() {
        let a = parse(&["run", "--seed", "1", "--oops"]);
        assert!(a.check_known(&["seed"]).is_err());
        assert!(a.check_known(&["seed", "oops"]).is_ok());
    }

    #[test]
    fn short_options_rejected() {
        assert!(Args::parse(vec!["-x".to_string()]).is_err());
    }

    #[test]
    fn help_renders() {
        let help = render_help(
            "robus",
            "fair cache allocation",
            &[("run", "run a workload")],
            &[OptSpec { name: "seed", help: "rng seed", default: Some("42") }],
        );
        assert!(help.contains("robus"));
        assert!(help.contains("--seed"));
        assert!(help.contains("[default: 42]"));
    }
}
