//! A minimal scoped worker pool over boxed jobs — the generic sibling
//! of the shard-typed pool in `cluster::runtime`. Threads are created
//! once per `with_worker_pool` call and multiplex every job submitted
//! during its body; nothing inside the body spawns. Used by the
//! pipelined coordinator (`coordinator::pipeline`) for its planner
//! thread, and available to any other long-running host-side work.
//!
//! Jobs are `FnOnce() + Send + 'env`: they may borrow anything that
//! outlives the `with_worker_pool` call itself, so state a job needs
//! must be created *before* entering the pool (see `run_pipelined`,
//! which builds its planner first for exactly this reason).

use std::sync::Arc;

use crate::util::sync::{mpsc, Mutex};

type Job<'env> = Box<dyn FnOnce() + Send + 'env>;

/// Handle to a live pool; [`WorkerPool::submit`] hands jobs to free
/// workers. Dropping it (done by [`with_worker_pool`] on exit) closes
/// the job channel, which is what terminates the workers.
pub struct WorkerPool<'env> {
    tx: mpsc::Sender<Job<'env>>,
}

impl<'env> WorkerPool<'env> {
    /// Queue one job; whichever worker is free picks it up. A panicking
    /// job tears the pool down and resurfaces at the scope join, like a
    /// panic on a directly spawned scoped thread.
    pub fn submit(&self, job: impl FnOnce() + Send + 'env) {
        self.tx
            .send(Box::new(job))
            .expect("worker pool hung up before shutdown");
    }
}

/// Run `f` with a pool of `workers` threads (clamped to at least 1):
/// spawn once, hand `f` the submit handle, then close the channel and
/// join the workers. Returns `f`'s result.
pub fn with_worker_pool<'env, R>(
    workers: usize,
    f: impl FnOnce(&WorkerPool<'env>) -> R,
) -> R {
    let workers = workers.max(1);
    let (tx, rx) = mpsc::channel::<Job<'env>>();
    let rx = Arc::new(Mutex::new(rx));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let rx = Arc::clone(&rx);
            scope.spawn(move || loop {
                // Hold the shared-receiver lock only for the dequeue.
                let job = { rx.lock().expect("job queue poisoned").recv() };
                match job {
                    Ok(job) => job(),
                    Err(_) => break, // channel closed: pool shutting down
                }
            });
        }
        let pool = WorkerPool { tx };
        let out = f(&pool);
        // Dropping the handle drops the sender; every worker's next
        // recv errors and it exits, letting the scope join cleanly.
        drop(pool);
        out
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn jobs_run_and_join_before_return() {
        let counter = AtomicUsize::new(0);
        with_worker_pool(3, |pool| {
            for _ in 0..20 {
                pool.submit(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        // The pool joins its workers before returning, so every
        // submitted job has finished here.
        assert_eq!(counter.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn jobs_overlap_the_calling_thread() {
        let mut out = Vec::new();
        let (tx, rx) = mpsc::channel::<usize>();
        with_worker_pool(2, |pool| {
            for i in 0..8usize {
                let tx = tx.clone();
                pool.submit(move || tx.send(i * i).unwrap());
            }
            drop(tx);
            // The calling thread keeps working while jobs run.
            out.extend(rx.iter().take(8));
        });
        out.sort_unstable();
        assert_eq!(out, vec![0, 1, 4, 9, 16, 25, 36, 49]);
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let ran = AtomicUsize::new(0);
        with_worker_pool(0, |pool| {
            pool.submit(|| {
                ran.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    // Spin-waits on a worker under wall-clock scheduling — excluded
    // from the Miri subset (spin loops crawl under the interpreter).
    #[cfg_attr(miri, ignore)]
    fn long_job_does_not_block_other_workers() {
        // One worker parks on a gate; the other must still drain the
        // remaining jobs — submit distributes over free workers.
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let done = AtomicUsize::new(0);
        with_worker_pool(2, |pool| {
            pool.submit(move || {
                gate_rx.recv().unwrap();
            });
            for _ in 0..4 {
                pool.submit(|| {
                    done.fetch_add(1, Ordering::SeqCst);
                });
            }
            while done.load(Ordering::SeqCst) < 4 {
                std::thread::yield_now();
            }
            gate_tx.send(()).unwrap();
        });
        assert_eq!(done.load(Ordering::SeqCst), 4);
    }
}
