//! Deterministic pseudo-random number generation and the sampling
//! distributions used by the workload generators and randomized policies.
//!
//! The offline build environment has no `rand` crate, so we implement a
//! small, well-tested PCG64 (XSL-RR 128/64) generator from scratch plus
//! the distributions the paper's evaluation requires: uniform, normal
//! (Box–Muller), exponential, Poisson (Knuth / PTRD-lite), and Zipf
//! (rejection-free inverse-CDF over a finite support, which is exactly
//! what "Zipf distribution over 30 datasets" in §5.1 needs).

/// SplitMix64 finalizer: a cheap, high-quality 64-bit mixing function.
/// Used wherever a deterministic hash of a small integer is needed
/// without carrying generator state — consistent-hash placement points
/// (`cluster::placement`), per-tenant seed derivation (`robus serve`),
/// and replica spreading in the federation router.
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// PCG-XSL-RR-128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
///
/// Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation" (2014).
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed with a fixed stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream selector so independent
    /// subsystems (arrival process, access process, policy sampling) can
    /// share a seed without sharing a sequence.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    #[inline]
    fn step(&mut self) {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1] — safe as a log() argument.
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        1.0 - self.next_f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0) is undefined");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let threshold = n.wrapping_neg() % n;
            while lo < threshold {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (polar-free variant; we accept the
    /// two-transcendental cost, this is not on the hot path).
    pub fn normal(&mut self, mean: f64, std_dev: f64) -> f64 {
        let u1 = self.next_f64_open();
        let u2 = self.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std_dev * z
    }

    /// Exponential with the given rate (mean = 1/rate). Used for Poisson
    /// inter-arrival gaps.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -self.next_f64_open().ln() / rate
    }

    /// Poisson-distributed count with the given mean. Knuth's product
    /// method for small means; normal approximation above 30 (the paper's
    /// per-batch query counts keep means well below that, the fallback is
    /// for generality).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        assert!(mean >= 0.0);
        if mean == 0.0 {
            return 0;
        }
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64_open();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.normal(mean, mean.sqrt());
            if x < 0.0 {
                0
            } else {
                x.round() as u64
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.index(i + 1);
            items.swap(i, j);
        }
    }

    /// A random permutation of 0..n (used by Random Serial Dictatorship).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample an index from an explicit (unnormalized, non-negative)
    /// weight vector. Panics if all weights are zero.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(
            total > 0.0 && total.is_finite(),
            "weighted_index needs positive finite total, got {total}"
        );
        let mut target = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            debug_assert!(w >= 0.0);
            if target < w {
                return i;
            }
            target -= w;
        }
        // Floating-point slack: return the last positive-weight index.
        weights
            .iter()
            .rposition(|&w| w > 0.0)
            .expect("at least one positive weight")
    }

    /// A random point on the unit L2 sphere in `dim` dimensions with
    /// non-negative coordinates — the random weight vectors of the
    /// configuration-pruning heuristic (§4.3).
    pub fn unit_weight_vector(&mut self, dim: usize) -> Vec<f64> {
        assert!(dim > 0);
        loop {
            let v: Vec<f64> = (0..dim).map(|_| self.normal(0.0, 1.0).abs()).collect();
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 1e-12 {
                return v.into_iter().map(|x| x / norm).collect();
            }
        }
    }
}

/// A finite Zipf distribution over ranks 0..n with exponent `s`:
/// P(rank k) ∝ 1/(k+1)^s. Precomputes the CDF for O(log n) sampling.
/// This matches the paper's "Zipf distribution over 30 Sales datasets"
/// (§5.1) where a permutation maps ranks to datasets so each of g1..g4
/// can be "skewed towards a different subset of datasets".
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
    /// rank -> item index
    perm: Vec<usize>,
}

impl Zipf {
    /// Identity-permuted Zipf over n items.
    pub fn new(n: usize, exponent: f64) -> Self {
        Self::with_permutation(n, exponent, (0..n).collect())
    }

    /// Zipf with rank r mapped to item `perm[r]`.
    pub fn with_permutation(n: usize, exponent: f64, perm: Vec<usize>) -> Self {
        assert!(n > 0);
        assert_eq!(perm.len(), n);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / ((k + 1) as f64).powf(exponent);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Self { cdf, perm }
    }

    /// Zipf whose rank→item mapping is a random permutation drawn from
    /// `rng` — the mechanism for generating distinct g1..g4 skews.
    pub fn randomized(n: usize, exponent: f64, rng: &mut Pcg64) -> Self {
        Self::with_permutation(n, exponent, rng.permutation(n))
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Sample an item index.
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        let rank = match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i,
        };
        self.perm[rank.min(self.cdf.len() - 1)]
    }

    /// Probability mass assigned to item `item`.
    pub fn pmf(&self, item: usize) -> f64 {
        let rank = self
            .perm
            .iter()
            .position(|&p| p == item)
            .expect("item not in support");
        let lo = if rank == 0 { 0.0 } else { self.cdf[rank - 1] };
        self.cdf[rank] - lo
    }

    /// Items ordered by decreasing probability (rank order).
    pub fn items_by_rank(&self) -> &[usize] {
        &self.perm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix64_is_deterministic_and_spreads() {
        assert_eq!(mix64(0), mix64(0));
        // Distinct small inputs land far apart (no trivial collisions
        // over the ranges we hash: view ids, shard ids, tenant ids).
        let mut seen = std::collections::HashSet::new();
        for x in 0..10_000u64 {
            assert!(seen.insert(mix64(x)), "collision at {x}");
        }
        // High bits move even for consecutive inputs.
        assert_ne!(mix64(1) >> 32, mix64(2) >> 32);
    }

    #[test]
    fn pcg_is_deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        let first: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        let mut a2 = Pcg64::new(42);
        let other: Vec<u64> = (0..8).map(|_| a2.next_u64()).collect();
        assert_ne!(first, other);
    }

    #[test]
    fn streams_are_independent_sequences() {
        let mut a = Pcg64::with_stream(7, 1);
        let mut b = Pcg64::with_stream(7, 2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Pcg64::new(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Pcg64::new(2);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            let v = rng.below(7) as usize;
            counts[v] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 10_000).abs() < 600, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal(5.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.03, "mean={mean}");
        assert!((var - 4.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::new(4);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.03, "mean={mean}");
    }

    #[test]
    fn poisson_mean_and_variance() {
        let mut rng = Pcg64::new(5);
        for &lambda in &[0.5, 3.0, 20.0, 60.0] {
            let n = 50_000;
            let xs: Vec<f64> = (0..n).map(|_| rng.poisson(lambda) as f64).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
            assert!((mean - lambda).abs() < lambda.max(1.0) * 0.05, "λ={lambda} mean={mean}");
            assert!((var - lambda).abs() < lambda.max(1.0) * 0.12, "λ={lambda} var={var}");
        }
    }

    #[test]
    fn poisson_zero_mean() {
        let mut rng = Pcg64::new(6);
        assert_eq!(rng.poisson(0.0), 0);
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut rng = Pcg64::new(7);
        let p = rng.permutation(50);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Pcg64::new(8);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0u32; 3];
        for _ in 0..40_000 {
            counts[rng.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.2, "ratio={ratio}");
    }

    #[test]
    #[should_panic]
    fn weighted_index_all_zero_panics() {
        let mut rng = Pcg64::new(9);
        rng.weighted_index(&[0.0, 0.0]);
    }

    #[test]
    fn unit_weight_vector_is_unit_and_nonnegative() {
        let mut rng = Pcg64::new(10);
        for dim in [1, 2, 5, 16] {
            let v = rng.unit_weight_vector(dim);
            assert_eq!(v.len(), dim);
            let norm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((norm - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn zipf_is_skewed_and_normalized() {
        let z = Zipf::new(30, 1.0);
        let total: f64 = (0..30).map(|i| z.pmf(i)).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(z.pmf(0) > z.pmf(1));
        assert!(z.pmf(1) > z.pmf(10));
        // Head heaviness: rank-0 mass for s=1, n=30 is 1/H_30 ≈ 0.2503.
        assert!((z.pmf(0) - 0.2503).abs() < 0.001);
    }

    #[test]
    fn zipf_sampling_matches_pmf() {
        let mut rng = Pcg64::new(11);
        let z = Zipf::new(10, 1.2);
        let n = 200_000;
        let mut counts = vec![0u32; 10];
        for _ in 0..n {
            counts[z.sample(&mut rng)] += 1;
        }
        for i in 0..10 {
            let emp = counts[i] as f64 / n as f64;
            assert!((emp - z.pmf(i)).abs() < 0.01, "i={i} emp={emp} pmf={}", z.pmf(i));
        }
    }

    #[test]
    fn zipf_permutation_reskews() {
        let mut rng = Pcg64::new(12);
        let z1 = Zipf::randomized(30, 1.0, &mut rng);
        let z2 = Zipf::randomized(30, 1.0, &mut rng);
        // Same shape, (almost surely) different favourite item.
        assert_ne!(z1.items_by_rank()[..5], z2.items_by_rank()[..5]);
        let top1 = z1.items_by_rank()[0];
        assert!((z1.pmf(top1) - 0.2503).abs() < 0.001);
    }
}
