//! A bounded model checker for the crate's lock-free protocols — the
//! loom pattern, self-built (std-only, zero deps, like everything under
//! `util/`). `check` runs a closure many times, each time forcing a
//! different thread interleaving, until every schedule within the
//! preemption bound has been explored (or a bound is hit).
//!
//! How it works:
//! - **Cooperative serialization.** Threads created with [`spawn`] are
//!   real OS threads, but a shared scheduler (`Exec`) lets exactly one
//!   run at a time. Every operation on a [`crate::util::sync`] wrapper
//!   (atomic access, mutex lock/unlock, channel send/recv) is a *yield
//!   point*: the running thread hands control to the scheduler, which
//!   picks who runs next.
//! - **DFS over schedules.** Whenever more than one thread is runnable,
//!   the scheduler records a decision. After an execution completes, the
//!   deepest decision with an unexplored alternative is flipped and the
//!   prefix replayed — classic stateless DFS with backtracking. A
//!   CHESS-style *preemption bound* prunes schedules that switch away
//!   from a runnable thread more than `preemption_bound` times, which
//!   keeps exploration exhaustive-within-bound and tractable.
//! - **Happens-before tracking.** Each thread carries a vector clock.
//!   `Release` stores (and release-sequence RMWs) attach the writer's
//!   clock to the atomic location; `Acquire` loads join it. Channel
//!   sends carry the sender's clock to the receiver; mutex unlock/lock
//!   edges do the same. A [`RaceCell`] is plain (non-atomic) data whose
//!   reads and writes are checked against those clocks — two accesses
//!   that are not ordered by happens-before fail the execution as a
//!   data race, with the interleaving trace attached.
//!
//! Scope, honestly stated: atomic *values* follow the interleaving
//! order (sequentially consistent per location). `Ordering` arguments
//! do not produce weak-memory value anomalies; they drive the
//! happens-before bookkeeping. An ordering bug therefore surfaces as a
//! data race on the payload the atomic was supposed to publish (see the
//! seeded `Release`→`Relaxed` mutation test in
//! `rust/tests/model_concurrency.rs`), not as a stale atomic read.
//! That is exactly the failure mode that matters for the router-epoch,
//! pool, and trace-writer protocols this checker pins.
//!
//! Caveat for test authors: shared state must live at a stable address
//! before model threads touch it (construct atomics inside their
//! `Arc`/`Box` and don't move the owner afterwards) — locations are
//! keyed by address. All threads must be created with [`spawn`] (a raw
//! `std::thread::spawn` would escape the scheduler), and joined or
//! leaked-on-abort; `check` panics on the first failing interleaving.

use std::any::Any;
use std::cell::RefCell;
use std::collections::HashMap;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::atomic::{AtomicU64 as StdAtomicU64, Ordering as StdOrdering};
use std::sync::{Arc, Condvar, Mutex as StdMutex, MutexGuard as StdMutexGuard, Once};

/// Maximum trace lines replayed in a failure report.
const TRACE_TAIL: usize = 120;

// ---------------------------------------------------------------------------
// Vector clocks
// ---------------------------------------------------------------------------

/// A per-thread vector clock; index = model thread id.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct VClock(Vec<u64>);

impl VClock {
    fn grow(&mut self, n: usize) {
        if self.0.len() < n {
            self.0.resize(n, 0);
        }
    }

    fn tick(&mut self, tid: usize) {
        self.grow(tid + 1);
        self.0[tid] += 1;
    }

    fn join(&mut self, other: &VClock) {
        self.grow(other.0.len());
        for (i, &v) in other.0.iter().enumerate() {
            if v > self.0[i] {
                self.0[i] = v;
            }
        }
    }

    fn get(&self, tid: usize) -> u64 {
        self.0.get(tid).copied().unwrap_or(0)
    }
}

// ---------------------------------------------------------------------------
// Scheduler state
// ---------------------------------------------------------------------------

/// What a blocked thread is waiting on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ResKey {
    Mutex(usize),
    Chan(u64),
    Thread(usize),
}

#[derive(Clone, Debug, PartialEq, Eq)]
enum Status {
    Runnable,
    Blocked(ResKey),
    Finished,
}

#[derive(Debug)]
struct ThreadInfo {
    status: Status,
    clock: VClock,
}

/// One recorded scheduling decision (taken where >1 thread was
/// runnable): which threads were enabled, which index was chosen, and
/// which thread was running when the decision was made (for preemption
/// accounting).
#[derive(Clone, Debug)]
struct Decision {
    enabled: Vec<usize>,
    chosen: usize,
    running: usize,
}

#[derive(Debug, Default)]
struct AtomicMeta {
    /// Clock attached by the last release store / release sequence;
    /// `None` after a plain (non-release) store broke the chain.
    release: Option<VClock>,
}

#[derive(Debug, Default)]
struct MutexMeta {
    owner: Option<usize>,
    release: Option<VClock>,
}

struct Core {
    threads: Vec<ThreadInfo>,
    active: usize,
    /// Forced choices for the DFS prefix being replayed.
    schedule: Vec<usize>,
    decisions: Vec<Decision>,
    trace: Vec<String>,
    atomics: HashMap<usize, AtomicMeta>,
    mutexes: HashMap<usize, MutexMeta>,
    steps: usize,
    max_steps: usize,
    failure: Option<String>,
    aborting: bool,
    completed: bool,
}

struct Exec {
    core: StdMutex<Core>,
    cv: Condvar,
}

/// Panic payload used to unwind model threads when an execution aborts
/// (failure found, or teardown). Never reported as a user panic.
struct AbortToken;

/// Panic payload for *deliberate* panics inside model tests (e.g. the
/// pool's panic-propagation protocol): behaves like any user panic but
/// is suppressed by the quiet panic hook, so exploring thousands of
/// panicking interleavings does not flood stderr with backtraces.
pub struct QuietPanic(pub &'static str);

thread_local! {
    static CTX: RefCell<Option<(Arc<Exec>, usize)>> = const { RefCell::new(None) };
}

fn set_ctx(exec: Arc<Exec>, tid: usize) {
    CTX.with(|c| *c.borrow_mut() = Some((exec, tid)));
}

fn clear_ctx() {
    CTX.with(|c| *c.borrow_mut() = None);
}

fn try_ctx() -> Option<(Arc<Exec>, usize)> {
    CTX.with(|c| c.borrow().clone())
}

fn ctx() -> (Arc<Exec>, usize) {
    try_ctx().expect("model operation outside a model::check run")
}

/// True when the calling thread is running inside a `check` execution —
/// the `util::sync` wrappers consult this to decide whether to route
/// through the scheduler or behave exactly like `std::sync`.
pub(crate) fn in_model() -> bool {
    CTX.with(|c| c.borrow().is_some())
}

fn abort_unwind() -> ! {
    panic::panic_any(AbortToken)
}

fn panic_message(p: &(dyn Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else if let Some(q) = p.downcast_ref::<QuietPanic>() {
        q.0.to_string()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Install (once, chained) a panic hook that stays silent for the
/// checker's own control-flow panics; everything else goes to the
/// previous hook untouched.
fn install_quiet_hook() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = panic::take_hook();
        panic::set_hook(Box::new(move |info| {
            let quiet = info.payload().downcast_ref::<AbortToken>().is_some()
                || info.payload().downcast_ref::<QuietPanic>().is_some();
            if !quiet {
                prev(info);
            }
        }));
    });
}

impl Exec {
    fn new(schedule: Vec<usize>, max_steps: usize) -> Exec {
        Exec {
            core: StdMutex::new(Core {
                threads: vec![ThreadInfo {
                    status: Status::Runnable,
                    clock: VClock::default(),
                }],
                active: 0,
                schedule,
                decisions: Vec::new(),
                trace: Vec::new(),
                atomics: HashMap::new(),
                mutexes: HashMap::new(),
                steps: 0,
                max_steps,
                failure: None,
                aborting: false,
                completed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Lock the core, recovering from poison (a panicking model thread
    /// must not wedge the whole exploration).
    fn lock_core(&self) -> StdMutexGuard<'_, Core> {
        self.core.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// The central yield point: record the op, advance the caller's
    /// clock, optionally block the caller, pick who runs next, and
    /// return once the caller is scheduled again.
    fn reschedule(&self, me: usize, desc: &str, block_on: Option<ResKey>) {
        let mut core = self.lock_core();
        if core.aborting {
            drop(core);
            abort_unwind();
        }
        core.trace.push(format!("t{me}: {desc}"));
        core.threads[me].clock.tick(me);
        if let Some(key) = block_on {
            core.threads[me].status = Status::Blocked(key);
        }
        self.pick_next(&mut core, me);
        loop {
            if core.aborting {
                drop(core);
                abort_unwind();
            }
            if core.active == me && core.threads[me].status == Status::Runnable {
                return;
            }
            core = self
                .cv
                .wait(core)
                .unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Choose the next thread to run. Called with the core locked, by
    /// the thread that just yielded/blocked/finished.
    fn pick_next(&self, core: &mut Core, me: usize) {
        if core.aborting || core.completed {
            self.cv.notify_all();
            return;
        }
        core.steps += 1;
        if core.steps > core.max_steps {
            core.failure = Some(format!(
                "step budget ({}) exceeded — livelock or an unbounded loop in the model body",
                core.max_steps
            ));
            core.aborting = true;
            self.cv.notify_all();
            return;
        }
        let enabled: Vec<usize> = core
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| t.status == Status::Runnable)
            .map(|(i, _)| i)
            .collect();
        if enabled.is_empty() {
            if core.threads.iter().all(|t| t.status == Status::Finished) {
                core.completed = true;
            } else {
                let stuck: Vec<String> = core
                    .threads
                    .iter()
                    .enumerate()
                    .filter(|(_, t)| matches!(t.status, Status::Blocked(_)))
                    .map(|(i, t)| format!("t{i} {:?}", t.status))
                    .collect();
                core.failure =
                    Some(format!("deadlock: no runnable thread ({})", stuck.join(", ")));
                core.aborting = true;
            }
            self.cv.notify_all();
            return;
        }
        let choice = if enabled.len() == 1 {
            0
        } else {
            let forced = core.schedule.get(core.decisions.len()).copied();
            // Default policy past the forced prefix: stay on the current
            // thread when it is still enabled (non-preemptive), else the
            // lowest-id runnable one. Alternatives are explored by the
            // DFS flipping recorded decisions.
            let idx = forced
                .unwrap_or_else(|| enabled.iter().position(|&t| t == me).unwrap_or(0))
                .min(enabled.len() - 1);
            core.decisions.push(Decision {
                enabled: enabled.clone(),
                chosen: idx,
                running: me,
            });
            idx
        };
        core.active = enabled[choice];
        self.cv.notify_all();
    }

    /// First scheduling of a freshly spawned thread.
    fn wait_until_scheduled(&self, tid: usize) {
        let mut core = self.lock_core();
        loop {
            if core.aborting {
                drop(core);
                abort_unwind();
            }
            if core.active == tid && core.threads[tid].status == Status::Runnable {
                return;
            }
            core = self
                .cv
                .wait(core)
                .unwrap_or_else(|p| p.into_inner());
        }
    }

    /// A model thread's body returned (or panicked with `user_panic`).
    fn thread_finished(&self, me: usize, user_panic: Option<String>) {
        let mut core = self.lock_core();
        core.threads[me].status = Status::Finished;
        core.trace.push(format!("t{me}: finished"));
        if let Some(msg) = user_panic {
            if !core.aborting {
                core.failure = Some(format!("thread t{me} panicked: {msg}"));
                core.aborting = true;
            }
            self.cv.notify_all();
            return;
        }
        for t in core.threads.iter_mut() {
            if t.status == Status::Blocked(ResKey::Thread(me)) {
                t.status = Status::Runnable;
            }
        }
        self.pick_next(&mut core, me);
    }

    /// A model thread unwound with an `AbortToken`: mark it gone without
    /// touching the failure state the abort is delivering.
    fn thread_finished_quiet(&self, me: usize) {
        let mut core = self.lock_core();
        core.threads[me].status = Status::Finished;
        self.cv.notify_all();
    }

    /// Record `msg` as the execution's failure, wake everyone, unwind.
    fn fail(&self, mut core: StdMutexGuard<'_, Core>, msg: String) -> ! {
        if !core.aborting {
            let tail: Vec<&str> = core
                .trace
                .iter()
                .rev()
                .take(TRACE_TAIL)
                .map(String::as_str)
                .collect();
            let trace: Vec<&str> = tail.into_iter().rev().collect();
            core.failure = Some(format!("{msg}\n--- interleaving ---\n{}", trace.join("\n")));
            core.aborting = true;
        }
        self.cv.notify_all();
        drop(core);
        abort_unwind()
    }

    /// Block the controller until the execution completed or aborted.
    fn wait_done(&self) {
        let mut core = self.lock_core();
        while !(core.completed || core.aborting) {
            core = self
                .cv
                .wait(core)
                .unwrap_or_else(|p| p.into_inner());
        }
    }

    fn outcome(&self) -> (Option<String>, Vec<Decision>) {
        let core = self.lock_core();
        (core.failure.clone(), core.decisions.clone())
    }
}

fn wake_waiters(core: &mut Core, key: ResKey) {
    for t in core.threads.iter_mut() {
        if t.status == Status::Blocked(key) {
            t.status = Status::Runnable;
        }
    }
}

// ---------------------------------------------------------------------------
// Operations called by the util::sync wrappers
// ---------------------------------------------------------------------------

/// How an atomic access interacts with the release chain of its
/// location.
#[derive(Clone, Copy, Debug)]
pub(crate) enum AccessKind {
    Load,
    Store,
    Rmw,
}

fn apply_atomic_hb(core: &mut Core, me: usize, addr: usize, kind: AccessKind, order: Ordering) {
    // ordering: classification only — the orderings below are the
    // *caller's*; this function is the model's HB bookkeeping, not a
    // memory-access site.
    let acquires = !matches!(kind, AccessKind::Store)
        && matches!(order, Ordering::Acquire | Ordering::AcqRel | Ordering::SeqCst);
    let releases = !matches!(kind, AccessKind::Load)
        && matches!(order, Ordering::Release | Ordering::AcqRel | Ordering::SeqCst);
    if acquires {
        let rel = core.atomics.get(&addr).and_then(|m| m.release.clone());
        if let Some(r) = rel {
            core.threads[me].clock.join(&r);
        }
    }
    match kind {
        AccessKind::Load => {}
        AccessKind::Store => {
            let snap = if releases {
                Some(core.threads[me].clock.clone())
            } else {
                // A plain store breaks the location's release chain:
                // later acquire loads get no edge to earlier releases.
                None
            };
            core.atomics.entry(addr).or_default().release = snap;
        }
        AccessKind::Rmw => {
            if releases {
                let snap = core.threads[me].clock.clone();
                let slot = &mut core.atomics.entry(addr).or_default().release;
                match slot {
                    Some(r) => r.join(&snap),
                    None => *slot = Some(snap),
                }
            }
            // A relaxed RMW continues an existing release sequence:
            // leave the attached clock as-is.
        }
    }
}

/// Yield, then perform `op` (the real `std` atomic op) at the scheduled
/// point, applying happens-before per `kind`/`order`.
pub(crate) fn atomic_access<R>(
    addr: usize,
    desc: &str,
    kind: AccessKind,
    order: Ordering,
    op: impl FnOnce() -> R,
) -> R {
    let (exec, me) = ctx();
    exec.reschedule(me, desc, None);
    let mut core = exec.lock_core();
    let r = op();
    apply_atomic_hb(&mut core, me, addr, kind, order);
    r
}

/// Compare-exchange: RMW semantics with the success ordering when `op`
/// returns `Ok`, load semantics with the failure ordering otherwise.
pub(crate) fn atomic_cas<V>(
    addr: usize,
    desc: &str,
    success: Ordering,
    failure: Ordering,
    op: impl FnOnce() -> Result<V, V>,
) -> Result<V, V> {
    let (exec, me) = ctx();
    exec.reschedule(me, desc, None);
    let mut core = exec.lock_core();
    let r = op();
    match &r {
        Ok(_) => apply_atomic_hb(&mut core, me, addr, AccessKind::Rmw, success),
        Err(_) => apply_atomic_hb(&mut core, me, addr, AccessKind::Load, failure),
    }
    r
}

/// Model-aware mutex acquire: yields, then takes ownership or blocks
/// until the owner releases. The unlock→lock happens-before edge rides
/// on the mutex's release clock.
pub(crate) fn mutex_lock(addr: usize) {
    let (exec, me) = ctx();
    loop {
        exec.reschedule(me, "mutex.lock", None);
        let mut core = exec.lock_core();
        let free = {
            let m = core.mutexes.entry(addr).or_default();
            if m.owner.is_none() {
                m.owner = Some(me);
                true
            } else {
                false
            }
        };
        if free {
            let rel = core.mutexes.get(&addr).and_then(|m| m.release.clone());
            if let Some(r) = rel {
                core.threads[me].clock.join(&r);
            }
            return;
        }
        drop(core);
        exec.reschedule(me, "mutex.blocked", Some(ResKey::Mutex(addr)));
    }
}

/// Release a model mutex. Called from guard `Drop`, so it must never
/// panic — abort delivery waits for the thread's next yield point.
pub(crate) fn mutex_unlock(addr: usize) {
    let Some((exec, me)) = try_ctx() else { return };
    let mut core = exec.lock_core();
    let my = core.threads[me].clock.clone();
    {
        let m = core.mutexes.entry(addr).or_default();
        m.owner = None;
        m.release = Some(my);
    }
    wake_waiters(&mut core, ResKey::Mutex(addr));
}

/// Process-global channel id allocator (ids key blocked-waiter lists;
/// endpoints move between threads, so addresses would not do).
pub(crate) fn new_chan_id() -> u64 {
    static NEXT: StdAtomicU64 = StdAtomicU64::new(1);
    // ordering: Relaxed pairs with nothing — this is a unique-id
    // counter, not a publication.
    NEXT.fetch_add(1, StdOrdering::Relaxed)
}

/// Yield point before a channel operation.
pub(crate) fn chan_yield(id: u64, desc: &str) {
    let (exec, me) = ctx();
    exec.reschedule(me, &format!("{desc}(ch{id})"), None);
}

/// Block until another endpoint operation on channel `id` wakes us.
pub(crate) fn chan_block(id: u64) {
    let (exec, me) = ctx();
    exec.reschedule(me, "chan.blocked", Some(ResKey::Chan(id)));
}

/// Wake every thread blocked on channel `id` (they re-check and may
/// re-block — spurious wakes are safe). Called from endpoint `Drop`
/// too, so it must never panic.
pub(crate) fn chan_wake(id: u64) {
    if let Some((exec, _)) = try_ctx() {
        let mut core = exec.lock_core();
        wake_waiters(&mut core, ResKey::Chan(id));
    }
}

/// The calling thread's current vector clock (attached to sends).
pub(crate) fn clock_snapshot() -> VClock {
    let (exec, me) = ctx();
    exec.lock_core().threads[me].clock.clone()
}

/// Join a received clock into the calling thread's (the send→recv
/// happens-before edge).
pub(crate) fn join_clock(c: &VClock) {
    let (exec, me) = ctx();
    exec.lock_core().threads[me].clock.join(c);
}

// ---------------------------------------------------------------------------
// Threads
// ---------------------------------------------------------------------------

/// Handle to a thread spawned inside a model execution.
pub struct JoinHandle<T> {
    tid: usize,
    result: Arc<StdMutex<Option<std::thread::Result<T>>>>,
    os: Option<std::thread::JoinHandle<()>>,
}

/// Spawn a model thread. Must be called from inside a `check` closure;
/// the child inherits the parent's clock (everything the parent did
/// before the spawn happens-before everything the child does).
pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let (exec, me) = ctx();
    let tid = {
        let mut core = exec.lock_core();
        let parent_clock = core.threads[me].clock.clone();
        let tid = core.threads.len();
        core.threads.push(ThreadInfo {
            status: Status::Runnable,
            clock: parent_clock,
        });
        core.trace.push(format!("t{me}: spawn t{tid}"));
        tid
    };
    let result: Arc<StdMutex<Option<std::thread::Result<T>>>> = Arc::new(StdMutex::new(None));
    let (exec2, result2) = (Arc::clone(&exec), Arc::clone(&result));
    let os = std::thread::spawn(move || {
        set_ctx(Arc::clone(&exec2), tid);
        let _ = panic::catch_unwind(AssertUnwindSafe(|| {
            exec2.wait_until_scheduled(tid);
            match panic::catch_unwind(AssertUnwindSafe(f)) {
                Ok(v) => {
                    *result2.lock().unwrap_or_else(|p| p.into_inner()) = Some(Ok(v));
                    exec2.thread_finished(tid, None);
                }
                Err(p) => {
                    if p.downcast_ref::<AbortToken>().is_some() {
                        exec2.thread_finished_quiet(tid);
                    } else {
                        let msg = panic_message(p.as_ref());
                        *result2.lock().unwrap_or_else(|q| q.into_inner()) = Some(Err(p));
                        exec2.thread_finished(tid, Some(msg));
                    }
                }
            }
        }));
        clear_ctx();
    });
    exec.reschedule(me, "spawn", None);
    JoinHandle {
        tid,
        result,
        os: Some(os),
    }
}

impl<T> JoinHandle<T> {
    /// Join the model thread: blocks (in model time) until it finishes,
    /// joins its clock into the caller's, and returns its result — the
    /// same `Result` shape as `std::thread::JoinHandle::join`.
    pub fn join(mut self) -> std::thread::Result<T> {
        let (exec, me) = ctx();
        loop {
            {
                let mut core = exec.lock_core();
                if core.aborting {
                    drop(core);
                    abort_unwind();
                }
                if core.threads[self.tid].status == Status::Finished {
                    let child = core.threads[self.tid].clock.clone();
                    core.threads[me].clock.join(&child);
                    break;
                }
            }
            exec.reschedule(me, "join", Some(ResKey::Thread(self.tid)));
        }
        if let Some(os) = self.os.take() {
            let _ = os.join();
        }
        self.result
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .take()
            .expect("model thread finished without a result")
    }
}

// ---------------------------------------------------------------------------
// RaceCell — plain data under race detection
// ---------------------------------------------------------------------------

/// Non-atomic shared data with FastTrack-style race detection: the
/// model twin of "a plain field published through an atomic". Reads
/// and writes are checked against the location's happens-before state;
/// an unordered pair fails the execution as a data race.
pub struct RaceCell<T> {
    value: std::cell::UnsafeCell<T>,
    meta: StdMutex<CellMeta>,
}

#[derive(Debug, Default)]
struct CellMeta {
    /// Last write: (thread, that thread's clock component at the write).
    write: Option<(usize, u64)>,
    /// Last read per thread since the last write.
    reads: Vec<(usize, u64)>,
}

// SAFETY: all cross-thread access is mediated by the model scheduler
// (exactly one model thread runs at a time) and vetted by the race
// detector before the cell is touched; outside a model run the cell is
// plain single-threaded data.
unsafe impl<T: Send> Sync for RaceCell<T> {}

impl<T: Copy> RaceCell<T> {
    pub fn new(value: T) -> Self {
        RaceCell {
            value: std::cell::UnsafeCell::new(value),
            meta: StdMutex::new(CellMeta::default()),
        }
    }

    pub fn read(&self) -> T {
        if let Some((exec, me)) = try_ctx() {
            exec.reschedule(me, "RaceCell.read", None);
            let core = exec.lock_core();
            let mut meta = self.meta.lock().unwrap_or_else(|p| p.into_inner());
            if let Some((wt, wc)) = meta.write {
                if core.threads[me].clock.get(wt) < wc {
                    drop(meta);
                    exec.fail(
                        core,
                        format!(
                            "data race: t{me} read a RaceCell not ordered after t{wt}'s write"
                        ),
                    );
                }
            }
            let c = core.threads[me].clock.get(me);
            match meta.reads.iter_mut().find(|(t, _)| *t == me) {
                Some(entry) => entry.1 = c,
                None => meta.reads.push((me, c)),
            }
        }
        // SAFETY: serialized by the model scheduler (or single-threaded
        // outside it) and race-checked above — no concurrent mutation
        // can be in flight here.
        unsafe { *self.value.get() }
    }

    pub fn write(&self, v: T) {
        if let Some((exec, me)) = try_ctx() {
            exec.reschedule(me, "RaceCell.write", None);
            let core = exec.lock_core();
            let mut meta = self.meta.lock().unwrap_or_else(|p| p.into_inner());
            if let Some((wt, wc)) = meta.write {
                if core.threads[me].clock.get(wt) < wc {
                    drop(meta);
                    exec.fail(
                        core,
                        format!(
                            "data race: t{me} wrote a RaceCell not ordered after t{wt}'s write"
                        ),
                    );
                }
            }
            let racy_read = meta
                .reads
                .iter()
                .find(|(rt, rc)| core.threads[me].clock.get(*rt) < *rc)
                .copied();
            if let Some((rt, _)) = racy_read {
                drop(meta);
                exec.fail(
                    core,
                    format!("data race: t{me} wrote a RaceCell concurrently read by t{rt}"),
                );
            }
            meta.write = Some((me, core.threads[me].clock.get(me)));
            meta.reads.clear();
        }
        // SAFETY: serialized by the model scheduler (or single-threaded
        // outside it) and race-checked above — no concurrent access can
        // be in flight here.
        unsafe {
            *self.value.get() = v;
        }
    }
}

// ---------------------------------------------------------------------------
// The explorer
// ---------------------------------------------------------------------------

/// Outcome of a `check` run that found no failing interleaving.
#[derive(Clone, Copy, Debug)]
pub struct Report {
    /// Interleavings executed.
    pub executions: usize,
    /// True when every schedule within the preemption bound was
    /// explored; false when `max_executions` stopped exploration early.
    pub complete: bool,
}

/// Exploration bounds. `preemption_bound: None` removes the CHESS
/// pruning entirely (full DFS — only for very small models).
#[derive(Clone, Copy, Debug)]
pub struct Builder {
    pub max_executions: usize,
    pub preemption_bound: Option<usize>,
    pub max_steps: usize,
}

impl Default for Builder {
    fn default() -> Self {
        Builder {
            max_executions: 40_000,
            preemption_bound: Some(2),
            max_steps: 10_000,
        }
    }
}

pub fn builder() -> Builder {
    Builder::default()
}

/// Explore `f` under the default bounds. Panics, with the failing
/// interleaving's trace, on the first execution that deadlocks, races,
/// or panics.
pub fn check<F>(f: F) -> Report
where
    F: Fn() + Send + Sync + 'static,
{
    builder().check(f)
}

/// True when flipping decision `d` to `choice` switches away from a
/// still-runnable current thread — a preemption in the CHESS sense.
fn is_preemption(d: &Decision, choice: usize) -> bool {
    d.enabled.contains(&d.running) && d.enabled[choice] != d.running
}

/// Deepest-first backtracking: find the last decision with an untried
/// alternative whose prefix stays within the preemption bound.
fn next_schedule(decisions: &[Decision], bound: Option<usize>) -> Option<Vec<usize>> {
    let prefix_preemptions: Vec<usize> = {
        let mut acc = Vec::with_capacity(decisions.len());
        let mut p = 0usize;
        for d in decisions {
            acc.push(p);
            if is_preemption(d, d.chosen) {
                p += 1;
            }
        }
        acc
    };
    for i in (0..decisions.len()).rev() {
        let d = &decisions[i];
        for cand in d.chosen + 1..d.enabled.len() {
            let p = prefix_preemptions[i] + usize::from(is_preemption(d, cand));
            if let Some(b) = bound {
                if p > b {
                    continue;
                }
            }
            let mut schedule: Vec<usize> =
                decisions[..i].iter().map(|d| d.chosen).collect();
            schedule.push(cand);
            return Some(schedule);
        }
    }
    None
}

impl Builder {
    pub fn max_executions(mut self, n: usize) -> Self {
        self.max_executions = n;
        self
    }

    pub fn preemption_bound(mut self, b: Option<usize>) -> Self {
        self.preemption_bound = b;
        self
    }

    /// Run the exploration. See [`check`].
    pub fn check<F>(&self, f: F) -> Report
    where
        F: Fn() + Send + Sync + 'static,
    {
        install_quiet_hook();
        let f = Arc::new(f);
        let mut schedule: Vec<usize> = Vec::new();
        let mut executions = 0usize;
        loop {
            let exec = Arc::new(Exec::new(schedule.clone(), self.max_steps));
            let (exec0, f0) = (Arc::clone(&exec), Arc::clone(&f));
            let t0 = std::thread::spawn(move || {
                set_ctx(Arc::clone(&exec0), 0);
                let _ = panic::catch_unwind(AssertUnwindSafe(|| {
                    match panic::catch_unwind(AssertUnwindSafe(|| f0())) {
                        Ok(()) => exec0.thread_finished(0, None),
                        Err(p) => {
                            if p.downcast_ref::<AbortToken>().is_some() {
                                exec0.thread_finished_quiet(0);
                            } else {
                                exec0.thread_finished(0, Some(panic_message(p.as_ref())));
                            }
                        }
                    }
                }));
                clear_ctx();
            });
            exec.wait_done();
            let _ = t0.join();
            executions += 1;
            let (failure, decisions) = exec.outcome();
            if let Some(msg) = failure {
                panic!("model check failed on execution {executions}: {msg}");
            }
            if executions >= self.max_executions {
                return Report {
                    executions,
                    complete: false,
                };
            }
            match next_schedule(&decisions, self.preemption_bound) {
                Some(s) => schedule = s,
                None => {
                    return Report {
                        executions,
                        complete: true,
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::atomic::{AtomicU64, Ordering};
    use crate::util::sync::{mpsc, Mutex};
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::Arc;

    fn failure_message(f: impl Fn() + Send + Sync + 'static) -> String {
        let err = catch_unwind(AssertUnwindSafe(move || {
            builder().check(f);
        }))
        .expect_err("expected the model checker to fail");
        if let Some(s) = err.downcast_ref::<String>() {
            s.clone()
        } else if let Some(s) = err.downcast_ref::<&str>() {
            (*s).to_string()
        } else {
            String::from("<non-string panic>")
        }
    }

    /// The checker's own message-passing core: release publish /
    /// acquire consume is race-free in every interleaving.
    #[test]
    fn model_release_acquire_publication_is_race_free() {
        let report = check(|| {
            let cell = Arc::new(RaceCell::new(0u64));
            let flag = Arc::new(AtomicU64::new(0));
            let (c, f) = (Arc::clone(&cell), Arc::clone(&flag));
            let t = spawn(move || {
                c.write(42);
                // ordering: Release pairs with the Acquire load below —
                // the mutation-catch test flips exactly this.
                f.store(1, Ordering::Release);
            });
            if flag.load(Ordering::Acquire) == 1 {
                assert_eq!(cell.read(), 42);
            }
            t.join().unwrap();
        });
        assert!(report.complete, "small model must explore exhaustively");
        assert!(report.executions >= 2, "got {} executions", report.executions);
    }

    /// Self-validation: downgrading the publishing store to `Relaxed`
    /// removes the happens-before edge, and the checker must report the
    /// resulting data race on the payload.
    #[test]
    fn model_relaxed_publication_race_is_caught() {
        let msg = failure_message(|| {
            let cell = Arc::new(RaceCell::new(0u64));
            let flag = Arc::new(AtomicU64::new(0));
            let (c, f) = (Arc::clone(&cell), Arc::clone(&flag));
            let t = spawn(move || {
                c.write(42);
                // ordering: deliberately Relaxed — the seeded bug.
                f.store(1, Ordering::Relaxed);
            });
            if flag.load(Ordering::Acquire) == 1 {
                let _ = cell.read();
            }
            t.join().unwrap();
        });
        assert!(msg.contains("data race"), "unexpected failure: {msg}");
    }

    /// ABBA lock ordering must surface as a reported deadlock, not a
    /// hung test.
    #[test]
    fn model_detects_abba_deadlock() {
        let msg = failure_message(|| {
            let a = Arc::new(Mutex::new(0u64));
            let b = Arc::new(Mutex::new(0u64));
            let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
            let t = spawn(move || {
                let ga = a2.lock().unwrap();
                let gb = b2.lock().unwrap();
                drop((ga, gb));
            });
            {
                let gb = b.lock().unwrap();
                let ga = a.lock().unwrap();
                drop((ga, gb));
            }
            t.join().unwrap();
        });
        assert!(msg.contains("deadlock"), "unexpected failure: {msg}");
    }

    /// Channel transfer carries happens-before: writing plain data then
    /// sending is race-free for the receiver in every interleaving.
    #[test]
    fn model_channel_send_carries_happens_before() {
        let report = check(|| {
            let cell = Arc::new(RaceCell::new(0u64));
            let (tx, rx) = mpsc::channel::<()>();
            let c = Arc::clone(&cell);
            let t = spawn(move || {
                c.write(7);
                tx.send(()).unwrap();
            });
            rx.recv().unwrap();
            assert_eq!(cell.read(), 7);
            t.join().unwrap();
        });
        assert!(report.complete);
    }

    /// Mutex critical sections order their contents: two lock-protected
    /// increments never race and always sum.
    #[test]
    fn model_mutex_orders_critical_sections() {
        let report = check(|| {
            let n = Arc::new(Mutex::new(0u64));
            let n2 = Arc::clone(&n);
            let t = spawn(move || {
                *n2.lock().unwrap() += 1;
            });
            *n.lock().unwrap() += 1;
            t.join().unwrap();
            assert_eq!(*n.lock().unwrap(), 2);
        });
        assert!(report.complete);
        assert!(report.executions >= 2);
    }

    /// A panic inside a model thread is reported as a failure with its
    /// message, not swallowed.
    #[test]
    fn model_reports_thread_panics() {
        let msg = failure_message(|| {
            let t = spawn(|| {
                std::panic::panic_any(QuietPanic("child boom"));
            });
            let _ = t.join();
        });
        assert!(msg.contains("child boom"), "unexpected failure: {msg}");
    }
}
