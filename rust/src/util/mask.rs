//! `ConfigMask` — the compact cache-configuration representation used by
//! every layer of the solve path (policies, configuration space, cache
//! manager, coordinator records).
//!
//! A configuration is a subset of the candidate views (Definition 2).
//! Representing it as a `u64`-block bitset instead of a `Vec<bool>`
//! makes the operations the per-batch solve hammers — subset tests
//! against query-class view sets, equality/dedup during configuration
//! pruning, hashing for the interning arena — single word ops instead of
//! per-view walks, and shrinks every stored configuration to
//! ⌈n_views/64⌉ words.
//!
//! Invariant: bits at positions ≥ `n_bits` are always zero, so
//! `Eq`/`Ord`/`Hash` agree with set semantics. `Ord` mirrors the legacy
//! `Vec<bool>` lexicographic order (index 0 first, `false < true`), so
//! `BTreeMap`-based allocation merging visits configurations exactly as
//! the pre-mask code did — sampling stays reproducible across the
//! refactor.

use std::cmp::Ordering;
use std::fmt;

/// A fixed-width bitset over the candidate views of one batch problem.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct ConfigMask {
    n_bits: usize,
    words: Vec<u64>,
}

impl ConfigMask {
    /// The empty configuration over `n_bits` candidate views.
    pub fn empty(n_bits: usize) -> Self {
        Self {
            n_bits,
            words: vec![0; n_bits.div_ceil(64)],
        }
    }

    /// Build from an explicit per-view selection slice.
    pub fn from_bools(bits: &[bool]) -> Self {
        let mut mask = Self::empty(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                mask.words[i / 64] |= 1u64 << (i % 64);
            }
        }
        mask
    }

    /// Build from set-bit indices (need not be sorted or unique).
    pub fn from_indices(n_bits: usize, indices: &[usize]) -> Self {
        let mut mask = Self::empty(n_bits);
        for &i in indices {
            mask.set(i, true);
        }
        mask
    }

    /// Expand to the legacy per-view representation (reporting, tests).
    pub fn to_bools(&self) -> Vec<bool> {
        (0..self.n_bits).map(|i| self.get(i)).collect()
    }

    /// Number of candidate views this mask ranges over (not the number
    /// of selected views — see [`ConfigMask::count_ones`]).
    pub fn n_bits(&self) -> usize {
        self.n_bits
    }

    /// Is view `i` selected?
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        assert!(i < self.n_bits, "bit {i} out of range ({} bits)", self.n_bits);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Select (`true`) or deselect (`false`) view `i`.
    #[inline]
    pub fn set(&mut self, i: usize, value: bool) {
        assert!(i < self.n_bits, "bit {i} out of range ({} bits)", self.n_bits);
        let (w, b) = (i / 64, i % 64);
        if value {
            self.words[w] |= 1u64 << b;
        } else {
            self.words[w] &= !(1u64 << b);
        }
    }

    /// Select view `i`.
    #[inline]
    pub fn insert(&mut self, i: usize) {
        self.set(i, true);
    }

    /// Number of selected views.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// True iff no view is selected.
    pub fn none_set(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// Word-wise subset test: does `self` contain every view in
    /// `required`? This is the all-or-nothing utility-model check
    /// (`R(q) ⊆ S`) — the innermost operation of `utilities()` and the
    /// WELFARE oracle evaluation.
    #[inline]
    pub fn contains_all(&self, required: &ConfigMask) -> bool {
        debug_assert_eq!(self.n_bits, required.n_bits);
        required
            .words
            .iter()
            .zip(&self.words)
            .all(|(r, s)| r & !s == 0)
    }

    /// Do the two masks share any selected view?
    pub fn intersects(&self, other: &ConfigMask) -> bool {
        debug_assert_eq!(self.n_bits, other.n_bits);
        self.words.iter().zip(&other.words).any(|(a, b)| a & b != 0)
    }

    /// In-place union.
    pub fn union_with(&mut self, other: &ConfigMask) {
        debug_assert_eq!(self.n_bits, other.n_bits);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// Hamming distance (number of views whose selection differs) —
    /// the per-batch cache-churn measure.
    pub fn diff_count(&self, other: &ConfigMask) -> usize {
        debug_assert_eq!(self.n_bits, other.n_bits);
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a ^ b).count_ones() as usize)
            .sum()
    }

    /// Iterate the selected view indices in ascending order.
    pub fn ones(&self) -> Ones<'_> {
        Ones {
            words: &self.words,
            word_idx: 0,
            current: self.words.first().copied().unwrap_or(0),
        }
    }

    /// Raw words (for accelerated backends that marshal the mask).
    pub fn words(&self) -> &[u64] {
        &self.words
    }
}

impl Ord for ConfigMask {
    /// Lexicographic on the per-view bools from index 0, `false < true`
    /// — identical to `Vec<bool>`'s ordering. Per word pair, the lowest
    /// differing bit decides.
    fn cmp(&self, other: &Self) -> Ordering {
        for (a, b) in self.words.iter().zip(&other.words) {
            let d = a ^ b;
            if d != 0 {
                let bit = d.trailing_zeros();
                return if (a >> bit) & 1 == 0 {
                    Ordering::Less
                } else {
                    Ordering::Greater
                };
            }
        }
        self.n_bits.cmp(&other.n_bits)
    }
}

impl PartialOrd for ConfigMask {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl fmt::Debug for ConfigMask {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ConfigMask[")?;
        for i in 0..self.n_bits {
            write!(f, "{}", if self.get(i) { '1' } else { '0' })?;
        }
        write!(f, "]")
    }
}

/// Iterator over set-bit indices (see [`ConfigMask::ones`]).
pub struct Ones<'a> {
    words: &'a [u64],
    word_idx: usize,
    current: u64,
}

impl Iterator for Ones<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        while self.current == 0 {
            self.word_idx += 1;
            self.current = *self.words.get(self.word_idx)?;
        }
        let bit = self.current.trailing_zeros() as usize;
        self.current &= self.current - 1;
        Some(self.word_idx * 64 + bit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bools() {
        for n in [0usize, 1, 3, 63, 64, 65, 130] {
            let bits: Vec<bool> = (0..n).map(|i| i % 3 == 0).collect();
            let mask = ConfigMask::from_bools(&bits);
            assert_eq!(mask.n_bits(), n);
            assert_eq!(mask.to_bools(), bits);
            assert_eq!(mask.count_ones(), bits.iter().filter(|&&b| b).count());
        }
    }

    #[test]
    fn ones_iterates_ascending_set_bits() {
        let mask = ConfigMask::from_indices(130, &[0, 5, 63, 64, 129, 5]);
        let got: Vec<usize> = mask.ones().collect();
        assert_eq!(got, vec![0, 5, 63, 64, 129]);
        assert!(ConfigMask::empty(70).ones().next().is_none());
        assert!(ConfigMask::empty(0).ones().next().is_none());
    }

    #[test]
    fn subset_tests_match_per_view_semantics() {
        let all = ConfigMask::from_bools(&[true, true, true, true]);
        let some = ConfigMask::from_bools(&[true, false, true, false]);
        let other = ConfigMask::from_bools(&[false, true, false, false]);
        let empty = ConfigMask::empty(4);
        assert!(all.contains_all(&some));
        assert!(!some.contains_all(&all));
        assert!(some.contains_all(&some));
        assert!(some.contains_all(&empty));
        assert!(!some.contains_all(&other));
        assert!(!some.intersects(&other));
        assert!(all.intersects(&other));
    }

    #[test]
    fn multiword_subset() {
        let big = ConfigMask::from_indices(200, &[3, 64, 150, 199]);
        let sub = ConfigMask::from_indices(200, &[64, 199]);
        let not_sub = ConfigMask::from_indices(200, &[64, 100]);
        assert!(big.contains_all(&sub));
        assert!(!big.contains_all(&not_sub));
    }

    #[test]
    fn set_get_and_union() {
        let mut m = ConfigMask::empty(80);
        m.insert(79);
        m.set(2, true);
        assert!(m.get(79) && m.get(2) && !m.get(3));
        m.set(79, false);
        assert!(!m.get(79));
        let mut a = ConfigMask::from_indices(80, &[1]);
        a.union_with(&ConfigMask::from_indices(80, &[70]));
        assert_eq!(a.ones().collect::<Vec<_>>(), vec![1, 70]);
    }

    #[test]
    fn diff_count_is_hamming_distance() {
        let a = ConfigMask::from_bools(&[true, false, true, false]);
        let b = ConfigMask::from_bools(&[true, true, false, false]);
        assert_eq!(a.diff_count(&b), 2);
        assert_eq!(a.diff_count(&a), 0);
    }

    #[test]
    fn eq_ord_hash_consistency() {
        use std::collections::HashMap;
        let a = ConfigMask::from_bools(&[true, false]);
        let b = ConfigMask::from_indices(2, &[0]);
        assert_eq!(a, b);
        let mut map: HashMap<ConfigMask, usize> = HashMap::new();
        map.insert(a.clone(), 1);
        assert_eq!(map.get(&b), Some(&1));
        // Legacy Vec<bool> lexicographic order: index 0 decides first.
        let c = ConfigMask::from_bools(&[false, true]);
        assert!(c < a, "false at index 0 sorts before true");
    }

    #[test]
    fn ord_matches_vec_bool_lexicographic() {
        let mut rng_state = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            rng_state ^= rng_state << 13;
            rng_state ^= rng_state >> 7;
            rng_state ^= rng_state << 17;
            rng_state
        };
        for n in [1usize, 2, 7, 64, 65, 130] {
            for _ in 0..50 {
                let x: Vec<bool> = (0..n).map(|_| next() & 1 == 1).collect();
                let y: Vec<bool> = (0..n).map(|_| next() & 1 == 1).collect();
                let mx = ConfigMask::from_bools(&x);
                let my = ConfigMask::from_bools(&y);
                assert_eq!(mx.cmp(&my), x.cmp(&y), "x={x:?} y={y:?}");
            }
        }
    }

    #[test]
    #[should_panic]
    fn out_of_range_get_panics() {
        ConfigMask::empty(4).get(4);
    }

    #[test]
    fn debug_renders_bit_string() {
        let m = ConfigMask::from_bools(&[true, false, true]);
        assert_eq!(format!("{m:?}"), "ConfigMask[101]");
    }
}
