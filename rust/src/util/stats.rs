//! Descriptive statistics shared by the metrics module, the experiment
//! runner, and the benchmark harness — including Jain's fairness index
//! \[37\], which the paper uses as its fairness metric (Equation 5).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0.0 for fewer than two samples.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolation percentile (p in [0, 100]) over a copy of the data.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by_key(|&x| crate::util::ordf64::OrdF64(x));
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Several percentiles over one sorted copy of the data. Report paths
/// always want a p50/p99 (or p50/p95) pair; calling [`percentile`]
/// twice copies and sorts the same vector twice. Returns one value per
/// requested `p`, same interpolation rule as [`percentile`].
pub fn percentiles_of(xs: &[f64], ps: &[f64]) -> Vec<f64> {
    if xs.is_empty() {
        return vec![0.0; ps.len()];
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by_key(|&x| crate::util::ordf64::OrdF64(x));
    ps.iter()
        .map(|&p| {
            let rank = (p / 100.0) * (sorted.len() - 1) as f64;
            let lo = rank.floor() as usize;
            let hi = rank.ceil() as usize;
            if lo == hi {
                sorted[lo]
            } else {
                let frac = rank - lo as f64;
                sorted[lo] * (1.0 - frac) + sorted[hi] * frac
            }
        })
        .collect()
}

/// Jain's fairness index over per-tenant values:
/// J(x) = (Σ x_i)² / (n · Σ x_i²). Equals 1.0 when all values are equal,
/// approaches 1/n when one tenant dominates. Values are the tenants'
/// weight-normalized speedups X_i/λ_i per Equation 5.
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sum_sq: f64 = xs.iter().map(|x| x * x).sum();
    if sum_sq <= 0.0 {
        // All-zero vector: perfectly equal, define as fair.
        return 1.0;
    }
    (sum * sum) / (xs.len() as f64 * sum_sq)
}

/// Simple online accumulator for streams of samples.
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    n: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Self {
            n: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sum_sq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.sum_sq / self.n as f64 - m * m).max(0.0)
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_slices_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(jain_index(&[]), 1.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
        // Unsorted input handled.
        let ys = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&ys, 50.0), 3.0);
    }

    /// Edge cases the federation's membership-transient metrics rely on
    /// (ISSUE 4 satellite): single-sample percentiles, exact p=0/p=100
    /// endpoints, and a NaN-free guarantee under the OrdF64 sort.
    #[test]
    fn percentile_single_sample_any_p() {
        for p in [0.0, 1.0, 37.5, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[4.25], p), 4.25, "p={p}");
        }
    }

    #[test]
    fn percentile_endpoints_are_exact() {
        // p=0 and p=100 land on integer ranks: the exact min/max with
        // no interpolation drift, even on unsorted negative data.
        let xs = [7.3, -2.5, 0.0, 19.75, 4.5];
        assert_eq!(percentile(&xs, 0.0), -2.5);
        assert_eq!(percentile(&xs, 100.0), 19.75);
        let dup = [3.0, 3.0, 3.0];
        assert_eq!(percentile(&dup, 0.0), 3.0);
        assert_eq!(percentile(&dup, 100.0), 3.0);
    }

    #[test]
    fn percentile_never_nan_on_finite_inputs() {
        let datasets: [&[f64]; 4] = [
            &[1.0],
            &[0.0, -0.0, 0.0],
            &[5.0, -3.5, 5.0, 0.25, 1e12, -1e12],
            &[2.0, 2.0, 4.0, 8.0, 8.0, 8.0, 16.0],
        ];
        for xs in datasets {
            let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            for p10 in 0..=1000 {
                let p = p10 as f64 / 10.0;
                let v = percentile(xs, p);
                assert!(v.is_finite(), "percentile({xs:?}, {p}) = {v}");
                assert!(
                    (lo..=hi).contains(&v),
                    "percentile({xs:?}, {p}) = {v} outside [{lo}, {hi}]"
                );
            }
        }
    }

    #[test]
    fn percentiles_of_matches_percentile() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0, 9.5, -1.25, 3.0];
        let ps = [0.0, 12.5, 50.0, 95.0, 99.0, 100.0];
        let batch = percentiles_of(&xs, &ps);
        assert_eq!(batch.len(), ps.len());
        for (i, &p) in ps.iter().enumerate() {
            assert_eq!(batch[i], percentile(&xs, p), "p={p}");
        }
        assert_eq!(percentiles_of(&[], &ps), vec![0.0; ps.len()]);
        assert_eq!(percentiles_of(&xs, &[]), Vec::<f64>::new());
    }

    #[test]
    fn jain_index_extremes() {
        assert!((jain_index(&[3.0, 3.0, 3.0, 3.0]) - 1.0).abs() < 1e-12);
        // One dominant tenant among n → 1/n.
        let j = jain_index(&[1.0, 0.0, 0.0, 0.0]);
        assert!((j - 0.25).abs() < 1e-12);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn jain_index_paper_style() {
        // Two tenants with 2:1 speedups: (3)^2/(2*5) = 0.9.
        let j = jain_index(&[2.0, 1.0]);
        assert!((j - 0.9).abs() < 1e-12);
    }

    #[test]
    fn accumulator_matches_batch_stats() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut acc = Accumulator::new();
        for &x in &xs {
            acc.push(x);
        }
        assert_eq!(acc.count(), 8);
        assert!((acc.mean() - mean(&xs)).abs() < 1e-12);
        assert!((acc.variance() - variance(&xs)).abs() < 1e-9);
        assert_eq!(acc.min(), 2.0);
        assert_eq!(acc.max(), 9.0);
    }
}
