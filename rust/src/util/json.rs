//! A minimal JSON value model, parser, and serializer.
//!
//! The offline registry has no `serde`/`serde_json`, so experiment
//! configurations and result reports use this hand-rolled implementation.
//! It supports the full JSON grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null) and pretty printing; it does not aim
//! for serde's zero-copy performance — configs and reports are tiny.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a BTreeMap so serialization is
/// deterministic (stable key order) — handy for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---- constructors ---------------------------------------------------

    pub fn object() -> Json {
        Json::Object(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Object(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn array_of_f64(xs: &[f64]) -> Json {
        Json::Array(xs.iter().map(|&x| Json::Number(x)).collect())
    }

    pub fn array_of_str(xs: &[&str]) -> Json {
        Json::Array(xs.iter().map(|s| Json::String(s.to_string())).collect())
    }

    // ---- accessors ------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Number(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; None for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object().and_then(|m| m.get(key))
    }

    /// Insert into an object value; panics on non-objects (programmer error).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Object(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ---- serialization --------------------------------------------------

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Number(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    // JSON has no Inf/NaN; emit null like most encoders.
                    out.push_str("null");
                }
            }
            Json::String(s) => write_escaped(out, s),
            Json::Array(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Object(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    // ---- parsing ----------------------------------------------------------

    pub fn parse(input: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.parse_value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(width) = indent {
        out.push('\n');
        for _ in 0..width * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn parse_value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.parse_object(),
            Some(b'[') => self.parse_array(),
            Some(b'"') => Ok(Json::String(self.parse_string()?)),
            Some(b't') => self.parse_lit("true", Json::Bool(true)),
            Some(b'f') => self.parse_lit("false", Json::Bool(false)),
            Some(b'n') => self.parse_lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.parse_number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn parse_lit(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn parse_number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Number)
            .map_err(|_| self.err("invalid number"))
    }

    fn parse_string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not needed for configs);
                            // map lone surrogates to replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn parse_array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.parse_value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Array(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn parse_object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.parse_string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.parse_value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Object(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.25", "1e3", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let re = Json::parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, re, "text={text}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{'a':1}").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let original = Json::String("line1\nline2\t\"quoted\" \\ \u{1}".to_string());
        let text = original.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_content() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Number(5.0).to_string_compact(), "5");
        assert_eq!(Json::Number(5.5).to_string_compact(), "5.5");
    }

    #[test]
    fn object_builder_and_pretty() {
        let mut o = Json::object();
        o.set("throughput", Json::Number(7.8))
            .set("policy", Json::String("FASTPF".into()))
            .set("series", Json::array_of_f64(&[1.0, 2.0]));
        let pretty = o.to_string_pretty();
        assert!(pretty.contains("\"policy\": \"FASTPF\""));
        assert_eq!(Json::parse(&pretty).unwrap(), o);
    }

    #[test]
    fn as_u64_guards() {
        assert_eq!(Json::Number(3.0).as_u64(), Some(3));
        assert_eq!(Json::Number(3.5).as_u64(), None);
        assert_eq!(Json::Number(-1.0).as_u64(), None);
        assert_eq!(Json::Bool(true).as_u64(), None);
    }

    #[test]
    fn nonfinite_numbers_serialize_as_null() {
        assert_eq!(Json::Number(f64::NAN).to_string_compact(), "null");
        assert_eq!(Json::Number(f64::INFINITY).to_string_compact(), "null");
    }
}
