//! The unified event substrate behind both execution modes of the
//! coordinator: a [`Clock`] trait with a discrete-event driver
//! ([`SimClock`], time jumps instantly — the paper's experiments) and a
//! wall-clock driver ([`RealTimeClock`], time waits — the `robus serve`
//! online service), plus the ordered [`EventQueue`] the simulator's
//! engine and any future event-driven component share.
//!
//! The queue orders events by `(time, payload)` using [`OrdF64`], so a
//! payload type with the legacy tuple ordering reproduces the original
//! `BinaryHeap<Reverse<(OrdF64, ..)>>` pop order bit-for-bit.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::{Duration, Instant};

use crate::util::ordf64::OrdF64;

/// A monotonically advancing time axis in seconds. The coordinator is
/// written against this trait; swapping the driver swaps batch pacing
/// between "as fast as the solve allows" (simulation) and "real time"
/// (service) without touching the loop logic.
pub trait Clock {
    /// Current time on this clock's axis (seconds since its origin).
    fn now(&mut self) -> f64;

    /// Advance to at least `t`: a sim clock jumps, a real-time clock
    /// sleeps. Returns the time actually reached (`>= t` unless the
    /// clock was already past it).
    fn wait_until(&mut self, t: f64) -> f64;

    /// Whether a second on this axis costs a second of host time.
    /// Clock-generic drivers use this for *presentation* decisions only
    /// (e.g. the serve loop's once-per-second live metrics line, which
    /// would spam once per simulated batch on a jumping clock) — never
    /// for pacing or batch logic, which must stay driver-independent.
    fn is_real_time(&self) -> bool {
        false
    }
}

/// Discrete-event clock: advancing is free, so a run executes as fast
/// as the host can solve. Bit-identical to the pre-refactor loop, which
/// tracked batch windows with plain arithmetic.
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    now: f64,
}

impl SimClock {
    pub fn new() -> Self {
        Self { now: 0.0 }
    }

    pub fn at(t: f64) -> Self {
        Self { now: t }
    }
}

impl Clock for SimClock {
    fn now(&mut self) -> f64 {
        self.now
    }

    fn wait_until(&mut self, t: f64) -> f64 {
        if t > self.now {
            self.now = t;
        }
        self.now
    }
}

/// Wall-clock driver: `now` is host seconds since construction and
/// `wait_until` sleeps the calling thread. Drives `robus serve`.
#[derive(Debug, Clone)]
pub struct RealTimeClock {
    origin: Instant,
}

impl RealTimeClock {
    pub fn new() -> Self {
        Self {
            origin: Instant::now(),
        }
    }

    /// A clock sharing this one's origin (producer threads and the
    /// service loop must agree on the time axis).
    pub fn handle(&self) -> RealTimeClock {
        self.clone()
    }
}

impl Default for RealTimeClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealTimeClock {
    fn now(&mut self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }

    fn wait_until(&mut self, t: f64) -> f64 {
        let now = self.now();
        if t > now {
            std::thread::sleep(Duration::from_secs_f64(t - now));
        }
        self.now()
    }

    fn is_real_time(&self) -> bool {
        true
    }
}

/// An ordered event queue: min-heap over `(time, payload)`. Ties on
/// time are broken by the payload's own `Ord`, which is what makes the
/// engine's task-completion processing deterministic.
#[derive(Debug, Clone)]
pub struct EventQueue<P: Ord> {
    heap: BinaryHeap<Reverse<(OrdF64, P)>>,
}

impl<P: Ord> EventQueue<P> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
        }
    }

    /// Schedule `payload` at time `t`.
    pub fn push(&mut self, t: f64, payload: P) {
        self.heap.push(Reverse((OrdF64(t), payload)));
    }

    /// Remove and return the earliest event.
    pub fn pop(&mut self) -> Option<(f64, P)> {
        self.heap.pop().map(|Reverse((t, p))| (t.get(), p))
    }

    /// Time of the earliest event without removing it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|Reverse((t, _))| t.get())
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<P: Ord> Default for EventQueue<P> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_jumps() {
        let mut c = SimClock::new();
        assert_eq!(c.now(), 0.0);
        assert_eq!(c.wait_until(40.0), 40.0);
        // Never goes backwards.
        assert_eq!(c.wait_until(10.0), 40.0);
        assert_eq!(c.now(), 40.0);
    }

    #[test]
    fn real_time_flag_distinguishes_drivers() {
        assert!(!SimClock::new().is_real_time());
        assert!(RealTimeClock::new().is_real_time());
    }

    #[test]
    fn real_time_clock_waits() {
        let mut c = RealTimeClock::new();
        let t0 = c.now();
        let reached = c.wait_until(t0 + 0.02);
        assert!(reached >= t0 + 0.02 - 1e-9);
        // Waiting for the past returns immediately.
        let before = c.now();
        let after = c.wait_until(0.0);
        assert!(after >= before);
    }

    #[test]
    fn queue_pops_in_time_order() {
        let mut q: EventQueue<usize> = EventQueue::new();
        q.push(3.0, 0);
        q.push(1.0, 1);
        q.push(2.0, 2);
        assert_eq!(q.len(), 3);
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.pop(), Some((1.0, 1)));
        assert_eq!(q.pop(), Some((2.0, 2)));
        assert_eq!(q.pop(), Some((3.0, 0)));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn ties_break_by_payload_order() {
        // Same semantics as the engine's legacy (time, query, tenant)
        // tuple heap: equal times pop in ascending payload order.
        let mut q: EventQueue<(usize, usize)> = EventQueue::new();
        q.push(5.0, (2, 0));
        q.push(5.0, (1, 9));
        q.push(5.0, (1, 3));
        assert_eq!(q.pop(), Some((5.0, (1, 3))));
        assert_eq!(q.pop(), Some((5.0, (1, 9))));
        assert_eq!(q.pop(), Some((5.0, (2, 0))));
    }

    #[test]
    fn queue_matches_legacy_heap_order() {
        // The module contract, checked property-style: for any event
        // sequence, `EventQueue` pops in exactly the order of the
        // pre-refactor `BinaryHeap<Reverse<(OrdF64, P)>>` the engine
        // used inline. Times are drawn from a coarse grid so ties (the
        // interesting case) occur constantly.
        crate::util::proptest::check(
            200,
            |rng| {
                let n = 1 + rng.index(60);
                (0..n)
                    .map(|_| {
                        let t = rng.index(8) as f64 * 0.5;
                        (t, (rng.index(5), rng.index(5)))
                    })
                    .collect::<Vec<(f64, (usize, usize))>>()
            },
            |events| {
                // Shrink by dropping one event at a time.
                (0..events.len())
                    .map(|i| {
                        let mut v = events.clone();
                        v.remove(i);
                        v
                    })
                    .collect()
            },
            |events| {
                let mut q: EventQueue<(usize, usize)> = EventQueue::new();
                let mut legacy: BinaryHeap<Reverse<(OrdF64, (usize, usize))>> = BinaryHeap::new();
                for &(t, p) in events {
                    q.push(t, p);
                    legacy.push(Reverse((OrdF64(t), p)));
                }
                while let Some(Reverse((t, p))) = legacy.pop() {
                    let got = q.pop();
                    if got != Some((t.get(), p)) {
                        return Err(format!(
                            "legacy popped ({}, {:?}), queue popped {:?}",
                            t.get(),
                            p,
                            got
                        ));
                    }
                }
                if let Some(extra) = q.pop() {
                    return Err(format!("queue had leftover event {extra:?}"));
                }
                Ok(())
            },
        );
    }
}
