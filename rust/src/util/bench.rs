//! A criterion-style micro-benchmark harness (the offline registry has no
//! `criterion`). Used by the `harness = false` bench targets under
//! `rust/benches/`.
//!
//! Each benchmark runs a closure repeatedly: a warmup phase sizes the
//! per-sample iteration count so one sample takes ~`sample_target`, then
//! `samples` timed samples are collected and summarized (mean / p50 /
//! p95 / min / max, iterations per second).

use std::hint::black_box;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats;

/// Re-export of `std::hint::black_box` so benches don't need to import it.
pub fn bb<T>(x: T) -> T {
    black_box(x)
}

/// Host-speed index: nanoseconds for one pass of a fixed, deterministic
/// CPU workload (a 2M-step `mix64` chain; best of five passes). Every
/// `BENCH_*.json` embeds this so the CI bench-regression gate can
/// compare hardware-dependent metrics (batches/sec, solve p99) across
/// runner generations by *normalizing* fresh numbers to the baseline
/// host's speed instead of comparing absolutes — a 2× slower runner
/// reports a ~2× larger calibration, cancelling out of the ratio.
pub fn calibration_ns() -> f64 {
    use crate::util::rng::mix64;
    let mut best = f64::INFINITY;
    for _ in 0..5 {
        let t0 = Instant::now();
        let mut x = 0x9e37_79b9_7f4a_7c15u64;
        for _ in 0..2_000_000u32 {
            x = mix64(x);
        }
        black_box(x);
        best = best.min(t0.elapsed().as_nanos() as f64);
    }
    best
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Wall-clock budget for the warmup phase.
    pub warmup: Duration,
    /// Number of timed samples.
    pub samples: usize,
    /// Target wall-clock duration of one sample.
    pub sample_target: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            samples: 20,
            sample_target: Duration::from_millis(50),
        }
    }
}

/// Quick preset for expensive end-to-end benches.
impl BenchConfig {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            samples: 5,
            sample_target: Duration::from_millis(100),
        }
    }
}

/// Summary of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Nanoseconds per iteration for each sample.
    pub samples_ns: Vec<f64>,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        stats::mean(&self.samples_ns)
    }

    pub fn p50_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 50.0)
    }

    pub fn p95_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 95.0)
    }

    /// Both report percentiles from one sort (see
    /// `stats::percentiles_of`): (p50, p95).
    fn report_percentiles(&self) -> (f64, f64) {
        let ps = stats::percentiles_of(&self.samples_ns, &[50.0, 95.0]);
        (ps[0], ps[1])
    }

    pub fn min_ns(&self) -> f64 {
        self.samples_ns
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min)
    }

    pub fn throughput_per_sec(&self) -> f64 {
        1e9 / self.mean_ns()
    }

    /// One human-readable report line.
    pub fn report_line(&self) -> String {
        let (p50, p95) = self.report_percentiles();
        format!(
            "{:<44} {:>12}/iter  p50 {:>12}  p95 {:>12}  ({:.1} iters/s)",
            self.name,
            fmt_ns(self.mean_ns()),
            fmt_ns(p50),
            fmt_ns(p95),
            self.throughput_per_sec(),
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// A named collection of benchmark results with a markdown report.
pub struct BenchSuite {
    pub name: String,
    pub config: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new(name: &str) -> Self {
        let config = if std::env::var("ROBUS_BENCH_QUICK").is_ok() {
            BenchConfig::quick()
        } else {
            BenchConfig::default()
        };
        Self {
            name: name.to_string(),
            config,
            results: Vec::new(),
        }
    }

    /// Run one benchmark. The closure should perform one logical iteration
    /// and return a value (passed through `black_box` to defeat DCE).
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) {
        // Warmup + calibration: find iters such that a sample hits target.
        let warmup_start = Instant::now();
        let mut iters_done: u64 = 0;
        while warmup_start.elapsed() < self.config.warmup || iters_done == 0 {
            black_box(f());
            iters_done += 1;
        }
        let per_iter = warmup_start.elapsed().as_nanos() as f64 / iters_done as f64;
        let iters_per_sample =
            ((self.config.sample_target.as_nanos() as f64 / per_iter).ceil() as u64).max(1);

        let mut samples_ns = Vec::with_capacity(self.config.samples);
        for _ in 0..self.config.samples {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                black_box(f());
            }
            let elapsed = t0.elapsed().as_nanos() as f64;
            samples_ns.push(elapsed / iters_per_sample as f64);
        }

        let result = BenchResult {
            name: name.to_string(),
            samples_ns,
            iters_per_sample,
        };
        println!("{}", result.report_line());
        self.results.push(result);
    }

    /// Machine-readable JSON of all results (ns/iter statistics per
    /// benchmark) — the BENCH_*.json trajectory files future PRs diff
    /// against.
    pub fn to_json(&self) -> Json {
        let benchmarks = Json::Array(
            self.results
                .iter()
                .map(|r| {
                    let (p50, p95) = r.report_percentiles();
                    Json::from_pairs(vec![
                        ("name", Json::String(r.name.clone())),
                        ("mean_ns_per_iter", Json::Number(r.mean_ns())),
                        ("p50_ns_per_iter", Json::Number(p50)),
                        ("p95_ns_per_iter", Json::Number(p95)),
                        ("min_ns_per_iter", Json::Number(r.min_ns())),
                        ("iters_per_sample", Json::Number(r.iters_per_sample as f64)),
                        ("iters_per_sec", Json::Number(r.throughput_per_sec())),
                    ])
                })
                .collect(),
        );
        Json::from_pairs(vec![
            ("suite", Json::String(self.name.clone())),
            ("samples_per_bench", Json::Number(self.config.samples as f64)),
            // The regression gate's normalization anchor (see
            // [`calibration_ns`] and scripts/check_bench_regression.py).
            ("host_calibration_ns", Json::Number(calibration_ns())),
            ("benchmarks", benchmarks),
        ])
    }

    /// Write the JSON report to `path`.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
    }

    /// Markdown table of all results.
    pub fn markdown(&self) -> String {
        let mut out = format!("### {}\n\n", self.name);
        out.push_str("| benchmark | mean/iter | p50 | p95 | iters/s |\n");
        out.push_str("|---|---|---|---|---|\n");
        for r in &self.results {
            let (p50, p95) = r.report_percentiles();
            out.push_str(&format!(
                "| {} | {} | {} | {} | {:.1} |\n",
                r.name,
                fmt_ns(r.mean_ns()),
                fmt_ns(p50),
                fmt_ns(p95),
                r.throughput_per_sec()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_timings() {
        let mut suite = BenchSuite::new("unit");
        suite.config = BenchConfig {
            warmup: Duration::from_millis(5),
            samples: 5,
            sample_target: Duration::from_millis(2),
        };
        suite.bench("sum", || (0..1000u64).sum::<u64>());
        let r = &suite.results[0];
        assert_eq!(r.samples_ns.len(), 5);
        assert!(r.mean_ns() > 0.0);
        assert!(r.iters_per_sample >= 1);
        assert!(r.p95_ns() >= r.p50_ns() * 0.5);
    }

    #[test]
    fn markdown_report_contains_rows() {
        let mut suite = BenchSuite::new("unit");
        suite.config = BenchConfig {
            warmup: Duration::from_millis(2),
            samples: 3,
            sample_target: Duration::from_millis(1),
        };
        suite.bench("a", || 1 + 1);
        suite.bench("b", || 2 + 2);
        let md = suite.markdown();
        assert!(md.contains("| a |"));
        assert!(md.contains("| b |"));
    }

    #[test]
    fn json_report_has_all_fields() {
        let mut suite = BenchSuite::new("unit");
        suite.config = BenchConfig {
            warmup: Duration::from_millis(2),
            samples: 3,
            sample_target: Duration::from_millis(1),
        };
        suite.bench("sum", || (0..100u64).sum::<u64>());
        let json = suite.to_json();
        assert_eq!(json.get("suite").unwrap().as_str().unwrap(), "unit");
        let benches = json.get("benchmarks").unwrap().as_array().unwrap();
        assert_eq!(benches.len(), 1);
        let b = &benches[0];
        assert_eq!(b.get("name").unwrap().as_str().unwrap(), "sum");
        assert!(b.get("mean_ns_per_iter").unwrap().as_f64().unwrap() > 0.0);
        // Round-trips through the parser.
        let text = json.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), json);
    }

    #[test]
    fn calibration_is_positive_and_embedded() {
        let ns = calibration_ns();
        assert!(ns > 0.0 && ns.is_finite());
        let mut suite = BenchSuite::new("unit");
        suite.config = BenchConfig {
            warmup: Duration::from_millis(2),
            samples: 2,
            sample_target: Duration::from_millis(1),
        };
        suite.bench("a", || 1 + 1);
        let cal = suite
            .to_json()
            .get("host_calibration_ns")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(cal > 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
        assert_eq!(fmt_ns(3_000_000_000.0), "3.000 s");
    }
}
