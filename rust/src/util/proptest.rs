//! A small property-based testing driver (the offline registry has no
//! `proptest`). Tests express a property over randomly generated inputs;
//! the driver runs many seeded cases and, on failure, retries the failing
//! case with progressively "smaller" inputs via a user-supplied shrink
//! function to report a minimal counterexample.
//!
//! Usage:
//! ```ignore
//! check(200, gen_instance, shrink_instance, |inst| prop_holds(inst));
//! ```

use crate::util::rng::Pcg64;

/// Outcome of a property check, carrying the minimal counterexample text.
#[derive(Debug)]
pub struct PropFailure {
    pub case_index: usize,
    pub seed: u64,
    pub description: String,
}

impl std::fmt::Display for PropFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed on case #{} (seed {}): {}",
            self.case_index, self.seed, self.description
        )
    }
}

/// Run `cases` random cases of a property. `gen` builds an input from an
/// RNG; `shrink` proposes simpler variants of a failing input (return an
/// empty vec to stop); `prop` returns `Ok(())` or a failure message.
///
/// Panics with a formatted report (including the driving seed so the case
/// is reproducible) if any case fails after shrinking.
pub fn check<T, G, S, P>(cases: usize, mut gen: G, shrink: S, prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let base_seed = std::env::var("ROBUS_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xc0ffee_u64);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Pcg64::with_stream(seed, 999);
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Shrink loop: greedily accept the first simpler failing variant.
            let mut current = input;
            let mut msg = first_msg;
            let mut budget = 1000;
            'outer: while budget > 0 {
                for candidate in shrink(&current) {
                    budget -= 1;
                    if let Err(m) = prop(&candidate) {
                        current = candidate;
                        msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "{}",
                PropFailure {
                    case_index: case,
                    seed,
                    description: format!("{msg}\nminimal counterexample: {current:#?}"),
                }
            );
        }
    }
}

/// Convenience: no shrinking.
pub fn no_shrink<T>(_: &T) -> Vec<T> {
    Vec::new()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            100,
            |rng| rng.index(1000) as i64,
            no_shrink,
            |&x| {
                if x >= 0 {
                    Ok(())
                } else {
                    Err("negative".into())
                }
            },
        );
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let result = std::panic::catch_unwind(|| {
            check(
                100,
                |rng| 10 + rng.index(1000) as i64,
                |&x| if x > 0 { vec![x / 2, x - 1] } else { vec![] },
                |&x| {
                    if x < 7 {
                        Ok(())
                    } else {
                        Err(format!("{x} >= 7"))
                    }
                },
            );
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        // Greedy shrink must land exactly on the boundary value 7.
        assert!(msg.contains("counterexample: 7"), "msg={msg}");
    }
}
