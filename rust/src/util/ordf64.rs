//! `OrdF64` — a total-ordering wrapper over `f64`.
//!
//! Rust's `f64` is only `PartialOrd` (NaN breaks totality), so every
//! place that needs floats as ordered keys — the discrete-event heap in
//! the simulator, the shared event queue of `util::event`, sort keys —
//! used to carry its own private wrapper. This is the one shared copy;
//! ordering is IEEE 754 `total_cmp` (which agrees with `<`/`==` on the
//! non-NaN, non-signed-zero values the simulator produces).

use std::cmp::Ordering;

/// Total-ordering wrapper for `f64` keys (event times, sort keys).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OrdF64(pub f64);

impl OrdF64 {
    /// The wrapped value.
    #[inline]
    pub fn get(self) -> f64 {
        self.0
    }
}

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    #[inline]
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    #[inline]
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

impl From<f64> for OrdF64 {
    #[inline]
    fn from(x: f64) -> Self {
        OrdF64(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_like_f64_on_normal_values() {
        let mut xs = vec![OrdF64(3.0), OrdF64(-1.5), OrdF64(0.0), OrdF64(2.25)];
        xs.sort();
        let got: Vec<f64> = xs.iter().map(|x| x.get()).collect();
        assert_eq!(got, vec![-1.5, 0.0, 2.25, 3.0]);
    }

    #[test]
    fn total_order_handles_nan() {
        // NaN sorts after +inf under total_cmp instead of panicking.
        let mut xs = vec![OrdF64(f64::NAN), OrdF64(f64::INFINITY), OrdF64(1.0)];
        xs.sort();
        assert_eq!(xs[0], OrdF64(1.0));
        assert_eq!(xs[1], OrdF64(f64::INFINITY));
        assert!(xs[2].get().is_nan());
    }

    #[test]
    fn eq_and_from() {
        assert_eq!(OrdF64::from(2.0), OrdF64(2.0));
        assert!(OrdF64(1.0) < OrdF64(2.0));
        assert!(OrdF64(2.0) > OrdF64(1.0));
    }
}
