//! Crate-wide synchronization shim: the single import point for
//! atomics, `Mutex`, and `mpsc` channels on the concurrent hot paths
//! (`cluster::serving`, `cluster::runtime`, `util::pool`,
//! `coordinator::pipeline`, `telemetry::*`).
//!
//! Without the `model` feature this module is nothing but `pub use`
//! re-exports of `std::sync` — zero cost, zero behavior change, and
//! the SimClock bit-identical-replay contract is untouched by
//! construction.
//!
//! With `--features model`, the same names resolve to thin wrappers
//! that check a thread-local: inside a [`crate::util::model::check`]
//! run every operation becomes a scheduler yield point with
//! happens-before tracking (see `util/model.rs`); outside one they
//! delegate straight to `std`, so the full ordinary test suite also
//! passes under the feature.
//!
//! Model-mode deviations from `std`, by design:
//! - lock poisoning is swallowed inside model runs (a deliberately
//!   panicking interleaving must not cascade poison panics through
//!   the exploration);
//! - `sync_channel(0)` is given capacity 1 inside a model run — the
//!   model's blocking loops are try-op based and a rendezvous channel
//!   never accepts a `try_send`.

#[cfg(not(feature = "model"))]
pub use std::sync::{Mutex, MutexGuard};

/// Atomics: `std::sync::atomic` verbatim when the model feature is
/// off.
#[cfg(not(feature = "model"))]
pub mod atomic {
    pub use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU64, AtomicUsize, Ordering};
}

/// Channels: `std::sync::mpsc` verbatim when the model feature is
/// off.
#[cfg(not(feature = "model"))]
pub mod mpsc {
    pub use std::sync::mpsc::{
        channel, sync_channel, IntoIter, Iter, Receiver, RecvError, SendError, Sender, SyncSender,
        TryIter, TryRecvError, TrySendError,
    };
}

#[cfg(feature = "model")]
pub use self::model_impl::{Mutex, MutexGuard};

#[cfg(feature = "model")]
pub mod atomic {
    pub use super::model_impl::atomic::{AtomicBool, AtomicPtr, AtomicU64, AtomicUsize};
    pub use std::sync::atomic::Ordering;
}

#[cfg(feature = "model")]
pub mod mpsc {
    pub use super::model_impl::mpsc::{channel, sync_channel, Iter, Receiver, Sender, SyncSender};
    pub use std::sync::mpsc::{RecvError, SendError, TryRecvError, TrySendError};
}

#[cfg(feature = "model")]
mod model_impl {
    use crate::util::model;
    use std::fmt;
    use std::sync::{LockResult, PoisonError, TryLockError};

    // -- Mutex --------------------------------------------------------

    /// `std::sync::Mutex` twin; inside a model run, lock/unlock are
    /// scheduler yield points carrying the unlock→lock happens-before
    /// edge.
    #[derive(Default)]
    pub struct Mutex<T> {
        inner: std::sync::Mutex<T>,
    }

    pub struct MutexGuard<'a, T> {
        /// `Option` so `Drop` can release the OS lock *before* telling
        /// the model scheduler the mutex is free.
        inner: Option<std::sync::MutexGuard<'a, T>>,
        model_addr: Option<usize>,
    }

    impl<T> Mutex<T> {
        pub const fn new(t: T) -> Self {
            Mutex {
                inner: std::sync::Mutex::new(t),
            }
        }

        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            if model::in_model() {
                let addr = self as *const Self as usize;
                model::mutex_lock(addr);
                // The scheduler granted ownership, so the OS lock is
                // free (guards release it before notifying the model);
                // recover poison rather than cascading panics across
                // explored interleavings.
                let g = match self.inner.try_lock() {
                    Ok(g) => g,
                    Err(TryLockError::Poisoned(p)) => p.into_inner(),
                    Err(TryLockError::WouldBlock) => {
                        self.inner.lock().unwrap_or_else(|p| p.into_inner())
                    }
                };
                Ok(MutexGuard {
                    inner: Some(g),
                    model_addr: Some(addr),
                })
            } else {
                match self.inner.lock() {
                    Ok(g) => Ok(MutexGuard {
                        inner: Some(g),
                        model_addr: None,
                    }),
                    Err(p) => Err(PoisonError::new(MutexGuard {
                        inner: Some(p.into_inner()),
                        model_addr: None,
                    })),
                }
            }
        }
    }

    impl<T> fmt::Debug for Mutex<T> {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.debug_struct("Mutex").finish_non_exhaustive()
        }
    }

    impl<T> std::ops::Deref for MutexGuard<'_, T> {
        type Target = T;

        fn deref(&self) -> &T {
            self.inner.as_ref().expect("guard accessed after drop")
        }
    }

    impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.inner.as_mut().expect("guard accessed after drop")
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            // Release the OS lock first, then the model lock: the next
            // thread the scheduler admits must find the OS mutex free.
            self.inner.take();
            if let Some(addr) = self.model_addr {
                model::mutex_unlock(addr);
            }
        }
    }

    // -- Atomics ------------------------------------------------------

    pub mod atomic {
        use crate::util::model;
        use std::fmt;
        use std::sync::atomic::Ordering;

        macro_rules! model_atomic_int {
            ($name:ident, $std:ident, $prim:ty) => {
                /// `std::sync::atomic` twin; inside a model run every
                /// access is a yield point and its `Ordering` feeds
                /// happens-before tracking.
                #[derive(Debug, Default)]
                pub struct $name {
                    inner: std::sync::atomic::$std,
                }

                impl $name {
                    pub const fn new(v: $prim) -> Self {
                        Self {
                            inner: std::sync::atomic::$std::new(v),
                        }
                    }

                    fn addr(&self) -> usize {
                        self as *const Self as usize
                    }

                    pub fn load(&self, order: Ordering) -> $prim {
                        if model::in_model() {
                            model::atomic_access(
                                self.addr(),
                                concat!(stringify!($name), ".load"),
                                model::AccessKind::Load,
                                order,
                                || self.inner.load(order),
                            )
                        } else {
                            self.inner.load(order)
                        }
                    }

                    pub fn store(&self, v: $prim, order: Ordering) {
                        if model::in_model() {
                            model::atomic_access(
                                self.addr(),
                                concat!(stringify!($name), ".store"),
                                model::AccessKind::Store,
                                order,
                                || self.inner.store(v, order),
                            )
                        } else {
                            self.inner.store(v, order)
                        }
                    }

                    pub fn fetch_add(&self, v: $prim, order: Ordering) -> $prim {
                        if model::in_model() {
                            model::atomic_access(
                                self.addr(),
                                concat!(stringify!($name), ".fetch_add"),
                                model::AccessKind::Rmw,
                                order,
                                || self.inner.fetch_add(v, order),
                            )
                        } else {
                            self.inner.fetch_add(v, order)
                        }
                    }

                    pub fn fetch_sub(&self, v: $prim, order: Ordering) -> $prim {
                        if model::in_model() {
                            model::atomic_access(
                                self.addr(),
                                concat!(stringify!($name), ".fetch_sub"),
                                model::AccessKind::Rmw,
                                order,
                                || self.inner.fetch_sub(v, order),
                            )
                        } else {
                            self.inner.fetch_sub(v, order)
                        }
                    }

                    pub fn compare_exchange(
                        &self,
                        current: $prim,
                        new: $prim,
                        success: Ordering,
                        failure: Ordering,
                    ) -> Result<$prim, $prim> {
                        if model::in_model() {
                            model::atomic_cas(
                                self.addr(),
                                concat!(stringify!($name), ".compare_exchange"),
                                success,
                                failure,
                                || self.inner.compare_exchange(current, new, success, failure),
                            )
                        } else {
                            self.inner.compare_exchange(current, new, success, failure)
                        }
                    }
                }
            };
        }

        model_atomic_int!(AtomicU64, AtomicU64, u64);
        model_atomic_int!(AtomicUsize, AtomicUsize, usize);

        /// `std::sync::atomic::AtomicBool` twin (load/store surface —
        /// all the crate uses).
        #[derive(Debug, Default)]
        pub struct AtomicBool {
            inner: std::sync::atomic::AtomicBool,
        }

        impl AtomicBool {
            pub const fn new(v: bool) -> Self {
                Self {
                    inner: std::sync::atomic::AtomicBool::new(v),
                }
            }

            fn addr(&self) -> usize {
                self as *const Self as usize
            }

            pub fn load(&self, order: Ordering) -> bool {
                if model::in_model() {
                    model::atomic_access(
                        self.addr(),
                        "AtomicBool.load",
                        model::AccessKind::Load,
                        order,
                        || self.inner.load(order),
                    )
                } else {
                    self.inner.load(order)
                }
            }

            pub fn store(&self, v: bool, order: Ordering) {
                if model::in_model() {
                    model::atomic_access(
                        self.addr(),
                        "AtomicBool.store",
                        model::AccessKind::Store,
                        order,
                        || self.inner.store(v, order),
                    )
                } else {
                    self.inner.store(v, order)
                }
            }
        }

        /// `std::sync::atomic::AtomicPtr` twin (load/store surface —
        /// the RCU epoch pointer in `cluster::serving`).
        pub struct AtomicPtr<T> {
            inner: std::sync::atomic::AtomicPtr<T>,
        }

        impl<T> AtomicPtr<T> {
            pub const fn new(p: *mut T) -> Self {
                Self {
                    inner: std::sync::atomic::AtomicPtr::new(p),
                }
            }

            fn addr(&self) -> usize {
                self as *const Self as usize
            }

            pub fn load(&self, order: Ordering) -> *mut T {
                if model::in_model() {
                    model::atomic_access(
                        self.addr(),
                        "AtomicPtr.load",
                        model::AccessKind::Load,
                        order,
                        || self.inner.load(order),
                    )
                } else {
                    self.inner.load(order)
                }
            }

            pub fn store(&self, p: *mut T, order: Ordering) {
                if model::in_model() {
                    model::atomic_access(
                        self.addr(),
                        "AtomicPtr.store",
                        model::AccessKind::Store,
                        order,
                        || self.inner.store(p, order),
                    )
                } else {
                    self.inner.store(p, order)
                }
            }
        }

        impl<T> fmt::Debug for AtomicPtr<T> {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                f.debug_struct("AtomicPtr").finish_non_exhaustive()
            }
        }
    }

    // -- mpsc ---------------------------------------------------------

    pub mod mpsc {
        use crate::util::model;
        use std::fmt;
        use std::sync::mpsc as std_mpsc;
        use std::sync::mpsc::{RecvError, SendError, TryRecvError, TrySendError};

        /// Messages carry the sender's vector clock inside model runs
        /// so recv can join it (the send→recv happens-before edge).
        type Payload<T> = (T, Option<model::VClock>);

        pub struct Sender<T> {
            inner: std_mpsc::Sender<Payload<T>>,
            id: u64,
        }

        pub struct SyncSender<T> {
            inner: std_mpsc::SyncSender<Payload<T>>,
            id: u64,
        }

        pub struct Receiver<T> {
            inner: std_mpsc::Receiver<Payload<T>>,
            id: u64,
        }

        pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
            let id = model::new_chan_id();
            let (tx, rx) = std_mpsc::channel();
            (Sender { inner: tx, id }, Receiver { inner: rx, id })
        }

        pub fn sync_channel<T>(bound: usize) -> (SyncSender<T>, Receiver<T>) {
            let id = model::new_chan_id();
            // Model runs need capacity ≥ 1: the model's blocking loops
            // are try-op based, and a rendezvous channel only accepts
            // try_send while a receiver sits inside the *real* recv.
            let bound = if model::in_model() { bound.max(1) } else { bound };
            let (tx, rx) = std_mpsc::sync_channel(bound);
            (SyncSender { inner: tx, id }, Receiver { inner: rx, id })
        }

        impl<T> Sender<T> {
            pub fn send(&self, t: T) -> Result<(), SendError<T>> {
                if model::in_model() {
                    model::chan_yield(self.id, "send");
                    let clock = model::clock_snapshot();
                    match self.inner.send((t, Some(clock))) {
                        Ok(()) => {
                            model::chan_wake(self.id);
                            Ok(())
                        }
                        Err(SendError((v, _))) => Err(SendError(v)),
                    }
                } else {
                    self.inner
                        .send((t, None))
                        .map_err(|SendError((v, _))| SendError(v))
                }
            }
        }

        impl<T> SyncSender<T> {
            pub fn send(&self, t: T) -> Result<(), SendError<T>> {
                if model::in_model() {
                    let mut item = t;
                    loop {
                        model::chan_yield(self.id, "send");
                        let clock = model::clock_snapshot();
                        match self.inner.try_send((item, Some(clock))) {
                            Ok(()) => {
                                model::chan_wake(self.id);
                                return Ok(());
                            }
                            Err(TrySendError::Full((v, _))) => {
                                item = v;
                                model::chan_block(self.id);
                            }
                            Err(TrySendError::Disconnected((v, _))) => return Err(SendError(v)),
                        }
                    }
                } else {
                    self.inner
                        .send((t, None))
                        .map_err(|SendError((v, _))| SendError(v))
                }
            }

            pub fn try_send(&self, t: T) -> Result<(), TrySendError<T>> {
                if model::in_model() {
                    model::chan_yield(self.id, "try_send");
                    let clock = model::clock_snapshot();
                    match self.inner.try_send((t, Some(clock))) {
                        Ok(()) => {
                            model::chan_wake(self.id);
                            Ok(())
                        }
                        Err(TrySendError::Full((v, _))) => Err(TrySendError::Full(v)),
                        Err(TrySendError::Disconnected((v, _))) => {
                            Err(TrySendError::Disconnected(v))
                        }
                    }
                } else {
                    self.inner.try_send((t, None)).map_err(|e| match e {
                        TrySendError::Full((v, _)) => TrySendError::Full(v),
                        TrySendError::Disconnected((v, _)) => TrySendError::Disconnected(v),
                    })
                }
            }
        }

        impl<T> Receiver<T> {
            pub fn recv(&self) -> Result<T, RecvError> {
                if model::in_model() {
                    loop {
                        model::chan_yield(self.id, "recv");
                        match self.inner.try_recv() {
                            Ok((v, clock)) => {
                                if let Some(c) = &clock {
                                    model::join_clock(c);
                                }
                                model::chan_wake(self.id);
                                return Ok(v);
                            }
                            Err(TryRecvError::Empty) => model::chan_block(self.id),
                            Err(TryRecvError::Disconnected) => return Err(RecvError),
                        }
                    }
                } else {
                    self.inner.recv().map(|(v, _)| v)
                }
            }

            pub fn try_recv(&self) -> Result<T, TryRecvError> {
                if model::in_model() {
                    model::chan_yield(self.id, "try_recv");
                    match self.inner.try_recv() {
                        Ok((v, clock)) => {
                            if let Some(c) = &clock {
                                model::join_clock(c);
                            }
                            model::chan_wake(self.id);
                            Ok(v)
                        }
                        Err(e) => Err(e),
                    }
                } else {
                    self.inner.try_recv().map(|(v, _)| v)
                }
            }

            pub fn iter(&self) -> Iter<'_, T> {
                Iter { rx: self }
            }
        }

        pub struct Iter<'a, T> {
            rx: &'a Receiver<T>,
        }

        impl<T> Iterator for Iter<'_, T> {
            type Item = T;

            fn next(&mut self) -> Option<T> {
                self.rx.recv().ok()
            }
        }

        impl<T> Clone for Sender<T> {
            fn clone(&self) -> Self {
                Sender {
                    inner: self.inner.clone(),
                    id: self.id,
                }
            }
        }

        impl<T> Clone for SyncSender<T> {
            fn clone(&self) -> Self {
                SyncSender {
                    inner: self.inner.clone(),
                    id: self.id,
                }
            }
        }

        // Dropping an endpoint can disconnect the channel: wake model
        // waiters so they re-check and observe the disconnect. Safe
        // ordering because woken threads only *run* after this thread's
        // next yield point, by which time the field drop has completed.
        impl<T> Drop for Sender<T> {
            fn drop(&mut self) {
                model::chan_wake(self.id);
            }
        }

        impl<T> Drop for SyncSender<T> {
            fn drop(&mut self) {
                model::chan_wake(self.id);
            }
        }

        impl<T> Drop for Receiver<T> {
            fn drop(&mut self) {
                model::chan_wake(self.id);
            }
        }

        impl<T> fmt::Debug for Sender<T> {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                f.debug_struct("Sender").finish_non_exhaustive()
            }
        }

        impl<T> fmt::Debug for SyncSender<T> {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                f.debug_struct("SyncSender").finish_non_exhaustive()
            }
        }

        impl<T> fmt::Debug for Receiver<T> {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                f.debug_struct("Receiver").finish_non_exhaustive()
            }
        }
    }
}
