//! Self-built substrates: the offline crate registry contains only the
//! `xla` crate's dependency closure, so random number generation, JSON,
//! CLI parsing, statistics, benchmarking, and property testing are all
//! implemented here from scratch (see DESIGN.md §1).

pub mod bench;
pub mod cli;
pub mod event;
pub mod json;
pub mod mask;
#[cfg(feature = "model")]
pub mod model;
pub mod ordf64;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod sync;

pub use event::{Clock, EventQueue, RealTimeClock, SimClock};
pub use ordf64::OrdF64;
