//! Clock-generic periodic snapshot scheduling. The timer is driven by
//! the run's *own* clock (`Clock::now` — simulated or real), not host
//! time, so a SimClock test exercises the identical snapshot path a
//! production soak does, deterministically.

use crate::util::sync::atomic::{AtomicU64, Ordering};

/// Decides when a periodic counter snapshot is due. Lock-free: the
/// next-due instant is an `f64` stored as bits in an `AtomicU64`, and
/// [`SnapshotTimer::due`] claims a tick with one CAS — safe to consult
/// from concurrent loops without double-emitting for the same period.
#[derive(Debug)]
pub struct SnapshotTimer {
    period: f64,
    next: AtomicU64,
}

impl SnapshotTimer {
    /// `period <= 0` disables the timer entirely.
    pub fn new(period: f64) -> SnapshotTimer {
        SnapshotTimer {
            period,
            next: AtomicU64::new(0f64.to_bits()),
        }
    }

    pub fn enabled(&self) -> bool {
        self.period > 0.0
    }

    /// Returns true exactly once per elapsed period: the first call at
    /// `now >= next` wins the CAS and re-arms the timer at
    /// `now + period`.
    pub fn due(&self, now: f64) -> bool {
        if !(self.period > 0.0) {
            return false;
        }
        loop {
            // ordering: Relaxed pairs with the Relaxed CAS below — the
            // timer claims a tick, it publishes no data; the winner
            // only gains the right to emit a snapshot, and the counters
            // it then reads are themselves Relaxed observability values
            // (audited PR 9: no visibility guarantee is riding on this
            // flag, so Acquire/Release would buy nothing).
            let cur = self.next.load(Ordering::Relaxed);
            if now < f64::from_bits(cur) {
                return false;
            }
            let next = (now + self.period).to_bits();
            // ordering: Relaxed pairs with the Relaxed load above (tick
            // claim only — see that comment).
            if self
                .next
                .compare_exchange(cur, next, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                return true;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_once_per_period() {
        let t = SnapshotTimer::new(1.0);
        assert!(t.enabled());
        assert!(t.due(0.0), "first tick fires immediately");
        assert!(!t.due(0.5));
        assert!(!t.due(0.999));
        assert!(t.due(1.0));
        assert!(!t.due(1.25));
        // A long stall re-arms relative to `now`, not the missed grid.
        assert!(t.due(10.0));
        assert!(!t.due(10.9));
        assert!(t.due(11.0));
    }

    #[test]
    fn disabled_never_fires() {
        let t = SnapshotTimer::new(0.0);
        assert!(!t.enabled());
        assert!(!t.due(0.0));
        assert!(!t.due(1e9));
        let neg = SnapshotTimer::new(-3.0);
        assert!(!neg.due(5.0));
    }
}
